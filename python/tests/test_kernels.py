"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal of the compile path: the fused MoE FFN kernel
(forward + custom VJP) and the prototype routing kernel must match ref.py
to tight tolerances over a hypothesis-driven sweep of shapes and seeds.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import moe_ffn as K
from compile.kernels import ref
from compile.kernels.routing import route_top1

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rand(key, *shape, scale=0.5):
    return scale * jax.random.normal(key, shape)


# --------------------------------------------------------------------------- #
# moe_ffn forward
# --------------------------------------------------------------------------- #


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    e=st.integers(1, 6),
    c=st.integers(1, 24),
    m=st.sampled_from([8, 16, 48]),
    i=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_fwd_matches_ref(e, c, m, i, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(ks[0], e, c, m)
    w1 = rand(ks[1], e, m, i, scale=0.2)
    w2 = rand(ks[2], e, i, m, scale=0.2)
    got = K.moe_ffn(x, w1, w2, None)
    want = ref.moe_ffn(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("i_block", [8, 16, 32, 64])
def test_moe_ffn_i_block_invariance(i_block):
    """Any valid intermediate tile size gives the same result."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = rand(ks[0], 3, 8, 16)
    w1 = rand(ks[1], 3, 16, 64, scale=0.2)
    w2 = rand(ks[2], 3, 64, 16, scale=0.2)
    base = ref.moe_ffn(x, w1, w2)
    got = K.moe_ffn(x, w1, w2, i_block)
    np.testing.assert_allclose(got, base, rtol=2e-5, atol=2e-5)


def test_pick_i_block_handles_odd_sizes():
    # any positive intermediate gets a dividing tile (worst case 1)
    for i in [24, 7, 100, 21248]:
        blk = K._pick_i_block(i, None)
        assert blk >= 1 and i % blk == 0
    # an explicit non-dividing request degrades to a divisor
    assert 24 % K._pick_i_block(24, 5) == 0


def test_pick_i_block_divides():
    for i in [16, 64, 256, 512, 4096, 21248]:
        blk = K._pick_i_block(i, None)
        assert i % blk == 0, (i, blk)


# --------------------------------------------------------------------------- #
# moe_ffn backward (custom VJP with Pallas bwd kernels)
# --------------------------------------------------------------------------- #


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    e=st.integers(1, 4),
    c=st.integers(1, 12),
    m=st.sampled_from([8, 16]),
    i=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_grads_match_ref(e, c, m, i, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(ks[0], e, c, m)
    w1 = rand(ks[1], e, m, i, scale=0.2)
    w2 = rand(ks[2], e, i, m, scale=0.2)

    def loss_k(x, w1, w2):
        return jnp.sum(jnp.tanh(K.moe_ffn(x, w1, w2, None)))

    def loss_r(x, w1, w2):
        return jnp.sum(jnp.tanh(ref.moe_ffn(x, w1, w2)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w1, w2)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w1, w2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-5)


def test_gelu_grad_is_analytic_derivative():
    x = jnp.linspace(-4, 4, 101)
    auto = jax.vmap(jax.grad(lambda t: ref.gelu(t)))(x)
    np.testing.assert_allclose(ref.gelu_grad(x), auto, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------- #
# routing kernel
# --------------------------------------------------------------------------- #


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    z=st.integers(1, 4),
    t=st.integers(1, 64),
    f=st.integers(1, 16),
    capacity=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_routing_matches_ref(z, t, f, capacity, seed):
    key = jax.random.PRNGKey(seed)
    gates = jax.nn.softmax(jax.random.normal(key, (z, t, f)), axis=-1)
    offsets = jnp.zeros((z, f))
    got = route_top1(gates, offsets, capacity)
    want = ref.route_top1(gates, offsets, capacity)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    t=st.integers(1, 48),
    f=st.integers(2, 8),
    capacity=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_routing_invariants(t, f, capacity, seed):
    """Capacity is never exceeded; positions are unique per expert; keep
    accounting matches counts."""
    key = jax.random.PRNGKey(seed)
    gates = jax.nn.softmax(jax.random.normal(key, (1, t, f)), axis=-1)
    idx, pos, keep, counts = (
        np.asarray(a) for a in route_top1(gates, jnp.zeros((1, f)), capacity)
    )
    assert counts.max() <= capacity
    kept_positions = {}
    for ti in range(t):
        if keep[0, ti] > 0:
            assert pos[0, ti] < capacity
            slot = (idx[0, ti], pos[0, ti])
            assert slot not in kept_positions, "duplicate capacity slot"
            kept_positions[slot] = ti
    assert counts.sum() == keep.sum()


def test_routing_offsets_shift_positions():
    gates = jnp.broadcast_to(
        jnp.array([[0.9, 0.1]]), (1, 4, 2)
    )  # all tokens pick expert 0
    off = jnp.array([[3.0, 0.0]])
    idx, pos, keep, counts = route_top1(gates, off, 5)
    np.testing.assert_array_equal(np.asarray(pos[0]), [3, 4, 5, 6])
    np.testing.assert_array_equal(np.asarray(keep[0]), [1, 1, 0, 0])
    assert counts[0, 0] == 5  # 3 offset + 2 kept


def test_routing_zero_gradient():
    """Routing decisions carry zero cotangent; gate-path gradients flow."""
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (2, 8, 4))
    off = jnp.zeros((2, 4))

    def f(lg):
        gates = jax.nn.softmax(lg, -1)
        idx, pos, keep, counts = route_top1(gates, off, 3)
        return jnp.sum(gates * keep[..., None])

    g = jax.grad(f)(logits)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0


# --------------------------------------------------------------------------- #
# static analysis helpers (used by DESIGN.md §Perf)
# --------------------------------------------------------------------------- #


def test_vmem_budget_paper_geometry():
    """The default tiling must fit the paper's base geometry in 16MB VMEM."""
    c = 40  # base capacity
    bytes_ = K.vmem_bytes(c, 1024, K.DEFAULT_I_BLOCK)
    assert bytes_ < 16 * 1024 * 1024, bytes_


def test_mxu_estimate_bounds():
    assert 0.0 < K.mxu_utilization_estimate(40, 1024, 512) <= 1.0
    # aligned shapes hit 100%
    assert K.mxu_utilization_estimate(128, 1024, 512) == 1.0


def test_fwd_flops_formula():
    assert K.fwd_flops(2, 3, 4, 5) == 2 * (2 * 3 * 4 * 5 + 2 * 3 * 5 * 4)
