"""Cross-check of the rust analytical FLOPs model (Table 1) against JAX's
own cost analysis of the lowered eval module.

The rust model counts the dominant matmul terms; XLA's cost analysis counts
everything post-fusion. We assert agreement on the *dominant* terms (within
2x) and on the Table-1 *structure*: FLOPs scale linearly with k at capacity
kx and stay flat at capacity 1x — the paper's actual claim.
"""

import jax
import jax.numpy as jnp
import pytest

from compile import train
from compile.config import ModelConfig, Routing

jax.config.update("jax_platform_name", "cpu")


def cfg_with(routing, capacity_mode) -> ModelConfig:
    return ModelConfig(
        name="flops-x",
        vocab_size=128,
        hidden=32,
        intermediate=64,
        layers=2,
        heads=2,
        head_dim=16,
        patch_dim=16,
        num_experts=8,
        routing=routing,
        capacity_mode=capacity_mode,
        batch=2,
        patches=4,
        text_len=12,
    )


def xla_flops(cfg) -> float:
    patches, tokens = train.batch_specs(cfg)
    params = jax.eval_shape(
        train.init_fn(cfg), jax.ShapeDtypeStruct((), jnp.int32)
    )[0]
    # concrete params needed for compile; use zeros
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), params
    )
    compiled = jax.jit(train.eval_step_fn(cfg)).lower(
        params,
        jnp.zeros(patches.shape, patches.dtype),
        jnp.zeros(tokens.shape, tokens.dtype),
    ).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    return float(cost["flops"])


def analytic_forward_flops(cfg) -> float:
    """Python twin of rust flops::forward_flops (dominant terms only)."""
    t = cfg.tokens_per_batch
    m, i, e, c, l = cfg.hidden, cfg.intermediate, cfg.num_experts, cfg.capacity, cfg.layers
    h = cfg.heads * cfg.head_dim
    s = cfg.seq_len
    b = cfg.batch
    attention = l * (4 * 2 * t * m * h + 2 * 2 * b * s * s * h)
    gating = l * 2 * t * m * e
    dispatch = l * 2 * (2 * t * e * c * m)
    expert = l * 4 * e * c * m * i
    head = 2 * (b * cfg.text_len) * m * cfg.vocab_size
    return attention + gating + dispatch + expert + head


class TestCrossCheck:
    def test_dominant_terms_within_convention(self):
        # XLA's cost analysis counts post-fusion and uses a MAC-ish
        # convention for dot (observed ~0.5x of the 2*N*M*K convention the
        # rust model and the TF profiler use); dominant terms must agree
        # within that factor band.
        cfg = cfg_with(Routing("topk", 1), "k")
        got = xla_flops(cfg)
        want = analytic_forward_flops(cfg)
        assert 0.3 < got / want < 2.0, (got, want)

    def test_capacity_kx_scales_with_k(self):
        f1 = xla_flops(cfg_with(Routing("topk", 1), "k"))
        f2 = xla_flops(cfg_with(Routing("topk", 2), "k"))
        f4 = xla_flops(cfg_with(Routing("topk", 4), "k"))
        # expert+dispatch dominate; ratios land between 1.3x and k-x
        assert f2 > 1.25 * f1, (f1, f2)
        assert f4 > 1.3 * f2, (f2, f4)

    def test_capacity_1x_equalizes(self):
        f1 = xla_flops(cfg_with(Routing("topk", 1), "k"))  # top-1: same both modes
        f2 = xla_flops(cfg_with(Routing("topk", 2), "1"))
        f4 = xla_flops(cfg_with(Routing("topk", 4), "1"))
        p2 = xla_flops(cfg_with(Routing("prototype", 2), "1"))
        for f in (f2, f4, p2):
            assert abs(f / f1 - 1.0) < 0.15, (f, f1)

    def test_prototyping_flops_equal_topk(self):
        tk = xla_flops(cfg_with(Routing("topk", 2), "k"))
        pr = xla_flops(cfg_with(Routing("prototype", 2), "k"))
        assert abs(tk / pr - 1.0) < 0.1, (tk, pr)
