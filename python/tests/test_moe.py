"""L2 MoE machinery: dispatch/combine algebra, capacity semantics, aux
loss, top-k vs prototyping equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import moe
from compile.config import ModelConfig, Routing

jax.config.update("jax_platform_name", "cpu")


def cfg_with(**kw) -> ModelConfig:
    base = dict(
        name="t",
        vocab_size=64,
        hidden=16,
        intermediate=32,
        layers=1,
        heads=2,
        head_dim=8,
        patch_dim=8,
        num_experts=4,
        batch=2,
        patches=2,
        text_len=6,
    )
    base.update(kw)
    return ModelConfig(**base)


def tokens_and_router(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    t = cfg.tokens_per_batch
    x = jax.random.normal(key, (t, cfg.hidden))
    rw = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1),
        (cfg.hidden, cfg.prototypes, cfg.experts_per_prototype),
    )
    return x, rw


class TestRoute:
    def test_combine_dispatch_shapes(self):
        cfg = cfg_with()
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        t, z, f, c = r.combine.shape
        assert (t, z, f) == (cfg.tokens_per_batch, 1, 4)
        assert c == cfg.capacity
        assert r.dispatch.shape == r.combine.shape
        assert r.load.shape == (cfg.num_experts,)

    def test_dispatch_is_indicator_of_combine(self):
        cfg = cfg_with()
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        np.testing.assert_array_equal(
            np.asarray(r.dispatch) > 0, np.asarray(r.combine) > 0
        )
        assert set(np.unique(np.asarray(r.dispatch))) <= {0.0, 1.0}

    def test_top1_each_kept_token_one_slot(self):
        cfg = cfg_with()
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        per_token = np.asarray(r.dispatch).reshape(x.shape[0], -1).sum(-1)
        assert set(np.unique(per_token)) <= {0.0, 1.0}

    def test_topk_two_slots_when_capacity_ample(self):
        cfg = cfg_with(routing=Routing("topk", 2), capacity_factor=8.0)
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        per_token = np.asarray(r.dispatch).reshape(x.shape[0], -1).sum(-1)
        np.testing.assert_array_equal(per_token, 2.0)
        assert float(r.dropped) == 0.0

    def test_topk_gates_renormalized(self):
        cfg = cfg_with(routing=Routing("topk", 2), capacity_factor=8.0)
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        sums = np.asarray(r.combine).reshape(x.shape[0], -1).sum(-1)
        np.testing.assert_allclose(sums, 1.0, rtol=1e-4)

    def test_prototype_one_expert_per_group(self):
        cfg = cfg_with(routing=Routing("prototype", 2), capacity_factor=8.0)
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        d = np.asarray(r.dispatch)  # (T, 2, 2, C)
        per_group = d.sum(axis=(2, 3))
        np.testing.assert_array_equal(per_group, 1.0)

    def test_load_excludes_padding(self):
        """Paper §3.1: padding slots don't count as compute load."""
        cfg = cfg_with()
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        kept = float(np.asarray(r.load).sum())
        assert kept + float(r.dropped) == cfg.tokens_per_batch

    def test_tiny_capacity_drops(self):
        cfg = cfg_with(capacity_factor=0.01)
        assert cfg.capacity == 1
        x, rw = tokens_and_router(cfg)
        r = moe.route_cfg(x, rw, cfg)
        assert float(r.dropped) > 0
        assert np.asarray(r.load).max() <= 1

    def test_aux_loss_near_one_when_balanced(self):
        """The mesh-tf aux loss is ~1 for uniform assignment."""
        cfg = cfg_with(num_experts=4, capacity_factor=8.0)
        t = cfg.tokens_per_batch
        # craft logits that spread tokens uniformly round-robin
        logits = jnp.eye(4)[jnp.arange(t) % 4] * 10.0
        gates = jax.nn.softmax(logits, -1)[None]
        # route() consumes x/router; call the internals via route with a
        # one-hot-ish router: simpler to check density math directly
        density = jnp.mean(jax.nn.one_hot(jnp.argmax(gates, -1), 4), axis=1)
        proxy = jnp.mean(gates, axis=1)
        aux = jnp.mean(jnp.sum(density * proxy, -1)) * 4
        assert 0.9 < float(aux) < 1.1

    def test_gradients_flow_to_router(self):
        cfg = cfg_with()
        x, rw = tokens_and_router(cfg)

        def f(rw):
            r = moe.route_cfg(x, rw, cfg)
            return jnp.sum(r.combine)

        g = jax.grad(f)(rw)
        assert float(jnp.abs(g).sum()) > 0


class TestMoeFfnLayer:
    def test_output_shape_and_residual_zero_for_dropped(self):
        cfg = cfg_with(capacity_factor=0.01)  # capacity 1: most tokens drop
        x, rw = tokens_and_router(cfg)
        key = jax.random.PRNGKey(3)
        w1 = 0.1 * jax.random.normal(key, (4, 16, 32))
        w2 = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 16))
        out, r = moe.moe_ffn_layer(x, rw, w1, w2, cfg)
        assert out.shape == x.shape
        # dropped tokens contribute exactly zero (residual path carries them)
        d = np.asarray(r.dispatch).reshape(x.shape[0], -1).sum(-1)
        dropped_rows = np.asarray(out)[d == 0]
        np.testing.assert_allclose(dropped_rows, 0.0, atol=1e-6)

    def test_pallas_and_ref_paths_agree(self):
        cfg = cfg_with(capacity_factor=4.0)
        x, rw = tokens_and_router(cfg)
        key = jax.random.PRNGKey(4)
        w1 = 0.1 * jax.random.normal(key, (4, 16, 32))
        w2 = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 16))
        a, _ = moe.moe_ffn_layer(x, rw, w1, w2, cfg, use_pallas=True)
        b, _ = moe.moe_ffn_layer(x, rw, w1, w2, cfg, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_equal_experts_topk_equals_sum_of_gated(self):
        """With ample capacity the layer output equals the explicit sum of
        gated expert FFNs — the defining property of Eq. 1/3."""
        cfg = cfg_with(routing=Routing("prototype", 2), capacity_factor=16.0)
        x, rw = tokens_and_router(cfg)
        key = jax.random.PRNGKey(5)
        w1 = 0.1 * jax.random.normal(key, (4, 16, 32))
        w2 = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 16))
        out, r = moe.moe_ffn_layer(x, rw, w1, w2, cfg)

        # manual: for each token, for each prototype, the argmax expert's
        # FFN output weighted by its gate
        from compile.kernels import ref

        logits = jnp.einsum("tm,mzf->ztf", x, rw)
        gates = jax.nn.softmax(logits, -1)  # (2, T, 2)
        want = jnp.zeros_like(x)
        for z in range(2):
            idx = jnp.argmax(gates[z], -1)  # (T,)
            for t in range(x.shape[0]):
                e = z * 2 + int(idx[t])
                h = ref.gelu(x[t] @ w1[e])
                want = want.at[t].add(gates[z, t, idx[t]] * (h @ w2[e]))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


class TestCapacitySemantics:
    @pytest.mark.parametrize("k,mode,expect_rel", [(2, "k", 2), (4, "k", 4), (2, "1", 1), (4, "1", 1)])
    def test_eq2(self, k, mode, expect_rel):
        base = cfg_with().capacity
        c = cfg_with(routing=Routing("topk", k), capacity_mode=mode).capacity
        assert c == expect_rel * base
