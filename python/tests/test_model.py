"""L2 model + optimizer tests: shapes, masking, loss behaviour, training
dynamics on the smallest configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optim, train
from compile.config import BOS_ID, PAD_ID, ModelConfig, Routing, get

jax.config.update("jax_platform_name", "cpu")


def tiny(**kw) -> ModelConfig:
    base = dict(
        name="tiny",
        vocab_size=64,
        hidden=16,
        intermediate=32,
        layers=2,
        heads=2,
        head_dim=8,
        patch_dim=8,
        num_experts=4,
        batch=2,
        patches=2,
        text_len=8,
        warmup=2,
        lr=1e-2,
    )
    base.update(kw)
    return ModelConfig(**base)


def batch_for(cfg, seed=0):
    rng = np.random.RandomState(seed)
    patches = rng.randn(cfg.batch, cfg.patches, cfg.patch_dim).astype(np.float32)
    tokens = rng.randint(3, cfg.vocab_size, (cfg.batch, cfg.text_len)).astype(np.int32)
    tokens[:, 0] = BOS_ID
    return patches, tokens


class TestForward:
    def test_loss_near_log_vocab_at_init(self):
        cfg = tiny()
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        r = model.forward(params, p, t, cfg)
        assert abs(float(r.loss) - np.log(cfg.vocab_size)) < 1.0

    def test_pad_targets_ignored(self):
        cfg = tiny()
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        t_padded = t.copy()
        t_padded[:, -3:] = PAD_ID
        r = model.forward(params, p, t_padded, cfg)
        # 8 positions; targets are tokens[1:]+PAD: with 3 trailing PADs,
        # positions predicting PAD are masked
        assert float(r.token_count) < cfg.batch * cfg.text_len

    def test_load_and_dropped_shapes(self):
        cfg = tiny()
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        r = model.forward(params, p, t, cfg)
        assert r.load.shape == (cfg.layers, cfg.num_experts)
        assert r.dropped.shape == (cfg.layers,)
        kept_plus_dropped = float(r.load.sum() + r.dropped.sum())
        assert kept_plus_dropped == cfg.layers * cfg.tokens_per_batch

    def test_scan_and_unroll_agree(self):
        cfg_s = tiny(scan_layers=True)
        cfg_u = tiny(scan_layers=False)
        params = model.init_params(cfg_s, jax.random.PRNGKey(0))
        p, t = batch_for(cfg_s)
        rs = model.forward(params, p, t, cfg_s)
        ru = model.forward(params, p, t, cfg_u)
        np.testing.assert_allclose(float(rs.loss), float(ru.loss), rtol=1e-5)
        np.testing.assert_allclose(rs.load, ru.load)

    def test_prefix_mask_blocks_future_text(self):
        """Changing a later text token must not affect earlier predictions."""
        cfg = tiny()
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        t2 = t.copy()
        t2[:, -1] = (t2[:, -1] % 60) + 3  # change the last input token

        def nll_at(tok, pos):
            r = model.forward(params, p, tok, cfg)
            return r  # loss aggregates; compare sum over early positions

        # compare per-position nll by masking targets after pos
        # simpler: loss over the first half must be identical
        t_half = t.copy()
        t_half[:, 5:] = PAD_ID
        t2_half = t2.copy()
        t2_half[:, 5:] = PAD_ID
        r1 = model.forward(params, p, t_half, cfg)
        r2 = model.forward(params, p, t2_half, cfg)
        np.testing.assert_allclose(float(r1.loss), float(r2.loss), rtol=1e-6)

    def test_patches_influence_predictions(self):
        cfg = tiny()
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        r1 = model.forward(params, p, t, cfg)
        r2 = model.forward(params, p + 1.0, t, cfg)
        assert not np.allclose(float(r1.loss), float(r2.loss))

    def test_moe_attention_traces(self):
        cfg = tiny(moe_attention=True, attn_num_experts=4)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        r = model.forward(params, p, t, cfg)
        assert np.isfinite(float(r.loss))

    def test_prototype_routing_traces(self):
        cfg = tiny(routing=Routing("prototype", 2))
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        p, t = batch_for(cfg)
        r = model.forward(params, p, t, cfg)
        assert np.isfinite(float(r.loss))

    def test_init_std_scales_weights(self):
        cfg_big = tiny(init_std=0.02)
        cfg_small = tiny(init_std=0.002)
        pb = model.init_params(cfg_big, jax.random.PRNGKey(0))
        ps = model.init_params(cfg_small, jax.random.PRNGKey(0))
        rb = float(jnp.std(pb["tok_embed"]))
        rs = float(jnp.std(ps["tok_embed"]))
        assert abs(rb / rs - 10.0) < 0.5


class TestOptim:
    def test_lr_warmup(self):
        cfg = tiny(warmup=10, lr=1e-2)
        lr0 = float(optim.lr_schedule(cfg, jnp.int32(0)))
        lr5 = float(optim.lr_schedule(cfg, jnp.int32(4)))
        lr20 = float(optim.lr_schedule(cfg, jnp.int32(20)))
        assert lr0 < lr5 < lr20
        assert abs(lr20 - 1e-2) < 1e-9

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones((4,)) * 10.0}
        clipped, norm = optim.clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 20.0) < 1e-5
        assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5

    @pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
    def test_loss_decreases(self, opt_name):
        cfg = tiny(optimizer=opt_name, lr=1e-2 if opt_name == "adamw" else 5e-2)
        step_fn = jax.jit(train.train_step_fn(cfg))
        params, opt = train.init_fn(cfg)(jnp.int32(0))
        p, t = batch_for(cfg)
        losses = []
        for i in range(30):
            params, opt, loss, *_ = step_fn(params, opt, jnp.int32(i), p, t)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses[::10]

    def test_adafactor_state_is_sublinear(self):
        """The paper's reason for Adafactor at 1T: factored second moments."""
        cfg = tiny(optimizer="adafactor")
        params, opt = train.init_fn(cfg)(jnp.int32(0))
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
        n_opt = sum(x.size for x in jax.tree_util.tree_leaves(opt))
        assert n_opt < 0.2 * n_params, (n_opt, n_params)

    def test_adamw_state_is_2x(self):
        cfg = tiny(optimizer="adamw")
        params, opt = train.init_fn(cfg)(jnp.int32(0))
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
        n_opt = sum(x.size for x in jax.tree_util.tree_leaves(opt))
        assert n_opt == 2 * n_params


class TestTrainStep:
    def test_train_step_outputs(self):
        cfg = tiny()
        step_fn = jax.jit(train.train_step_fn(cfg))
        params, opt = train.init_fn(cfg)(jnp.int32(0))
        p, t = batch_for(cfg)
        out = step_fn(params, opt, jnp.int32(0), p, t)
        new_params, new_opt, loss, aux, gnorm, load, dropped = out
        assert load.shape == (cfg.layers, cfg.num_experts)
        assert dropped.shape == (cfg.layers,)
        assert float(gnorm) > 0
        # params actually moved
        delta = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), params, new_params
        )
        assert max(jax.tree_util.tree_leaves(delta)) > 0

    def test_eval_step_matches_forward(self):
        cfg = tiny()
        params, _ = train.init_fn(cfg)(jnp.int32(0))
        p, t = batch_for(cfg)
        nll, cnt = train.eval_step_fn(cfg)(params, p, t)
        r = model.forward(params, p, t, cfg)
        np.testing.assert_allclose(float(nll), float(r.sum_nll), rtol=1e-6)
        assert float(cnt) == float(r.token_count)

    def test_determinism(self):
        cfg = tiny()
        step_fn = jax.jit(train.train_step_fn(cfg))
        p, t = batch_for(cfg)
        outs = []
        for _ in range(2):
            params, opt = train.init_fn(cfg)(jnp.int32(7))
            out = step_fn(params, opt, jnp.int32(0), p, t)
            outs.append(float(out[2]))
        assert outs[0] == outs[1]


class TestRegistry:
    def test_all_variants_constructible(self):
        from compile.config import VARIANTS

        assert len(VARIANTS) >= 20
        for name, cfg in VARIANTS.items():
            assert cfg.num_experts % cfg.prototypes == 0, name
            assert cfg.capacity >= 1
            assert cfg.param_count() > 0

    def test_e2e_config_is_about_100m(self):
        cfg = get("e2e-100m")
        assert 80e6 < cfg.param_count() < 130e6

    def test_recipe_configs(self):
        good = get("recipe-1t")
        bad = get("recipe-1t-divergent")
        assert good.optimizer == "adafactor"
        assert good.init_std == 0.002
        assert bad.lr > good.lr
        assert bad.init_std == 0.02
