"""AOT lowering: HLO-text generation, manifest consistency, parser-
compatibility guards (the rust runtime links xla_extension 0.5.1 whose HLO
text parser predates several opcodes — anything we emit must stay inside
its vocabulary)."""

import json
import os
import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, train
from compile.config import ModelConfig, get

jax.config.update("jax_platform_name", "cpu")

# HLO opcodes known to be ABSENT from the 0.5.1 text parser. If a model
# change starts emitting one of these, the rust side will fail at load —
# catch it here instead.
FORBIDDEN_OPCODES = [" erf(", " erf-inv(", " topk(", " stochastic-convert("]


def tiny():
    return ModelConfig(
        name="tiny-aot",
        vocab_size=64,
        hidden=16,
        intermediate=32,
        layers=1,
        heads=2,
        head_dim=8,
        patch_dim=8,
        num_experts=4,
        batch=2,
        patches=2,
        text_len=8,
    )


class TestLowering:
    def test_hlo_text_is_parseable_shape(self):
        cfg = tiny()
        patches, tokens = train.batch_specs(cfg)
        lowered = jax.jit(train.eval_step_fn(cfg)).lower(
            jax.eval_shape(train.init_fn(cfg), jax.ShapeDtypeStruct((), jnp.int32))[0],
            patches,
            tokens,
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_no_forbidden_opcodes_in_tiny_modules(self):
        cfg = tiny()
        entry = aot.lower_variant(cfg, "/tmp/m6t-aot-test")
        for fname in entry["files"].values():
            text = open(os.path.join("/tmp/m6t-aot-test", fname)).read()
            for op in FORBIDDEN_OPCODES:
                assert op not in text, f"{fname} contains parser-unknown {op!r}"

    def test_manifest_entry_consistency(self):
        cfg = tiny()
        entry = aot.lower_variant(cfg, "/tmp/m6t-aot-test")
        assert entry["n_state"] == entry["n_params"] + entry["n_opt"]
        assert len(entry["state_leaves"]) == entry["n_state"]
        assert entry["param_count"] == cfg.param_count()
        # leaf element count must equal the true param count for params
        n = sum(
            int(jnp.prod(jnp.array(l["shape"] or [1])))
            for l in entry["state_leaves"][: entry["n_params"]]
        )
        assert n == cfg.param_count()

    def test_step_io_contract(self):
        cfg = tiny()
        entry = aot.lower_variant(cfg, "/tmp/m6t-aot-test")
        names = [o["name"] for o in entry["step_outputs"]]
        assert names == ["loss", "aux_loss", "grad_norm", "load", "dropped"]
        assert entry["step_outputs"][3]["shape"] == [cfg.layers, cfg.num_experts]
        # step extra inputs: scalar step, patches, tokens
        shapes = [tuple(i["shape"]) for i in entry["step_inputs"]]
        assert shapes == [
            (),
            (cfg.batch, cfg.patches, cfg.patch_dim),
            (cfg.batch, cfg.text_len),
        ]


@pytest.mark.skipif(
    not os.path.exists("../artifacts/manifest.json"),
    reason="run `make artifacts` first",
)
class TestRealManifest:
    def manifest(self):
        with open("../artifacts/manifest.json") as f:
            return json.load(f)

    def test_all_registry_variants_present(self):
        from compile.config import VARIANTS

        m = self.manifest()
        missing = set(VARIANTS) - set(m["variants"])
        assert not missing, f"artifacts stale, missing {missing}"

    def test_files_exist_and_nonempty(self):
        m = self.manifest()
        for name, v in m["variants"].items():
            for fname in v["files"].values():
                path = os.path.join("../artifacts", name, fname)
                assert os.path.getsize(path) > 1000, path

    def test_param_counts_match_configs(self):
        m = self.manifest()
        for name, v in m["variants"].items():
            assert v["param_count"] == get(name).param_count(), name

    def test_no_forbidden_opcodes_anywhere(self):
        m = self.manifest()
        for name, v in m["variants"].items():
            for fname in v["files"].values():
                text = open(os.path.join("../artifacts", name, fname)).read()
                for op in FORBIDDEN_OPCODES:
                    assert op not in text, f"{name}/{fname} has {op!r}"
