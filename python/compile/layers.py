"""Layer-2 transformer building blocks (plain jnp; the MoE parts live in
``moe.py`` and call the Pallas kernels).

The model follows the paper's §A.1 setup: image patch features and text
embeddings are concatenated into one sequence; a prefix-LM mask lets the
patch prefix attend bidirectionally while text is causal (image-captioning
teacher forcing); the FFN of every transformer block is an MoE layer.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .moe import RoutingResult, moe_linear_layer


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def prefix_lm_mask(patches: int, seq_len: int, dtype=jnp.float32) -> jax.Array:
    """(S, S) additive mask: patch prefix bidirectional, text causal.

    Position i may attend j iff j <= i (causal) or j < patches (everyone
    sees the whole image).  Returns 0 where allowed, -1e9 where masked.
    """
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    allowed = (j <= i) | (j < patches)
    return jnp.where(allowed, 0.0, -1e9).astype(dtype)


def _heads_split(x: jax.Array, heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, heads, -1).transpose(0, 2, 1, 3)  # (B, H, S, D)


def _heads_merge(x: jax.Array) -> jax.Array:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                   heads: int) -> jax.Array:
    """Scaled dot-product attention over already-projected q/k/v (B,S,HD)."""
    qh, kh, vh = (_heads_split(t, heads) for t in (q, k, v))
    d = qh.shape[-1]
    scores = jnp.einsum("bhid,bhjd->bhij", qh, kh) / jnp.sqrt(jnp.asarray(d, qh.dtype))
    scores = scores + mask[None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", probs, vh)
    return _heads_merge(out)


def dense_attention(x: jax.Array, p: Dict[str, jax.Array], mask: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Standard multi-head attention with dense Q/K/V/O projections."""
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    o = attention_core(q, k, v, mask, cfg.heads)
    return o @ p["wo"]


def moe_attention(x: jax.Array, p: Dict[str, jax.Array], mask: jax.Array,
                  cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """MoE attention (§3.4): Q/K/V/O projections each replaced by an MoE of
    one-layer linear experts, sharing the routing strategy of the config.

    Returns (output (B,S,M), summed aux loss of the four routers).
    """
    b, s, m = x.shape
    flat = x.reshape(b * s, m)
    aux = jnp.zeros((), x.dtype)

    def proj(name: str) -> jax.Array:
        nonlocal aux
        out, r = moe_linear_layer(flat, p[f"router_{name}"], p[f"w{name}"], cfg)
        aux = aux + r.aux_loss
        return out.reshape(b, s, -1)

    q, k, v = proj("q"), proj("k"), proj("v")
    o = attention_core(q, k, v, mask, cfg.heads)
    oh = o.reshape(b * s, -1)
    out, r = moe_linear_layer(oh, p["router_o"], p["wo"], cfg)
    aux = aux + r.aux_loss
    return out.reshape(b, s, m), aux


def dropout(x: jax.Array, rate: float, key: Optional[jax.Array]) -> jax.Array:
    if rate <= 0.0 or key is None:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
