"""AOT lowering: JAX (L2+L1) -> HLO text artifacts + manifest for rust (L3).

For every variant in :data:`compile.config.VARIANTS` this emits

    artifacts/<variant>/init.hlo.txt   seed -> flat train state
    artifacts/<variant>/step.hlo.txt   (state..., step, patches, tokens)
                                        -> (state'..., loss, aux, gnorm,
                                            load, dropped)
    artifacts/<variant>/eval.hlo.txt   (params..., patches, tokens)
                                        -> (sum_nll, token_count)

plus a single ``artifacts/manifest.json`` describing the flat buffer
orders, shapes, and dtypes so the coordinator can wire device buffers
without ever reconstructing the pytree.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out DIR] [--variant NAME ...] [--force]
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as cfglib
from . import train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_specs(tree) -> list[dict]:
    """Flatten a pytree of ShapeDtypeStruct/arrays into manifest entries."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": jnp.dtype(leaf.dtype).name,
            }
        )
    return out


def _eval_state(fn, *args):
    """jax.eval_shape wrapper returning the abstract output pytree."""
    return jax.eval_shape(fn, *args)


def lower_variant(cfg: cfglib.ModelConfig, out_dir: str) -> dict:
    """Lower init/step/eval for one config; returns its manifest entry."""
    os.makedirs(out_dir, exist_ok=True)
    patches_spec, tokens_spec = train.batch_specs(cfg)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)

    init = train.init_fn(cfg)
    state_abs = _eval_state(init, seed_spec)  # (params, opt)
    params_abs, opt_abs = state_abs
    n_params = len(jax.tree_util.tree_leaves(params_abs))
    n_opt = len(jax.tree_util.tree_leaves(opt_abs))

    t0 = time.time()
    init_hlo = to_hlo_text(jax.jit(init).lower(seed_spec))

    step_fn = train.train_step_fn(cfg)
    step_hlo = to_hlo_text(
        jax.jit(step_fn).lower(params_abs, opt_abs, step_spec, patches_spec, tokens_spec)
    )

    eval_fn = train.eval_step_fn(cfg)
    eval_hlo = to_hlo_text(jax.jit(eval_fn).lower(params_abs, patches_spec, tokens_spec))
    lower_s = time.time() - t0

    files = {"init": "init.hlo.txt", "step": "step.hlo.txt", "eval": "eval.hlo.txt"}
    for key, fname in files.items():
        text = {"init": init_hlo, "step": step_hlo, "eval": eval_hlo}[key]
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)

    entry = {
        "config": dataclasses.asdict(cfg),
        "files": files,
        "n_params": n_params,
        "n_opt": n_opt,
        "n_state": n_params + n_opt,
        "param_count": cfg.param_count(),
        "capacity": cfg.capacity,
        "state_leaves": _leaf_specs(state_abs),
        # step extra inputs after the state: step scalar, patches, tokens
        "step_inputs": _leaf_specs((step_spec, patches_spec, tokens_spec)),
        # step extra outputs after the new state
        "step_outputs": [
            {"name": "loss", "shape": [], "dtype": "float32"},
            {"name": "aux_loss", "shape": [], "dtype": "float32"},
            {"name": "grad_norm", "shape": [], "dtype": "float32"},
            {"name": "load", "shape": [cfg.layers, cfg.num_experts], "dtype": "float32"},
            {"name": "dropped", "shape": [cfg.layers], "dtype": "float32"},
        ],
        "eval_outputs": [
            {"name": "sum_nll", "shape": [], "dtype": "float32"},
            {"name": "token_count", "shape": [], "dtype": "float32"},
        ],
        "lower_seconds": round(lower_s, 2),
    }
    return entry


def _config_fingerprint(cfg: cfglib.ModelConfig) -> str:
    return hashlib.sha256(cfg.to_json().encode()).hexdigest()[:16]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variant", action="append", default=None,
                    help="lower only these variants (default: all)")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the fingerprint matches")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"variants": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            try:
                manifest = json.load(f)
            except json.JSONDecodeError:
                manifest = {"variants": {}}

    names = args.variant or sorted(cfglib.VARIANTS)
    for name in names:
        cfg = cfglib.get(name)
        fp = _config_fingerprint(cfg)
        prev = manifest["variants"].get(name)
        out_dir = os.path.join(args.out, name)
        complete = prev is not None and all(
            os.path.exists(os.path.join(out_dir, f))
            for f in prev.get("files", {}).values()
        )
        if complete and prev.get("fingerprint") == fp and not args.force:
            print(f"[aot] {name}: up to date")
            continue
        print(f"[aot] lowering {name} ...", flush=True)
        entry = lower_variant(cfg, out_dir)
        entry["fingerprint"] = fp
        manifest["variants"][name] = entry
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"[aot] {name}: done in {entry['lower_seconds']}s "
              f"({entry['param_count']/1e6:.1f}M params)")

    print(f"[aot] manifest at {manifest_path} ({len(manifest['variants'])} variants)")


if __name__ == "__main__":
    main()
