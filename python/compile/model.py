"""Layer-2 model: the M6-style multimodal MoE transformer (paper §A.1).

Decoder-style transformer over ``[patch features ; text tokens]`` with a
prefix-LM mask, MoE FFN in every block (optionally MoE attention, §3.4),
trained with teacher-forced image captioning.  The forward pass also
returns per-layer expert compute loads and dropped-token counts so the
rust coordinator can track the paper's c_v balance metric (Fig. 1) without
ever re-running the gate on the host.

Layer parameters are stacked on a leading ``layers`` axis and consumed by
``lax.scan`` (``cfg.scan_layers=False`` unrolls instead — the L2 perf
ablation in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import PAD_ID, ModelConfig
from .layers import (
    dense_attention,
    dropout,
    layer_norm,
    moe_attention,
    prefix_lm_mask,
)
from .moe import moe_ffn_layer

Params = Dict


class ForwardResult(NamedTuple):
    loss: jax.Array        # mean NLL over real text tokens
    aux_loss: jax.Array    # summed balancing loss over layers (and attn MoE)
    sum_nll: jax.Array     # total NLL (for exact PPL aggregation)
    token_count: jax.Array
    load: jax.Array        # (layers, E) kept tokens per expert
    dropped: jax.Array     # (layers,) overflowed tokens


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def _trunc_normal(key, shape, std, dtype=jnp.float32):
    """BERT-style initializer: normal(0, std) clipped at 2 sigma.

    Implemented via Box-Muller over uniforms instead of
    ``jax.random.truncated_normal`` because the latter lowers to the ``erf``
    /``erf-inv`` HLO opcodes, which the xla_extension 0.5.1 text parser the
    rust runtime links against does not know. Clipping (vs re-sampling)
    changes the tail mass by <5%, irrelevant for an initializer. The paper's
    1T recipe (§4) reduces std by 10x.
    """
    k1, k2 = jax.random.split(key)
    shape = tuple(shape)
    u1 = jax.random.uniform(k1, shape, dtype, minval=1e-7, maxval=1.0)
    u2 = jax.random.uniform(k2, shape, dtype)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return std * jnp.clip(z, -2.0, 2.0)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    m, i, e = cfg.hidden, cfg.intermediate, cfg.num_experts
    h = cfg.heads * cfg.head_dim
    z, f = cfg.prototypes, cfg.experts_per_prototype
    lyr = cfg.layers
    std = cfg.init_std

    keys = iter(jax.random.split(key, 32))

    def tn(shape, s=std):
        return _trunc_normal(next(keys), shape, s)

    if cfg.moe_attention:
        ea = cfg.attn_num_experts
        za = cfg.prototypes if cfg.routing.kind == "prototype" else 1
        if ea % za:
            raise ValueError(f"attn_num_experts={ea} not divisible by Z={za}")
        fa = ea // za
        attn = {
            "router_q": tn((lyr, m, za, fa)),
            "router_k": tn((lyr, m, za, fa)),
            "router_v": tn((lyr, m, za, fa)),
            "router_o": tn((lyr, h, za, fa)),
            "wq": tn((lyr, ea, m, h)),
            "wk": tn((lyr, ea, m, h)),
            "wv": tn((lyr, ea, m, h)),
            "wo": tn((lyr, ea, h, m)),
        }
    else:
        attn = {
            "wq": tn((lyr, m, h)),
            "wk": tn((lyr, m, h)),
            "wv": tn((lyr, m, h)),
            "wo": tn((lyr, h, m)),
        }

    return {
        "tok_embed": tn((cfg.vocab_size, m)),
        "patch_proj": tn((cfg.patch_dim, m)),
        "pos_embed": tn((cfg.seq_len, m)),
        "layers": {
            "ln1_scale": jnp.ones((lyr, m)),
            "ln1_bias": jnp.zeros((lyr, m)),
            "ln2_scale": jnp.ones((lyr, m)),
            "ln2_bias": jnp.zeros((lyr, m)),
            "attn": attn,
            "router": tn((lyr, m, z, f)),
            "w1": tn((lyr, e, m, i)),
            "w2": tn((lyr, e, i, m)),
        },
        "ln_f_scale": jnp.ones((m,)),
        "ln_f_bias": jnp.zeros((m,)),
    }


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #


def _block(x: jax.Array, lp: Params, mask: jax.Array, cfg: ModelConfig,
           drop_key: Optional[jax.Array]) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """One transformer block; returns (x, (aux, load, dropped))."""
    b, s, m = x.shape
    aux = jnp.zeros((), x.dtype)

    h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
    if cfg.moe_attention:
        a, attn_aux = moe_attention(h, lp["attn"], mask, cfg)
        aux = aux + attn_aux
    else:
        a = dense_attention(h, lp["attn"], mask, cfg)
    if drop_key is not None:
        k1, k2, drop_key = jax.random.split(drop_key, 3)
        a = dropout(a, cfg.dropout, k1)
    x = x + a

    h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
    flat = h.reshape(b * s, m)
    out, r = moe_ffn_layer(flat, lp["router"], lp["w1"], lp["w2"], cfg)
    out = out.reshape(b, s, m)
    if drop_key is not None:
        out = dropout(out, cfg.dropout, k2)
    x = x + out
    return x, (aux + r.aux_loss, r.load, r.dropped)


def forward(params: Params, patches: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, *, rng: Optional[jax.Array] = None) -> ForwardResult:
    """Teacher-forced captioning forward pass.

    patches: (B, P, patch_dim) f32 synthetic ResNet features
    tokens:  (B, L) i32, tokens[:, 0] == BOS; PAD-padded tail
    """
    b = tokens.shape[0]
    tok_emb = params["tok_embed"][tokens]                      # (B, L, M)
    patch_emb = patches @ params["patch_proj"]                 # (B, P, M)
    x = jnp.concatenate([patch_emb, tok_emb], axis=1)
    x = x + params["pos_embed"][None, :, :]
    mask = prefix_lm_mask(cfg.patches, cfg.seq_len, x.dtype)

    lp = params["layers"]
    if cfg.scan_layers:
        keys = (
            jax.random.split(rng, cfg.layers) if rng is not None else None
        )

        def body(carry, xs):
            layer_params, key = xs
            y, stats = _block(carry, layer_params, mask, cfg, key)
            return y, stats

        xs = (lp, keys) if keys is not None else (lp, jnp.zeros((cfg.layers, 0)))
        if keys is None:
            def body(carry, xs):  # noqa: F811 — no-dropout variant
                layer_params, _ = xs
                y, stats = _block(carry, layer_params, mask, cfg, None)
                return y, stats

        x, (aux, load, dropped) = jax.lax.scan(body, x, xs)
        aux = jnp.sum(aux)
    else:
        auxes, loads, droppeds = [], [], []
        for l in range(cfg.layers):
            layer_params = jax.tree_util.tree_map(lambda t: t[l], lp)
            key = jax.random.fold_in(rng, l) if rng is not None else None
            x, (a, ld, dr) = _block(x, layer_params, mask, cfg, key)
            auxes.append(a)
            loads.append(ld)
            droppeds.append(dr)
        aux = jnp.sum(jnp.stack(auxes))
        load = jnp.stack(loads)
        dropped = jnp.stack(droppeds)

    x = layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])
    # text positions only; logits tied to the token embedding
    text_x = x[:, cfg.patches :, :]                            # (B, L, M)
    logits = text_x @ params["tok_embed"].T                    # (B, L, V)

    # next-token targets: shift left, PAD at the end (ignored by the mask)
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full((b, 1), PAD_ID, tokens.dtype)], axis=1
    )
    mask_t = (targets != PAD_ID).astype(x.dtype)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    sum_nll = jnp.sum(nll * mask_t)
    count = jnp.sum(mask_t)
    loss = sum_nll / jnp.maximum(count, 1.0)
    return ForwardResult(loss, aux, sum_nll, count, load, dropped)


def loss_fn(params: Params, patches: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, rng: Optional[jax.Array] = None):
    """Scalar training objective + stats, for jax.grad."""
    r = forward(params, patches, tokens, cfg, rng=rng)
    total = r.loss + cfg.aux_loss_coef * r.aux_loss
    return total, r
