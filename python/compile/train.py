"""Layer-2 entry points lowered to HLO: init / train_step / eval_step.

These three functions are what ``aot.py`` lowers per variant and what the
rust coordinator executes.  Their flattened argument/result orders are
recorded in the artifact manifest; the train state (params + optimizer
moments) round-trips as opaque device buffers on the rust side.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .model import forward, init_params, loss_fn
from .optim import clip_by_global_norm, opt_init, opt_update


def init_fn(cfg: ModelConfig):
    """seed (i32 scalar) -> flat train state (params..., opt moments...)."""

    def init(seed: jax.Array):
        key = jax.random.PRNGKey(seed)
        params = init_params(cfg, key)
        opt = opt_init(cfg, params)
        return params, opt

    return init


def train_step_fn(cfg: ModelConfig):
    """(params, opt, step i32, patches, tokens) ->
    (params', opt', loss, aux, gnorm, load (layers,E), dropped (layers,))."""

    def step_fn(params, opt, step, patches, tokens):
        rng = jax.random.PRNGKey(step) if cfg.dropout > 0 else None

        def objective(p):
            total, r = loss_fn(p, patches, tokens, cfg, rng)
            return total, r

        (total, r), grads = jax.value_and_grad(objective, has_aux=True)(params)
        if cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        else:
            from .optim import global_norm

            gnorm = global_norm(grads)
        new_params, new_opt = opt_update(cfg, params, grads, opt, step)
        return new_params, new_opt, r.loss, r.aux_loss, gnorm, r.load, r.dropped

    return step_fn


def eval_step_fn(cfg: ModelConfig):
    """(params, patches, tokens) -> (sum_nll, token_count) for exact PPL."""

    def ev(params, patches, tokens):
        r = forward(params, patches, tokens, cfg, rng=None)
        return r.sum_nll, r.token_count

    return ev


def batch_specs(cfg: ModelConfig) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
    patches = jax.ShapeDtypeStruct((cfg.batch, cfg.patches, cfg.patch_dim), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.text_len), jnp.int32)
    return patches, tokens
