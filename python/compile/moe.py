"""Layer-2 MoE machinery: gating, dispatch/combine, auxiliary loss.

Implements both routing families behind one interface (paper §3.2/§3.3):

* **top-k** — one router over all E experts, k *sequential* argmax rounds
  (the "looping argmax" the paper identifies as the efficiency problem,
  Table 2).  Gate values of the k selections are renormalized to sum to 1
  (Eq. 1).
* **k top-1 expert prototyping** — experts reshaped to (Z=k, F=E/k), one
  router per prototype, a single *parallel* routing round; prototype
  outputs are summed without cross-prototype renormalization (Eq. 3).

The integer routing decisions come from the Pallas kernel
(:mod:`kernels.routing`); the differentiable parts (softmax gates, combine
tensor, auxiliary balancing loss of Fig. 8) are assembled here so router
weights receive gradients exactly as in GShard/Switch.

Dispatch/combine use the paper's one-hot einsum formulation (Fig. 7):
``dispatch (T,Z,F,C)`` scatters token slabs to per-expert buffers,
``combine`` gathers them back scaled by the gate probability.  Overflowed
tokens (``keep == 0``) take the residual path implicitly: they simply do
not appear in any expert buffer, so the MoE layer contributes zero and the
transformer's residual connection carries them through (§2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import moe_ffn as moe_ffn_kernel
from .kernels import ref as kref
from .kernels.routing import route_top1


class RoutingResult(NamedTuple):
    """Everything a MoE layer needs after gating."""

    combine: jax.Array        # (T, Z, F, C) float: gate * onehot(expert) * onehot(slot)
    dispatch: jax.Array       # (T, Z, F, C) float 0/1, stop-gradient
    aux_loss: jax.Array       # scalar, mesh-tf density * density_proxy form
    load: jax.Array           # (E,) kept tokens per expert (compute load, Fig. 1)
    dropped: jax.Array        # scalar, tokens that overflowed capacity


def route(x: jax.Array, router_w: jax.Array, *, prototypes: int, rounds: int,
          capacity: int, renormalize: bool) -> RoutingResult:
    """Route ``T`` tokens through ``Z = prototypes`` routers.

    x: (T, M) token representations; router_w: (M, Z, F) gating weights.

    ``rounds > 1`` reproduces GShard top-k: each round masks the experts
    already chosen and re-runs the top-1 kernel with updated per-expert
    offsets so capacity slots are shared across rounds.  ``prototypes > 1``
    with ``rounds == 1`` is expert prototyping.
    """
    t, m = x.shape
    _, z, f = router_w.shape
    dtype = x.dtype

    logits = jnp.einsum("tm,mzf->ztf", x, router_w)
    raw_gates = jax.nn.softmax(logits, axis=-1)  # (Z, T, F)

    offsets = jnp.zeros((z, f), dtype)
    avail = jnp.ones((z, t, f), dtype)  # 1 where the expert is still selectable
    sel_gate, sel_onehot_e, sel_onehot_c, sel_keep = [], [], [], []
    for _ in range(rounds):
        # masking instead of -inf keeps the value lookup on raw_gates exact
        idx, pos, keep, counts = route_top1(raw_gates * avail, offsets, capacity)
        onehot_e = jax.nn.one_hot(idx, f, dtype=dtype)           # (Z, T, F)
        onehot_c = jax.nn.one_hot(pos, capacity, dtype=dtype)    # (Z, T, C)
        gate = jnp.sum(raw_gates * onehot_e, axis=-1)            # (Z, T)
        sel_gate.append(gate)
        sel_onehot_e.append(jax.lax.stop_gradient(onehot_e))
        sel_onehot_c.append(jax.lax.stop_gradient(onehot_c))
        sel_keep.append(keep)
        offsets = counts
        avail = avail * (1.0 - onehot_e)

    gates = jnp.stack(sel_gate)          # (R, Z, T)
    keeps = jnp.stack(sel_keep)          # (R, Z, T)
    if renormalize and rounds > 1:
        denom = jnp.sum(gates, axis=0, keepdims=True) + 1e-9
        gates = gates / denom

    # combine tensor: sum over rounds of p * onehot(expert) x onehot(slot)
    oe = jnp.stack(sel_onehot_e)         # (R, Z, T, F)
    oc = jnp.stack(sel_onehot_c)         # (R, Z, T, C)
    w = gates * keeps                    # (R, Z, T)
    combine = jnp.einsum("rzt,rztf,rztc->tzfc", w, oe, oc)
    dispatch = jax.lax.stop_gradient((combine > 0).astype(dtype))

    # auxiliary balancing loss (Fig. 8 / mesh-tf): first-round assignment
    # density x mean gate probability, scaled by F^2, averaged over Z.
    density = jnp.mean(oe[0], axis=1)          # (Z, F) fraction assigned
    density_proxy = jnp.mean(raw_gates, axis=1)  # (Z, F) mean prob
    aux = jnp.mean(jnp.sum(density * density_proxy, axis=-1)) * f

    # effective compute load: kept (real) tokens per expert — padding slots
    # are excluded, matching the paper's c_v definition (§3.1).
    load = jnp.einsum("rzt,rztf->zf", keeps, oe).reshape(-1)  # (E,)
    dropped = rounds * z * t - jnp.sum(keeps)
    return RoutingResult(combine, dispatch, aux, load, dropped)


def route_cfg(x: jax.Array, router_w: jax.Array, cfg: ModelConfig) -> RoutingResult:
    """Routing with geometry taken from a :class:`ModelConfig` (FFN MoE)."""
    return route(
        x,
        router_w,
        prototypes=cfg.prototypes,
        rounds=cfg.rounds,
        capacity=cfg.capacity,
        renormalize=cfg.routing.kind == "topk",
    )


def moe_ffn_layer(x: jax.Array, router_w: jax.Array, w1: jax.Array, w2: jax.Array,
                  cfg: ModelConfig, use_pallas: bool = True) -> tuple[jax.Array, RoutingResult]:
    """Full MoE FFN layer over flattened tokens.

    x: (T, M); router_w: (M, Z, F); w1: (E, M, I); w2: (E, I, M).
    Returns (output (T, M), routing stats).
    """
    t, m = x.shape
    e = w1.shape[0]
    r = route_cfg(x, router_w, cfg)
    z, f = router_w.shape[1], router_w.shape[2]
    c = cfg.capacity
    # dispatch: one (C, M) slab per expert (paper Fig. 7 dispatch einsum)
    slabs = jnp.einsum("tzfc,tm->zfcm", r.dispatch, x).reshape(e, c, m)
    if use_pallas:
        out_slabs = moe_ffn_kernel.moe_ffn(slabs, w1, w2, None)
    else:
        out_slabs = kref.moe_ffn(slabs, w1, w2)
    out = jnp.einsum("tzfc,zfcm->tm", r.combine, out_slabs.reshape(z, f, c, m))
    return out, r


def moe_linear_layer(x: jax.Array, router_w: jax.Array, w: jax.Array,
                     cfg: ModelConfig) -> tuple[jax.Array, RoutingResult]:
    """MoE over a single linear projection (MoE attention, §3.4).

    Each expert is a one-layer linear map (M -> H) "viewed as a one-layer
    FFN without non-linear activation" (paper).  x: (T, M); router_w:
    (M, Z, F); w: (E, M, H).  Capacity follows the same Eq.-2 policy.
    """
    t, m = x.shape
    e, _, h = w.shape
    z, f = router_w.shape[1], router_w.shape[2]
    r = route(
        x,
        router_w,
        prototypes=z,
        rounds=cfg.rounds if cfg.routing.kind == "topk" else 1,
        capacity=_attn_capacity(cfg, t, e),
        renormalize=cfg.routing.kind == "topk",
    )
    c = _attn_capacity(cfg, t, e)
    slabs = jnp.einsum("tzfc,tm->zfcm", r.dispatch, x).reshape(e, c, m)
    out_slabs = jnp.einsum("ecm,emh->ech", slabs, w)
    out = jnp.einsum("tzfc,zfch->th", r.combine, out_slabs.reshape(z, f, c, h))
    return out, r


def _attn_capacity(cfg: ModelConfig, t: int, e: int) -> int:
    """Eq.-2 capacity for the attention MoE (its own expert count)."""
    k_eff = cfg.routing.k if cfg.capacity_mode == "k" else 1
    import math

    return max(1, int(math.ceil(k_eff * t / e * cfg.capacity_factor)))
