"""Generate the golden parity fixtures pinning the Rust kernels to the
Python reference (``rust/tests/fixtures/*.json``).

The Rust FFN (``rust/src/moe/ffn.rs``) and optimizer
(``rust/src/runtime/optim.rs``) ports are asserted against these to 1e-5
relative tolerance by ``rust/tests/ffn_parity.rs``.  Everything here runs
through the *same* code the Pallas kernels are tested against:

  * gelu / gelu_grad           -> kernels.ref
  * moe_ffn forward + VJP      -> kernels.moe_ffn (custom-VJP entry point,
                                  interpret mode — the analytic-gelu_grad
                                  backward the Rust port mirrors)
  * AdamW / Adafactor steps    -> compile.optim

Run from the repo root:

    python3 -m python.compile.kernels.gen_fixtures
"""

from __future__ import annotations

import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np

from . import moe_ffn as kernel
from . import ref
from .. import optim

OUT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "rust", "tests", "fixtures"
)


def flat(x) -> list[float]:
    return [float(v) for v in np.asarray(x, dtype=np.float32).reshape(-1)]


def rand(rng: np.random.RandomState, shape, scale: float) -> jnp.ndarray:
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def gelu_fixture() -> dict:
    xs = np.array(
        [-6.0, -3.0, -1.5, -0.7, -0.1, 0.0, 0.1, 0.7, 1.5, 3.0, 6.0, 0.044715],
        dtype=np.float32,
    )
    x = jnp.asarray(xs)
    return {
        "x": flat(x),
        "gelu": flat(ref.gelu(x)),
        "gelu_grad": flat(ref.gelu_grad(x)),
    }


# The acceptance grid: base geometry, non-128-multiple dims, single
# expert, capacity 1.  (seed, e, c, m, i, i_block)
FFN_CASES = [
    ("base", 101, 8, 6, 32, 64, 16),
    ("nonmult", 202, 3, 5, 7, 24, 8),
    ("e1", 303, 1, 6, 8, 16, 16),
    ("c1", 404, 2, 1, 8, 16, 8),
]


def ffn_fixture() -> dict:
    cases = []
    for name, seed, e, c, m, i, i_block in FFN_CASES:
        rng = np.random.RandomState(seed)
        x = rand(rng, (e, c, m), 1.0)
        w1 = rand(rng, (e, m, i), 0.2)
        w2 = rand(rng, (e, i, m), 0.2)
        g = rand(rng, (e, c, m), 0.1)
        out, vjp = jax.vjp(lambda x, w1, w2: kernel.moe_ffn(x, w1, w2, i_block), x, w1, w2)
        dx, dw1, dw2 = vjp(g)
        cases.append(
            {
                "name": name,
                "experts": e,
                "capacity": c,
                "hidden": m,
                "intermediate": i,
                "i_block": i_block,
                "x": flat(x),
                "w1": flat(w1),
                "w2": flat(w2),
                "g": flat(g),
                "out": flat(out),
                "dx": flat(dx),
                "dw1": flat(dw1),
                "dw2": flat(dw2),
            }
        )
    return {"cases": cases}


def optim_fixture() -> dict:
    cfg = types.SimpleNamespace(lr=2e-3, warmup=10, weight_decay=0.01)
    out: dict = {}

    # -- AdamW: one step at t=3 with non-zero accumulated moments --------
    rng = np.random.RandomState(1234)
    shape = (2, 3, 4)
    p = rand(rng, shape, 1.0)
    g = rand(rng, shape, 0.1)
    m0 = rand(rng, shape, 0.01)
    v0 = jnp.abs(rand(rng, shape, 0.001))
    step = jnp.asarray(3, dtype=jnp.int32)
    params = {"w": p}
    new_p, st = optim.adamw_update(
        cfg, params, {"w": g}, optim.AdamWState(m={"w": m0}, v={"w": v0}), step
    )
    out["adamw"] = {
        "lr": cfg.lr,
        "warmup": cfg.warmup,
        "weight_decay": cfg.weight_decay,
        "step": 3,
        "shape": list(shape),
        "p": flat(p),
        "g": flat(g),
        "m": flat(m0),
        "v": flat(v0),
        "new_p": flat(new_p["w"]),
        "new_m": flat(st.m["w"]),
        "new_v": flat(st.v["w"]),
    }

    # -- Adafactor, factored 3-D leaf at t=7 -----------------------------
    rng = np.random.RandomState(5678)
    p = rand(rng, shape, 1.0)
    g = rand(rng, shape, 0.1)
    vr0 = jnp.abs(rand(rng, shape[:-1], 0.001))
    vc0 = jnp.abs(rand(rng, shape[:-2] + shape[-1:], 0.001))
    step = jnp.asarray(7, dtype=jnp.int32)
    new_p, st = optim.adafactor_update(
        cfg, {"w": p}, {"w": g}, optim.AdafactorState(v_row={"w": vr0}, v_col={"w": vc0}), step
    )
    out["adafactor_factored"] = {
        "lr": cfg.lr,
        "warmup": cfg.warmup,
        "weight_decay": cfg.weight_decay,
        "step": 7,
        "shape": list(shape),
        "p": flat(p),
        "g": flat(g),
        "vr": flat(vr0),
        "vc": flat(vc0),
        "new_p": flat(new_p["w"]),
        "new_vr": flat(st.v_row["w"]),
        "new_vc": flat(st.v_col["w"]),
    }

    # -- Adafactor, unfactored vector leaf at t=7 ------------------------
    rng = np.random.RandomState(9012)
    p = rand(rng, (5,), 1.0)
    g = rand(rng, (5,), 0.1)
    v0 = jnp.abs(rand(rng, (5,), 0.001))
    dummy = jnp.zeros((1,), jnp.float32)
    new_p, st = optim.adafactor_update(
        cfg, {"w": p}, {"w": g}, optim.AdafactorState(v_row={"w": v0}, v_col={"w": dummy}), step
    )
    out["adafactor_vector"] = {
        "lr": cfg.lr,
        "warmup": cfg.warmup,
        "weight_decay": cfg.weight_decay,
        "step": 7,
        "p": flat(p),
        "g": flat(g),
        "v": flat(v0),
        "new_p": flat(new_p["w"]),
        "new_v": flat(st.v_row["w"]),
    }
    return out


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    for name, payload in [
        ("gelu.json", gelu_fixture()),
        ("moe_ffn.json", ffn_fixture()),
        ("optim.json", optim_fixture()),
    ]:
        path = os.path.join(OUT_DIR, name)
        with open(path, "w") as f:
            json.dump(payload, f)
            f.write("\n")
        print(f"wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
