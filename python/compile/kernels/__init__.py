"""Layer-1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""
from . import moe_ffn, ref, routing  # noqa: F401
