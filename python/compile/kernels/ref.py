"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: ``pytest python/tests`` asserts the
Pallas kernels (interpret mode) match these to tight tolerances, including
gradients (the kernels carry custom VJPs).  They are also what the L2 model
falls back to when ``use_pallas=False`` — useful for A/B-ing kernel vs
reference inside the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654
GELU_C = 0.044715


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximated GeLU — must match the kernel's formulation exactly."""
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + GELU_C * x * x * x)))


def gelu_grad(x: jax.Array) -> jax.Array:
    """Analytic d gelu / dx for the tanh approximation (used by the bwd kernel)."""
    u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x)
    t = jnp.tanh(u)
    du = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du


def moe_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    """Expert-batched FFN: the two einsums holding ~98% of MoE FLOPs (§A.3).

    x:  (E, C, M)  dispatched token blocks, one (C, M) slab per expert
    w1: (E, M, I)  per-expert up-projection
    w2: (E, I, M)  per-expert down-projection
    returns (E, C, M)
    """
    h = jnp.einsum("ecm,emi->eci", x, w1)
    a = gelu(h)
    return jnp.einsum("eci,eim->ecm", a, w2)


def route_top1(
    gates: jax.Array, offsets: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Reference top-1 routing with capacity, per prototype.

    gates:   (Z, T, F) router probabilities (already softmaxed)
    offsets: (Z, F)    tokens already assigned to each expert by earlier
                       top-k rounds (0 for round 0 / prototyping)
    capacity: per-expert capacity C (Eq. 2)

    Returns (expert_index (Z,T) i32, position (Z,T) i32, keep (Z,T) f32,
    counts (Z,F) f32).  ``position`` is the slot the token occupies in its
    expert's buffer (offset included); ``keep`` is 0 where the token
    overflowed capacity and is dropped to the residual path; ``counts`` is
    the number of *kept* tokens per expert, fed back as the next round's
    offsets (GShard top-k semantics).
    """
    z, t, f = gates.shape
    idx = jnp.argmax(gates, axis=-1)  # (Z, T)
    onehot = jax.nn.one_hot(idx, f, dtype=gates.dtype)  # (Z, T, F)
    # exclusive cumulative count of earlier tokens choosing the same expert
    cum = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_round = jnp.sum(cum * onehot, axis=-1)  # (Z, T)
    my_offset = jnp.take_along_axis(offsets, idx, axis=-1)  # (Z, T)
    pos = pos_in_round + my_offset
    keep = (pos < capacity).astype(gates.dtype)
    counts = offsets + jnp.sum(onehot * keep[..., None], axis=1)
    return idx.astype(jnp.int32), pos.astype(jnp.int32), keep, counts
