"""Layer-1 Pallas kernel: prototype-parallel top-1 routing with capacity.

Implements the integer half of the paper's Figure-8 ``prototype_gating``:
argmax expert selection, the exclusive token-position cumsum, and the
capacity cut (Eq. 2).  The *differentiable* half (softmax over router
logits, gate values, the combine tensor, the auxiliary balancing loss)
stays in plain jnp in ``compile/moe.py`` so gradients flow to the router
weights; this kernel's outputs are routing *decisions* and carry zero
cotangent (custom_vjp below).

The grid iterates over prototypes: the paper's core efficiency argument
(§3.3) is that top-k's looping argmax serializes k rounds, while k top-1
prototyping runs k *independent* routers.  Here that is literal — each
prototype is one grid program with no cross-program dependency, whereas
top-k calls this kernel k times sequentially with updated offsets
(see ``moe.py::route``), mirroring the Table-2 speed asymmetry.

TPU mapping: per grid step the (T, F) gate block lives in VMEM; argmax and
one-hot run on the VPU; the cumsum over T is the standard prefix-sum
ladder.  interpret=True as required for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _route_kernel(gates_ref, offsets_ref, idx_ref, pos_ref, keep_ref, counts_ref, *, capacity: int):
    gates = gates_ref[0]      # (T, F)
    offsets = offsets_ref[0]  # (F,)
    t, f = gates.shape

    idx = jnp.argmax(gates, axis=-1)                       # (T,)
    onehot = jax.nn.one_hot(idx, f, dtype=gates.dtype)     # (T, F)
    # exclusive cumsum: how many earlier tokens chose the same expert
    cum = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_round = jnp.sum(cum * onehot, axis=-1)          # (T,)
    my_offset = jnp.sum(onehot * offsets[None, :], axis=-1)
    pos = pos_in_round + my_offset
    keep = (pos < capacity).astype(gates.dtype)

    idx_ref[0] = idx.astype(jnp.int32)
    pos_ref[0] = pos.astype(jnp.int32)
    keep_ref[0] = keep
    counts_ref[0] = offsets + jnp.sum(onehot * keep[:, None], axis=0)


def _route_pallas(gates: jax.Array, offsets: jax.Array, capacity: int):
    z, t, f = gates.shape
    kern = functools.partial(_route_kernel, capacity=capacity)
    return pl.pallas_call(
        kern,
        grid=(z,),
        in_specs=[
            pl.BlockSpec((1, t, f), lambda zi: (zi, 0, 0)),
            pl.BlockSpec((1, f), lambda zi: (zi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t), lambda zi: (zi, 0)),
            pl.BlockSpec((1, t), lambda zi: (zi, 0)),
            pl.BlockSpec((1, t), lambda zi: (zi, 0)),
            pl.BlockSpec((1, f), lambda zi: (zi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((z, t), jnp.int32),
            jax.ShapeDtypeStruct((z, t), jnp.int32),
            jax.ShapeDtypeStruct((z, t), gates.dtype),
            jax.ShapeDtypeStruct((z, f), gates.dtype),
        ],
        interpret=True,
    )(gates, offsets)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def route_top1(gates: jax.Array, offsets: jax.Array, capacity: int):
    """Top-1 routing decisions per prototype, with capacity.

    gates (Z, T, F) softmaxed router probabilities; offsets (Z, F) tokens
    already committed per expert by earlier top-k rounds.

    Returns ``(expert_index i32 (Z,T), position i32 (Z,T), keep f32 (Z,T),
    counts f32 (Z,F))``.  Decisions are non-differentiable: the VJP returns
    zero cotangents (gradients reach the router through the gate values
    assembled in moe.py, exactly as in GShard/Switch).
    """
    return _route_pallas(gates, offsets, capacity)


def _route_fwd(gates, offsets, capacity):
    return _route_pallas(gates, offsets, capacity), (gates, offsets)


def _route_bwd(capacity, res, _g):
    gates, offsets = res
    return jnp.zeros_like(gates), jnp.zeros_like(offsets)


route_top1.defvjp(_route_fwd, _route_bwd)
