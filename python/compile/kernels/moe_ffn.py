"""Layer-1 Pallas kernel: expert-batched MoE feed-forward.

This is the paper's compute hot spot — §A.3 profiles the two expert matmuls
(``eCM x eMI -> eCI`` then ``eCI x eIM -> eCM``) at ~98% of the MoE layer's
forward FLOPs.  The kernel fuses them with the GeLU so the (C, I_blk)
activation tile never leaves VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid = (E, I // I_BLK): the expert index is the outer grid dimension —
    the TPU analogue of the paper's one-expert-per-worker placement; each
    grid step streams one expert's (M, I_blk)/(I_blk, M) weight tiles
    HBM -> VMEM.
  * the (C, M) token slab and the (C, M) f32 accumulator stay resident in
    VMEM across the inner I-tile loop; the MXU sees two back-to-back
    (C x M)@(M x I_blk) / (C x I_blk)@(I_blk x M) matmuls per step.
  * ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
    Mosaic custom-calls, so the kernel lowers to plain HLO; the BlockSpec
    structure (VMEM footprint, MXU tile shapes) is what carries to real
    TPUs and is what DESIGN.md §Perf estimates.

The custom VJP runs the backward pass as Pallas kernels too, recomputing
the (C, I_blk) activation tile instead of storing it (rematerialization:
saves E*C*I bytes of residual at the cost of one extra fwd matmul).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu, gelu_grad

# Default inner tile over the intermediate dimension.  Chosen so that for
# the paper's base geometry (M=1024, I=4096) the VMEM working set
#   C*M + M*I_blk + I_blk*M + C*I_blk + C*M
# stays under 16 MB with C=128 (see python/tests/test_vmem.py).
DEFAULT_I_BLOCK = 512


def _pick_i_block(intermediate: int, requested: int | None) -> int:
    blk = requested or DEFAULT_I_BLOCK
    blk = min(blk, intermediate)
    while intermediate % blk:
        blk //= 2
        if blk == 0:
            raise ValueError(f"intermediate={intermediate} has no power-of-2 tile")
    return blk


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #


def _fwd_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One (expert, I-tile) grid step of the fused FFN."""
    i = pl.program_id(1)
    x = x_ref[0]          # (C, M)
    w1 = w1_ref[0]        # (M, I_blk)
    w2 = w2_ref[0]        # (I_blk, M)
    h = jnp.dot(x, w1)    # MXU matmul 1
    a = gelu(h)
    part = jnp.dot(a, w2)  # MXU matmul 2

    @pl.when(i == 0)
    def _init():
        o_ref[0] = part

    @pl.when(i > 0)
    def _accum():
        o_ref[0] += part


def _fwd_pallas(x: jax.Array, w1: jax.Array, w2: jax.Array, i_block: int) -> jax.Array:
    e, c, m = x.shape
    _, _, i = w1.shape
    n_i = i // i_block
    return pl.pallas_call(
        _fwd_kernel,
        grid=(e, n_i),
        in_specs=[
            pl.BlockSpec((1, c, m), lambda ei, ii: (ei, 0, 0)),
            pl.BlockSpec((1, m, i_block), lambda ei, ii: (ei, 0, ii)),
            pl.BlockSpec((1, i_block, m), lambda ei, ii: (ei, ii, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, m), lambda ei, ii: (ei, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, m), x.dtype),
        interpret=True,
    )(x, w1, w2)


# --------------------------------------------------------------------------- #
# backward
# --------------------------------------------------------------------------- #


def _bwd_kernel(x_ref, w1_ref, w2_ref, g_ref, dx_ref, dw1_ref, dw2_ref):
    """Backward for one (expert, I-tile): recomputes the activation tile.

    h  = x @ w1_t          (C, I_blk)
    a  = gelu(h)
    da = g @ w2_t.T        (C, I_blk)
    dh = da * gelu'(h)
    dx  += dh @ w1_t.T     accumulated over I tiles
    dw1_t = x.T @ dh       (M, I_blk)   one tile per grid step
    dw2_t = a.T @ g        (I_blk, M)
    """
    i = pl.program_id(1)
    x = x_ref[0]    # (C, M)
    w1 = w1_ref[0]  # (M, I_blk)
    w2 = w2_ref[0]  # (I_blk, M)
    g = g_ref[0]    # (C, M)

    h = jnp.dot(x, w1)
    a = gelu(h)
    da = jnp.dot(g, w2.T)
    dh = da * gelu_grad(h)

    @pl.when(i == 0)
    def _init():
        dx_ref[0] = jnp.dot(dh, w1.T)

    @pl.when(i > 0)
    def _accum():
        dx_ref[0] += jnp.dot(dh, w1.T)

    dw1_ref[0] = jnp.dot(x.T, dh)
    dw2_ref[0] = jnp.dot(a.T, g)


def _bwd_pallas(x, w1, w2, g, i_block: int):
    e, c, m = x.shape
    _, _, i = w1.shape
    n_i = i // i_block
    return pl.pallas_call(
        _bwd_kernel,
        grid=(e, n_i),
        in_specs=[
            pl.BlockSpec((1, c, m), lambda ei, ii: (ei, 0, 0)),
            pl.BlockSpec((1, m, i_block), lambda ei, ii: (ei, 0, ii)),
            pl.BlockSpec((1, i_block, m), lambda ei, ii: (ei, ii, 0)),
            pl.BlockSpec((1, c, m), lambda ei, ii: (ei, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, m), lambda ei, ii: (ei, 0, 0)),
            pl.BlockSpec((1, m, i_block), lambda ei, ii: (ei, 0, ii)),
            pl.BlockSpec((1, i_block, m), lambda ei, ii: (ei, ii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, c, m), x.dtype),
            jax.ShapeDtypeStruct((e, m, i), w1.dtype),
            jax.ShapeDtypeStruct((e, i, m), w2.dtype),
        ],
        interpret=True,
    )(x, w1, w2, g)


# --------------------------------------------------------------------------- #
# public custom-vjp entry point
# --------------------------------------------------------------------------- #


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def moe_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array, i_block: int | None = None) -> jax.Array:
    """Fused expert-batched FFN: ``gelu(x @ w1) @ w2`` per expert.

    x (E, C, M), w1 (E, M, I), w2 (E, I, M) -> (E, C, M).
    Matches :func:`kernels.ref.moe_ffn` bit-for-bit in interpret mode.
    """
    return _fwd_pallas(x, w1, w2, _pick_i_block(w1.shape[2], i_block))


def _vjp_fwd(x, w1, w2, i_block):
    out = _fwd_pallas(x, w1, w2, _pick_i_block(w1.shape[2], i_block))
    return out, (x, w1, w2)


def _vjp_bwd(i_block, res, g):
    x, w1, w2 = res
    dx, dw1, dw2 = _bwd_pallas(x, w1, w2, g, _pick_i_block(w1.shape[2], i_block))
    return dx, dw1, dw2


moe_ffn.defvjp(_vjp_fwd, _vjp_bwd)


# --------------------------------------------------------------------------- #
# static analysis used by DESIGN.md §Perf and the rust flops module
# --------------------------------------------------------------------------- #


def vmem_bytes(c: int, m: int, i_block: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one fwd grid step (token slab + weight tiles +
    activation tile + accumulator)."""
    return dtype_bytes * (c * m + m * i_block + i_block * m + c * i_block + c * m)


def fwd_flops(e: int, c: int, m: int, i: int) -> int:
    """MXU FLOPs of the fused forward (2 matmuls, 2*N*M*K each)."""
    return e * (2 * c * m * i + 2 * c * i * m)


def mxu_utilization_estimate(c: int, m: int, i_block: int, workers: int = 1) -> float:
    """Fraction of 128x128 MXU tiles that are full for the inner matmuls.

    Real-TPU efficiency proxy (interpret-mode wall clock is meaningless):
    dims that are not multiples of 128 waste the remainder lanes.

    ``workers`` models the paper's eDCM buffer layout (§A.3): after the
    all-to-all, each expert's token slab holds D*C rows (one C-block from
    every worker), so the MXU row occupancy on the real cluster is that of
    D*C, not C. The perf pass (EXPERIMENTS.md §Perf L1) exploits exactly
    this: the kernel's token-slab BlockSpec treats the worker dimension as
    part of the row axis, taking base-geometry utilization from 0.31 to
    0.83 without touching the compute.
    """

    def eff(n: int) -> float:
        tiles = -(-n // 128)
        return n / (tiles * 128)

    rows = c * max(1, workers)
    # matmul1: (D*C,M)@(M,I_blk); matmul2: (D*C,I_blk)@(I_blk,M)
    m1 = eff(rows) * eff(m) * eff(i_block)
    m2 = eff(rows) * eff(i_block) * eff(m)
    return (m1 + m2) / 2.0
