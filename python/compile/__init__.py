"""Build-time python package: JAX model (L2) + Pallas kernels (L1) + AOT lowering.

Never imported at runtime -- `make artifacts` lowers everything to HLO text
that the rust coordinator loads via PJRT.
"""
