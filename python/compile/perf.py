"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

L1: interpret-mode wall clock is NOT a TPU proxy, so the kernel is
assessed structurally — VMEM working set and MXU-tile utilization of the
fused expert-FFN grid step at every paper geometry, across candidate
I-tile sizes. The chosen default must fit 16 MB VMEM everywhere and keep
tile utilization at the roofline the geometry allows.

L2: lowered-HLO statistics for the scan-vs-unroll ablation and the XLA
cost analysis (flops / bytes accessed) of the step module.

Usage: python -m compile.perf [--variant base-sim]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from . import config as cfglib
from . import train
from .kernels import moe_ffn as K


def l1_table() -> list[dict]:
    """VMEM + MXU estimates for the paper's Table-5 geometries."""
    rows = []
    # (name, M, I, C at k=1, workers D) — Table 5 rows
    geoms = [
        ("base", 1024, 4096, 1024 * 1.25 / 32, 8),
        ("10B", 1024, 4096, 1024 * 1.25 / 128, 16),
        ("100B", 1024, 4096, 1024 * 1.25 / 512, 128),
        ("1T", 1024, 21248, 1024 * 1.25 / 960, 480),
    ]
    for name, m, i, c, d in geoms:
        c = max(1, int(c))
        for i_block in [256, 512, 1024, 2048]:
            if i % i_block and i_block < i:
                # non-dividing tiles are skipped by the kernel's picker
                continue
            blk = min(i_block, i)
            rows.append(
                {
                    "geometry": name,
                    "M": m,
                    "I": i,
                    "C": c,
                    "i_block": blk,
                    "vmem_mb": K.vmem_bytes(c * d, m, blk) / 1e6,
                    "mxu_util_d1": K.mxu_utilization_estimate(c, m, blk),
                    "mxu_util_cluster": K.mxu_utilization_estimate(c, m, blk, workers=d),
                    "fits_vmem": K.vmem_bytes(c * d, m, blk) <= 16 * 1024 * 1024,
                }
            )
    return rows


def l2_stats(variant: str) -> dict:
    """HLO size + cost analysis for scan vs unroll of one variant."""
    cfg = cfglib.get(variant)
    out = {}
    for mode, scan in [("scan", True), ("unroll", False)]:
        c = cfg.with_(name=f"{cfg.name}-{mode}", scan_layers=scan)
        patches, tokens = train.batch_specs(c)
        params_abs = jax.eval_shape(
            train.init_fn(c), jax.ShapeDtypeStruct((), jnp.int32)
        )[0]
        lowered = jax.jit(train.eval_step_fn(c)).lower(params_abs, patches, tokens)
        text = lowered.compiler_ir("stablehlo")
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out[mode] = {
            "stablehlo_chars": len(str(text)),
            "flops": float(cost.get("flops", float("nan"))),
            "bytes_accessed": float(cost.get("bytes accessed", float("nan"))),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--variant", default="base-sim")
    args = ap.parse_args()

    print("== L1: fused expert-FFN kernel, paper geometries ==")
    print(f"{'geom':>6} {'M':>6} {'I':>6} {'C':>4} {'I_blk':>6} {'VMEM MB':>8} "
          f"{'MXU@D=1':>8} {'MXU@D':>6} fits")
    for r in l1_table():
        print(
            f"{r['geometry']:>6} {r['M']:>6} {r['I']:>6} {r['C']:>4} "
            f"{r['i_block']:>6} {r['vmem_mb']:>8.2f} {r['mxu_util_d1']:>8.2f} "
            f"{r['mxu_util_cluster']:>6.2f} {r['fits_vmem']}"
        )

    print(f"\n== L2: scan vs unroll ({args.variant}) ==")
    stats = l2_stats(args.variant)
    for mode, s in stats.items():
        print(
            f"{mode:>7}: stablehlo {s['stablehlo_chars']/1e3:.0f}k chars, "
            f"flops {s['flops']/1e9:.2f}G, bytes {s['bytes_accessed']/1e6:.1f}M"
        )
    ratio = stats["unroll"]["stablehlo_chars"] / stats["scan"]["stablehlo_chars"]
    print(f"unroll/scan HLO-size ratio: {ratio:.2f}x")


if __name__ == "__main__":
    main()
