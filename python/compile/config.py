"""Model / experiment configuration for the M6-T reproduction.

One :class:`ModelConfig` fully determines a lowered HLO variant: the
transformer shape, the MoE routing strategy (top-k vs k-top-1 expert
prototyping), the expert-capacity policy, the optimizer, and the batch
geometry.  ``VARIANTS`` is the registry that ``aot.py`` lowers and that the
rust coordinator addresses by name; pytest sweeps the same registry so the
artifacts rust loads are exactly the configurations that were tested.

Paper reference: Table 5 (hyperparameters), Sec. 2 (capacity, Eq. 2),
Sec. 3.3 (expert prototyping, Eq. 3), Sec. 4 (1T recipe).  The ``*-sim``
configs are downscaled twins of the paper's base/10B rows that train in
seconds-to-minutes on a single CPU core; DESIGN.md §2 documents the
substitution.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Tuple

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2


@dataclasses.dataclass(frozen=True)
class Routing:
    """Routing strategy for every MoE layer.

    ``kind`` is one of:
      * ``"topk"``       — GShard-style top-k over all ``num_experts``
                           (k sequential argmax rounds; Sec. 3.2).
      * ``"prototype"``  — k top-1 expert prototyping (Sec. 3.3, Eq. 3):
                           experts are split into ``k`` prototypes of
                           ``num_experts // k`` experts, one top-1 router
                           per prototype, outputs summed.
    ``k`` is the number of activated experts per token in both cases.
    """

    kind: str = "topk"
    k: int = 1

    def __post_init__(self) -> None:
        if self.kind not in ("topk", "prototype"):
            raise ValueError(f"unknown routing kind {self.kind!r}")
        if self.k < 1:
            raise ValueError("k must be >= 1")

    @property
    def name(self) -> str:
        if self.kind == "topk":
            return f"top{self.k}"
        return f"{self.k}top1"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Everything needed to build + lower one experiment variant."""

    name: str = "base-sim"
    # --- transformer geometry -------------------------------------------
    vocab_size: int = 2048
    hidden: int = 128           # M in the paper's notation
    intermediate: int = 512     # I
    layers: int = 4
    heads: int = 4
    head_dim: int = 32
    patch_dim: int = 32         # synthetic ResNet-patch feature width
    # --- MoE --------------------------------------------------------------
    num_experts: int = 16       # E (N in Sec. 2)
    routing: Routing = dataclasses.field(default_factory=Routing)
    capacity_factor: float = 1.25   # gamma in Eq. 2
    capacity_mode: str = "k"        # "k" => C = k*T/N*gamma ; "1" => C = T/N*gamma
    aux_loss_coef: float = 0.0      # 0 disables the balancing loss (Sec. 3.1)
    moe_attention: bool = False     # Sec. 3.4
    attn_num_experts: int = 8       # experts for Q/K/V/O MoE when enabled
    # --- batch geometry ----------------------------------------------------
    # Downscale note (DESIGN.md §2): the sim twins use batch=4 and a short
    # warmup/larger lr so that 150-300-step runs on one CPU core land in the
    # differentiated regime the paper reaches after thousands of GPU steps.
    batch: int = 4              # B (per-"GPU" in the paper; single host here)
    patches: int = 16           # P image patches per example (paper: 4x4)
    text_len: int = 48          # L
    # --- optimization -------------------------------------------------------
    optimizer: str = "adamw"    # "adamw" | "adafactor" (paper 1T recipe)
    lr: float = 1e-3            # paper uses 8e-5 at hidden=1024; scaled up for the tiny twins
    warmup: int = 50            # paper: 500
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    init_std: float = 0.02      # BERT init; 0.002 for the 1T recipe
    dropout: float = 0.0        # paper uses 0.1; off by default for clean curves
    # --- lowering -------------------------------------------------------------
    scan_layers: bool = True    # scan over stacked layer params vs unroll

    # ------------------------------------------------------------------ helpers
    @property
    def seq_len(self) -> int:
        """Total sequence length S = patches + text."""
        return self.patches + self.text_len

    @property
    def tokens_per_batch(self) -> int:
        """T in the paper's notation (Eq. 2)."""
        return self.batch * self.seq_len

    @property
    def capacity(self) -> int:
        """Per-expert capacity C (Eq. 2) under the configured policy.

        ``capacity_mode == "k"`` is the paper's "Capacity kx": C scales
        with the number of activated experts.  ``"1"`` is "Capacity 1x":
        every strategy gets the top-1 budget, equalizing FLOPs (Table 1).
        """
        k_eff = self.routing.k if self.capacity_mode == "k" else 1
        c = k_eff * self.tokens_per_batch / self.num_experts * self.capacity_factor
        return max(1, int(math.ceil(c)))

    @property
    def prototypes(self) -> int:
        """Z: number of parallel routers (1 for top-k)."""
        return self.routing.k if self.routing.kind == "prototype" else 1

    @property
    def experts_per_prototype(self) -> int:
        """F = E / Z."""
        z = self.prototypes
        if self.num_experts % z:
            raise ValueError(
                f"num_experts={self.num_experts} not divisible by prototypes={z}"
            )
        return self.num_experts // z

    @property
    def rounds(self) -> int:
        """Sequential argmax rounds per router (k for top-k, 1 for prototyping)."""
        return self.routing.k if self.routing.kind == "topk" else 1

    def param_count(self) -> int:
        """Exact parameter count of the model this config builds."""
        m, i, e = self.hidden, self.intermediate, self.num_experts
        embed = self.vocab_size * m + self.patch_dim * m + self.seq_len * m
        attn_dense = 4 * m * (self.heads * self.head_dim)
        if self.moe_attention:
            # 4 MoE projections, each attn_num_experts experts of (M x H) or
            # (H x M), plus one router per projection.
            h = self.heads * self.head_dim
            attn = 4 * self.attn_num_experts * m * h + 4 * m * self.attn_num_experts
        else:
            attn = attn_dense
        moe_ffn = e * (m * i + i * m) + m * e  # experts + router
        ln = 2 * 2 * m  # two LNs per layer (scale+bias)
        per_layer = attn + moe_ffn + ln
        final_ln = 2 * m
        return embed + self.layers * per_layer + final_ln

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)


# --------------------------------------------------------------------------- #
# Variant registry: every artifact the rust coordinator can load.
# --------------------------------------------------------------------------- #

def _base(**kw) -> ModelConfig:
    return ModelConfig(**kw)


def _routing_grid(base: ModelConfig, caps: Tuple[str, ...] = ("k", "1")) -> Dict[str, ModelConfig]:
    """All five strategies of Tables 1/3 x capacity policies."""
    out: Dict[str, ModelConfig] = {}
    strategies = [
        Routing("topk", 1),
        Routing("topk", 2),
        Routing("topk", 4),
        Routing("prototype", 2),
        Routing("prototype", 4),
    ]
    for cap in caps:
        for r in strategies:
            if r.kind == "topk" and r.k == 1 and cap == "1":
                continue  # top-1 at capacity 1x == top-1 at capacity kx
            name = f"{base.name}-{r.name}-cap{cap}"
            out[name] = base.with_(name=name, routing=r, capacity_mode=cap)
    return out


def build_variants() -> Dict[str, ModelConfig]:
    v: Dict[str, ModelConfig] = {}

    # ---- base-sim: downscaled twin of the paper's "base" (Table 5 col 1).
    base = _base(name="base-sim")
    v[base.name] = base
    v.update(_routing_grid(base))

    # Fig 1: base-sim with the auxiliary balancing loss on.
    aux = base.with_(name="base-sim-aux", aux_loss_coef=1e-2)
    v[aux.name] = aux

    # Fig 4 (left): MoE attention, shallow.
    mattn = base.with_(name="base-sim-moeattn", moe_attention=True)
    v[mattn.name] = mattn
    v[mattn.name + "-2top1"] = mattn.with_(
        name=mattn.name + "-2top1", routing=Routing("prototype", 2)
    )
    # Fig 4 (right): deeper model, fewer experts (paper: 4x layers, 8 experts).
    deep = base.with_(
        name="deep-sim", layers=8, num_experts=8, attn_num_experts=4
    )
    v[deep.name] = deep
    v[deep.name + "-moeattn"] = deep.with_(name=deep.name + "-moeattn", moe_attention=True)
    v[deep.name + "-moeattn-2top1"] = deep.with_(
        name=deep.name + "-moeattn-2top1",
        moe_attention=True,
        routing=Routing("prototype", 2),
    )

    # ---- large-sim: twin of the "10B" row (2x layers, 4x experts vs base-sim).
    # Used for Fig 5 / Table 4: the claim is that the k-top-1 advantage grows
    # with scale, so large-sim only needs capacity-1x variants.
    large = base.with_(name="large-sim", layers=6, num_experts=32, capacity_mode="1")
    v[large.name] = large
    for r in (Routing("topk", 2), Routing("prototype", 2), Routing("prototype", 4)):
        name = f"large-sim-{r.name}-cap1"
        v[name] = large.with_(name=name, routing=r)

    # ---- xlarge-sim: third scale point for Fig 5/6 trend (more experts).
    xl = base.with_(name="xlarge-sim", layers=6, num_experts=64, capacity_mode="1")
    v[xl.name] = xl
    v["xlarge-sim-2top1-cap1"] = xl.with_(
        name="xlarge-sim-2top1-cap1", routing=Routing("prototype", 2)
    )

    # ---- e2e-100m: the end-to-end validation model (~100M params).
    e2e = _base(
        name="e2e-100m",
        batch=8,
        hidden=256,
        intermediate=1024,
        layers=6,
        heads=8,
        head_dim=32,
        num_experts=32,
        routing=Routing("prototype", 2),
        capacity_mode="k",
    )
    v[e2e.name] = e2e

    # ---- 1T recipe demo (Sec. 4): Adafactor + reduced init; tiny geometry,
    # the point is the *stability recipe*, not the scale.
    recipe = base.with_(
        name="recipe-1t",
        optimizer="adafactor",
        lr=5e-3,
        init_std=0.002,
        routing=Routing("prototype", 2),
    )
    v[recipe.name] = recipe
    # the divergent counter-example: default lr 1e-2 + default init
    v["recipe-1t-divergent"] = recipe.with_(
        name="recipe-1t-divergent", lr=1e-2, init_std=0.02
    )
    return v


VARIANTS: Dict[str, ModelConfig] = build_variants()


def get(name: str) -> ModelConfig:
    try:
        return VARIANTS[name]
    except KeyError:
        raise KeyError(
            f"unknown variant {name!r}; known: {sorted(VARIANTS)}"
        ) from None
