"""Layer-2 optimizers: AdamW (paper Table 5) and Adafactor (the 1T recipe,
§4 — chosen by the paper for its sublinear memory cost).

Both operate on the parameter pytree and are lowered *inside* the train
step HLO so the rust coordinator never touches optimizer math: one call to
the compiled step advances parameters, moments, and the warmup schedule.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict


def lr_schedule(cfg: ModelConfig, step: jax.Array) -> jax.Array:
    """Linear warmup to cfg.lr over cfg.warmup steps, then constant
    (paper §A.2: warmup 500)."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / float(max(1, cfg.warmup)))
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Params, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-6))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #


class AdamWState(NamedTuple):
    m: Params
    v: Params


def adamw_init(params: Params) -> AdamWState:
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(m=zeros(), v=zeros())


def adamw_update(cfg: ModelConfig, params: Params, grads: Params,
                 state: AdamWState, step: jax.Array,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    new_m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay * p)

    new_p = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_p, AdamWState(new_m, new_v)


# --------------------------------------------------------------------------- #
# Adafactor (Shazeer & Stern 2018), as used by the paper's 1T recipe
# --------------------------------------------------------------------------- #


class AdafactorState(NamedTuple):
    # one entry per leaf: for ndim>=2 leaves, (v_row, v_col); else (v, dummy)
    v_row: Params
    v_col: Params


def _is_factored(x: jax.Array) -> bool:
    return x.ndim >= 2


def adafactor_init(params: Params) -> AdafactorState:
    def row(p):
        return jnp.zeros(p.shape[:-1], p.dtype) if _is_factored(p) else jnp.zeros_like(p)

    def col(p):
        return (
            jnp.zeros(p.shape[:-2] + p.shape[-1:], p.dtype)
            if _is_factored(p)
            else jnp.zeros((1,), p.dtype)
        )

    return AdafactorState(
        v_row=jax.tree_util.tree_map(row, params),
        v_col=jax.tree_util.tree_map(col, params),
    )


def adafactor_update(cfg: ModelConfig, params: Params, grads: Params,
                     state: AdafactorState, step: jax.Array,
                     eps1: float = 1e-30, clip_threshold: float = 1.0):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    # beta2 schedule from the paper: 1 - t^-0.8
    beta2 = 1.0 - jnp.power(t, -0.8)

    def upd(p, g, vr, vc):
        g2 = g * g + eps1
        if _is_factored(p):
            new_vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            new_vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            # factored second-moment estimate: v ~ outer(vr, vc) / mean(vr)
            r = new_vr / jnp.mean(new_vr, axis=-1, keepdims=True)
            denom = jnp.sqrt(r)[..., :, None] * jnp.sqrt(new_vc)[..., None, :]
            u = g / denom
        else:
            new_vr = beta2 * vr + (1 - beta2) * g2
            new_vc = vc
            u = g / jnp.sqrt(new_vr)
        # update clipping by RMS (d = 1.0)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        new_p = p - lr * u - lr * cfg.weight_decay * p
        return new_p, new_vr, new_vc

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_vr = jax.tree_util.tree_leaves(state.v_row)
    flat_vc = jax.tree_util.tree_leaves(state.v_col)
    out = [upd(p, g, vr, vc) for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_vr = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_vc = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, AdafactorState(new_vr, new_vc)


# --------------------------------------------------------------------------- #
# dispatch
# --------------------------------------------------------------------------- #


def opt_init(cfg: ModelConfig, params: Params):
    if cfg.optimizer == "adamw":
        return adamw_init(params)
    if cfg.optimizer == "adafactor":
        return adafactor_init(params)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


def opt_update(cfg: ModelConfig, params, grads, state, step):
    if cfg.optimizer == "adamw":
        return adamw_update(cfg, params, grads, state, step)
    if cfg.optimizer == "adafactor":
        return adafactor_update(cfg, params, grads, state, step)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
