//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment vendors no registry crates, so this workspace
//! carries the small slice of anyhow's API the codebase actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` macros. Semantics match upstream where it matters:
//! `Display` shows the outermost message, `{:#}` walks the context chain,
//! and any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// Error with a chain of context frames (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error in an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Context frames, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent next to the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_trait_works_on_both_error_kinds() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "missing thing"]);

        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("outer {}", 2)).unwrap_err();
        assert_eq!(e2.chain(), vec!["outer 2", "inner"]);
    }

    #[test]
    fn macros_format_and_bail() {
        let x = 7;
        let e = anyhow!("value {x} bad {:?}", "why");
        assert_eq!(format!("{e}"), "value 7 bad \"why\"");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 1");
    }

    #[test]
    fn error_msg_accepts_string() {
        let e = Error::msg(String::from("plain"));
        assert_eq!(format!("{e}"), "plain");
    }
}
