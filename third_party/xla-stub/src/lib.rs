//! Type-level stub of the vendored `xla` PJRT crate.
//!
//! The real crate (a patched xla-rs with `ExecuteOptions::untuple_result`)
//! is not shipped in the offline environment. This stub mirrors exactly the
//! API surface `m6t`'s PJRT engine and `smoke` binary use, so
//! `cargo build --features pjrt` type-checks and links; every runtime entry
//! point returns [`Error`] explaining that the backend is unavailable.
//! Swap this path dependency for the vendored crate to run on real PJRT.

use std::fmt;

/// Stub error: carries the "backend unavailable" message.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is unavailable — the vendored PJRT crate is absent; \
         build without --features pjrt to use the native backend"
    )))
}

/// Element types the PJRT host-buffer paths accept.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }
    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }
    pub fn device_count(&self) -> usize {
        0
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn scalar<T: ArrayElement>(_value: T) -> Literal {
        Literal
    }
    pub fn vec1<T: ArrayElement>(_values: &[T]) -> Literal {
        Literal
    }
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape;

impl ArrayShape {
    pub fn new<T: ArrayElement>(_dims: Vec<i64>) -> ArrayShape {
        ArrayShape
    }
}

#[derive(Debug)]
pub enum Shape {
    Array(ArrayShape),
}

#[derive(Debug, Clone)]
pub struct XlaOp;

impl XlaOp {
    pub fn reduce_sum(&self, _dims: &[i64], _keep_dims: bool) -> Result<XlaOp> {
        unavailable("XlaOp::reduce_sum")
    }
}

impl std::ops::Add for XlaOp {
    type Output = Result<XlaOp>;
    fn add(self, _rhs: XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::add")
    }
}

impl std::ops::Mul for XlaOp {
    type Output = Result<XlaOp>;
    fn mul(self, _rhs: XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::mul")
    }
}

#[derive(Debug)]
pub struct XlaBuilder;

impl XlaBuilder {
    pub fn new(_name: &str) -> XlaBuilder {
        XlaBuilder
    }
    pub fn parameter_s(&self, _id: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        unavailable("XlaBuilder::parameter_s")
    }
    pub fn c0<T: ArrayElement>(&self, _value: T) -> Result<XlaOp> {
        unavailable("XlaBuilder::c0")
    }
    pub fn tuple(&self, _ops: &[XlaOp]) -> Result<XlaOp> {
        unavailable("XlaBuilder::tuple")
    }
    pub fn build(&self, _root: &XlaOp) -> Result<XlaComputation> {
        unavailable("XlaBuilder::build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
