//! `m6t` — launcher CLI for the M6-T reproduction.
//!
//! Every subcommand runs out of the box on the pure-Rust native backend
//! (zero artifacts); with `--features pjrt` and a compiled artifact set,
//! the same commands execute the real lowered HLO instead (DESIGN.md).
//!
//!   list                    show runnable variants
//!   run                     short native training demo (c_v, drops, latency);
//!                           --workers D runs the expert-parallel sharded runtime
//!   train                   train one variant (checkpoints, metrics)
//!   eval                    eval PPL of a checkpoint / fresh init
//!   bench                   measured vs simulated ms/step per strategy;
//!                           --routing / --dispatch / --step / --overlap / --ffn
//!                           run the tracked suites (BENCH_*.json)
//!   sweep                   declarative grid sweeps over the content-addressed
//!                           experiment store; `m6t sweep gc` prunes dead cells
//!   serve-sim               open-loop serving simulation over the sharded
//!                           engine (arrivals x load x skew x drain; writes
//!                           BENCH_serve.json)
//!   flops                   Table 1 (analytical per-GPU GFLOPs)
//!   simulate                Table 2 (calibrated cluster simulator)
//!   figure fig1|fig3|fig4|fig5|fig6
//!   tables                  Tables 3 & 4 (downstream PPL)
//!   report                  run everything, write results/ CSVs
//!   lint-unsafe             enforce the unsafe-budget allowlist (CI gate)

#![forbid(unsafe_code)]

use std::process::ExitCode;

use anyhow::Result;

use m6t::config::paper;
use m6t::coordinator::{Checkpoint, TrainOptions, Trainer};
use m6t::experiments::{self, Runner};
use m6t::runtime::{measure_step_ms, Backend as _, BackendProvider, NativeProvider};
use m6t::sweep::{self, report, Engine, OutputFormat, SweepSpec};
use m6t::util::cli::Command;
use m6t::util::json::Value;
use m6t::util::table::{f1, f2, Table};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => {
            eprintln!("{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    match dispatch(sub, &rest) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "m6t — M6-T sparse-expert reproduction
subcommands:
  list | run | train | eval | bench | sweep | serve-sim | flops | simulate | figure | tables
  | report | lint-unsafe
run `m6t <subcommand> --help` for options";

fn common(cmd: Command) -> Command {
    cmd.opt_default("artifacts", "artifacts", "artifact directory (used with --features pjrt)")
        .opt_default("results", "results", "results directory")
        .opt_default("seed", "42", "data/init seed")
}

/// Pick the execution backend: the PJRT engine when the feature is on and
/// artifacts exist, the zero-artifact native runtime otherwise.
fn make_provider(artifacts: &str) -> Result<Box<dyn BackendProvider>> {
    #[cfg(feature = "pjrt")]
    {
        if std::path::Path::new(artifacts).join("manifest.json").exists() {
            return Ok(Box::new(m6t::runtime::PjrtProvider::new(artifacts)?));
        }
    }
    let _ = artifacts;
    Ok(Box::new(NativeProvider::new()))
}

fn dispatch(sub: &str, rest: &[String]) -> Result<()> {
    match sub {
        "list" => cmd_list(rest),
        "run" => cmd_run(rest),
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "bench" => cmd_bench(rest),
        "sweep" => cmd_sweep(rest),
        "serve-sim" => cmd_serve_sim(rest),
        "flops" => cmd_flops(rest),
        "simulate" => cmd_simulate(rest),
        "figure" => cmd_figure(rest),
        "tables" => cmd_tables(rest),
        "report" => cmd_report(rest),
        "lint-unsafe" => cmd_lint_unsafe(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn parse(cmd: Command, rest: &[String]) -> Result<m6t::util::cli::Args> {
    cmd.parse(rest).map_err(|e| anyhow::anyhow!("{e}"))
}

fn out_format(args: &m6t::util::cli::Args) -> Result<OutputFormat> {
    OutputFormat::parse(args.get("output-format").unwrap())
}

fn cmd_list(rest: &[String]) -> Result<()> {
    let args = parse(common(Command::new("list", "show runnable variants")), rest)?;
    let provider = make_provider(args.get("artifacts").unwrap())?;
    println!("{:<28} {:>9} {:>6} {:>8} {:>7}", "variant", "params", "C", "routing", "layers");
    for name in provider.names() {
        let v = provider.info(&name)?;
        println!(
            "{:<28} {:>8.1}M {:>6} {:>8} {:>7}",
            name,
            v.param_count as f64 / 1e6,
            v.capacity,
            v.config.routing.name(),
            v.config.layers
        );
    }
    Ok(())
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let cmd = Command::new("run", "short native training run: balance, drops, latency")
        .opt_default("variant", "base-top2", "native variant (see `m6t list`)")
        .opt_default("steps", "40", "training steps")
        .opt_default("seed", "42", "data/init seed")
        .opt_default("workers", "1", "expert-parallel workers D (sharded runtime when > 1)")
        .opt_default(
            "workers-per-node",
            "1",
            "node grouping for the hierarchical link model (1 = flat)",
        )
        .flag("no-overlap", "report only the serial (pre-overlap) cluster model")
        .flag(
            "elastic-capacity",
            "adapt per-shard expert capacity to measured demand at a fixed slot budget \
             (simulated compute only)",
        )
        .opt_default(
            "placement",
            "identity",
            "expert-shard placement search over measured traffic: identity|greedy|swap",
        )
        .flag("quiet", "suppress progress lines");
    let args = parse(cmd, rest)?;
    let workers: usize = args.get_or("workers", 1usize).map_err(anyhow::Error::msg)?;
    if workers == 0 {
        anyhow::bail!("--workers must be at least 1");
    }
    let placement = m6t::cluster::PlacementStrategy::parse(args.get("placement").unwrap())?;
    // Elastic capacity and placement both live in the sharded runtime;
    // at D=1 the sharded path is bitwise-equal to the native backend, so
    // routing through it is a pure superset.
    if workers > 1
        || args.flag("elastic-capacity")
        || placement != m6t::cluster::PlacementStrategy::Identity
    {
        return cmd_run_sharded(&args, workers);
    }
    let provider = NativeProvider::new();
    let name = args.get("variant").unwrap();
    let info = provider.info(name)?;
    eprintln!(
        "[m6t] {} — {:.1}M params, E={}, C={}, {} routing, native backend",
        name,
        info.param_count as f64 / 1e6,
        info.config.num_experts,
        info.capacity,
        info.config.routing.name(),
    );
    let opts = TrainOptions {
        steps: args.get_or("steps", 40i64).map_err(anyhow::Error::msg)?,
        seed: args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?,
        verbose: !args.flag("quiet"),
        ..Default::default()
    };
    let trainer = Trainer::new(provider.load(name)?, opts);
    let (outcome, state) = trainer.train()?;
    let ppl = trainer.eval_ppl(&state, 8)?;
    println!(
        "final: step {} loss {:.4} eval-PPL {:.3}",
        outcome.final_state_step,
        outcome.log.tail_loss(20),
        ppl
    );
    if let Some(last) = outcome.log.last() {
        let cvs: Vec<String> = last.cv_per_layer.iter().map(|c| format!("{c:.3}")).collect();
        let drops: Vec<String> =
            last.dropped_per_layer.iter().map(|d| format!("{d:.0}")).collect();
        println!("per-layer load c_v:          [{}]", cvs.join(", "));
        println!("per-layer dropped tokens:    [{}]", drops.join(", "));
        println!("simulated cluster step time: {:.1} ms/step", last.sim_ms);
        println!("measured host step time:     {:.2} ms/step", last.ms_per_step);
    }
    Ok(())
}

/// `m6t run --workers D` — the expert-parallel sharded runtime: every
/// worker routes its own local batch, the all-to-all exchange is
/// accounted exactly, and the cluster model consumes the *measured*
/// traffic in place of its analytic estimate — per link and overlapped
/// against expert compute unless `--no-overlap` asks for the serial
/// baseline.
fn cmd_run_sharded(args: &m6t::util::cli::Args, workers: usize) -> Result<()> {
    use m6t::metrics::RunLog;
    use m6t::runtime::ShardedRun;

    let provider = NativeProvider::new();
    let name = args.get("variant").unwrap();
    let info = provider.info(name)?;
    let cfg = info.config.clone();
    let wpn: usize = args.get_or("workers-per-node", 1usize).map_err(anyhow::Error::msg)?;
    if wpn == 0 {
        anyhow::bail!("--workers-per-node must be at least 1");
    }
    let mut run = ShardedRun::new(&cfg, workers)?;
    run.set_workers_per_node(wpn);
    let elastic = args.flag("elastic-capacity");
    if elastic {
        run.set_elastic_capacity(true)?;
    }
    let placement = m6t::cluster::PlacementStrategy::parse(args.get("placement").unwrap())?;
    run.set_placement(placement);
    let topo = run.topology();
    eprintln!(
        "[m6t] {} — sharded: D={} workers, E={} ({} experts/shard), C={} per worker, {} routing, {} topology",
        name,
        workers,
        cfg.num_experts,
        cfg.num_experts / workers,
        run.info().capacity,
        cfg.routing.name(),
        topo.name(),
    );
    if elastic || placement != m6t::cluster::PlacementStrategy::Identity {
        eprintln!(
            "[m6t] elastic capacity: {}, placement: {}",
            if elastic { "on" } else { "off" },
            placement.name(),
        );
    }
    let steps: i64 = args.get_or("steps", 40i64).map_err(anyhow::Error::msg)?;
    let seed: u64 = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let mut log = RunLog::new(format!("{name}-d{workers}"));
    let state = run.train(steps, seed, &mut log, !args.flag("quiet"))?;
    let ppl = run.eval_ppl(&state, 8, seed)?;
    println!("final: step {} loss {:.4} eval-PPL {:.3}", state.step, log.tail_loss(20), ppl);
    if let Some(last) = log.last() {
        let dsp = last
            .dispatch
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("sharded run produced no dispatch record"))?;
        let fmt0 = |xs: &[f64]| -> String {
            xs.iter().map(|x| format!("{x:.0}")).collect::<Vec<_>>().join(", ")
        };
        let drop_rates: Vec<String> = dsp
            .per_shard_recv
            .iter()
            .zip(&dsp.per_shard_dropped)
            .map(|(&recv, &drop)| format!("{:.3}", drop / (recv + drop).max(1.0)))
            .collect();
        if dsp.elastic {
            println!(
                "elastic capacity:            C in [{}, {}] per (layer, shard), budget {} slots/layer",
                dsp.capacity_min,
                dsp.capacity_max,
                workers * run.info().capacity
            );
        }
        if placement != m6t::cluster::PlacementStrategy::Identity {
            println!(
                "expert placement:            {} search, {:.2}x bottleneck gain, placed link share {:.3} (identity {:.3})",
                placement.name(),
                dsp.placement_gain,
                dsp.placed_link_share,
                dsp.bottleneck_link_share()
            );
        }
        println!("cross-worker load c_v:       {:.3}", dsp.shard_load_cv);
        println!("per-worker dropped tokens:   [{}]", fmt0(&dsp.per_worker_dropped));
        println!("per-shard recv tokens:       [{}]", fmt0(&dsp.per_shard_recv));
        println!("per-shard drop rate:         [{}]", drop_rates.join(", "));
        println!(
            "measured all-to-all:         {:.3} MB/step ({:.1}% of routed tokens cross workers)",
            dsp.a2a_bytes_step / 1e6,
            dsp.cross_fraction * 100.0
        );
        if args.flag("no-overlap") {
            // the serial baseline, formatted exactly as before the
            // overlap model existed — the oracle comparison surface
            println!(
                "cluster step time:           analytic {:.1} ms -> observed {:.1} ms",
                last.sim_ms, dsp.observed_ms
            );
        } else {
            println!(
                "bottleneck link:             w{} -> w{}  {:.3} MB/step ({:.0}% of cross bytes)",
                dsp.bottleneck_src,
                dsp.bottleneck_dst,
                dsp.max_link_bytes * 4.0 / 1e6,
                dsp.bottleneck_link_share() * 100.0
            );
            println!(
                "cluster step time:           analytic {:.1} ms -> serial {:.1} ms -> overlapped {:.1} ms ({:.2}x, {:.0}% of comm hidden)",
                last.sim_ms,
                dsp.observed_ms,
                dsp.observed_overlap_ms,
                dsp.overlap_speedup(),
                dsp.overlap_efficiency * 100.0
            );
        }
        println!("measured host step time:     {:.2} ms/step", last.ms_per_step);
    }
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let cmd = common(Command::new("train", "train one variant"))
        .opt_default("variant", "base-sim", "variant name (see `m6t list`)")
        .opt_default("steps", "200", "training steps")
        .opt_default("eval-every", "0", "eval cadence (0 = end only)")
        .opt("checkpoint", "write final checkpoint here")
        .opt("resume", "resume from checkpoint")
        .flag("quiet", "suppress progress lines");
    let args = parse(cmd, rest)?;
    let provider = make_provider(args.get("artifacts").unwrap())?;
    let name = args.get("variant").unwrap();
    let info = provider.info(name)?;
    eprintln!(
        "[m6t] {} — {:.1}M params, C={}, {} routing",
        name,
        info.param_count as f64 / 1e6,
        info.capacity,
        info.config.routing.name(),
    );
    let opts = TrainOptions {
        steps: args.get_or("steps", 200i64).map_err(anyhow::Error::msg)?,
        seed: args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?,
        eval_every: args.get_or("eval-every", 0i64).map_err(anyhow::Error::msg)?,
        metrics_dir: Some(format!("{}/metrics", args.get("results").unwrap())),
        verbose: !args.flag("quiet"),
        ..Default::default()
    };
    let trainer = Trainer::new(provider.load(name)?, opts);
    let (outcome, state) = match args.get("resume") {
        Some(path) => {
            let ck = Checkpoint::load(path)?;
            eprintln!("[m6t] resuming from step {}", ck.step);
            let state = trainer.restore(&ck)?;
            trainer.train_from(state)?
        }
        None => trainer.train()?,
    };
    println!(
        "final: step {} loss {:.4} eval-PPL {:.3}",
        outcome.final_state_step,
        outcome.log.tail_loss(20),
        outcome.evals.last().map(|&(_, p)| p).unwrap_or(f64::NAN)
    );
    if let Some(path) = args.get("checkpoint") {
        trainer.snapshot(&state)?.save(path)?;
        eprintln!("[m6t] checkpoint -> {path}");
    }
    Ok(())
}

fn cmd_eval(rest: &[String]) -> Result<()> {
    let cmd = common(Command::new("eval", "evaluate PPL"))
        .opt_default("variant", "base-sim", "variant name")
        .opt("checkpoint", "checkpoint to evaluate (default: fresh init)")
        .opt_default("batches", "16", "eval batches");
    let args = parse(cmd, rest)?;
    let provider = make_provider(args.get("artifacts").unwrap())?;
    let opts = TrainOptions {
        seed: args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    let trainer = Trainer::new(provider.load(args.get("variant").unwrap())?, opts);
    let state = match args.get("checkpoint") {
        Some(path) => trainer.restore(&Checkpoint::load(path)?)?,
        None => trainer.backend.init_state(42)?,
    };
    let n = args.get_or("batches", 16usize).map_err(anyhow::Error::msg)?;
    let ppl = trainer.eval_ppl(&state, n)?;
    println!("eval PPL over {n} batches: {ppl:.3}");
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "measured host vs simulated cluster ms/step")
        .opt_default("steps", "12", "measured steps per variant")
        .opt_default("results", "results", "results directory")
        .flag("routing", "run the routing-engine microbench instead (writes BENCH_routing.json)")
        .opt_default("tokens", "16384", "--routing: tokens per route call")
        .opt_default("out", "BENCH_routing.json", "--routing: output JSON path")
        .flag("dispatch", "run the sharded-dispatch suite instead (writes BENCH_dispatch.json)")
        .opt_default("dispatch-out", "BENCH_dispatch.json", "--dispatch: output JSON path")
        .flag(
            "step",
            "run the fused-vs-baseline step-throughput suite instead (writes BENCH_step.json)",
        )
        .opt_default("step-out", "BENCH_step.json", "--step: output JSON path")
        .flag(
            "overlap",
            "run the overlap/topology suite instead (writes BENCH_overlap.json)",
        )
        .opt_default("overlap-out", "BENCH_overlap.json", "--overlap: output JSON path")
        .flag(
            "ffn",
            "run the expert-FFN kernel suite instead (writes BENCH_ffn.json)",
        )
        .opt_default("ffn-out", "BENCH_ffn.json", "--ffn: output JSON path")
        .flag("force", "re-run sweep cells even when the store already has them")
        .opt_default("output-format", "stream", "stream|json|markdown summary output");
    let args = parse(cmd, rest)?;
    if args.flag("routing") {
        return cmd_bench_routing(&args);
    }
    if args.flag("dispatch") {
        return cmd_bench_dispatch(&args);
    }
    if args.flag("step") {
        return cmd_bench_step(&args);
    }
    if args.flag("overlap") {
        return cmd_bench_overlap(&args);
    }
    if args.flag("ffn") {
        return cmd_bench_ffn(&args);
    }
    let samples: usize = args.get_or("steps", 12usize).map_err(anyhow::Error::msg)?;
    let provider = NativeProvider::new();
    let variants = ["base-top1", "base-top2", "base-top4", "base-2top1", "base-4top1"];
    let mut t = Table::new(
        "native backend: measured host ms/step vs simulated cluster ms/step",
        &["strategy", "host ms/step", "sim cluster ms/step"],
    );
    for name in variants {
        let backend = provider.load(name)?;
        let (host_ms, stats) = measure_step_ms(backend.as_ref(), 42, 1, samples)?;
        t.row(vec![name.to_string(), f2(host_ms), f1(stats.sim_step_ms)]);
        eprintln!(
            "[bench] {name}: host {host_ms:.2} ms/step, sim {:.1} ms/step",
            stats.sim_step_ms
        );
    }
    report::emit(out_format(&args)?, &t, None);
    t.save_csv(format!("{}/bench_native.csv", args.get("results").unwrap()))?;
    Ok(())
}

/// The `Engine` behind the `m6t bench --*` modes: the shared store under
/// `<results>/store`, re-measuring only under `--force`.
fn bench_engine(args: &m6t::util::cli::Args) -> Engine {
    Engine::new(args.get("results").unwrap()).force(args.flag("force"))
}

/// `m6t bench --routing` — tokens/sec of the allocation-free RoutingEngine
/// vs the naive reference `route()` across the paper's five strategies,
/// E in {16, 64}, and tight/ample capacity. Writes the perf-trajectory
/// JSON (BENCH_routing.json at the repo root by default).
fn cmd_bench_routing(args: &m6t::util::cli::Args) -> Result<()> {
    use m6t::moe::microbench;
    let tokens: usize = args.get_or("tokens", 16384usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("out").unwrap().to_string();
    eprintln!("[bench] routing engine vs reference, {tokens} tokens per route call");
    let rows = microbench::run_suite(tokens);
    report::emit(out_format(args)?, &microbench::render_table(&rows, tokens), None);
    microbench::write_json(&rows, tokens, &out_path)?;
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t bench --dispatch` — the sharded expert-parallel runtime over
/// {base, 10B geometry twins} x {top1, top2, 2top1} x D in {1, 4, 8}:
/// measured host ms/step, cross-worker load c_v, drop rates, measured
/// all-to-all bytes, and the cluster model's analytic-vs-observed gap.
/// Also runs the elastic-capacity grid (skewed base-twin x D in {4, 8}):
/// static-vs-elastic drop rates at the same slot budget, whose
/// `max_elastic_drop_delta` field is a CI regression gate (<= 0.0 —
/// elastic must never drop more tokens than static). Writes
/// BENCH_dispatch.json at the repo root by default.
fn cmd_bench_dispatch(args: &m6t::util::cli::Args) -> Result<()> {
    use m6t::runtime::dispatch_bench;
    let steps: usize = args.get_or("steps", 12usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("dispatch-out").unwrap().to_string();
    eprintln!("[bench] sharded dispatch suite, {steps} steps per cell");
    let engine = bench_engine(args);
    let (rows, outcome) = dispatch_bench::run_suite(&engine, steps)?;
    let (erows, _elastic_outcome) = dispatch_bench::run_elastic_suite(&engine, steps)?;
    let mut doc = dispatch_bench::to_json(&rows, &erows, steps);
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(args)?, &dispatch_bench::render_table(&rows), Some(&doc));
    report::emit(out_format(args)?, &dispatch_bench::render_elastic_table(&erows), None);
    report::write_doc(&doc, &out_path)?;
    eprintln!(
        "[bench] max elastic drop delta: {:+.4}",
        erows.iter().map(|r| r.drop_delta).fold(f64::NEG_INFINITY, f64::max)
    );
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t bench --step` — end-to-end sharded step throughput: the fused
/// parallel (worker x layer) grid against the pre-fusion serial two-pass
/// baseline, measured in the same run over {base, large, xlarge-sim} x
/// {top1, top2, 2top1, 4top1} x D in {1, 4, 8}. Reports p50/p95 step ms,
/// steps/sec, routed-tokens/sec, the baseline-vs-fused speedup, and the
/// gate-matrix bytes the fused path never materializes. Writes
/// BENCH_step.json at the repo root by default.
fn cmd_bench_step(args: &m6t::util::cli::Args) -> Result<()> {
    use m6t::runtime::step_bench;
    let steps: usize = args.get_or("steps", 12usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("step-out").unwrap().to_string();
    eprintln!("[bench] fused vs two-pass sharded step, {steps} steps per cell and mode");
    let (rows, outcome) = step_bench::run_suite(&bench_engine(args), steps)?;
    let mut doc = step_bench::to_json(&rows, steps);
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(args)?, &step_bench::render_table(&rows, steps), Some(&doc));
    report::write_doc(&doc, &out_path)?;
    eprintln!(
        "[bench] xlarge-sim min speedup at D>=4: {:.2}x",
        step_bench::xlarge_min_speedup(&rows)
    );
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t bench --overlap` — the link-level, overlap-aware cluster model
/// over {base, large, xlarge-sim} x {top1, top2, 2top1} x D in {4, 8, 16}
/// x {flat, hierarchical} topologies: serial vs overlapped cluster ms,
/// overlap efficiency, and per-cell bottleneck-link concentration.
/// Writes BENCH_overlap.json at the repo root by default; its
/// `min_overlap_speedup` field is a CI regression gate (>= 1.0 is
/// structural — below it the cost model broke). Also runs the
/// topology-aware placement grid ({base, large-sim} x D in {4, 8},
/// hierarchical): greedy+swap search vs the identity layout, whose
/// `min_placement_gain` (>= 1.0) and `max_placement_share_delta`
/// (<= 0.0) fields are CI regression gates — both structural, since the
/// search falls back to identity when no dominating assignment exists.
fn cmd_bench_overlap(args: &m6t::util::cli::Args) -> Result<()> {
    use m6t::runtime::overlap_bench;
    let steps: usize = args.get_or("steps", 12usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("overlap-out").unwrap().to_string();
    eprintln!("[bench] overlap/topology suite, {steps} steps per cell");
    let engine = bench_engine(args);
    let (rows, outcome) = overlap_bench::run_suite(&engine, steps)?;
    let (prows, _placement_outcome) = overlap_bench::run_placement_suite(&engine, steps)?;
    let mut doc = overlap_bench::to_json(&rows, &prows, steps);
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(args)?, &overlap_bench::render_table(&rows, steps), Some(&doc));
    report::emit(out_format(args)?, &overlap_bench::render_placement_table(&prows), None);
    report::write_doc(&doc, &out_path)?;
    eprintln!(
        "[bench] min overlap speedup: {:.2}x, max bottleneck link share: {:.2}",
        overlap_bench::min_overlap_speedup(&rows),
        overlap_bench::max_bottleneck_link_share(&rows)
    );
    eprintln!(
        "[bench] min placement gain: {:.2}x, max placement share delta: {:+.4}",
        overlap_bench::min_placement_gain(&prows),
        overlap_bench::max_placement_share_delta(&prows)
    );
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t bench --ffn` — the native expert-FFN kernels: the cache-tiled
/// `gelu(x @ w1) @ w2` forward and rematerializing backward against the
/// naive loop-order baseline, over three geometries x pool sizes. Each
/// cell asserts tiled-vs-naive parity before timing. Writes
/// BENCH_ffn.json at the repo root by default; its `min_tiled_speedup`
/// field is a CI regression gate (>= 1.0 is structural — the tiled
/// kernel exists to beat the textbook loop order).
fn cmd_bench_ffn(args: &m6t::util::cli::Args) -> Result<()> {
    use m6t::runtime::ffn_bench;
    let reps: usize = args.get_or("steps", 8usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("ffn-out").unwrap().to_string();
    eprintln!("[bench] expert-FFN kernel suite, {reps} reps per cell");
    let (rows, outcome) = ffn_bench::run_suite(&bench_engine(args), reps)?;
    let mut doc = ffn_bench::to_json(&rows, reps);
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(args)?, &ffn_bench::render_table(&rows, reps), Some(&doc));
    report::write_doc(&doc, &out_path)?;
    eprintln!("[bench] min tiled speedup: {:.2}x", ffn_bench::min_tiled_speedup(&rows));
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t serve-sim` — open-loop traffic over the sharded engine: seeded
/// arrival traces (poisson, bursty, diurnal) through the
/// continuous-batching admission loop, every batch priced by the
/// overlap-aware cluster model over traffic profiled from real sharded
/// steps. Sweeps mode x D in {1, 4, 8} x offered load x hot-expert skew
/// x worker drain through the `serve` sweep kind and writes
/// BENCH_serve.json, whose `max_p99_over_slo` (< 1.0) and
/// `min_goodput_share` (>= 0.9) fields are CI regression gates over the
/// calm-poisson gate rows.
fn cmd_serve_sim(rest: &[String]) -> Result<()> {
    use m6t::serve::bench as serve_bench;
    let cmd = Command::new("serve-sim", "open-loop serving simulation over the sharded engine")
        .opt_default("steps", "6", "profiling steps per cell")
        .opt_default("results", "results", "results directory")
        .opt_default("out", "BENCH_serve.json", "output JSON path")
        .flag("force", "re-run sweep cells even when the store already has them")
        .opt_default("output-format", "stream", "stream|json|markdown summary output");
    let args = parse(cmd, rest)?;
    let steps: usize = args.get_or("steps", 6usize).map_err(anyhow::Error::msg)?;
    let out_path = args.get("out").unwrap().to_string();
    eprintln!("[bench] open-loop serve sim, {steps} profiling steps per cell");
    let (rows, outcome) = serve_bench::run_suite(&bench_engine(&args), steps)?;
    let mut doc = serve_bench::to_json(&rows, steps);
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(&args)?, &serve_bench::render_table(&rows, steps), Some(&doc));
    report::write_doc(&doc, &out_path)?;
    eprintln!(
        "[bench] gate rows: max p99/SLO {:.3} (ceiling 1.0), min goodput share {:.3} (floor 0.9)",
        serve_bench::max_p99_over_slo(&rows),
        serve_bench::min_goodput_share(&rows)
    );
    eprintln!("[bench] wrote {out_path}");
    Ok(())
}

/// `m6t sweep <dispatch|step|overlap|ffn|elastic|placement|serve|spec.json>`
/// — run a declarative
/// grid through the content-addressed experiment store: cells whose
/// address already holds a completed result are served from the store, so
/// re-invoking an identical sweep performs zero re-runs and an
/// interrupted sweep resumes by skipping finished cells. `m6t sweep gc`
/// prunes store entries whose address no longer appears in any live spec.
fn cmd_sweep(rest: &[String]) -> Result<()> {
    let cmd = Command::new("sweep", "declarative sweeps over the content-addressed store")
        .opt_default("results", "results", "results directory (store lives at <results>/store)")
        .opt_default("steps", "12", "measured steps (reps) per cell; default 12 (ffn: 8)")
        .opt_default("output-format", "stream", "stream|json|markdown summary output")
        .opt("out", "also write the full document (rows + provenance) here")
        .opt_repeated("spec", "gc: extra spec file(s) whose cells stay alive")
        .flag("force", "re-run cells even when the store already has them")
        .flag("dry-run", "gc: report what would be pruned without deleting")
        .flag("quiet", "suppress per-cell progress lines");
    let args = parse(cmd, rest)?;
    let which = args
        .positional
        .first()
        .ok_or_else(|| {
            anyhow::anyhow!(
                "usage: m6t sweep <dispatch|step|overlap|ffn|elastic|placement|serve|spec.json|gc>"
            )
        })?
        .clone();
    if which == "gc" {
        return cmd_sweep_gc(&args);
    }
    let spec = load_spec(&which, steps_override(&args)?)?;
    let runner = sweep::runner_for(&spec.kind)?;
    let engine = Engine::new(args.get("results").unwrap())
        .force(args.flag("force"))
        .verbose(!args.flag("quiet"));
    let outcome = engine.run_spec(&spec, runner.as_ref())?;
    let (table, mut doc) = render_outcome(&outcome)?;
    sweep::attach_provenance(&mut doc, &outcome);
    report::emit(out_format(&args)?, &table, Some(&doc));
    if let Some(path) = args.get("out") {
        report::write_doc(&doc, path)?;
        eprintln!("[sweep] wrote {path}");
    }
    Ok(())
}

/// `--steps` only overrides a spec's cell budget when explicitly passed.
fn steps_override(args: &m6t::util::cli::Args) -> Result<Option<usize>> {
    if args.flag("steps") {
        Ok(Some(args.get_or("steps", 12usize).map_err(anyhow::Error::msg)?))
    } else {
        Ok(None)
    }
}

/// Resolve a sweep name: a builtin bench family or a spec-file path.
fn load_spec(which: &str, steps: Option<usize>) -> Result<SweepSpec> {
    if sweep::BUILTIN_SPECS.contains(&which) {
        return sweep::builtin_spec(which, steps);
    }
    let text = std::fs::read_to_string(which)
        .map_err(|e| anyhow::anyhow!("reading sweep spec {which:?}: {e}"))?;
    let mut spec = SweepSpec::parse(&text)?;
    if let Some(s) = steps {
        spec.steps = s;
    }
    Ok(spec)
}

/// Per-kind summary table + machine document for a finished sweep — the
/// document is the same BENCH_*.json body `m6t bench --<kind>` writes.
fn render_outcome(outcome: &sweep::SweepOutcome) -> Result<(Table, Value)> {
    use m6t::runtime::{dispatch_bench, ffn_bench, overlap_bench, step_bench};
    let steps = cell_steps(outcome);
    match outcome.kind.as_str() {
        "dispatch" => {
            let rows = dispatch_bench::rows_from(outcome)?;
            Ok((dispatch_bench::render_table(&rows), dispatch_bench::to_json(&rows, &[], steps)))
        }
        "elastic" => {
            let rows = dispatch_bench::elastic_rows_from(outcome)?;
            Ok((
                dispatch_bench::render_elastic_table(&rows),
                dispatch_bench::to_json(&[], &rows, steps),
            ))
        }
        "step" => {
            let rows = step_bench::rows_from(outcome)?;
            Ok((step_bench::render_table(&rows, steps), step_bench::to_json(&rows, steps)))
        }
        "overlap" => {
            let rows = overlap_bench::rows_from(outcome)?;
            Ok((
                overlap_bench::render_table(&rows, steps),
                overlap_bench::to_json(&rows, &[], steps),
            ))
        }
        "placement" => {
            let rows = overlap_bench::placement_rows_from(outcome)?;
            Ok((
                overlap_bench::render_placement_table(&rows),
                overlap_bench::to_json(&[], &rows, steps),
            ))
        }
        "ffn" => {
            let rows = ffn_bench::rows_from(outcome)?;
            Ok((ffn_bench::render_table(&rows, steps), ffn_bench::to_json(&rows, steps)))
        }
        "serve" => {
            use m6t::serve::bench as serve_bench;
            let rows = serve_bench::rows_from(outcome)?;
            Ok((serve_bench::render_table(&rows, steps), serve_bench::to_json(&rows, steps)))
        }
        other => anyhow::bail!("no summary renderer for sweep kind {other:?}"),
    }
}

/// Every cell in a sweep carries the same reserved `steps` param; recover
/// it for the document header.
fn cell_steps(outcome: &sweep::SweepOutcome) -> usize {
    outcome.outcomes.first().and_then(|o| o.cell.req_usize("steps").ok()).unwrap_or(12)
}

/// `m6t sweep gc` — the liveness set is every cell of the builtin bench
/// specs (at their defaults and, when passed, the `--steps` override)
/// plus any `--spec` files; store kinds no spec mentions are never
/// scanned, so training runs survive a bench-only gc.
fn cmd_sweep_gc(args: &m6t::util::cli::Args) -> Result<()> {
    use std::collections::BTreeSet;

    let steps = steps_override(args)?;
    let mut specs: Vec<SweepSpec> = Vec::new();
    for name in sweep::BUILTIN_SPECS {
        specs.push(sweep::builtin_spec(name, None)?);
        if steps.is_some() {
            specs.push(sweep::builtin_spec(name, steps)?);
        }
    }
    for path in args.get_all("spec") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading sweep spec {path:?}: {e}"))?;
        specs.push(SweepSpec::parse(&text)?);
    }
    let mut live: BTreeSet<(String, String)> = BTreeSet::new();
    let mut kinds: BTreeSet<String> = BTreeSet::new();
    for spec in &specs {
        let runner = sweep::runner_for(&spec.kind)?;
        live.extend(sweep::live_keys(spec, runner.as_ref())?);
        kinds.insert(spec.kind.clone());
    }
    let engine = Engine::new(args.get("results").unwrap());
    let dry = args.flag("dry-run");
    let gc = engine.store().gc(&live, &kinds, dry)?;
    let verb = if dry { "would prune" } else { "pruned" };
    for path in &gc.pruned {
        eprintln!("[sweep] {verb} {}", path.display());
    }
    println!(
        "sweep gc: {} cell(s) scanned, {} live, {} {}",
        gc.scanned,
        gc.kept,
        gc.pruned.len(),
        if dry { "prunable (dry-run)" } else { "pruned" }
    );
    Ok(())
}

/// `m6t lint-unsafe` — the unsafe-budget ratchet (DESIGN.md "Safety &
/// concurrency model"): scan the Rust sources, require every `unsafe`
/// token to sit in the audited allowlist with an adjacent `// SAFETY:`
/// comment, and fail on any drift in either direction.
fn cmd_lint_unsafe(rest: &[String]) -> Result<()> {
    let cmd = Command::new("lint-unsafe", "enforce the unsafe-budget allowlist")
        .opt_default("root", ".", "repository root to scan")
        .opt_default("allowlist", "rust/unsafe_allowlist.txt", "allowlist path (under --root)");
    let args = parse(cmd, rest)?;
    let root = std::path::PathBuf::from(args.get("root").unwrap());
    let allowlist = root.join(args.get("allowlist").unwrap());
    let report = m6t::util::lint::run(&root, &allowlist)?;
    if !report.violations.is_empty() {
        for v in &report.violations {
            eprintln!("lint-unsafe: {v}");
        }
        anyhow::bail!("{} unsafe-budget violation(s)", report.violations.len());
    }
    println!(
        "lint-unsafe: OK — {} files scanned, {} audited unsafe site(s), all within budget",
        report.files_scanned,
        report.unsafe_sites
    );
    Ok(())
}

fn cmd_flops(rest: &[String]) -> Result<()> {
    let cmd = Command::new("flops", "Table 1: analytical per-GPU GFLOPs")
        .opt_default("model", "base", "paper preset: base|10B|100B|250B|1T")
        .opt_default("results", "results", "results directory")
        .opt_default("output-format", "stream", "stream|json|markdown summary output");
    let args = parse(cmd, rest)?;
    let preset = paper::by_name(args.get("model").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset {:?}", args.get("model")))?;
    let t = experiments::table1::run(Some(preset));
    report::emit(out_format(&args)?, &t, None);
    t.save_csv(format!("{}/table1.csv", args.get("results").unwrap()))?;
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let cmd = Command::new("simulate", "Table 2: cluster-simulated ms/step")
        .opt_default("results", "results", "results directory")
        .opt_default("output-format", "stream", "stream|json|markdown summary output")
        .flag("compare", "also print paper-vs-simulated deltas");
    let args = parse(cmd, rest)?;
    let format = out_format(&args)?;
    let t = experiments::table2::run();
    report::emit(format, &t, None);
    t.save_csv(format!("{}/table2.csv", args.get("results").unwrap()))?;
    if args.flag("compare") {
        let c = experiments::table2::comparison();
        report::emit(format, &c, None);
        c.save_csv(format!("{}/table2_comparison.csv", args.get("results").unwrap()))?;
    }
    Ok(())
}

fn runner_from<'e>(
    args: &m6t::util::cli::Args,
    provider: &'e dyn BackendProvider,
) -> Result<Runner<'e>> {
    let mut r = Runner::new(provider, args.get("results").unwrap());
    r.seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    r.force = args.flag("force");
    Ok(r)
}

fn cmd_figure(rest: &[String]) -> Result<()> {
    let cmd = common(Command::new("figure", "reproduce a paper figure"))
        .opt_default("steps", "200", "steps per training run")
        .opt_default("side", "left", "fig3/fig4: left|right")
        .opt_default("output-format", "stream", "stream|json|markdown summary output")
        .flag("force", "ignore the run cache");
    let args = parse(cmd, rest)?;
    let format = out_format(&args)?;
    let which = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: m6t figure <fig1|fig3|fig4|fig5|fig6>"))?
        .clone();
    let provider = make_provider(args.get("artifacts").unwrap())?;
    let runner = runner_from(&args, provider.as_ref())?;
    let steps: i64 = args.get_or("steps", 200i64).map_err(anyhow::Error::msg)?;
    let results = args.get("results").unwrap().to_string();
    match which.as_str() {
        "fig1" => {
            let out = experiments::fig1::run(&runner, steps)?;
            report::emit(format, &out.summary, None);
            out.series.save_csv(format!("{results}/fig1_series.csv"))?;
            out.summary.save_csv(format!("{results}/fig1_summary.csv"))?;
        }
        "fig3" => {
            let side = args.get("side").unwrap();
            let out = experiments::fig3::run(&runner, steps, side)?;
            report::emit(format, &out.summary, None);
            out.curves.save_csv(format!("{results}/fig3_{side}_curves.csv"))?;
            out.summary.save_csv(format!("{results}/fig3_{side}_summary.csv"))?;
        }
        "fig4" => {
            let side = args.get("side").unwrap();
            let out = experiments::fig4::run(&runner, steps, side)?;
            report::emit(format, &out.summary, None);
            out.curves.save_csv(format!("{results}/fig4_{side}_curves.csv"))?;
            out.summary.save_csv(format!("{results}/fig4_{side}_summary.csv"))?;
        }
        "fig5" => {
            let out = experiments::fig5::run(&runner, steps)?;
            report::emit(format, &out.summary, None);
            out.curves.save_csv(format!("{results}/fig5_curves.csv"))?;
            out.summary.save_csv(format!("{results}/fig5_summary.csv"))?;
        }
        "fig6" => {
            let out = experiments::fig6::run(&runner, steps)?;
            report::emit(format, &out.summary, None);
            println!("modelled convergence speedup: {:.2}x (paper: ~5x)", out.speedup);
            out.curves.save_csv(format!("{results}/fig6_curves.csv"))?;
            out.summary.save_csv(format!("{results}/fig6_summary.csv"))?;
        }
        other => anyhow::bail!("unknown figure {other:?}"),
    }
    Ok(())
}

fn cmd_tables(rest: &[String]) -> Result<()> {
    let cmd = common(Command::new("tables", "Tables 3 & 4: downstream PPL"))
        .opt_default("steps", "200", "steps per training run")
        .opt_default("output-format", "stream", "stream|json|markdown summary output")
        .flag("force", "ignore the run cache");
    let args = parse(cmd, rest)?;
    let format = out_format(&args)?;
    let provider = make_provider(args.get("artifacts").unwrap())?;
    let runner = runner_from(&args, provider.as_ref())?;
    let steps: i64 = args.get_or("steps", 200i64).map_err(anyhow::Error::msg)?;
    let results = args.get("results").unwrap().to_string();
    let t3 = experiments::table34::table3(&runner, steps)?;
    report::emit(format, &t3, None);
    t3.save_csv(format!("{results}/table3.csv"))?;
    let t4 = experiments::table34::table4(&runner, steps)?;
    report::emit(format, &t4, None);
    t4.save_csv(format!("{results}/table4.csv"))?;
    Ok(())
}

fn cmd_report(rest: &[String]) -> Result<()> {
    let cmd = common(Command::new("report", "run every table and figure"))
        .opt_default("steps", "200", "steps per training run")
        .opt_default("output-format", "stream", "stream|json|markdown summary output")
        .flag("force", "ignore the run cache");
    let args = parse(cmd, rest)?;
    let format = out_format(&args)?;
    let provider = make_provider(args.get("artifacts").unwrap())?;
    let runner = runner_from(&args, provider.as_ref())?;
    let steps: i64 = args.get_or("steps", 200i64).map_err(anyhow::Error::msg)?;
    let results = args.get("results").unwrap().to_string();

    let t1 = experiments::table1::run(None);
    report::emit(format, &t1, None);
    t1.save_csv(format!("{results}/table1.csv"))?;
    let t2 = experiments::table2::run();
    report::emit(format, &t2, None);
    t2.save_csv(format!("{results}/table2.csv"))?;
    let t2c = experiments::table2::comparison();
    report::emit(format, &t2c, None);
    t2c.save_csv(format!("{results}/table2_comparison.csv"))?;

    let f1 = experiments::fig1::run(&runner, steps)?;
    report::emit(format, &f1.summary, None);
    f1.series.save_csv(format!("{results}/fig1_series.csv"))?;
    f1.summary.save_csv(format!("{results}/fig1_summary.csv"))?;

    for side in ["left", "right"] {
        let f3 = experiments::fig3::run(&runner, steps, side)?;
        report::emit(format, &f3.summary, None);
        f3.curves.save_csv(format!("{results}/fig3_{side}_curves.csv"))?;
        f3.summary.save_csv(format!("{results}/fig3_{side}_summary.csv"))?;
    }
    for side in ["left", "right"] {
        let f4 = experiments::fig4::run(&runner, steps, side)?;
        report::emit(format, &f4.summary, None);
        f4.curves.save_csv(format!("{results}/fig4_{side}_curves.csv"))?;
        f4.summary.save_csv(format!("{results}/fig4_{side}_summary.csv"))?;
    }
    let f5 = experiments::fig5::run(&runner, steps)?;
    report::emit(format, &f5.summary, None);
    f5.curves.save_csv(format!("{results}/fig5_curves.csv"))?;
    f5.summary.save_csv(format!("{results}/fig5_summary.csv"))?;

    let f6 = experiments::fig6::run(&runner, steps)?;
    report::emit(format, &f6.summary, None);
    println!("modelled convergence speedup: {:.2}x (paper: ~5x)", f6.speedup);
    f6.curves.save_csv(format!("{results}/fig6_curves.csv"))?;
    f6.summary.save_csv(format!("{results}/fig6_summary.csv"))?;

    let t3 = experiments::table34::table3(&runner, steps)?;
    report::emit(format, &t3, None);
    t3.save_csv(format!("{results}/table3.csv"))?;
    let t4 = experiments::table34::table4(&runner, steps)?;
    report::emit(format, &t4, None);
    t4.save_csv(format!("{results}/table4.csv"))?;

    eprintln!("[m6t] report complete — CSVs in {results}/");
    Ok(())
}
