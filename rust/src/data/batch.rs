//! Batcher: packs examples into the fixed-shape host buffers the PJRT
//! train step consumes. Kept xla-free so the data pipeline unit-tests run
//! without a PJRT client; `runtime::literals` does the Literal conversion.

use super::corpus::{Generator, Split};
use crate::config::ModelConfig;

/// One fixed-shape batch in host memory.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch: usize,
    pub patches: usize,
    pub patch_dim: usize,
    pub text_len: usize,
    /// (B, P, D) row-major
    pub patch_features: Vec<f32>,
    /// (B, L) row-major
    pub tokens: Vec<i32>,
}

impl Batch {
    pub fn patch_shape(&self) -> [usize; 3] {
        [self.batch, self.patches, self.patch_dim]
    }
    pub fn token_shape(&self) -> [usize; 2] {
        [self.batch, self.text_len]
    }
}

/// Streams deterministic batches for a split; `cursor` advances example
/// indices so every batch is fresh data (one epoch over the synthetic
/// corpus is effectively infinite).
pub struct Batcher {
    gen: Generator,
    split: Split,
    cursor: u64,
    batch: usize,
}

impl Batcher {
    pub fn new(gen: Generator, split: Split, batch: usize) -> Self {
        Self { gen, split, cursor: 0, batch }
    }

    pub fn for_config(cfg: &ModelConfig, split: Split, seed: u64) -> Self {
        let space = super::attrs::AttributeSpace::new(cfg.patch_dim, cfg.vocab_size as i32, seed);
        let gen = Generator::new(space, cfg.patches, cfg.text_len, seed);
        Self::new(gen, split, cfg.batch)
    }

    /// Reset to a fixed position — used to make eval batches identical
    /// across strategies so PPL comparisons are paired.
    pub fn seek(&mut self, cursor: u64) {
        self.cursor = cursor;
    }

    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch;
        let p = self.gen.patches;
        let d = self.gen.space.patch_dim;
        let l = self.gen.text_len;
        let mut patch_features = Vec::with_capacity(b * p * d);
        let mut tokens = Vec::with_capacity(b * l);
        for _ in 0..b {
            let ex = self.gen.example(self.split, self.cursor);
            self.cursor += 1;
            patch_features.extend_from_slice(&ex.patch_features);
            tokens.extend_from_slice(&ex.tokens);
        }
        Batch {
            batch: b,
            patches: p,
            patch_dim: d,
            text_len: l,
            patch_features,
            tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::attrs::AttributeSpace;

    fn batcher(split: Split) -> Batcher {
        let space = AttributeSpace::new(32, 2048, 1);
        Batcher::new(Generator::new(space, 8, 24, 1), split, 4)
    }

    #[test]
    fn shapes() {
        let mut b = batcher(Split::Train);
        let batch = b.next_batch();
        assert_eq!(batch.patch_features.len(), 4 * 8 * 32);
        assert_eq!(batch.tokens.len(), 4 * 24);
        assert_eq!(batch.patch_shape(), [4, 8, 32]);
        assert_eq!(batch.token_shape(), [4, 24]);
    }

    #[test]
    fn advances() {
        let mut b = batcher(Split::Train);
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_ne!(b1.tokens, b2.tokens);
    }

    #[test]
    fn seek_replays() {
        let mut b = batcher(Split::Eval);
        let b1 = b.next_batch();
        b.seek(0);
        let b2 = b.next_batch();
        assert_eq!(b1.tokens, b2.tokens);
        assert_eq!(b1.patch_features, b2.patch_features);
    }

    #[test]
    fn train_and_eval_streams_differ() {
        let mut tr = batcher(Split::Train);
        let mut ev = batcher(Split::Eval);
        assert_ne!(tr.next_batch().tokens, ev.next_batch().tokens);
    }
}
