//! Synthetic M6-Corpus substitute (DESIGN.md §2).
//!
//! The paper pretrains on proprietary image-text pairs (M6-Corpus) and
//! evaluates zero-shot captioning PPL on E-commerce IC. We replace both
//! with a generative process that preserves what the routing study needs:
//! a *learnable* cross-modal signal (captions are a stochastic function of
//! the image latents, so PPL falls with training and better models win)
//! plus local language structure (attribute phrases with function words).
//!
//! Pipeline: [`attrs::AttributeSpace`] defines latent product attributes →
//! [`corpus::Generator`] emits (patch-features, caption) pairs, split
//! deterministically into train/eval by hashing the latent combination →
//! [`batch::Batcher`] packs fixed-shape batches for the PJRT train step.

pub mod attrs;
pub mod batch;
pub mod corpus;

pub use attrs::AttributeSpace;
pub use batch::{Batch, Batcher};
pub use corpus::{Example, Generator, Split};
