//! Example generator: latent product → (patch features, caption tokens).
//!
//! Captions follow a stochastic template grammar over the attribute names
//! (function word · attribute phrase · ...), giving both a cross-modal
//! signal (content tokens are determined by the latents visible in the
//! patches) and a unimodal one (function-word bigrams). The train/eval
//! split is by latent-combination hash, so eval examples are unseen
//! products — the synthetic analogue of zero-shot E-commerce IC PPL.

use super::attrs::{AttributeSpace, BOS_ID, EOS_ID, FUNC_START, FUNC_WORDS, PAD_ID};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

/// One (image, caption) pair, already tokenized / featurized.
#[derive(Debug, Clone)]
pub struct Example {
    pub latent: Vec<usize>,
    /// (patches, patch_dim) row-major
    pub patch_features: Vec<f32>,
    /// BOS-prefixed, EOS-terminated, PAD-padded to `text_len`
    pub tokens: Vec<i32>,
}

/// Deterministic corpus generator.
pub struct Generator {
    pub space: AttributeSpace,
    pub patches: usize,
    pub text_len: usize,
    /// per-mille of latent combinations held out for eval (by hash)
    pub eval_per_mille: u64,
    noise: f32,
    seed: u64,
}

impl Generator {
    pub fn new(space: AttributeSpace, patches: usize, text_len: usize, seed: u64) -> Self {
        Self { space, patches, text_len, eval_per_mille: 50, noise: 0.25, seed }
    }

    pub fn split_of(&self, latent: &[usize]) -> Split {
        if self.space.latent_hash(latent) % 1000 < self.eval_per_mille {
            Split::Eval
        } else {
            Split::Train
        }
    }

    /// Generate the `idx`-th example of a split. Indices are stable across
    /// runs and processes — the rust twin of a seeded tf.data pipeline.
    pub fn example(&self, split: Split, idx: u64) -> Example {
        let tag = match split {
            Split::Train => 0x7124u64,
            Split::Eval => 0xEDA1u64,
        };
        let mut rng = Rng::new(self.seed).fold_in(tag).fold_in(idx);
        // rejection-sample a latent in the right split (eval is 5%, so the
        // expected number of draws is small and deterministic given idx)
        let latent = loop {
            let l = self.space.sample_latent(&mut rng);
            if self.split_of(&l) == split {
                break l;
            }
        };
        let patch_features = self.render_patches(&latent, &mut rng);
        let tokens = self.render_caption(&latent, &mut rng);
        Example { latent, patch_features, tokens }
    }

    /// Patches: each shows one (possibly repeated) attribute's feature
    /// direction plus Gaussian pixel noise — a stand-in for frozen ResNet
    /// features of a product photo.
    fn render_patches(&self, latent: &[usize], rng: &mut Rng) -> Vec<f32> {
        let d = self.space.patch_dim;
        let mut out = vec![0f32; self.patches * d];
        for p in 0..self.patches {
            let attr = rng.below(latent.len() as u64) as usize;
            let f = self.space.feature(attr, latent[attr]);
            let row = &mut out[p * d..(p + 1) * d];
            for (o, v) in row.iter_mut().zip(f) {
                *o = v + self.noise * rng.normal() as f32;
            }
        }
        out
    }

    /// Caption: BOS, then attribute phrases in a shuffled order, each
    /// introduced by a function word, then EOS + PAD fill.
    fn render_caption(&self, latent: &[usize], rng: &mut Rng) -> Vec<i32> {
        let mut toks = Vec::with_capacity(self.text_len);
        toks.push(BOS_ID);
        let mut order: Vec<usize> = (0..latent.len()).collect();
        rng.shuffle(&mut order);
        // mention 3..=all attributes
        let mentions = 3 + rng.below((latent.len() - 2) as u64) as usize;
        for &attr in order.iter().take(mentions) {
            if toks.len() + 4 >= self.text_len {
                break;
            }
            // function word biased by the attribute id → learnable bigrams
            let fw = FUNC_START
                + ((attr as i32 * 7 + rng.below(5) as i32) % FUNC_WORDS);
            toks.push(fw);
            for &t in self.space.name_tokens(attr, latent[attr]) {
                if toks.len() + 2 >= self.text_len {
                    break;
                }
                toks.push(t);
            }
        }
        toks.push(EOS_ID);
        while toks.len() < self.text_len {
            toks.push(PAD_ID);
        }
        toks.truncate(self.text_len);
        toks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::attrs::CONTENT_START;

    fn gen() -> Generator {
        Generator::new(AttributeSpace::new(32, 2048, 42), 16, 48, 42)
    }

    #[test]
    fn examples_are_deterministic() {
        let g = gen();
        let a = g.example(Split::Train, 17);
        let b = g.example(Split::Train, 17);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.patch_features, b.patch_features);
        assert_eq!(a.latent, b.latent);
    }

    #[test]
    fn different_indices_differ() {
        let g = gen();
        let a = g.example(Split::Train, 1);
        let b = g.example(Split::Train, 2);
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn splits_are_disjoint_by_hash() {
        let g = gen();
        for i in 0..50 {
            let tr = g.example(Split::Train, i);
            assert_eq!(g.split_of(&tr.latent), Split::Train);
            let ev = g.example(Split::Eval, i);
            assert_eq!(g.split_of(&ev.latent), Split::Eval);
        }
    }

    #[test]
    fn caption_structure() {
        let g = gen();
        for i in 0..30 {
            let e = g.example(Split::Train, i);
            assert_eq!(e.tokens.len(), 48);
            assert_eq!(e.tokens[0], BOS_ID);
            assert!(e.tokens.contains(&EOS_ID));
            // after EOS only PAD
            let eos = e.tokens.iter().position(|&t| t == EOS_ID).unwrap();
            assert!(e.tokens[eos + 1..].iter().all(|&t| t == PAD_ID));
            // all tokens in vocab
            assert!(e.tokens.iter().all(|&t| (0..2048).contains(&t)));
        }
    }

    #[test]
    fn caption_mentions_latent_names() {
        let g = gen();
        let e = g.example(Split::Train, 5);
        // at least one attribute's name span appears verbatim
        let found = (0..e.latent.len()).any(|a| {
            let span = g.space.name_tokens(a, e.latent[a]);
            e.tokens
                .windows(span.len())
                .any(|w| w == span)
        });
        assert!(found, "caption should mention visible attributes");
    }

    #[test]
    fn patches_correlate_with_latent() {
        // mean dot-product of patches with true attribute features should
        // exceed that with random other features
        let g = gen();
        let e = g.example(Split::Train, 9);
        let d = g.space.patch_dim;
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let mut true_score = 0f32;
        let mut alt_score = 0f32;
        for p in 0..g.patches {
            let row = &e.patch_features[p * d..(p + 1) * d];
            for (attr, &v) in e.latent.iter().enumerate() {
                true_score += dot(row, g.space.feature(attr, v));
                let alt = (v + 1) % g.space.attrs[attr].values;
                alt_score += dot(row, g.space.feature(attr, alt));
            }
        }
        assert!(true_score > alt_score, "true {true_score} vs alt {alt_score}");
    }

    #[test]
    fn eval_fraction_is_about_5_percent() {
        let g = gen();
        let mut rng = Rng::new(123);
        let eval = (0..4000)
            .filter(|_| g.split_of(&g.space.sample_latent(&mut rng)) == Split::Eval)
            .count();
        assert!((100..300).contains(&eval), "eval count {eval}");
    }

    #[test]
    fn content_tokens_present() {
        let g = gen();
        let e = g.example(Split::Train, 3);
        assert!(e.tokens.iter().any(|&t| t >= CONTENT_START));
    }
}
