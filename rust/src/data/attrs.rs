//! Latent attribute space for the synthetic multimodal corpus.
//!
//! An "image" is a bag of product attributes (category, color, material,
//! ...). Each attribute value owns a fixed random feature direction (its
//! "visual appearance") and a short token span (its "name"); captions
//! mention attribute values, so a model that reads patch features can
//! predict caption tokens far better than a unimodal LM — the learnable
//! cross-modal signal that stands in for M6-Corpus.

use crate::util::rng::Rng;

/// Number of reserved token ids: PAD=0, BOS=1, EOS=2 — must match
/// `python/compile/config.py`.
pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;
/// Function words occupy [3, FUNC_END); content tokens start there.
pub const FUNC_START: i32 = 3;
pub const FUNC_WORDS: i32 = 61;
pub const CONTENT_START: i32 = FUNC_START + FUNC_WORDS; // 64

#[derive(Debug, Clone)]
pub struct Attribute {
    pub name: &'static str,
    /// token span length per value (1..=3 subwords, like real product terms)
    pub values: usize,
}

/// The fixed attribute schema. Sizes chosen so the number of combinations
/// (~10^7) dwarfs the training budget: the eval split measures
/// generalization, not memorization.
pub fn schema() -> Vec<Attribute> {
    vec![
        Attribute { name: "category", values: 24 },
        Attribute { name: "color", values: 16 },
        Attribute { name: "material", values: 12 },
        Attribute { name: "style", values: 12 },
        Attribute { name: "size", values: 6 },
        Attribute { name: "brand", values: 32 },
    ]
}

/// Deterministic embedding + token-name tables for every attribute value.
pub struct AttributeSpace {
    pub attrs: Vec<Attribute>,
    /// per (attr, value): unit-ish feature direction of length `patch_dim`
    features: Vec<Vec<f32>>,
    /// per (attr, value): 1-3 content-token ids naming the value
    names: Vec<Vec<i32>>,
    offsets: Vec<usize>,
    pub patch_dim: usize,
    pub vocab_size: i32,
}

impl AttributeSpace {
    pub fn new(patch_dim: usize, vocab_size: i32, seed: u64) -> Self {
        let attrs = schema();
        let mut rng = Rng::new(seed).fold_in(0xA77);
        let total: usize = attrs.iter().map(|a| a.values).sum();
        let mut offsets = Vec::with_capacity(attrs.len());
        let mut acc = 0;
        for a in &attrs {
            offsets.push(acc);
            acc += a.values;
        }
        let scale = 1.0 / (patch_dim as f64).sqrt();
        let features = (0..total)
            .map(|_| {
                (0..patch_dim)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect()
            })
            .collect();
        let content_span = vocab_size - CONTENT_START;
        assert!(content_span > 3 * total as i32, "vocab too small for schema");
        let mut names = Vec::with_capacity(total);
        for _ in 0..total {
            let len = 1 + rng.below(3) as usize;
            let toks = (0..len)
                .map(|_| CONTENT_START + rng.below(content_span as u64) as i32)
                .collect();
            names.push(toks);
        }
        Self { attrs, features, names, offsets, patch_dim, vocab_size }
    }

    fn flat(&self, attr: usize, value: usize) -> usize {
        debug_assert!(value < self.attrs[attr].values);
        self.offsets[attr] + value
    }

    /// Visual feature direction of an attribute value.
    pub fn feature(&self, attr: usize, value: usize) -> &[f32] {
        &self.features[self.flat(attr, value)]
    }

    /// Token span naming an attribute value.
    pub fn name_tokens(&self, attr: usize, value: usize) -> &[i32] {
        &self.names[self.flat(attr, value)]
    }

    /// Sample a latent product: one value per attribute.
    pub fn sample_latent(&self, rng: &mut Rng) -> Vec<usize> {
        // Zipf-skewed: common categories/brands dominate, like a real
        // e-commerce corpus — this also produces *naturally imbalanced*
        // token distributions for the routing study.
        self.attrs
            .iter()
            .map(|a| rng.zipf(a.values, 1.1))
            .collect()
    }

    /// Stable 64-bit hash of a latent combination (for the train/eval split).
    pub fn latent_hash(&self, latent: &[usize]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for (i, v) in latent.iter().enumerate() {
            h ^= (*v as u64).wrapping_add((i as u64) << 32).wrapping_add(1);
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> AttributeSpace {
        AttributeSpace::new(32, 2048, 42)
    }

    #[test]
    fn deterministic_tables() {
        let a = space();
        let b = space();
        assert_eq!(a.feature(0, 3), b.feature(0, 3));
        assert_eq!(a.name_tokens(2, 5), b.name_tokens(2, 5));
    }

    #[test]
    fn names_are_content_tokens() {
        let s = space();
        for (ai, a) in s.attrs.iter().enumerate() {
            for v in 0..a.values {
                for &t in s.name_tokens(ai, v) {
                    assert!(t >= CONTENT_START && t < s.vocab_size);
                }
            }
        }
    }

    #[test]
    fn features_roughly_unit() {
        let s = space();
        let f = s.feature(1, 0);
        let norm: f32 = f.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((0.4..2.5).contains(&norm), "norm {norm}");
    }

    #[test]
    fn latents_in_range_and_skewed() {
        let s = space();
        let mut rng = Rng::new(7);
        let mut first_val_hits = 0;
        for _ in 0..2000 {
            let l = s.sample_latent(&mut rng);
            assert_eq!(l.len(), s.attrs.len());
            for (i, v) in l.iter().enumerate() {
                assert!(*v < s.attrs[i].values);
            }
            if l[0] == 0 {
                first_val_hits += 1;
            }
        }
        // zipf: value 0 of a 24-way attribute should be far above uniform 1/24
        assert!(first_val_hits > 2000 / 24 * 2, "hits {first_val_hits}");
    }

    #[test]
    fn hash_distinguishes_latents() {
        let s = space();
        assert_ne!(s.latent_hash(&[0, 0, 0, 0, 0, 0]), s.latent_hash(&[1, 0, 0, 0, 0, 0]));
        assert_eq!(s.latent_hash(&[3, 1, 2, 0, 4, 5]), s.latent_hash(&[3, 1, 2, 0, 4, 5]));
    }
}
