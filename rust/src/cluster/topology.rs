//! Link-level, topology-aware, overlap-capable all-to-all model.
//!
//! The aggregate observed model (a [`StepInputs`] run with measured
//! traffic but no per-layer comm) prices a layer's exchange as *total
//! cross bytes through one NIC*, fully serialized behind compute — a
//! deliberate upper bound. This module refines both halves:
//!
//!  * **Per-link bottleneck.** A [`DispatchPlan`]'s zero-diagonal D x D
//!    `bytes_matrix` maps each ordered worker pair onto a link whose tier
//!    is decided by a [`Topology`] (a workers-per-node grouping): peers on
//!    the same node exchange at `intra_node_bw` / `intra_node_latency`,
//!    peers on different nodes at `net_bw` / `a2a_latency`. Links fan out
//!    concurrently; what serializes is each worker's NIC, so the layer's
//!    exchange completes when the most-loaded worker has drained its
//!    send *and* receive queues ([`layer_bottleneck_seconds`]). On a flat
//!    topology this can never exceed the aggregate model (which pushes
//!    *every* worker's bytes through a single NIC) — the invariant
//!    `rust/tests/topology_model.rs` pins.
//!
//!  * **Compute/dispatch overlap.** [`overlap_outcome`] (run whenever a
//!    [`StepInputs`] carries per-layer comm) reworks the serial step into
//!    a two-resource pipeline: a compute engine (attention + gating +
//!    expert FFN + per-layer framework cost) and a comm engine (each
//!    layer's 4 all-to-all transfers) process layers in order, with layer
//!    ℓ's dispatch overlapping layer ℓ±1's expert compute (overlap depth
//!    1: compute of layer ℓ waits only on comm of layer ℓ-2, the
//!    double-buffering window). The serial schedule is always admissible,
//!    so the overlapped time is clamped to never exceed it —
//!    `overlap_speedup >= 1.0` is structural, not empirical.
//!
//! The `--no-overlap` path is not an approximation of the old model: it
//! *is* the old model ([`OverlapOutcome::serial_ms`] is the total of the
//! very [`StepTime`] the serial simulation produced, bit for bit).
//!
//! [`StepInputs`]: super::StepInputs

use super::{HardwareModel, StepTime};

/// A workers-per-node grouping of D expert-parallel workers. Worker `w`
/// lives on node `w / workers_per_node`; links between same-node workers
/// use the intra-node bandwidth/latency tier, everything else the
/// inter-node (RDMA) tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub workers: usize,
    pub workers_per_node: usize,
}

impl Topology {
    /// `workers_per_node` clamps to at least 1 (1 = flat).
    pub fn new(workers: usize, workers_per_node: usize) -> Self {
        Self { workers: workers.max(1), workers_per_node: workers_per_node.max(1) }
    }

    /// Every worker on its own node: all cross-worker links are
    /// inter-node — the paper's testbed and the pre-PR model's implicit
    /// topology.
    pub fn flat(workers: usize) -> Self {
        Self::new(workers, 1)
    }

    /// `wpn` workers per node; the last node may be smaller when `wpn`
    /// does not divide D.
    pub fn hierarchical(workers: usize, wpn: usize) -> Self {
        Self::new(workers, wpn)
    }

    pub fn is_flat(&self) -> bool {
        self.workers_per_node == 1
    }

    pub fn node_of(&self, worker: usize) -> usize {
        worker / self.workers_per_node
    }

    /// Do `w` and `v` share a node (their link is intra-node)?
    pub fn is_intra(&self, w: usize, v: usize) -> bool {
        self.node_of(w) == self.node_of(v)
    }

    pub fn nodes(&self) -> usize {
        self.workers.div_ceil(self.workers_per_node)
    }

    /// Bench/report label: `flat` or `nodes<wpn>`.
    pub fn name(&self) -> String {
        if self.is_flat() {
            "flat".to_string()
        } else {
            format!("nodes{}", self.workers_per_node)
        }
    }
}

/// One-direction completion time (seconds) of one layer's exchange under
/// the per-link bottleneck model: every worker drains its send and
/// receive queues concurrently, each queue split across the two
/// bandwidth tiers, plus the per-hop handshake latency to each peer
/// (paid whether or not bytes flow, exactly as the aggregate model
/// charges `a2a_latency * (D - 1)` even for an empty exchange). The
/// layer completes when the slowest worker does.
///
/// `link_bytes` is the row-major zero-diagonal D x D matrix of
/// [`DispatchPlan::bytes_matrix`](crate::moe::DispatchPlan::bytes_matrix).
/// D = 1 has no links and costs exactly zero.
pub fn layer_bottleneck_seconds(link_bytes: &[u64], topo: &Topology, hw: &HardwareModel) -> f64 {
    let d = topo.workers;
    assert_eq!(link_bytes.len(), d * d, "link matrix must be D x D");
    if d <= 1 {
        return 0.0;
    }
    let mut worst = 0.0f64;
    for w in 0..d {
        let mut send_inter = 0u64;
        let mut send_intra = 0u64;
        let mut recv_inter = 0u64;
        let mut recv_intra = 0u64;
        let mut latency = 0.0f64;
        for v in 0..d {
            if v == w {
                continue;
            }
            if topo.is_intra(w, v) {
                send_intra += link_bytes[w * d + v];
                recv_intra += link_bytes[v * d + w];
                latency += hw.intra_node_latency;
            } else {
                send_inter += link_bytes[w * d + v];
                recv_inter += link_bytes[v * d + w];
                latency += hw.a2a_latency;
            }
        }
        let send = send_inter as f64 / hw.net_bw + send_intra as f64 / hw.intra_node_bw;
        let recv = recv_inter as f64 / hw.net_bw + recv_intra as f64 / hw.intra_node_bw;
        worst = worst.max(send.max(recv) + latency);
    }
    worst
}

/// The overlap model's verdict on one step: the serial baseline (bitwise
/// the pre-overlap aggregate-serial total), the pipelined time, and the
/// decomposition both are built from.
#[derive(Debug, Clone, Copy)]
pub struct OverlapOutcome {
    /// today's aggregate-serial observed step time — the `--no-overlap`
    /// baseline/oracle, the total of the same serial [`StepTime`] as
    /// before this model existed (bit for bit)
    pub serial_ms: f64,
    /// two-resource pipeline step time; never exceeds `serial_ms`
    pub overlapped_ms: f64,
    /// the serial model's aggregate a2a total (L x 4 transfers through
    /// one NIC)
    pub comm_serial_ms: f64,
    /// the per-link bottleneck comm total (sum over layers of 4 x the
    /// layer's bottleneck time) — what the pipeline tries to hide
    pub comm_link_ms: f64,
    /// overlappable compute total (attention + gating + dispatch einsums
    /// + expert FFN + per-layer framework cost)
    pub compute_ms: f64,
    /// non-overlappable tail (head + dense all-reduce + optimizer +
    /// per-step framework cost)
    pub tail_ms: f64,
    /// fraction of the link-model comm hidden behind compute, in [0, 1]
    /// (1.0 when there is no comm to hide — D = 1, or an all-local step)
    pub overlap_efficiency: f64,
}

impl OverlapOutcome {
    /// Serial / overlapped step time (>= 1.0 by construction) — the
    /// bench's per-row regression field.
    pub fn overlap_speedup(&self) -> f64 {
        if self.overlapped_ms > 0.0 {
            self.serial_ms / self.overlapped_ms
        } else {
            1.0
        }
    }
}

/// Finish time of the layer pipeline: a compute engine runs `c` ms per
/// layer, a comm engine runs `comm_ms[l]` ms per layer, comm of layer ℓ
/// starts after its compute, and compute of layer ℓ waits only on comm
/// of layer ℓ-2 (the double-buffering window that lets layer ℓ's
/// dispatch overlap its neighbors' compute). Both engines are monotone,
/// so the result never exceeds the fully serial `Σc + Σcomm`.
fn pipeline_finish_ms(compute_layer_ms: f64, comm_ms: &[f64]) -> f64 {
    let mut compute_done = 0.0f64;
    let mut comm_done_prev = 0.0f64; // comm engine after layer l-1
    let mut comm_done_prev2 = 0.0f64; // comm engine after layer l-2
    for &m in comm_ms {
        compute_done = compute_done.max(comm_done_prev2) + compute_layer_ms;
        let comm_done = comm_done_prev.max(compute_done) + m;
        comm_done_prev2 = comm_done_prev;
        comm_done_prev = comm_done;
    }
    compute_done.max(comm_done_prev)
}

/// Split the serial step time into the pipeline's three pieces, all in
/// ms: per-layer overlappable compute, the non-overlappable tail, and
/// (implicitly) the a2a the link model reprices.
fn decompose(t: &StepTime, layers: usize, hw: &HardwareModel) -> (f64, f64) {
    let l = layers.max(1) as f64;
    let overlappable = t.attention_ms + t.gating_ms + t.dispatch_combine_ms + t.expert_ms;
    let compute_layer = overlappable / l + hw.framework_layer * 1e3;
    let tail = t.head_ms + t.allreduce_ms + t.optimizer_ms + hw.framework_step * 1e3;
    (compute_layer, tail)
}

/// Overlap-aware repricing of an already-simulated serial step — the
/// pipeline half of a [`StepInputs`](super::StepInputs) run that carries
/// per-layer comm. `per_layer_comm_ms` is each MoE layer's
/// **one-direction** per-link bottleneck time in ms
/// ([`layer_bottleneck_seconds`] x 1e3); the pipeline charges 4 transfers
/// per layer, exactly like the serial model. The serial baseline is the
/// total of the `serial` decomposition handed in (so `--no-overlap`
/// reproduces pre-overlap numbers bitwise), and the overlapped time is
/// clamped to it: the serial schedule is always admissible, so modelling
/// overlap can only help.
pub(crate) fn overlap_outcome(
    serial: &StepTime,
    layers: usize,
    hw: &HardwareModel,
    per_layer_comm_ms: &[f64],
) -> OverlapOutcome {
    assert_eq!(per_layer_comm_ms.len(), layers, "one comm entry per layer");
    let serial_ms = serial.total_ms();
    let (compute_layer, tail_ms) = decompose(serial, layers, hw);
    let compute_ms = compute_layer * layers as f64;

    // one comm-engine job per layer: its 4 transfers at the link-model
    // bottleneck rate (dispatch + combine, forward + backward)
    let mut comm_jobs: Vec<f64> = Vec::with_capacity(per_layer_comm_ms.len());
    let mut comm_link_ms = 0.0f64;
    for &m in per_layer_comm_ms {
        let job = 4.0 * m;
        comm_link_ms += job;
        comm_jobs.push(job);
    }

    let pipelined = pipeline_finish_ms(compute_layer, &comm_jobs) + tail_ms;
    let overlapped_ms = pipelined.min(serial_ms);

    // fraction of link-model comm hidden: the pipeline's win over the
    // fully serialized link schedule, normalized by the comm it had to
    // hide. No comm to hide counts as fully hidden.
    let serial_link_ms = compute_ms + comm_link_ms + tail_ms;
    let overlap_efficiency = if comm_link_ms > 0.0 {
        ((serial_link_ms - overlapped_ms) / comm_link_ms).clamp(0.0, 1.0)
    } else {
        1.0
    };

    OverlapOutcome {
        serial_ms,
        overlapped_ms,
        comm_serial_ms: serial.a2a_ms,
        comm_link_ms,
        compute_ms,
        tail_ms,
        overlap_efficiency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{table2_hardware, ObservedTraffic, StepInputs};
    use crate::config::{paper, CapacityMode, Routing};

    #[test]
    fn topology_grouping() {
        let flat = Topology::flat(8);
        assert!(flat.is_flat());
        assert_eq!(flat.nodes(), 8);
        assert!(!flat.is_intra(0, 1));
        assert_eq!(flat.name(), "flat");

        let hier = Topology::hierarchical(8, 4);
        assert_eq!(hier.nodes(), 2);
        assert!(hier.is_intra(0, 3));
        assert!(!hier.is_intra(3, 4));
        assert_eq!(hier.name(), "nodes4");

        // non-dividing grouping: the last node is smaller, nobody panics
        let ragged = Topology::hierarchical(6, 4);
        assert_eq!(ragged.nodes(), 2);
        assert!(ragged.is_intra(4, 5));
        assert!(!ragged.is_intra(3, 4));

        // zero clamps to flat
        assert!(Topology::new(4, 0).is_flat());
    }

    #[test]
    fn single_worker_has_zero_comm() {
        let hw = HardwareModel::v100();
        let t = Topology::flat(1);
        assert_eq!(layer_bottleneck_seconds(&[0], &t, &hw), 0.0);
    }

    #[test]
    fn flat_bottleneck_matches_nic_and_latency() {
        let hw = HardwareModel::v100();
        let t = Topology::flat(2);
        // worker 0 sends 125 MB to worker 1; nothing comes back
        let bytes = 125_000_000u64;
        let m = [0, bytes, 0, 0];
        let got = layer_bottleneck_seconds(&m, &t, &hw);
        let want = bytes as f64 / hw.net_bw + hw.a2a_latency;
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn hierarchy_never_slower_than_flat() {
        let hw = HardwareModel::v100();
        let d = 8;
        // a dense asymmetric exchange
        let mut m = vec![0u64; d * d];
        for w in 0..d {
            for v in 0..d {
                if w != v {
                    m[w * d + v] = ((w * 7 + v * 13 + 1) * 100_000) as u64;
                }
            }
        }
        let flat = layer_bottleneck_seconds(&m, &Topology::flat(d), &hw);
        let hier = layer_bottleneck_seconds(&m, &Topology::hierarchical(d, 4), &hw);
        assert!(
            hier <= flat,
            "intra-node links are faster, so grouping cannot slow the exchange: {hier} vs {flat}"
        );
    }

    #[test]
    fn pipeline_bounds() {
        // uniform work: the pipeline is bounded below by each engine's
        // total and above by the fully serial schedule
        let comm = vec![2.0; 8];
        let t = pipeline_finish_ms(3.0, &comm);
        assert!(t >= 8.0 * 3.0, "compute-bound floor: {t}");
        assert!(t <= 8.0 * (3.0 + 2.0), "serial ceiling: {t}");
        // comm-bound case
        let comm = vec![10.0; 8];
        let t = pipeline_finish_ms(1.0, &comm);
        assert!(t >= 80.0 && t <= 88.0, "{t}");
        // no layers -> nothing to do
        assert_eq!(pipeline_finish_ms(5.0, &[]), 0.0);
    }

    #[test]
    fn overlapped_never_exceeds_serial_and_speedup_is_at_least_one() {
        let base = paper::base();
        let hw = table2_hardware();
        let obs = ObservedTraffic { a2a_bytes_per_layer: 2.0e6, shard_balance: 1.3 };
        // per-link comm strictly cheaper than the aggregate serial charge
        let comm: Vec<f64> = (0..base.layers).map(|l| 0.01 + l as f64 * 0.001).collect();
        let outcome = StepInputs::new(&base, &hw)
            .routing(Routing::TopK(2))
            .capacity_mode(CapacityMode::Times1)
            .observed(&obs)
            .layer_comm_ms(&comm)
            .run();
        let out = outcome.overlap.expect("comm supplied, pipeline must run");
        assert!(out.overlapped_ms <= out.serial_ms);
        assert!(out.overlap_speedup() >= 1.0);
        assert!((0.0..=1.0).contains(&out.overlap_efficiency));
        assert_eq!(outcome.step_ms().to_bits(), out.overlapped_ms.to_bits());
        // the serial baseline is the unchanged observed model, bit for bit
        let oracle = StepInputs::new(&base, &hw)
            .routing(Routing::TopK(2))
            .capacity_mode(CapacityMode::Times1)
            .observed(&obs)
            .run()
            .serial_ms();
        assert_eq!(out.serial_ms.to_bits(), oracle.to_bits());
    }

    #[test]
    fn zero_comm_counts_as_fully_hidden() {
        let base = paper::base();
        let hw = table2_hardware();
        let obs = ObservedTraffic { a2a_bytes_per_layer: 0.0, shard_balance: 1.0 };
        let comm = vec![0.0; base.layers];
        let out = StepInputs::new(&base, &hw)
            .routing(Routing::TopK(1))
            .capacity_mode(CapacityMode::TimesK)
            .observed(&obs)
            .layer_comm_ms(&comm)
            .run()
            .overlap
            .expect("comm supplied, pipeline must run");
        assert_eq!(out.overlap_efficiency, 1.0);
        assert_eq!(out.comm_link_ms, 0.0);
        assert!(out.overlapped_ms <= out.serial_ms);
    }
}
