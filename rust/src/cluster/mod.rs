//! Cluster simulator: a discrete cost model of the paper's Whale testbed
//! (single-GPU V100-32GB workers on 100 Gb RDMA), standing in for the
//! 8..480-GPU clusters we do not have (DESIGN.md §2).
//!
//! The model reproduces the *mechanisms* that create the paper's systems
//! numbers:
//!  * expert compute scales with capacity C (padding included) — Table 1;
//!  * the top-k router serializes k argmax/cumsum rounds, each paying a
//!    fixed framework dispatch cost, while k top-1 prototyping routes all
//!    prototypes in one parallel round — the Table-2 asymmetry;
//!  * all-to-all dispatch/combine moves O(ECM) bytes per layer per
//!    direction (§A.3), twice more on the backward pass;
//!  * dense (non-expert) gradients are data-parallel all-reduced; expert
//!    gradients stay sharded.
//!
//! One free constant (per-layer framework overhead) is calibrated from a
//! single anchor cell of Table 2 (Base/top-2 = 218.2 ms/step); everything
//! else is predicted. `tests` assert the calibrated model lands within
//! tolerance of the paper's other known cells.

use anyhow::{bail, Result};

use crate::config::{CapacityMode, ModelConfig, Routing};
use crate::flops::forward_flops;

pub mod placement;
pub mod topology;

pub use placement::PlacementStrategy;
pub use topology::{OverlapOutcome, Topology};

/// Hardware + framework constants of one simulated worker.
#[derive(Debug, Clone)]
pub struct HardwareModel {
    /// effective matmul throughput, FLOP/s (V100 mixed precision under TF:
    /// ~30% of the 125 TFLOP/s tensor-core peak)
    pub flops_eff: f64,
    /// HBM bandwidth, bytes/s (V100: 900 GB/s)
    pub mem_bw: f64,
    /// per-worker RDMA bandwidth, bytes/s (100 Gb/s) — the *inter-node*
    /// tier of the link model (`cluster::topology`)
    pub net_bw: f64,
    /// all-to-all per-hop latency, seconds (inter-node tier)
    pub a2a_latency: f64,
    /// per-worker bandwidth between workers on the *same* node, bytes/s
    /// (NVLink/PCIe class — must be >= `net_bw` for the link model's
    /// "hierarchy never slower than flat" invariant to hold)
    pub intra_node_bw: f64,
    /// per-hop latency between same-node workers, seconds (must be <=
    /// `a2a_latency`)
    pub intra_node_latency: f64,
    /// workers grouped per node: 1 = flat (every cross-worker link is
    /// inter-node, the paper's single-GPU-per-host testbed); > 1 enables
    /// the hierarchical intra/inter tiers
    pub workers_per_node: usize,
    /// cost of one serialized routing round (argmax+cumsum+masking kernel
    /// chain dispatch under TF1), seconds
    pub routing_round: f64,
    /// extra cost per additional prototype in the parallel router
    pub proto_overhead: f64,
    /// fixed per-layer framework overhead (einsum/transpose scheduling),
    /// seconds — the calibrated constant
    pub framework_layer: f64,
    /// fixed per-step overhead (session run, input pipeline), seconds
    pub framework_step: f64,
}

impl HardwareModel {
    /// V100-32GB + TF1.15/Whale defaults, pre-calibration. The topology
    /// defaults to flat (`workers_per_node = 1`): the paper's testbed ran
    /// one GPU per host on 100 Gb RDMA, so every cross-worker link is
    /// inter-node and the hierarchical tier is inert until a caller opts
    /// into a grouping ([`HardwareModel::with_workers_per_node`]).
    pub fn v100() -> Self {
        Self {
            flops_eff: 37.5e12,
            mem_bw: 900e9,
            net_bw: 12.5e9,
            a2a_latency: 30e-6,
            intra_node_bw: 60e9,
            intra_node_latency: 3e-6,
            workers_per_node: 1,
            routing_round: 1.5e-3,
            proto_overhead: 0.5e-3,
            framework_layer: 25e-3,
            framework_step: 10e-3,
        }
    }

    /// The same hardware with `wpn` workers grouped per node — the
    /// hierarchical variant the overlap bench sweeps against flat.
    pub fn with_workers_per_node(mut self, wpn: usize) -> Self {
        self.workers_per_node = wpn.max(1);
        self
    }

    /// Calibrate `framework_layer` so that `cfg` under `routing`/`mode`
    /// predicts exactly `target_ms` — one-point anchor calibration.
    ///
    /// Fails when the anchor sits *below* the zero-overhead prediction:
    /// no non-negative framework overhead can fit such a target, which
    /// means the base hardware model over-predicts and "calibrated"
    /// would be a lie. Use [`HardwareModel::calibrated_to`] for the
    /// clamp-and-warn behavior.
    pub fn try_calibrated_to(
        mut self,
        cfg: &ModelConfig,
        routing: Routing,
        mode: CapacityMode,
        target_ms: f64,
    ) -> Result<Self> {
        self.framework_layer = 0.0;
        let base = simulate_step(cfg, routing, mode, &self).total_ms();
        let residual_ms = target_ms - base;
        if residual_ms < 0.0 {
            bail!(
                "calibration anchor {target_ms:.2} ms is below the zero-overhead \
                 prediction {base:.2} ms for {}/{}: the base hardware model \
                 over-predicts this cell and no non-negative framework_layer can fit it",
                cfg.name,
                routing.name()
            );
        }
        self.framework_layer = residual_ms / cfg.layers as f64 / 1e3;
        Ok(self)
    }

    /// Anchor calibration with the historical clamping behavior: an
    /// unreachable (too-cheap) target clamps `framework_layer` to zero —
    /// but no longer silently: the over-prediction is reported on stderr
    /// so a miscalibrated base model cannot hide behind its anchor.
    pub fn calibrated_to(
        self,
        cfg: &ModelConfig,
        routing: Routing,
        mode: CapacityMode,
        target_ms: f64,
    ) -> Self {
        match self.clone().try_calibrated_to(cfg, routing, mode, target_ms) {
            Ok(hw) => hw,
            Err(e) => {
                eprintln!("[cluster] warning: {e:#}; clamping framework_layer to 0");
                let mut hw = self;
                hw.framework_layer = 0.0;
                hw
            }
        }
    }
}

/// Measured expert-parallel traffic from an executed
/// [`DispatchPlan`](crate::moe::DispatchPlan) step — what
/// [`StepInputs::observed`] consumes in place of the analytic O(ECM)
/// all-to-all estimate.
#[derive(Debug, Clone, Copy)]
pub struct ObservedTraffic {
    /// measured all-to-all payload bytes per MoE layer per direction
    pub a2a_bytes_per_layer: f64,
    /// max/mean per-shard token load (>= 1): expert compute runs at the
    /// pace of the most-loaded shard, so imbalance stretches that phase
    pub shard_balance: f64,
}

/// Per-phase timing of one simulated training step (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct StepTime {
    pub attention_ms: f64,
    pub gating_ms: f64,
    pub dispatch_combine_ms: f64,
    pub expert_ms: f64,
    pub a2a_ms: f64,
    pub head_ms: f64,
    pub allreduce_ms: f64,
    pub optimizer_ms: f64,
    pub framework_ms: f64,
}

impl StepTime {
    pub fn total_ms(&self) -> f64 {
        self.attention_ms
            + self.gating_ms
            + self.dispatch_combine_ms
            + self.expert_ms
            + self.a2a_ms
            + self.head_ms
            + self.allreduce_ms
            + self.optimizer_ms
            + self.framework_ms
    }
}

/// The unified inputs of one step simulation — the single entry point
/// behind `m6t simulate`, the sharded runtime's observed pricing, the
/// overlap benches, and serve-sim. It replaces the positional sprawl of
/// the old `simulate_step_observed` / `simulate_step_overlapped` pair:
/// grow the model by adding a field here, and [`StepInputs::run`]'s
/// exhaustive destructure (mirroring [`crate::sweep::config_cell`])
/// makes every un-priced field a compile error instead of a silently
/// widening argument list.
///
/// Builder-style defaults: [`StepInputs::new`] prices the analytic
/// serial model under `cfg`'s own routing/capacity; `.observed(..)`
/// swaps in measured dispatch traffic; `.layer_comm_ms(..)` additionally
/// runs the overlap pipeline ([`topology`]).
#[derive(Debug, Clone, Copy)]
pub struct StepInputs<'a> {
    /// model geometry (its `workers` field is the expert-parallel D)
    pub cfg: &'a ModelConfig,
    /// routing strategy (defaults to `cfg.routing`)
    pub routing: Routing,
    /// capacity mode (defaults to `cfg.capacity_mode`)
    pub capacity_mode: CapacityMode,
    /// worker hardware + framework constants
    pub hw: &'a HardwareModel,
    /// measured dispatch traffic; `None` keeps the analytic O(ECM)
    /// all-to-all estimate and a perfectly balanced exchange
    pub observed: Option<&'a ObservedTraffic>,
    /// each MoE layer's one-direction per-link bottleneck time in ms
    /// ([`topology::layer_bottleneck_seconds`] x 1e3); `Some` runs the
    /// overlap pipeline on top of the serial model
    pub per_layer_comm_ms: Option<&'a [f64]>,
}

impl<'a> StepInputs<'a> {
    /// Analytic serial pricing of `cfg` under its own routing/capacity.
    pub fn new(cfg: &'a ModelConfig, hw: &'a HardwareModel) -> Self {
        Self {
            cfg,
            routing: cfg.routing,
            capacity_mode: cfg.capacity_mode,
            hw,
            observed: None,
            per_layer_comm_ms: None,
        }
    }

    /// Override the routing strategy (calibration sweeps strategies that
    /// differ from `cfg.routing`).
    pub fn routing(mut self, routing: Routing) -> Self {
        self.routing = routing;
        self
    }

    /// Override the capacity mode.
    pub fn capacity_mode(mut self, mode: CapacityMode) -> Self {
        self.capacity_mode = mode;
        self
    }

    /// Price with *measured* dispatch traffic: the observed all-to-all
    /// byte volume replaces the analytic per-layer O(ECM) estimate, and
    /// the observed shard imbalance stretches expert compute (the
    /// most-loaded shard paces the exchange).
    pub fn observed(mut self, observed: &'a ObservedTraffic) -> Self {
        self.observed = Some(observed);
        self
    }

    /// Also run the compute/dispatch overlap pipeline over these
    /// per-layer link-bottleneck comm times.
    pub fn layer_comm_ms(mut self, per_layer_comm_ms: &'a [f64]) -> Self {
        self.per_layer_comm_ms = Some(per_layer_comm_ms);
        self
    }

    /// Run the simulation. The serial decomposition is bitwise the old
    /// `simulate_step_observed` output, and the overlap verdict (when
    /// `per_layer_comm_ms` is set) is bitwise the old
    /// `simulate_step_overlapped` — the determinism pins in
    /// `rust/tests/topology_model.rs` ride through this call.
    pub fn run(&self) -> StepOutcome {
        // exhaustive destructure: a new field that nothing prices is a
        // compile error, not a latent default
        let StepInputs { cfg, routing, capacity_mode, hw, observed, per_layer_comm_ms } = *self;
        let serial = simulate(cfg, routing, capacity_mode, hw, observed);
        let overlap =
            per_layer_comm_ms.map(|comm| topology::overlap_outcome(&serial, cfg.layers, hw, comm));
        StepOutcome { serial, overlap }
    }
}

/// What one [`StepInputs::run`] produced: the per-phase serial
/// decomposition, plus the overlap pipeline's verdict when per-layer
/// comm was supplied.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// per-phase serial step time — the `--no-overlap` baseline/oracle
    pub serial: StepTime,
    /// overlap pipeline verdict; `None` when the inputs carried no
    /// per-layer comm decomposition
    pub overlap: Option<OverlapOutcome>,
}

impl StepOutcome {
    /// Total serial step milliseconds.
    pub fn serial_ms(&self) -> f64 {
        self.serial.total_ms()
    }

    /// The step time this simulation stands behind: overlapped when the
    /// pipeline ran, serial otherwise. Never exceeds [`Self::serial_ms`].
    pub fn step_ms(&self) -> f64 {
        self.overlap.map_or_else(|| self.serial.total_ms(), |o| o.overlapped_ms)
    }
}

/// Simulate one training step of `cfg` with the given routing strategy,
/// using the analytic O(ECM) all-to-all estimate. Thin positional
/// convenience over [`StepInputs`] for the calibration/Table-2 paths
/// that sweep routing strategies against a fixed config.
pub fn simulate_step(
    cfg: &ModelConfig,
    routing: Routing,
    mode: CapacityMode,
    hw: &HardwareModel,
) -> StepTime {
    StepInputs::new(cfg, hw).routing(routing).capacity_mode(mode).run().serial
}

fn simulate(
    cfg: &ModelConfig,
    routing: Routing,
    mode: CapacityMode,
    hw: &HardwareModel,
    observed: Option<&ObservedTraffic>,
) -> StepTime {
    let f = forward_flops(cfg, routing, mode);
    let l = cfg.layers as f64;
    let d = cfg.workers.max(1) as f64;
    // forward + backward ~ 3x forward FLOPs for matmul-dominated graphs
    let fb = 3.0;
    let ms = |flops: f64| flops / hw.flops_eff * 1e3;

    let mut t = StepTime::default();
    t.attention_ms = ms(f.attention) * fb;
    t.expert_ms = ms(f.expert_ffn) * fb;
    if let Some(obs) = observed {
        // imbalanced shards stretch expert compute: everyone waits for
        // the most-loaded shard before the combine all-to-all
        t.expert_ms *= obs.shard_balance.max(1.0);
    }
    t.dispatch_combine_ms = ms(f.dispatch_combine) * fb;
    t.head_ms = ms(f.embed_head) * fb;

    // routing: gate einsum FLOPs + the serialized rounds (fwd only — the
    // backward of argmax/cumsum is folded into the round constant)
    let rounds = routing.rounds() as f64;
    let protos = routing.prototypes() as f64;
    t.gating_ms =
        ms(f.gating) * fb + l * (rounds * hw.routing_round + (protos - 1.0) * hw.proto_overhead) * 1e3;

    // all-to-all: dispatch + combine on forward, their transposes on
    // backward => 4 transfers per MoE layer. With an observed plan the
    // measured payload replaces the analytic O(ECM) buffer volume.
    let a2a_bytes = observed.map_or(f.a2a_bytes_per_layer, |o| o.a2a_bytes_per_layer);
    let a2a_one = a2a_bytes / hw.net_bw + hw.a2a_latency * (d - 1.0).max(0.0);
    t.a2a_ms = l * 4.0 * a2a_one * 1e3;

    // data-parallel all-reduce of dense (non-expert) gradients:
    // ring all-reduce moves 2 x bytes x (D-1)/D
    let dense_params = dense_param_count(cfg) as f64;
    let ar_bytes = 2.0 * dense_params * 4.0 * (d - 1.0) / d.max(1.0);
    t.allreduce_ms = ar_bytes / hw.net_bw * 1e3;

    // optimizer update: memory-bound pass over the worker's parameter shard
    // (experts sharded E/D per worker + full dense replica); AdamW touches
    // p, g, m, v read + p, m, v write ~ 28 bytes/param
    let expert_params = (cfg.param_count() - dense_param_count(cfg)) as f64 / d;
    let shard = dense_params + expert_params;
    let opt_bytes_per_param = if cfg.optimizer == "adafactor" { 12.0 } else { 28.0 };
    t.optimizer_ms = shard * opt_bytes_per_param / hw.mem_bw * 1e3;

    t.framework_ms = (l * hw.framework_layer + hw.framework_step) * 1e3;
    t
}

/// Parameters replicated on every worker (everything but the experts).
pub fn dense_param_count(cfg: &ModelConfig) -> u64 {
    let m = cfg.hidden as u64;
    let h = (cfg.heads * cfg.head_dim) as u64;
    let embed =
        cfg.vocab_size as u64 * m + cfg.patch_dim as u64 * m + cfg.seq_len() as u64 * m;
    let attn = if cfg.moe_attention { 0 } else { 4 * m * h };
    let router = m * cfg.num_experts as u64;
    let ln = 4 * m;
    embed + cfg.layers as u64 * (attn + router + ln) + 2 * m
}

/// The calibrated Table-2 simulator: anchors on Base/top-2 = 218.2 ms.
pub fn table2_hardware() -> HardwareModel {
    let base = crate::config::paper::base();
    HardwareModel::v100().calibrated_to(
        &base,
        Routing::TopK(2),
        CapacityMode::Times1,
        218.2,
    )
}

/// Steps/second at paper scale — drives the Fig-6 wall-clock axis.
pub fn steps_per_second(cfg: &ModelConfig, routing: Routing, mode: CapacityMode) -> f64 {
    let hw = table2_hardware();
    1e3 / simulate_step(cfg, routing, mode, &hw).total_ms()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;

    fn predict(cfg: &ModelConfig, r: Routing) -> f64 {
        let hw = table2_hardware();
        simulate_step(cfg, r, CapacityMode::Times1, &hw).total_ms()
    }

    #[test]
    fn anchor_reproduces_exactly() {
        let ms = predict(&paper::base(), Routing::TopK(2));
        assert!((ms - 218.2).abs() < 0.5, "anchor {ms}");
    }

    #[test]
    fn table2_known_cells_within_tolerance() {
        // paper Table 2 (capacity 1x): Base 2top1=220.1, 4top1=225.3;
        // 10B: top2=493.0, 2top1=466.9, 4top1=473.9
        let base = paper::base();
        let ten = paper::ten_b();
        let cells = [
            (&base, Routing::Prototype(2), 220.1),
            (&base, Routing::Prototype(4), 225.3),
            (&ten, Routing::TopK(2), 493.0),
            (&ten, Routing::Prototype(2), 466.9),
            (&ten, Routing::Prototype(4), 473.9),
        ];
        for (cfg, r, want) in cells {
            let got = predict(cfg, r);
            let rel = (got - want).abs() / want;
            assert!(
                rel < 0.15,
                "{}/{}: predicted {got:.1} vs paper {want} (rel {rel:.2})",
                cfg.name,
                r.name()
            );
        }
    }

    #[test]
    fn topk_slows_with_k_prototyping_does_not() {
        let base = paper::base();
        let t1 = predict(&base, Routing::TopK(1));
        let t2 = predict(&base, Routing::TopK(2));
        let t4 = predict(&base, Routing::TopK(4));
        let p2 = predict(&base, Routing::Prototype(2));
        let p4 = predict(&base, Routing::Prototype(4));
        assert!(t4 > t2 && t2 > t1, "topk must serialize: {t1} {t2} {t4}");
        // the paper's claim: k top-1 stays near top-1 while top-k grows
        assert!((p4 - t1) < (t4 - t1) * 0.5, "p4 {p4} t4 {t4} t1 {t1}");
        assert!(p4 - p2 < t4 - t2, "prototype k-scaling must be flatter");
    }

    #[test]
    fn capacity_kx_costs_more() {
        let base = paper::base();
        let hw = table2_hardware();
        let limited = simulate_step(&base, Routing::TopK(4), CapacityMode::Times1, &hw);
        let full = simulate_step(&base, Routing::TopK(4), CapacityMode::TimesK, &hw);
        assert!(full.total_ms() > limited.total_ms() * 1.2);
        assert!(full.expert_ms > limited.expert_ms * 3.5); // ~4x capacity
    }

    #[test]
    fn one_t_step_time_is_minutes_scale_sane() {
        // 1T on 480 workers: the simulator should produce a finite,
        // plausible step time (paper trained 30k steps in days)
        let ms = predict(&paper::one_t(), Routing::Prototype(2));
        assert!((200.0..60_000.0).contains(&ms), "1T step {ms} ms");
    }

    #[test]
    fn observed_traffic_replaces_analytic_a2a() {
        let base = paper::base();
        let hw = table2_hardware();
        let analytic = simulate_step(&base, Routing::TopK(2), CapacityMode::Times1, &hw);
        // perfectly balanced exchange moving half the analytic volume
        let half = forward_flops(&base, Routing::TopK(2), CapacityMode::Times1)
            .a2a_bytes_per_layer
            / 2.0;
        let obs = ObservedTraffic { a2a_bytes_per_layer: half, shard_balance: 1.0 };
        let observe = |traffic: &ObservedTraffic| {
            StepInputs::new(&base, &hw)
                .routing(Routing::TopK(2))
                .capacity_mode(CapacityMode::Times1)
                .observed(traffic)
                .run()
                .serial
        };
        let observed = observe(&obs);
        assert!(observed.a2a_ms < analytic.a2a_ms, "less traffic must cost less");
        assert_eq!(observed.expert_ms, analytic.expert_ms, "balanced: no straggler stretch");
        // a 2x-imbalanced exchange doubles the expert critical path
        let skewed = ObservedTraffic { a2a_bytes_per_layer: half, shard_balance: 2.0 };
        let stretched = observe(&skewed);
        assert!((stretched.expert_ms - 2.0 * analytic.expert_ms).abs() < 1e-9);
        // zero observed traffic kills the bandwidth term but not latency
        let silent = ObservedTraffic { a2a_bytes_per_layer: 0.0, shard_balance: 1.0 };
        let quiet = observe(&silent);
        assert!(quiet.a2a_ms < analytic.a2a_ms * 0.2, "quiet {}", quiet.a2a_ms);
    }

    #[test]
    fn unreachable_anchor_errors_and_clamps() {
        // pin the satellite fix: a target below the zero-overhead floor
        // must surface as an error from try_calibrated_to, and the
        // clamping path must land exactly at framework_layer == 0
        let base = paper::base();
        let err = HardwareModel::v100()
            .try_calibrated_to(&base, Routing::TopK(2), CapacityMode::Times1, 1.0);
        assert!(err.is_err(), "1 ms anchor cannot be reachable");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("below the zero-overhead prediction"), "{msg}");
        let clamped = HardwareModel::v100()
            .calibrated_to(&base, Routing::TopK(2), CapacityMode::Times1, 1.0);
        assert_eq!(clamped.framework_layer, 0.0);
        // a reachable anchor still calibrates exactly
        let ok = HardwareModel::v100()
            .try_calibrated_to(&base, Routing::TopK(2), CapacityMode::Times1, 218.2)
            .unwrap();
        let got = simulate_step(&base, Routing::TopK(2), CapacityMode::Times1, &ok).total_ms();
        assert!((got - 218.2).abs() < 1e-6);
    }

    #[test]
    fn step_inputs_defaults_mirror_config_and_positional_wrapper() {
        let base = paper::base();
        let hw = table2_hardware();
        let inputs = StepInputs::new(&base, &hw);
        assert_eq!(inputs.routing, base.routing);
        assert_eq!(inputs.capacity_mode, base.capacity_mode);
        assert!(inputs.observed.is_none() && inputs.per_layer_comm_ms.is_none());
        // without per-layer comm there is no overlap verdict, and the
        // step time the outcome stands behind is the serial total
        let out = inputs.run();
        assert!(out.overlap.is_none());
        assert_eq!(out.step_ms().to_bits(), out.serial_ms().to_bits());
        // the positional wrapper is the same simulation, bit for bit
        let wrapped = simulate_step(&base, base.routing, base.capacity_mode, &hw).total_ms();
        assert_eq!(out.serial_ms().to_bits(), wrapped.to_bits());
    }

    #[test]
    fn dense_params_exclude_experts() {
        let base = paper::base();
        let dense = dense_param_count(&base);
        assert!(dense < base.param_count() / 10, "experts dominate: {dense}");
    }
}
