//! Cluster simulator: a discrete cost model of the paper's Whale testbed
//! (single-GPU V100-32GB workers on 100 Gb RDMA), standing in for the
//! 8..480-GPU clusters we do not have (DESIGN.md §2).
//!
//! The model reproduces the *mechanisms* that create the paper's systems
//! numbers:
//!  * expert compute scales with capacity C (padding included) — Table 1;
//!  * the top-k router serializes k argmax/cumsum rounds, each paying a
//!    fixed framework dispatch cost, while k top-1 prototyping routes all
//!    prototypes in one parallel round — the Table-2 asymmetry;
//!  * all-to-all dispatch/combine moves O(ECM) bytes per layer per
//!    direction (§A.3), twice more on the backward pass;
//!  * dense (non-expert) gradients are data-parallel all-reduced; expert
//!    gradients stay sharded.
//!
//! One free constant (per-layer framework overhead) is calibrated from a
//! single anchor cell of Table 2 (Base/top-2 = 218.2 ms/step); everything
//! else is predicted. `tests` assert the calibrated model lands within
//! tolerance of the paper's other known cells.

use crate::config::{CapacityMode, ModelConfig, Routing};
use crate::flops::forward_flops;

/// Hardware + framework constants of one simulated worker.
#[derive(Debug, Clone)]
pub struct HardwareModel {
    /// effective matmul throughput, FLOP/s (V100 mixed precision under TF:
    /// ~30% of the 125 TFLOP/s tensor-core peak)
    pub flops_eff: f64,
    /// HBM bandwidth, bytes/s (V100: 900 GB/s)
    pub mem_bw: f64,
    /// per-worker RDMA bandwidth, bytes/s (100 Gb/s)
    pub net_bw: f64,
    /// all-to-all per-hop latency, seconds
    pub a2a_latency: f64,
    /// cost of one serialized routing round (argmax+cumsum+masking kernel
    /// chain dispatch under TF1), seconds
    pub routing_round: f64,
    /// extra cost per additional prototype in the parallel router
    pub proto_overhead: f64,
    /// fixed per-layer framework overhead (einsum/transpose scheduling),
    /// seconds — the calibrated constant
    pub framework_layer: f64,
    /// fixed per-step overhead (session run, input pipeline), seconds
    pub framework_step: f64,
}

impl HardwareModel {
    /// V100-32GB + TF1.15/Whale defaults, pre-calibration.
    pub fn v100() -> Self {
        Self {
            flops_eff: 37.5e12,
            mem_bw: 900e9,
            net_bw: 12.5e9,
            a2a_latency: 30e-6,
            routing_round: 1.5e-3,
            proto_overhead: 0.5e-3,
            framework_layer: 25e-3,
            framework_step: 10e-3,
        }
    }

    /// Calibrate `framework_layer` so that `cfg` under `routing`/`mode`
    /// predicts exactly `target_ms` — one-point anchor calibration.
    pub fn calibrated_to(
        mut self,
        cfg: &ModelConfig,
        routing: Routing,
        mode: CapacityMode,
        target_ms: f64,
    ) -> Self {
        self.framework_layer = 0.0;
        let base = simulate_step(cfg, routing, mode, &self).total_ms();
        let residual_ms = target_ms - base;
        self.framework_layer = (residual_ms / cfg.layers as f64 / 1e3).max(0.0);
        self
    }
}

/// Per-phase timing of one simulated training step (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct StepTime {
    pub attention_ms: f64,
    pub gating_ms: f64,
    pub dispatch_combine_ms: f64,
    pub expert_ms: f64,
    pub a2a_ms: f64,
    pub head_ms: f64,
    pub allreduce_ms: f64,
    pub optimizer_ms: f64,
    pub framework_ms: f64,
}

impl StepTime {
    pub fn total_ms(&self) -> f64 {
        self.attention_ms
            + self.gating_ms
            + self.dispatch_combine_ms
            + self.expert_ms
            + self.a2a_ms
            + self.head_ms
            + self.allreduce_ms
            + self.optimizer_ms
            + self.framework_ms
    }
}

/// Simulate one training step of `cfg` with the given routing strategy.
pub fn simulate_step(
    cfg: &ModelConfig,
    routing: Routing,
    mode: CapacityMode,
    hw: &HardwareModel,
) -> StepTime {
    let f = forward_flops(cfg, routing, mode);
    let l = cfg.layers as f64;
    let d = cfg.workers.max(1) as f64;
    // forward + backward ~ 3x forward FLOPs for matmul-dominated graphs
    let fb = 3.0;
    let ms = |flops: f64| flops / hw.flops_eff * 1e3;

    let mut t = StepTime::default();
    t.attention_ms = ms(f.attention) * fb;
    t.expert_ms = ms(f.expert_ffn) * fb;
    t.dispatch_combine_ms = ms(f.dispatch_combine) * fb;
    t.head_ms = ms(f.embed_head) * fb;

    // routing: gate einsum FLOPs + the serialized rounds (fwd only — the
    // backward of argmax/cumsum is folded into the round constant)
    let rounds = routing.rounds() as f64;
    let protos = routing.prototypes() as f64;
    t.gating_ms =
        ms(f.gating) * fb + l * (rounds * hw.routing_round + (protos - 1.0) * hw.proto_overhead) * 1e3;

    // all-to-all: dispatch + combine on forward, their transposes on
    // backward => 4 transfers per MoE layer
    let a2a_one = f.a2a_bytes_per_layer / hw.net_bw + hw.a2a_latency * (d - 1.0).max(0.0);
    t.a2a_ms = l * 4.0 * a2a_one * 1e3;

    // data-parallel all-reduce of dense (non-expert) gradients:
    // ring all-reduce moves 2 x bytes x (D-1)/D
    let dense_params = dense_param_count(cfg) as f64;
    let ar_bytes = 2.0 * dense_params * 4.0 * (d - 1.0) / d.max(1.0);
    t.allreduce_ms = ar_bytes / hw.net_bw * 1e3;

    // optimizer update: memory-bound pass over the worker's parameter shard
    // (experts sharded E/D per worker + full dense replica); AdamW touches
    // p, g, m, v read + p, m, v write ~ 28 bytes/param
    let expert_params = (cfg.param_count() - dense_param_count(cfg)) as f64 / d;
    let shard = dense_params + expert_params;
    let opt_bytes_per_param = if cfg.optimizer == "adafactor" { 12.0 } else { 28.0 };
    t.optimizer_ms = shard * opt_bytes_per_param / hw.mem_bw * 1e3;

    t.framework_ms = (l * hw.framework_layer + hw.framework_step) * 1e3;
    t
}

/// Parameters replicated on every worker (everything but the experts).
pub fn dense_param_count(cfg: &ModelConfig) -> u64 {
    let m = cfg.hidden as u64;
    let h = (cfg.heads * cfg.head_dim) as u64;
    let embed =
        cfg.vocab_size as u64 * m + cfg.patch_dim as u64 * m + cfg.seq_len() as u64 * m;
    let attn = if cfg.moe_attention { 0 } else { 4 * m * h };
    let router = m * cfg.num_experts as u64;
    let ln = 4 * m;
    embed + cfg.layers as u64 * (attn + router + ln) + 2 * m
}

/// The calibrated Table-2 simulator: anchors on Base/top-2 = 218.2 ms.
pub fn table2_hardware() -> HardwareModel {
    let base = crate::config::paper::base();
    HardwareModel::v100().calibrated_to(
        &base,
        Routing::TopK(2),
        CapacityMode::Times1,
        218.2,
    )
}

/// Steps/second at paper scale — drives the Fig-6 wall-clock axis.
pub fn steps_per_second(cfg: &ModelConfig, routing: Routing, mode: CapacityMode) -> f64 {
    let hw = table2_hardware();
    1e3 / simulate_step(cfg, routing, mode, &hw).total_ms()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;

    fn predict(cfg: &ModelConfig, r: Routing) -> f64 {
        let hw = table2_hardware();
        simulate_step(cfg, r, CapacityMode::Times1, &hw).total_ms()
    }

    #[test]
    fn anchor_reproduces_exactly() {
        let ms = predict(&paper::base(), Routing::TopK(2));
        assert!((ms - 218.2).abs() < 0.5, "anchor {ms}");
    }

    #[test]
    fn table2_known_cells_within_tolerance() {
        // paper Table 2 (capacity 1x): Base 2top1=220.1, 4top1=225.3;
        // 10B: top2=493.0, 2top1=466.9, 4top1=473.9
        let base = paper::base();
        let ten = paper::ten_b();
        let cells = [
            (&base, Routing::Prototype(2), 220.1),
            (&base, Routing::Prototype(4), 225.3),
            (&ten, Routing::TopK(2), 493.0),
            (&ten, Routing::Prototype(2), 466.9),
            (&ten, Routing::Prototype(4), 473.9),
        ];
        for (cfg, r, want) in cells {
            let got = predict(cfg, r);
            let rel = (got - want).abs() / want;
            assert!(
                rel < 0.15,
                "{}/{}: predicted {got:.1} vs paper {want} (rel {rel:.2})",
                cfg.name,
                r.name()
            );
        }
    }

    #[test]
    fn topk_slows_with_k_prototyping_does_not() {
        let base = paper::base();
        let t1 = predict(&base, Routing::TopK(1));
        let t2 = predict(&base, Routing::TopK(2));
        let t4 = predict(&base, Routing::TopK(4));
        let p2 = predict(&base, Routing::Prototype(2));
        let p4 = predict(&base, Routing::Prototype(4));
        assert!(t4 > t2 && t2 > t1, "topk must serialize: {t1} {t2} {t4}");
        // the paper's claim: k top-1 stays near top-1 while top-k grows
        assert!((p4 - t1) < (t4 - t1) * 0.5, "p4 {p4} t4 {t4} t1 {t1}");
        assert!(p4 - p2 < t4 - t2, "prototype k-scaling must be flatter");
    }

    #[test]
    fn capacity_kx_costs_more() {
        let base = paper::base();
        let hw = table2_hardware();
        let limited = simulate_step(&base, Routing::TopK(4), CapacityMode::Times1, &hw);
        let full = simulate_step(&base, Routing::TopK(4), CapacityMode::TimesK, &hw);
        assert!(full.total_ms() > limited.total_ms() * 1.2);
        assert!(full.expert_ms > limited.expert_ms * 3.5); // ~4x capacity
    }

    #[test]
    fn one_t_step_time_is_minutes_scale_sane() {
        // 1T on 480 workers: the simulator should produce a finite,
        // plausible step time (paper trained 30k steps in days)
        let ms = predict(&paper::one_t(), Routing::Prototype(2));
        assert!((200.0..60_000.0).contains(&ms), "1T step {ms} ms");
    }

    #[test]
    fn dense_params_exclude_experts() {
        let base = paper::base();
        let dense = dense_param_count(&base);
        assert!(dense < base.param_count() / 10, "experts dominate: {dense}");
    }
}
