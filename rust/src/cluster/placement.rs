//! Topology-aware expert-shard placement: which worker hosts which shard.
//!
//! The sharded runtime maps expert shard `s` to worker `s` (identity) and
//! the link model (`topology::layer_bottleneck_seconds`) prices the
//! resulting D x D byte matrix over intra-/inter-node tiers. But the
//! measured traffic is *not* uniform — the persistent router bias makes
//! some shards chatty — so the identity mapping routinely puts a hot
//! shard's heaviest senders on the slow inter-node tier. This module
//! searches the shard→worker permutation for one that co-locates chatty
//! (worker, shard) pairs inside a node and shrinks the bottleneck link.
//!
//! **Input.** The *full* (worker, shard) kept-byte matrix (diagonal
//! included — [`DispatchPlan::add_full_bytes_matrix_into`]): under a
//! permutation, today's local traffic becomes a network flow unless the
//! shard stays co-resident, so the zero-diagonal matrix the runtime
//! prices with is not sufficient to evaluate a candidate.
//!
//! **Search.** A greedy seed (shards in descending traffic order, each
//! assigned to the free worker minimizing the partial bottleneck cost)
//! refined by local pairwise swaps. A candidate is accepted only when it
//! *dominates* the incumbent — bottleneck seconds and max-link bytes
//! both no worse, at least one strictly better — and the final answer is
//! checked against the identity assignment the same way. Two structural
//! consequences the benches' CI floors lean on: the returned placement's
//! cost never exceeds identity's (`placement_gain >= 1.0`), and its
//! bottleneck-link share never exceeds identity's. Ties break on the
//! lowest index everywhere and the search is single-threaded, so the
//! result is a deterministic pure function of its inputs (pool size
//! cannot matter — pinned by `placement_is_deterministic_across_pool_sizes`).

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use super::topology::{layer_bottleneck_seconds, Topology};
use super::HardwareModel;

/// Which placement the runtime applies to the measured traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Shard `s` on worker `s` — the static layout, kept as the oracle.
    Identity,
    /// Greedy seed only (descending-traffic first-fit by partial cost).
    Greedy,
    /// Greedy seed refined by local pairwise swaps — the full search.
    Swap,
}

impl PlacementStrategy {
    pub fn parse(s: &str) -> Result<PlacementStrategy> {
        match s {
            "identity" => Ok(PlacementStrategy::Identity),
            "greedy" => Ok(PlacementStrategy::Greedy),
            "swap" => Ok(PlacementStrategy::Swap),
            other => bail!("unknown placement strategy {other:?} (identity|greedy|swap)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlacementStrategy::Identity => "identity",
            PlacementStrategy::Greedy => "greedy",
            PlacementStrategy::Swap => "swap",
        }
    }
}

/// The identity assignment: shard `s` hosted by worker `s`.
pub fn identity(d: usize) -> Vec<usize> {
    (0..d).collect()
}

/// Zero-diagonal link bytes of `full` under `assign`, into `out` (D x D).
fn permute_into(full: &[u64], assign: &[usize], out: &mut [u64]) {
    let d = assign.len();
    out.fill(0);
    for w in 0..d {
        for s in 0..d {
            let v = assign[s];
            if v != w {
                out[w * d + v] += full[w * d + s];
            }
        }
    }
}

/// (bottleneck seconds, max single-link bytes) of `full` under `assign` —
/// the two objectives the dominance rule compares.
pub fn assignment_cost(
    full: &[u64],
    assign: &[usize],
    topo: &Topology,
    hw: &HardwareModel,
) -> (f64, u64) {
    let d = assign.len();
    assert_eq!(full.len(), d * d, "full byte matrix must be D x D");
    let mut link = vec![0u64; d * d];
    permute_into(full, assign, &mut link);
    let cost = layer_bottleneck_seconds(&link, topo, hw);
    let max_bytes = link.iter().copied().max().unwrap_or(0);
    (cost, max_bytes)
}

/// Candidate (a) dominates incumbent (b): no worse on either objective,
/// strictly better on at least one.
fn dominates(a: (f64, u64), b: (f64, u64)) -> bool {
    let le = a.0 <= b.0 && a.1 <= b.1;
    le && (a.0 < b.0 || a.1 < b.1)
}

/// Greedy seed: shards in descending received-byte order, each placed on
/// the free worker minimizing the bottleneck cost of the partial layout
/// (ties: lowest worker index). Under uniform traffic every choice ties,
/// so the lowest-index rule reproduces the identity assignment exactly.
fn greedy_seed(full: &[u64], d: usize, topo: &Topology, hw: &HardwareModel) -> Vec<usize> {
    // shard order: descending total received bytes (column sums), tie on
    // the lower shard index
    let mut order: Vec<usize> = (0..d).collect();
    let col = |s: usize| -> u64 { (0..d).map(|w| full[w * d + s]).sum() };
    order.sort_by(|&a, &b| col(b).cmp(&col(a)).then(a.cmp(&b)));

    let mut assign = vec![usize::MAX; d];
    let mut taken = vec![false; d];
    let mut partial = vec![0u64; d * d];
    let mut link = vec![0u64; d * d];
    for &s in &order {
        let mut best_worker = usize::MAX;
        let mut best_cost = f64::INFINITY;
        for v in 0..d {
            if taken[v] {
                continue;
            }
            // partial layout cost with shard s on worker v: bytes from
            // every source toward the already-placed shards plus s
            link.copy_from_slice(&partial);
            for w in 0..d {
                if v != w {
                    link[w * d + v] += full[w * d + s];
                }
            }
            let cost = layer_bottleneck_seconds(&link, topo, hw);
            if cost < best_cost {
                best_cost = cost;
                best_worker = v;
            }
        }
        let v = best_worker;
        assign[s] = v;
        taken[v] = true;
        for w in 0..d {
            if v != w {
                partial[w * d + v] += full[w * d + s];
            }
        }
    }
    assign
}

/// Search the shard→worker permutation for `strategy` over the full
/// (diagonal-included) step byte matrix. Always returns a bijection on
/// `0..D`; never returns an assignment that fails to dominate-or-equal
/// the identity layout on (bottleneck seconds, max-link bytes).
pub fn search(
    full: &[u64],
    d: usize,
    topo: &Topology,
    hw: &HardwareModel,
    strategy: PlacementStrategy,
) -> Vec<usize> {
    assert_eq!(full.len(), d * d, "full byte matrix must be D x D");
    let id = identity(d);
    if strategy == PlacementStrategy::Identity || d <= 1 {
        return id;
    }
    let id_cost = assignment_cost(full, &id, topo, hw);

    let mut best = greedy_seed(full, d, topo, hw);
    let mut best_cost = assignment_cost(full, &best, topo, hw);
    // the greedy seed optimizes cost only: fall back to identity before
    // swapping unless it already dominates on both objectives
    if !dominates(best_cost, id_cost) {
        best = id.clone();
        best_cost = id_cost;
    }

    if strategy == PlacementStrategy::Swap {
        // local pairwise swaps to a dominance-local optimum; each
        // accepted swap strictly improves an objective without hurting
        // the other, so the loop terminates (and cost is monotone
        // non-increasing — the property test's invariant)
        let max_passes = d * d;
        for _ in 0..max_passes {
            let mut improved = false;
            for i in 0..d {
                for j in (i + 1)..d {
                    best.swap(i, j);
                    let cost = assignment_cost(full, &best, topo, hw);
                    if dominates(cost, best_cost) {
                        best_cost = cost;
                        improved = true;
                    } else {
                        best.swap(i, j);
                    }
                }
            }
            if !improved {
                break;
            }
        }
    }

    if dominates(best_cost, id_cost) {
        best
    } else {
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::table2_hardware;
    use crate::util::rng::Rng;

    fn nodes4(d: usize) -> (Topology, HardwareModel) {
        let mut hw = table2_hardware();
        hw.workers_per_node = 4;
        (Topology::new(d, 4), hw)
    }

    fn random_full(d: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..d * d).map(|_| (rng.uniform() * 1e6) as u64).collect()
    }

    fn assert_bijection(assign: &[usize], d: usize) {
        let mut seen = vec![false; d];
        for &v in assign {
            assert!(v < d, "worker index in range");
            assert!(!seen[v], "worker {v} hosts two shards");
            seen[v] = true;
        }
    }

    #[test]
    fn permutation_is_always_a_bijection() {
        for d in [1usize, 2, 4, 8] {
            let (topo, hw) = nodes4(d);
            for seed in 0..8u64 {
                let full = random_full(d, 0xBEEF ^ seed);
                for strategy in
                    [PlacementStrategy::Identity, PlacementStrategy::Greedy, PlacementStrategy::Swap]
                {
                    let assign = search(&full, d, &topo, &hw, strategy);
                    assert_eq!(assign.len(), d);
                    assert_bijection(&assign, d);
                }
            }
        }
    }

    #[test]
    fn identity_is_a_fixed_point_under_uniform_traffic() {
        // every (worker, shard) cell equal: all layouts cost the same, so
        // nothing dominates identity and the lowest-index tie-breaks keep
        // the greedy seed at identity too
        for d in [2usize, 4, 8] {
            let (topo, hw) = nodes4(d);
            let full = vec![1_000_000u64; d * d];
            for strategy in [PlacementStrategy::Greedy, PlacementStrategy::Swap] {
                let assign = search(&full, d, &topo, &hw, strategy);
                assert_eq!(assign, identity(d), "D={d} {}", strategy.name());
            }
        }
    }

    #[test]
    fn swap_never_increases_cost_or_bottleneck_bytes() {
        // the dominance acceptance makes both objectives monotone
        // non-increasing relative to identity AND relative to the seed
        for d in [4usize, 8] {
            let (topo, hw) = nodes4(d);
            for seed in 0..16u64 {
                let full = random_full(d, 0xA11CE ^ (seed << 3));
                let id_cost = assignment_cost(&full, &identity(d), &topo, &hw);
                let swapped = search(&full, d, &topo, &hw, PlacementStrategy::Swap);
                let sw_cost = assignment_cost(&full, &swapped, &topo, &hw);
                assert!(sw_cost.0 <= id_cost.0, "cost exceeded identity (D={d}, seed {seed})");
                assert!(sw_cost.1 <= id_cost.1, "bytes exceeded identity (D={d}, seed {seed})");
                let greedy = search(&full, d, &topo, &hw, PlacementStrategy::Greedy);
                let gr_cost = assignment_cost(&full, &greedy, &topo, &hw);
                assert!(sw_cost.0 <= gr_cost.0, "swap must refine its own seed");
                assert!(gr_cost.0 <= id_cost.0, "greedy result never beats-then-loses identity");
                assert!(gr_cost.1 <= id_cost.1);
            }
        }
    }

    #[test]
    fn search_finds_a_strict_gain_on_skewed_traffic() {
        // a concentrated flow: worker 0 sends heavily to shard 7 hosted
        // across the node boundary under identity; the search must
        // co-locate them (or better) and strictly cut the bottleneck
        let d = 8;
        let (topo, hw) = nodes4(d);
        let mut full = vec![10_000u64; d * d];
        full[7] = 5_000_000; // worker 0 -> shard 7
        let id_cost = assignment_cost(&full, &identity(d), &topo, &hw);
        let assign = search(&full, d, &topo, &hw, PlacementStrategy::Swap);
        let cost = assignment_cost(&full, &assign, &topo, &hw);
        assert!(cost.0 < id_cost.0, "bottleneck seconds must strictly drop");
        assert!(cost.1 < id_cost.1, "max-link bytes must strictly drop");
    }

    #[test]
    fn search_is_deterministic() {
        let d = 8;
        let (topo, hw) = nodes4(d);
        let full = random_full(d, 42);
        for strategy in [PlacementStrategy::Greedy, PlacementStrategy::Swap] {
            let a = search(&full, d, &topo, &hw, strategy);
            let b = search(&full, d, &topo, &hw, strategy);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [PlacementStrategy::Identity, PlacementStrategy::Greedy, PlacementStrategy::Swap]
        {
            assert_eq!(PlacementStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(PlacementStrategy::parse("random").is_err());
    }
}
