//! Experiment configuration: the rust-side mirror of
//! `python/compile/config.py` plus the *paper-scale* presets (Table 5) used
//! by the analytical FLOPs model and the cluster simulator.
//!
//! Two kinds of configs coexist:
//!  * **runnable variants** — loaded from `artifacts/manifest.json`; their
//!    geometry comes from the python registry that lowered the HLO.
//!  * **paper presets** — base/10B/100B/1T at the paper's true scale; never
//!    executed, only analyzed (Tables 1-2, Fig 6).

use crate::util::json::Value;

/// Routing strategy (paper §3.2/§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Routing {
    /// GShard-style top-k over all experts: k sequential argmax rounds.
    TopK(u32),
    /// k top-1 expert prototyping: k parallel routers over E/k experts each.
    Prototype(u32),
}

impl Routing {
    /// Activated experts per token.
    pub fn k(&self) -> u32 {
        match self {
            Routing::TopK(k) | Routing::Prototype(k) => *k,
        }
    }
    /// Sequential argmax rounds (the paper's efficiency problem, Table 2).
    pub fn rounds(&self) -> u32 {
        match self {
            Routing::TopK(k) => *k,
            Routing::Prototype(_) => 1,
        }
    }
    /// Parallel routers.
    pub fn prototypes(&self) -> u32 {
        match self {
            Routing::TopK(_) => 1,
            Routing::Prototype(k) => *k,
        }
    }
    pub fn name(&self) -> String {
        match self {
            Routing::TopK(k) => format!("top{k}"),
            Routing::Prototype(k) => format!("{k}top1"),
        }
    }
    pub fn parse(s: &str) -> Option<Routing> {
        if let Some(k) = s.strip_prefix("top") {
            return k.parse().ok().map(Routing::TopK);
        }
        if let Some(k) = s.strip_suffix("top1") {
            return k.parse().ok().map(Routing::Prototype);
        }
        None
    }
}

/// What the step actually computes (see DESIGN.md §Native expert compute).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// Real per-expert FFN GEMMs + optimizer updates on the dispatched
    /// tokens — the default for the small `-real` registry twins.
    Real,
    /// PowerLaw loss + calibrated cluster latency model — still the only
    /// way to price D=480-GPU scenarios on one box.
    Simulated,
}

impl ComputeMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "real" => Some(ComputeMode::Real),
            "sim" | "simulated" => Some(ComputeMode::Simulated),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ComputeMode::Real => "real",
            ComputeMode::Simulated => "sim",
        }
    }
}

/// Capacity policy: the paper's "Capacity kx" vs "Capacity 1x" (Table 1/3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapacityMode {
    TimesK,
    Times1,
}

impl CapacityMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "k" => Some(CapacityMode::TimesK),
            "1" => Some(CapacityMode::Times1),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            CapacityMode::TimesK => "kx",
            CapacityMode::Times1 => "1x",
        }
    }
}

/// Full model/experiment geometry. Field names follow the paper's notation
/// table (§A.3): M hidden, I intermediate, E experts, C capacity, T tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub hidden: usize,       // M
    pub intermediate: usize, // I
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub patch_dim: usize,
    pub num_experts: usize, // E
    pub routing: Routing,
    pub capacity_factor: f64, // gamma
    pub capacity_mode: CapacityMode,
    pub aux_loss_coef: f64,
    pub moe_attention: bool,
    pub attn_num_experts: usize,
    pub batch: usize,   // B per worker
    pub patches: usize, // P
    pub text_len: usize,
    pub optimizer: String,
    pub lr: f64,
    pub warmup: usize,
    pub init_std: f64,
    /// decoupled weight decay (python `ModelConfig.weight_decay`).
    pub weight_decay: f64,
    /// what the native step executes: real expert compute or the
    /// simulated loss/latency models.
    pub compute: ComputeMode,
    /// number of workers the paper ran this row on (Table 5); used only by
    /// the cluster simulator.
    pub workers: usize,
}

impl ModelConfig {
    pub fn seq_len(&self) -> usize {
        self.patches + self.text_len
    }
    /// Tokens per worker per step (T in Eq. 2).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len()
    }
    /// Eq. 2 evaluated once: `C = ceil(k_eff · T / E · γ)`, floored at one
    /// slot. The single static baseline every capacity consumer shares —
    /// the two public entry points below both route through here, and the
    /// elastic controller (`moe::capacity`) diverges from exactly this
    /// value, so the formula can no longer drift between call sites.
    fn eq2_capacity(&self, routing: Routing, mode: CapacityMode) -> usize {
        let k_eff = match mode {
            CapacityMode::TimesK => routing.k() as f64,
            CapacityMode::Times1 => 1.0,
        };
        let c = k_eff * self.tokens_per_batch() as f64 / self.num_experts as f64
            * self.capacity_factor;
        (c.ceil() as usize).max(1)
    }
    /// Per-expert capacity C (Eq. 2) under the configured policy.
    pub fn capacity(&self) -> usize {
        self.eq2_capacity(self.routing, self.capacity_mode)
    }
    /// Capacity with an explicit override of routing/capacity-mode — used by
    /// the FLOPs/simulator sweeps so one preset covers all five strategies.
    pub fn capacity_for(&self, routing: Routing, mode: CapacityMode) -> usize {
        self.eq2_capacity(routing, mode)
    }
    /// Exact parameter count — mirrors `ModelConfig.param_count()` in python
    /// (asserted equal in the integration tests via the manifest).
    pub fn param_count(&self) -> u64 {
        let m = self.hidden as u64;
        let i = self.intermediate as u64;
        let e = self.num_experts as u64;
        let h = (self.heads * self.head_dim) as u64;
        let embed = self.vocab_size as u64 * m
            + self.patch_dim as u64 * m
            + self.seq_len() as u64 * m;
        let attn = if self.moe_attention {
            let ea = self.attn_num_experts as u64;
            4 * ea * m * h + 4 * m * ea
        } else {
            4 * m * h
        };
        let moe_ffn = e * (m * i + i * m) + m * e;
        let ln = 2 * 2 * m;
        let per_layer = attn + moe_ffn + ln;
        embed + self.layers as u64 * per_layer + 2 * m
    }

    /// Parse the `config` object embedded in the artifact manifest.
    pub fn from_manifest(v: &Value) -> anyhow::Result<ModelConfig> {
        let g = |k: &str| -> anyhow::Result<&Value> {
            v.get(k).ok_or_else(|| anyhow::anyhow!("manifest config missing {k:?}"))
        };
        let routing = g("routing")?;
        let kind = routing
            .get("kind")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow::anyhow!("bad routing.kind"))?;
        let k = routing.get("k").and_then(|x| x.as_i64()).unwrap_or(1) as u32;
        let routing = match kind {
            "topk" => Routing::TopK(k),
            "prototype" => Routing::Prototype(k),
            other => anyhow::bail!("unknown routing kind {other:?}"),
        };
        let cap_mode = match g("capacity_mode")?.as_str() {
            Some("k") => CapacityMode::TimesK,
            Some("1") => CapacityMode::Times1,
            other => anyhow::bail!("unknown capacity mode {other:?}"),
        };
        let usize_of = |k: &str| -> anyhow::Result<usize> {
            g(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("{k} not a usize"))
        };
        let f64_of = |k: &str| -> anyhow::Result<f64> {
            g(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("{k} not a number"))
        };
        Ok(ModelConfig {
            name: g("name")?.as_str().unwrap_or("?").to_string(),
            vocab_size: usize_of("vocab_size")?,
            hidden: usize_of("hidden")?,
            intermediate: usize_of("intermediate")?,
            layers: usize_of("layers")?,
            heads: usize_of("heads")?,
            head_dim: usize_of("head_dim")?,
            patch_dim: usize_of("patch_dim")?,
            num_experts: usize_of("num_experts")?,
            routing,
            capacity_factor: f64_of("capacity_factor")?,
            capacity_mode: cap_mode,
            aux_loss_coef: f64_of("aux_loss_coef")?,
            moe_attention: g("moe_attention")?.as_bool().unwrap_or(false),
            attn_num_experts: usize_of("attn_num_experts")?,
            batch: usize_of("batch")?,
            patches: usize_of("patches")?,
            text_len: usize_of("text_len")?,
            optimizer: g("optimizer")?.as_str().unwrap_or("adamw").to_string(),
            lr: f64_of("lr")?,
            warmup: usize_of("warmup")?,
            init_std: f64_of("init_std")?,
            // optional keys: older manifests predate them (python default
            // weight_decay is 0.01; lowered HLO variants are simulated-free
            // real compute on device, so the native mode tag is advisory)
            weight_decay: v.get("weight_decay").and_then(|x| x.as_f64()).unwrap_or(0.01),
            compute: v
                .get("compute")
                .and_then(|x| x.as_str())
                .and_then(ComputeMode::parse)
                .unwrap_or(ComputeMode::Simulated),
            workers: 1,
        })
    }
}

/// Paper-scale presets from Table 5. These drive Tables 1-2 and Fig 6.
pub mod paper {
    use super::*;

    fn common(name: &str) -> ModelConfig {
        ModelConfig {
            name: name.to_string(),
            vocab_size: 21128, // BERT-Chinese vocab (§A.2)
            hidden: 1024,
            intermediate: 4096,
            layers: 5,
            heads: 16,
            head_dim: 64,
            patch_dim: 2048, // ResNet feature width stand-in
            num_experts: 32,
            routing: Routing::TopK(1),
            capacity_factor: 1.25,
            capacity_mode: CapacityMode::TimesK,
            aux_loss_coef: 0.0,
            moe_attention: false,
            attn_num_experts: 8,
            batch: 8,     // per GPU (§A.2)
            patches: 16,  // 4x4 patches (§A.1)
            text_len: 112, // text shorter than 128 words (§A.1)
            optimizer: "adamw".into(),
            lr: 8e-5,
            warmup: 500,
            init_std: 0.02,
            weight_decay: 0.01,
            compute: ComputeMode::Simulated,
            workers: 8,
        }
    }

    /// "Base": 1.4B params, 8 GPUs.
    pub fn base() -> ModelConfig {
        common("base")
    }

    /// "10B": 10.8B params, 16 GPUs.
    pub fn ten_b() -> ModelConfig {
        let mut c = common("10B");
        c.layers = 10;
        c.num_experts = 128;
        c.workers = 16;
        c
    }

    /// "100B": 103.2B params, 128 GPUs.
    pub fn hundred_b() -> ModelConfig {
        let mut c = common("100B");
        c.layers = 24;
        c.num_experts = 512;
        c.workers = 128;
        c
    }

    /// Interpolated 250B row of Fig 6 (same depth as 100B, more experts).
    pub fn two_fifty_b() -> ModelConfig {
        let mut c = common("250B");
        c.layers = 24;
        c.num_experts = 1280;
        c.workers = 240;
        c
    }

    /// "1T": 1002.7B params, 480 GPUs, Adafactor + reduced init (§4).
    pub fn one_t() -> ModelConfig {
        let mut c = common("1T");
        c.layers = 24;
        c.intermediate = 21248;
        c.num_experts = 960;
        c.workers = 480;
        c.optimizer = "adafactor".into();
        c.lr = 5e-3;
        c.init_std = 0.002;
        c
    }

    pub fn all() -> Vec<ModelConfig> {
        vec![base(), ten_b(), hundred_b(), two_fifty_b(), one_t()]
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        all().into_iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_accessors() {
        assert_eq!(Routing::TopK(2).rounds(), 2);
        assert_eq!(Routing::TopK(2).prototypes(), 1);
        assert_eq!(Routing::Prototype(4).rounds(), 1);
        assert_eq!(Routing::Prototype(4).prototypes(), 4);
        assert_eq!(Routing::parse("top2"), Some(Routing::TopK(2)));
        assert_eq!(Routing::parse("4top1"), Some(Routing::Prototype(4)));
        assert_eq!(Routing::parse("bogus"), None);
    }

    #[test]
    fn capacity_eq2() {
        let mut c = paper::base();
        // T = 8 * 128 = 1024 tokens, E = 32: C = k*T/E*1.25
        assert_eq!(c.tokens_per_batch(), 1024);
        assert_eq!(c.capacity(), 40); // k=1
        c.routing = Routing::TopK(4);
        assert_eq!(c.capacity(), 160); // k=4 at capacity kx
        c.capacity_mode = CapacityMode::Times1;
        assert_eq!(c.capacity(), 40); // limited capacity
        // prototyping shares the same Eq.-2 formula
        assert_eq!(
            c.capacity_for(Routing::Prototype(4), CapacityMode::TimesK),
            160
        );
    }

    #[test]
    fn paper_param_counts_match_table5() {
        // Table 5 reports 1.4B / 10.8B / 103.2B / 1002.7B; our accounting
        // (which includes routers/LN/embeddings) must land within 5%.
        let tol = |got: u64, want: f64| {
            let rel = (got as f64 - want).abs() / want;
            assert!(rel < 0.05, "got {got}, want ~{want}, rel {rel}");
        };
        tol(paper::base().param_count(), 1.4e9);
        tol(paper::ten_b().param_count(), 10.8e9);
        tol(paper::hundred_b().param_count(), 103.2e9);
        tol(paper::one_t().param_count(), 1002.7e9);
    }

    #[test]
    fn one_t_uses_paper_recipe() {
        let c = paper::one_t();
        assert_eq!(c.optimizer, "adafactor");
        assert!((c.lr - 5e-3).abs() < 1e-12);
        assert!((c.init_std - 0.002).abs() < 1e-12);
        assert_eq!(c.workers, 480);
    }
}
