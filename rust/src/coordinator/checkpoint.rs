//! Checkpointing: the device-resident train state serialized to a simple
//! self-describing binary format (magic + leaf table + f32 data, little
//! endian). No external serialization crates are available offline.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::VariantInfo;

const MAGIC: &[u8; 8] = b"M6TCKPT1";

/// Upper bound on the on-disk leaf count. Real variants carry a handful
/// of leaves; anything near this is a corrupt header, and bounding it
/// keeps a hostile `n_leaves` from pre-allocating unbounded memory.
const MAX_LEAVES: u64 = 1 << 16;

/// Host-side checkpoint: leaf arrays in manifest order + the step counter.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub variant: String,
    pub step: i64,
    pub leaves: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(&path)
            .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
        f.write_all(MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        let name = self.variant.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(self.leaves.len() as u32).to_le_bytes())?;
        for leaf in &self.leaves {
            f.write_all(&(leaf.len() as u64).to_le_bytes())?;
            // SAFETY-free alternative: stream the f32s as LE bytes
            let mut buf = Vec::with_capacity(leaf.len() * 4);
            for v in leaf {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    /// Load and validate a checkpoint. On-disk sizes are *untrusted*:
    /// every claimed length is bounded with checked arithmetic against
    /// sane maxima and the actual file size before a single byte is
    /// allocated, so a corrupt or truncated file fails with an error
    /// instead of an OOM abort — and trailing garbage after the last
    /// leaf is rejected rather than silently ignored.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic {magic:?}");
        }
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let step = i64::from_le_bytes(b8);
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        if name_len > 4096 {
            bail!("unreasonable variant-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let variant = String::from_utf8(name).context("checkpoint variant name not utf-8")?;
        f.read_exact(&mut b4)?;
        let n_leaves = u32::from_le_bytes(b4) as u64;
        if n_leaves > MAX_LEAVES {
            bail!("checkpoint claims {n_leaves} leaves (max {MAX_LEAVES}): corrupt header");
        }
        // bytes consumed so far: magic + step + name header + name + leaf count
        let mut offset: u64 = 8 + 8 + 4 + name_len as u64 + 4;
        let mut leaves = Vec::with_capacity(n_leaves as usize);
        for i in 0..n_leaves {
            f.read_exact(&mut b8).with_context(|| format!("reading leaf {i} header"))?;
            offset += 8;
            let n = u64::from_le_bytes(b8);
            let bytes = n
                .checked_mul(4)
                .ok_or_else(|| anyhow!("leaf {i}: element count {n} overflows the byte size"))?;
            let remaining = file_len.saturating_sub(offset);
            if bytes > remaining {
                bail!(
                    "leaf {i}: claims {bytes} bytes but only {remaining} remain in the \
                     file (corrupt or truncated checkpoint)"
                );
            }
            let mut raw = vec![0u8; bytes as usize];
            f.read_exact(&mut raw).with_context(|| format!("reading leaf {i} data"))?;
            offset += bytes;
            let leaf = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            leaves.push(leaf);
        }
        if file_len > offset {
            bail!(
                "checkpoint has {} trailing bytes after the last leaf: corrupt file \
                 or mismatched leaf table",
                file_len - offset
            );
        }
        Ok(Checkpoint { variant, step, leaves })
    }

    /// Validate leaf count/sizes against a variant manifest.
    pub fn validate(&self, info: &VariantInfo) -> Result<()> {
        if self.variant != info.name {
            bail!("checkpoint is for {:?}, not {:?}", self.variant, info.name);
        }
        if self.leaves.len() != info.n_state {
            bail!("checkpoint has {} leaves, manifest wants {}", self.leaves.len(), info.n_state);
        }
        for (leaf, spec) in self.leaves.iter().zip(&info.state_leaves) {
            if leaf.len() != spec.elements() {
                bail!(
                    "leaf {:?}: {} elements vs spec {}",
                    spec.name,
                    leaf.len(),
                    spec.elements()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            variant: "base-sim".into(),
            step: 123,
            leaves: vec![vec![1.0, -2.5, 3.25], vec![0.0; 7]],
        };
        let path = std::env::temp_dir().join("m6t-ckpt-test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("m6t-ckpt-bad.bin");
        fs::write(&path, b"NOTMAGIC rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = fs::remove_file(path);
    }

    /// A syntactically valid header for one-leaf checkpoints, ending just
    /// before the leaf length u64.
    fn header_for(variant: &[u8], n_leaves: u32) -> Vec<u8> {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&7i64.to_le_bytes());
        bytes.extend_from_slice(&(variant.len() as u32).to_le_bytes());
        bytes.extend_from_slice(variant);
        bytes.extend_from_slice(&n_leaves.to_le_bytes());
        bytes
    }

    #[test]
    fn rejects_overflowing_leaf_length() {
        // regression: `n * 4` used to overflow / feed `vec![0u8; huge]`,
        // aborting the process on a corrupt file instead of erroring
        let path = std::env::temp_dir().join("m6t-ckpt-overflow.bin");
        let mut bytes = header_for(b"base-sim", 1);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // leaf "length"
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_oversized_leaf_length() {
        // length that multiplies fine but dwarfs the file: must error
        // before allocating, not OOM
        let path = std::env::temp_dir().join("m6t-ckpt-oversized.bin");
        let mut bytes = header_for(b"base-sim", 1);
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("remain in the file"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_unreasonable_leaf_count() {
        let path = std::env::temp_dir().join("m6t-ckpt-leafcount.bin");
        let bytes = header_for(b"base-sim", u32::MAX);
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("leaves"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_truncated_data() {
        let ck = Checkpoint {
            variant: "base-sim".into(),
            step: 5,
            leaves: vec![vec![1.0; 64]],
        };
        let path = std::env::temp_dir().join("m6t-ckpt-truncated.bin");
        ck.save(&path).unwrap();
        let full = fs::read(&path).unwrap();
        fs::write(&path, &full[..full.len() - 10]).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "truncated file must not load");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_trailing_garbage() {
        let ck = Checkpoint {
            variant: "base-sim".into(),
            step: 5,
            leaves: vec![vec![1.0, 2.0]],
        };
        let path = std::env::temp_dir().join("m6t-ckpt-trailing.bin");
        ck.save(&path).unwrap();
        let mut full = fs::read(&path).unwrap();
        full.extend_from_slice(b"JUNK");
        fs::write(&path, &full).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
        let _ = fs::remove_file(path);
    }
}
