//! Checkpointing: the device-resident train state serialized to a simple
//! self-describing binary format (magic + leaf table + f32 data, little
//! endian). No external serialization crates are available offline.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::VariantInfo;

const MAGIC: &[u8; 8] = b"M6TCKPT1";

/// Host-side checkpoint: leaf arrays in manifest order + the step counter.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub variant: String,
    pub step: i64,
    pub leaves: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(&path)
            .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
        f.write_all(MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        let name = self.variant.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(self.leaves.len() as u32).to_le_bytes())?;
        for leaf in &self.leaves {
            f.write_all(&(leaf.len() as u64).to_le_bytes())?;
            // SAFETY-free alternative: stream the f32s as LE bytes
            let mut buf = Vec::with_capacity(leaf.len() * 4);
            for v in leaf {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic {magic:?}");
        }
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let step = i64::from_le_bytes(b8);
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        if name_len > 4096 {
            bail!("unreasonable variant-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let variant = String::from_utf8(name).context("checkpoint variant name not utf-8")?;
        f.read_exact(&mut b4)?;
        let n_leaves = u32::from_le_bytes(b4) as usize;
        let mut leaves = Vec::with_capacity(n_leaves);
        for _ in 0..n_leaves {
            f.read_exact(&mut b8)?;
            let n = u64::from_le_bytes(b8) as usize;
            let mut raw = vec![0u8; n * 4];
            f.read_exact(&mut raw)?;
            let leaf = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            leaves.push(leaf);
        }
        Ok(Checkpoint { variant, step, leaves })
    }

    /// Validate leaf count/sizes against a variant manifest.
    pub fn validate(&self, info: &VariantInfo) -> Result<()> {
        if self.variant != info.name {
            bail!("checkpoint is for {:?}, not {:?}", self.variant, info.name);
        }
        if self.leaves.len() != info.n_state {
            bail!("checkpoint has {} leaves, manifest wants {}", self.leaves.len(), info.n_state);
        }
        for (leaf, spec) in self.leaves.iter().zip(&info.state_leaves) {
            if leaf.len() != spec.elements() {
                bail!(
                    "leaf {:?}: {} elements vs spec {}",
                    spec.name,
                    leaf.len(),
                    spec.elements()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            variant: "base-sim".into(),
            step: 123,
            leaves: vec![vec![1.0, -2.5, 3.25], vec![0.0; 7]],
        };
        let path = std::env::temp_dir().join("m6t-ckpt-test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("m6t-ckpt-bad.bin");
        fs::write(&path, b"NOTMAGIC rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = fs::remove_file(path);
    }
}
