//! Checkpointing: the train state serialized to a simple self-describing
//! binary format (magic + leaf table + f32 data, little endian). No
//! external serialization crates are available offline.
//!
//! Two on-disk versions exist:
//!  * **v2** (`M6TCKPT2`, what `save` writes): every leaf carries its
//!    manifest name and a dtype tag, so `validate` matches leaves **by
//!    name** against the variant manifest — a reordered or re-laid-out
//!    state surfaces as a named mismatch (or is silently permuted back
//!    into manifest order by [`Checkpoint::leaves_in_manifest_order`])
//!    instead of loading transposed data positionally.
//!  * **v1** (`M6TCKPT1`): the legacy anonymous-leaf format; still
//!    loadable read-only, validated positionally as before.
//!
//! Saves are **atomic**: the bytes stream into a `.tmp` sibling which is
//! fsynced and then renamed over the final path, so a crash mid-save can
//! never leave a truncated file where a good checkpoint (or none) was.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{DType, VariantInfo};

const MAGIC_V1: &[u8; 8] = b"M6TCKPT1";
const MAGIC_V2: &[u8; 8] = b"M6TCKPT2";

/// Upper bound on the on-disk leaf count. Real variants carry a handful
/// of leaves; anything near this is a corrupt header, and bounding it
/// keeps a hostile `n_leaves` from pre-allocating unbounded memory.
const MAX_LEAVES: u64 = 1 << 16;
/// Upper bound on any on-disk name length (variant or leaf).
const MAX_NAME_LEN: usize = 4096;

fn dtype_tag(d: &DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
    }
}

fn dtype_from_tag(tag: u8) -> Result<DType> {
    match tag {
        0 => Ok(DType::F32),
        1 => Ok(DType::I32),
        t => bail!("unknown leaf dtype tag {t}"),
    }
}

/// Host-side checkpoint: leaf arrays in manifest order + the step
/// counter. `names`/`dtypes` parallel `leaves`; both are empty only for
/// checkpoints read from the legacy v1 format.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub variant: String,
    pub step: i64,
    pub leaves: Vec<Vec<f32>>,
    /// manifest name of each leaf (empty for v1-loaded checkpoints)
    pub names: Vec<String>,
    /// manifest dtype of each leaf (empty for v1-loaded checkpoints)
    pub dtypes: Vec<DType>,
}

impl Checkpoint {
    /// Build a checkpoint whose leaf names/dtypes come from the variant
    /// manifest — the one constructor the training path uses, so every
    /// saved checkpoint is v2-complete by construction.
    pub fn from_manifest(info: &VariantInfo, step: i64, leaves: Vec<Vec<f32>>) -> Result<Self> {
        if leaves.len() != info.state_leaves.len() {
            bail!(
                "state has {} leaves, manifest {:?} wants {}",
                leaves.len(),
                info.name,
                info.state_leaves.len()
            );
        }
        Ok(Self {
            variant: info.name.clone(),
            step,
            leaves,
            names: info.state_leaves.iter().map(|s| s.name.clone()).collect(),
            dtypes: info.state_leaves.iter().map(|s| s.dtype.clone()).collect(),
        })
    }

    /// Atomically write the checkpoint (always the v2 named format):
    /// stream to a `.tmp` sibling, fsync, rename over `path`, then
    /// best-effort fsync the parent directory. A crash at any point
    /// leaves either the old file or the new one — never a torn write.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if self.names.len() != self.leaves.len() || self.dtypes.len() != self.leaves.len() {
            bail!(
                "checkpoint for {:?} has {} leaves but {} names / {} dtypes — \
                 construct it via Checkpoint::from_manifest",
                self.variant,
                self.leaves.len(),
                self.names.len(),
                self.dtypes.len()
            );
        }
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating checkpoint temp {tmp:?}"))?;
            f.write_all(MAGIC_V2)?;
            f.write_all(&self.step.to_le_bytes())?;
            let name = self.variant.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(self.leaves.len() as u32).to_le_bytes())?;
            for ((leaf, lname), dtype) in self.leaves.iter().zip(&self.names).zip(&self.dtypes) {
                let lname = lname.as_bytes();
                f.write_all(&(lname.len() as u32).to_le_bytes())?;
                f.write_all(lname)?;
                f.write_all(&[dtype_tag(dtype)])?;
                f.write_all(&(leaf.len() as u64).to_le_bytes())?;
                // SAFETY-free alternative: stream the f32s as LE bytes
                let mut buf = Vec::with_capacity(leaf.len() * 4);
                for v in leaf {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                f.write_all(&buf)?;
            }
            f.flush()?;
            f.sync_all().with_context(|| format!("fsyncing checkpoint temp {tmp:?}"))?;
        }
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming checkpoint {tmp:?} -> {path:?}"))?;
        // the rename itself must be durable too; failure to fsync the
        // directory is not data loss on the happy path, so best-effort
        if let Some(parent) = path.parent() {
            if let Ok(dir) = fs::File::open(parent) {
                let _ = dir.sync_all();
            }
        }
        Ok(())
    }

    /// Load and validate a checkpoint (v2 or legacy v1). On-disk sizes
    /// are *untrusted*: every claimed length is bounded with checked
    /// arithmetic against sane maxima and the actual file size before a
    /// single byte is allocated, so a corrupt or truncated file fails
    /// with an error instead of an OOM abort — and trailing garbage
    /// after the last leaf is rejected rather than silently ignored.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let v2 = match &magic {
            m if m == MAGIC_V2 => true,
            m if m == MAGIC_V1 => false,
            _ => bail!("bad checkpoint magic {magic:?}"),
        };
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let step = i64::from_le_bytes(b8);
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        if name_len > MAX_NAME_LEN {
            bail!("unreasonable variant-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let variant = String::from_utf8(name).context("checkpoint variant name not utf-8")?;
        f.read_exact(&mut b4)?;
        let n_leaves = u32::from_le_bytes(b4) as u64;
        if n_leaves > MAX_LEAVES {
            bail!("checkpoint claims {n_leaves} leaves (max {MAX_LEAVES}): corrupt header");
        }
        // bytes consumed so far: magic + step + name header + name + leaf count
        let mut offset: u64 = 8 + 8 + 4 + name_len as u64 + 4;
        let mut leaves = Vec::with_capacity(n_leaves as usize);
        let mut names = Vec::new();
        let mut dtypes = Vec::new();
        let mut b1 = [0u8; 1];
        for i in 0..n_leaves {
            if v2 {
                f.read_exact(&mut b4).with_context(|| format!("reading leaf {i} name length"))?;
                offset += 4;
                let lname_len = u32::from_le_bytes(b4) as usize;
                if lname_len > MAX_NAME_LEN {
                    bail!("leaf {i}: unreasonable name length {lname_len}");
                }
                if lname_len as u64 > file_len.saturating_sub(offset) {
                    bail!("leaf {i}: name runs past end of file (truncated checkpoint)");
                }
                let mut lname = vec![0u8; lname_len];
                f.read_exact(&mut lname).with_context(|| format!("reading leaf {i} name"))?;
                offset += lname_len as u64;
                names.push(
                    String::from_utf8(lname)
                        .with_context(|| format!("leaf {i} name not utf-8"))?,
                );
                f.read_exact(&mut b1).with_context(|| format!("reading leaf {i} dtype"))?;
                offset += 1;
                dtypes.push(dtype_from_tag(b1[0]).with_context(|| format!("leaf {i}"))?);
            }
            f.read_exact(&mut b8).with_context(|| format!("reading leaf {i} header"))?;
            offset += 8;
            let n = u64::from_le_bytes(b8);
            let bytes = n
                .checked_mul(4)
                .ok_or_else(|| anyhow!("leaf {i}: element count {n} overflows the byte size"))?;
            let remaining = file_len.saturating_sub(offset);
            if bytes > remaining {
                bail!(
                    "leaf {i}: claims {bytes} bytes but only {remaining} remain in the \
                     file (corrupt or truncated checkpoint)"
                );
            }
            let mut raw = vec![0u8; bytes as usize];
            f.read_exact(&mut raw).with_context(|| format!("reading leaf {i} data"))?;
            offset += bytes;
            let leaf = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            leaves.push(leaf);
        }
        if file_len > offset {
            bail!(
                "checkpoint has {} trailing bytes after the last leaf: corrupt file \
                 or mismatched leaf table",
                file_len - offset
            );
        }
        Ok(Checkpoint { variant, step, leaves, names, dtypes })
    }

    /// Validate against a variant manifest. v2 checkpoints (named
    /// leaves) are matched **by name** — every leaf must exist in the
    /// manifest with the same element count and dtype, with no
    /// duplicates and no missing leaves; leaf *order* is free, since
    /// [`Checkpoint::leaves_in_manifest_order`] restores it. Legacy v1
    /// checkpoints fall back to the old positional check.
    pub fn validate(&self, info: &VariantInfo) -> Result<()> {
        if self.variant != info.name {
            bail!("checkpoint is for {:?}, not {:?}", self.variant, info.name);
        }
        if self.leaves.len() != info.n_state {
            bail!("checkpoint has {} leaves, manifest wants {}", self.leaves.len(), info.n_state);
        }
        if self.names.is_empty() {
            // legacy v1: anonymous leaves, positional validation
            for (leaf, spec) in self.leaves.iter().zip(&info.state_leaves) {
                if leaf.len() != spec.elements() {
                    bail!(
                        "leaf {:?}: {} elements vs spec {}",
                        spec.name,
                        leaf.len(),
                        spec.elements()
                    );
                }
            }
            return Ok(());
        }
        if self.names.len() != self.leaves.len() || self.dtypes.len() != self.leaves.len() {
            bail!(
                "checkpoint names/dtypes ({}/{}) do not match its {} leaves",
                self.names.len(),
                self.dtypes.len(),
                self.leaves.len()
            );
        }
        let mut seen = std::collections::BTreeSet::new();
        for ((leaf, name), dtype) in self.leaves.iter().zip(&self.names).zip(&self.dtypes) {
            if !seen.insert(name.as_str()) {
                bail!("checkpoint has duplicate leaf {name:?}");
            }
            let spec = info
                .state_leaves
                .iter()
                .find(|s| &s.name == name)
                .ok_or_else(|| anyhow!("checkpoint leaf {name:?} is not in the manifest"))?;
            if leaf.len() != spec.elements() {
                bail!("leaf {name:?}: {} elements vs spec {}", leaf.len(), spec.elements());
            }
            if dtype != &spec.dtype {
                bail!("leaf {name:?}: dtype {dtype:?} vs spec {:?}", spec.dtype);
            }
        }
        // counts equal + no duplicates + all present => bijection
        Ok(())
    }

    /// The leaf arrays permuted into the manifest's order — what
    /// `Backend::state_from_host` expects. v1 checkpoints (no names) are
    /// already positional; v2 checkpoints are matched by name, so a
    /// checkpoint whose leaves were written in a different order still
    /// restores correctly. Call [`Checkpoint::validate`] first.
    pub fn leaves_in_manifest_order(&self, info: &VariantInfo) -> Result<Vec<Vec<f32>>> {
        if self.names.is_empty() {
            return Ok(self.leaves.clone());
        }
        info.state_leaves
            .iter()
            .map(|spec| {
                self.names
                    .iter()
                    .position(|n| n == &spec.name)
                    .map(|at| self.leaves[at].clone())
                    .ok_or_else(|| anyhow!("checkpoint is missing leaf {:?}", spec.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn named(variant: &str, step: i64, leaves: Vec<Vec<f32>>) -> Checkpoint {
        let names = (0..leaves.len()).map(|i| format!("leaf{i}")).collect();
        let dtypes = vec![DType::F32; leaves.len()];
        Checkpoint { variant: variant.into(), step, leaves, names, dtypes }
    }

    fn info_for(ck: &Checkpoint) -> VariantInfo {
        let state_leaves: Vec<TensorSpec> = ck
            .leaves
            .iter()
            .zip(&ck.names)
            .map(|(leaf, name)| TensorSpec {
                name: name.clone(),
                shape: vec![leaf.len()],
                dtype: DType::F32,
            })
            .collect();
        VariantInfo {
            name: ck.variant.clone(),
            dir: Default::default(),
            config: crate::config::paper::base(),
            init_hlo: Default::default(),
            step_hlo: Default::default(),
            eval_hlo: Default::default(),
            n_params: state_leaves.len(),
            n_opt: 0,
            n_state: state_leaves.len(),
            param_count: 0,
            capacity: 0,
            state_leaves,
            step_inputs: Vec::new(),
            step_outputs: Vec::new(),
            eval_outputs: Vec::new(),
        }
    }

    #[test]
    fn roundtrip() {
        let ck = named("base-sim", 123, vec![vec![1.0, -2.5, 3.25], vec![0.0; 7]]);
        let path = std::env::temp_dir().join("m6t-ckpt-test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.names, vec!["leaf0".to_string(), "leaf1".to_string()]);
        assert_eq!(back.dtypes, vec![DType::F32, DType::F32]);
        let _ = fs::remove_file(path);
    }

    #[test]
    fn save_refuses_anonymous_leaves() {
        let ck = Checkpoint {
            variant: "base-sim".into(),
            step: 1,
            leaves: vec![vec![1.0]],
            names: Vec::new(),
            dtypes: Vec::new(),
        };
        let path = std::env::temp_dir().join("m6t-ckpt-anon.bin");
        assert!(ck.save(&path).is_err(), "v2 save requires names/dtypes");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn interrupted_save_leaves_previous_checkpoint_intact() {
        // regression: save() used to stream straight into the final path,
        // so a crash mid-write destroyed the previous good checkpoint.
        // Simulate the crash by materializing the half-written temp file
        // next to a good save — the final path must still load clean.
        let dir = std::env::temp_dir().join("m6t-ckpt-atomic");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("state.ckpt");
        let good = named("base-sim", 10, vec![vec![1.0; 32], vec![2.0; 8]]);
        good.save(&path).unwrap();
        let full = fs::read(&path).unwrap();
        // a torn write of a *newer* checkpoint dies mid-stream
        fs::write(path.with_extension("tmp"), &full[..full.len() / 2]).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, good, "torn temp file must not affect the published checkpoint");
        // and no stale temp is ever loadable as a checkpoint
        assert!(Checkpoint::load(path.with_extension("tmp")).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn loads_legacy_v1_format() {
        // hand-craft a v1 file: anonymous leaves, positional layout
        let path = std::env::temp_dir().join("m6t-ckpt-v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&42i64.to_le_bytes());
        bytes.extend_from_slice(&(8u32).to_le_bytes());
        bytes.extend_from_slice(b"base-sim");
        bytes.extend_from_slice(&(2u32).to_le_bytes());
        for leaf in [vec![1.0f32, -2.0], vec![0.5f32; 3]] {
            bytes.extend_from_slice(&(leaf.len() as u64).to_le_bytes());
            for v in leaf {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.variant, "base-sim");
        assert_eq!(back.step, 42);
        assert_eq!(back.leaves, vec![vec![1.0, -2.0], vec![0.5; 3]]);
        assert!(back.names.is_empty(), "v1 has no leaf names");
        assert!(back.dtypes.is_empty());
        let _ = fs::remove_file(path);
    }

    #[test]
    fn validate_matches_by_name_and_rejects_mismatches() {
        let ck = named("base-sim", 3, vec![vec![1.0, 2.0], vec![3.0; 4]]);
        let info = info_for(&ck);
        ck.validate(&info).unwrap();

        // reordered leaves still validate and restore in manifest order
        let mut reordered = ck.clone();
        reordered.leaves.swap(0, 1);
        reordered.names.swap(0, 1);
        reordered.validate(&info).unwrap();
        let restored = reordered.leaves_in_manifest_order(&info).unwrap();
        assert_eq!(restored, ck.leaves, "by-name restore must undo the permutation");

        // an unknown leaf name is rejected (the old positional check
        // would have accepted any equal-size leaf here)
        let mut renamed = ck.clone();
        renamed.names[1] = "not-a-leaf".into();
        assert!(renamed.validate(&info).is_err());

        // dtype mismatches are rejected
        let mut retyped = ck.clone();
        retyped.dtypes[0] = DType::I32;
        assert!(retyped.validate(&info).is_err());

        // duplicate names are rejected even when sizes line up
        let mut duped = ck.clone();
        duped.names[1] = duped.names[0].clone();
        duped.leaves[1] = duped.leaves[0].clone();
        assert!(duped.validate(&info).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("m6t-ckpt-bad.bin");
        fs::write(&path, b"NOTMAGIC rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = fs::remove_file(path);
    }

    /// A syntactically valid v2 header for one-leaf checkpoints, ending
    /// just after the first leaf's name + dtype, right before the leaf
    /// length u64.
    fn header_for(variant: &[u8], n_leaves: u32) -> Vec<u8> {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&7i64.to_le_bytes());
        bytes.extend_from_slice(&(variant.len() as u32).to_le_bytes());
        bytes.extend_from_slice(variant);
        bytes.extend_from_slice(&n_leaves.to_le_bytes());
        bytes.extend_from_slice(&(5u32).to_le_bytes());
        bytes.extend_from_slice(b"leaf0");
        bytes.push(0); // dtype tag: F32
        bytes
    }

    #[test]
    fn rejects_overflowing_leaf_length() {
        // regression: `n * 4` used to overflow / feed `vec![0u8; huge]`,
        // aborting the process on a corrupt file instead of erroring
        let path = std::env::temp_dir().join("m6t-ckpt-overflow.bin");
        let mut bytes = header_for(b"base-sim", 1);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // leaf "length"
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_oversized_leaf_length() {
        // length that multiplies fine but dwarfs the file: must error
        // before allocating, not OOM
        let path = std::env::temp_dir().join("m6t-ckpt-oversized.bin");
        let mut bytes = header_for(b"base-sim", 1);
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("remain in the file"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_unreasonable_leaf_count() {
        let path = std::env::temp_dir().join("m6t-ckpt-leafcount.bin");
        let bytes = header_for(b"base-sim", u32::MAX);
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("leaves"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_oversized_leaf_name() {
        let path = std::env::temp_dir().join("m6t-ckpt-badname.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&7i64.to_le_bytes());
        bytes.extend_from_slice(&(8u32).to_le_bytes());
        bytes.extend_from_slice(b"base-sim");
        bytes.extend_from_slice(&(1u32).to_le_bytes());
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes()); // leaf name "length"
        fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("name"), "{err:#}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_truncated_data() {
        let ck = named("base-sim", 5, vec![vec![1.0; 64]]);
        let path = std::env::temp_dir().join("m6t-ckpt-truncated.bin");
        ck.save(&path).unwrap();
        let full = fs::read(&path).unwrap();
        fs::write(&path, &full[..full.len() - 10]).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "truncated file must not load");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn rejects_trailing_garbage() {
        let ck = named("base-sim", 5, vec![vec![1.0, 2.0]]);
        let path = std::env::temp_dir().join("m6t-ckpt-trailing.bin");
        ck.save(&path).unwrap();
        let mut full = fs::read(&path).unwrap();
        full.extend_from_slice(b"JUNK");
        fs::write(&path, &full).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
        let _ = fs::remove_file(path);
    }
}
