//! The training coordinator — L3's orchestration core.
//!
//! Owns the step loop over a pluggable [`Backend`] (native or PJRT), the
//! synthetic data pipeline, metric collection (loss curves, per-layer c_v,
//! drops), periodic paired evaluation (identical eval batches across
//! strategies), and checkpointing. Every figure/table driver in
//! `experiments` is built on [`Trainer`].

pub mod checkpoint;

use std::time::Instant;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::metrics::RunLog;
use crate::runtime::{Backend, TrainState, VariantInfo};

pub use checkpoint::Checkpoint;

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: i64,
    pub seed: u64,
    pub log_every: i64,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: i64,
    pub eval_batches: usize,
    /// optional JSONL metrics directory
    pub metrics_dir: Option<String>,
    /// print progress lines
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 200,
            seed: 42,
            log_every: 1,
            eval_every: 0,
            eval_batches: 8,
            metrics_dir: None,
            verbose: true,
        }
    }
}

/// Result of a run: the step log plus (step, eval-PPL) points.
pub struct TrainOutcome {
    pub log: RunLog,
    pub evals: Vec<(i64, f64)>,
    pub final_state_step: i64,
}

/// Drives one variant end to end through any [`Backend`].
pub struct Trainer {
    pub backend: Box<dyn Backend>,
    pub opts: TrainOptions,
}

impl Trainer {
    pub fn new(backend: Box<dyn Backend>, opts: TrainOptions) -> Self {
        Self { backend, opts }
    }

    /// Static description of the loaded variant.
    pub fn info(&self) -> &VariantInfo {
        self.backend.info()
    }

    /// Teacher-forced PPL over `n` fixed eval batches (cursor reset so all
    /// strategies see identical data — paired comparison, Table 3/4).
    pub fn eval_ppl(&self, state: &TrainState, n: usize) -> Result<f64> {
        let cfg = &self.backend.info().config;
        let mut batcher = Batcher::for_config(cfg, Split::Eval, self.opts.seed);
        batcher.seek(0);
        let mut sum_nll = 0.0;
        let mut count = 0.0;
        for _ in 0..n {
            let batch = batcher.next_batch();
            let (nll, c) = self.backend.eval(state, &batch)?;
            sum_nll += nll;
            count += c;
        }
        Ok((sum_nll / count.max(1.0)).exp())
    }

    /// Run `steps` training steps from a fresh init; returns the outcome
    /// and the final state (for checkpointing / further eval).
    pub fn train(&self) -> Result<(TrainOutcome, TrainState)> {
        let state = self.backend.init_state(self.opts.seed)?;
        self.train_from(state)
    }

    /// Continue training from an existing state.
    pub fn train_from(&self, mut state: TrainState) -> Result<(TrainOutcome, TrainState)> {
        let info = self.backend.info();
        let mut log = RunLog::new(info.name.clone());
        if let Some(dir) = &self.opts.metrics_dir {
            // a resumed run (checkpoint restore) must append — truncating
            // the sink would destroy its recorded history — but the steps
            // at and past the checkpoint are about to be re-executed, so
            // their old records are dropped first: resuming the same
            // checkpoint twice must not double-log the overlap range
            log = if state.step > 0 {
                log.with_sink_resume(dir, state.step)?
            } else {
                log.with_sink(dir)?
            };
        }
        let mut batcher = Batcher::for_config(&info.config, Split::Train, self.opts.seed);
        // resume-aware: skip the batches already consumed
        batcher.seek(state.step as u64 * info.config.batch as u64);

        let mut evals = Vec::new();
        let start_step = state.step;
        let end_step = start_step + self.opts.steps;
        while state.step < end_step {
            let batch = batcher.next_batch();
            let t0 = Instant::now();
            let (next, stats) = self.backend.step(state, &batch)?;
            state = next;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let step_now = state.step - 1;
            if step_now % self.opts.log_every == 0 {
                log.push(step_now, &stats, ms)?;
            }
            if self.opts.verbose && step_now % 50 == 0 {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} aux {:.3} gnorm {:.2} drop {:>5.0} {:.0} ms",
                    info.name,
                    step_now,
                    stats.loss,
                    stats.aux_loss,
                    stats.grad_norm,
                    stats.total_dropped(),
                    ms
                );
            }
            if self.opts.eval_every > 0
                && step_now > start_step
                && step_now % self.opts.eval_every == 0
            {
                let ppl = self.eval_ppl(&state, self.opts.eval_batches)?;
                if self.opts.verbose {
                    eprintln!("[{}] step {:>5} eval PPL {:.3}", info.name, step_now, ppl);
                }
                evals.push((step_now, ppl));
            }
        }
        let ppl = self.eval_ppl(&state, self.opts.eval_batches)?;
        evals.push((state.step, ppl));
        if self.opts.verbose {
            eprintln!(
                "[{}] done: {} steps, final loss {:.4}, eval PPL {:.3}",
                info.name,
                state.step - start_step,
                log.tail_loss(20),
                ppl
            );
        }
        Ok((
            TrainOutcome { log, evals, final_state_step: state.step },
            state,
        ))
    }

    /// Snapshot the state into a host checkpoint (leaves named and
    /// dtype-tagged from the variant manifest — the v2 on-disk format).
    pub fn snapshot(&self, state: &TrainState) -> Result<Checkpoint> {
        Checkpoint::from_manifest(
            self.backend.info(),
            state.step,
            self.backend.state_to_host(state)?,
        )
    }

    /// Restore a checkpoint into a runnable state. v2 checkpoints are
    /// validated and restored by leaf *name*; legacy v1 positionally.
    pub fn restore(&self, ck: &Checkpoint) -> Result<TrainState> {
        let info = self.backend.info();
        ck.validate(info)?;
        self.backend.state_from_host(&ck.leaves_in_manifest_order(info)?, ck.step)
    }
}
