//! Persistent worker pool for the routing hot path (rayon is not in the
//! offline vendor set).
//!
//! The native backend used to spawn one scoped thread per layer on every
//! `step()` — a 12-layer config cost 12 spawns/joins per step regardless
//! of core count. This pool spawns its threads once (bounded by
//! [`std::thread::available_parallelism`]) and hands them work units
//! through a shared queue; [`WorkerPool::parallel_for`] is the only
//! scheduling primitive the hot path needs.
//!
//! Determinism contract: `parallel_for(n, body)` runs `body(i)` exactly
//! once for every `i in 0..n`, with no promise about order or about which
//! thread runs which index. Callers that want bitwise-identical results
//! across pool sizes must make each work unit a pure function of its
//! index — which is exactly how the routing engine, the two-pass gate
//! materializer, and the fused (worker x layer x tile) step grid
//! (`runtime::native::route_grid_counts`, the pool's largest client:
//! one flat `parallel_for` over the whole D x L x tile space) are
//! written (per-unit seeds, disjoint output slices via
//! [`crate::util::shard`]). The caller's thread participates in the loop,
//! so a pool with zero workers degrades to a plain serial loop and nested
//! `parallel_for` calls cannot deadlock (a blocked caller drains the
//! queue while it waits).
#![forbid(unsafe_code)]

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work_ready: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one `parallel_for` batch.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(remaining: usize) -> Self {
        Self { state: Mutex::new(LatchState { remaining, panicked: false }), done: Condvar::new() }
    }

    fn count_down(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        st.panicked |= panicked;
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }
}

/// A fixed set of persistent worker threads plus a shared work queue.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with exactly `workers` threads. Zero is valid: every
    /// `parallel_for` then runs inline on the caller.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("m6t-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Self { shared, workers, handles }
    }

    /// Number of worker threads (the caller participates on top of these).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `body(i)` exactly once for every `i in 0..items`, spreading the
    /// indices over the pool plus the calling thread. Returns only after
    /// every index has completed; panics (once) if any `body` panicked.
    pub fn parallel_for<'scope>(&self, items: usize, body: &(dyn Fn(usize) + Sync + 'scope)) {
        if items == 0 {
            return;
        }
        let helpers = self.workers.min(items.saturating_sub(1));
        if helpers == 0 {
            for i in 0..items {
                body(i);
            }
            return;
        }
        // The latch below guarantees every helper job has finished (and
        // thus dropped its copy of this reference) before this function
        // returns — even when the caller's own loop panics — so the 'scope
        // borrow never escapes its true lifetime. That protocol is the
        // safety contract of `erase_body_lifetime` (see util::shard).
        let body_static = crate::util::shard::erase_body_lifetime(body);
        let next = Arc::new(AtomicUsize::new(0));
        let latch = Arc::new(Latch::new(helpers));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..helpers {
                let next = Arc::clone(&next);
                let latch = Arc::clone(&latch);
                q.push_back(Box::new(move || {
                    let res = catch_unwind(AssertUnwindSafe(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items {
                            break;
                        }
                        body_static(i);
                    }));
                    latch.count_down(res.is_err());
                }));
            }
        }
        self.shared.work_ready.notify_all();
        // the caller claims indices too: a busy pool never stalls the loop
        let caller = catch_unwind(AssertUnwindSafe(|| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= items {
                break;
            }
            body(i);
        }));
        let helper_panicked = self.wait_draining(&latch);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if helper_panicked {
            panic!("parallel_for: a pool worker panicked while running a work unit");
        }
    }

    /// Block until `latch` opens, helping with queued jobs in the
    /// meantime so nested `parallel_for` calls cannot deadlock.
    fn wait_draining(&self, latch: &Latch) -> bool {
        loop {
            let job = {
                let st = latch.state.lock().unwrap();
                if st.remaining == 0 {
                    return st.panicked;
                }
                drop(st);
                self.shared.queue.lock().unwrap().pop_front()
            };
            match job {
                // jobs track their own completion; a panicking job must
                // not unwind through us and skip our own latch wait
                Some(j) => {
                    let _ = catch_unwind(AssertUnwindSafe(j));
                }
                None => {
                    let st = latch.state.lock().unwrap();
                    if st.remaining == 0 {
                        return st.panicked;
                    }
                    let (st, _timeout) =
                        latch.done.wait_timeout(st, Duration::from_millis(1)).unwrap();
                    if st.remaining == 0 {
                        return st.panicked;
                    }
                }
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // store shutdown while holding the queue mutex: a worker is then
        // either before its own critical section (it will see the flag)
        // or already parked in wait() (the notify below wakes it) — a
        // store outside the lock could land between a worker's check and
        // its wait, losing the only wakeup and hanging join() forever
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.work_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.work_ready.wait(q).unwrap();
            }
        };
        match job {
            // keep the worker alive across panicking jobs; the job's own
            // latch reports the failure to whoever is waiting on it
            Some(j) => {
                let _ = catch_unwind(AssertUnwindSafe(j));
            }
            None => return,
        }
    }
}

/// Shard dispatch policy shared by every token-sharded hot-path phase:
/// run `body(s)` for `s in 0..shards` on `pool` (or the global pool when
/// `None`) when `work` crosses `min_work` and there is more than one
/// shard; as a plain serial loop on the caller otherwise. Both paths
/// produce identical outputs, and the global pool is only instantiated
/// if the parallel branch is actually taken.
pub fn run_shards(
    pool: Option<&WorkerPool>,
    shards: usize,
    work: usize,
    min_work: usize,
    body: &(dyn Fn(usize) + Sync),
) {
    if shards > 1 && work >= min_work {
        pool.unwrap_or_else(global).parallel_for(shards, body);
    } else {
        for s in 0..shards {
            body(s);
        }
    }
}

/// Default worker count: one per available core, capped — routing shards
/// are memory-bandwidth-bound well before 8 threads.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8)
}

/// The process-wide pool the hot path uses unless a caller injects its
/// own (tests inject 1- and 2-worker pools to pin determinism).
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(default_workers()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(100, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn disjoint_writes_identical_across_pool_sizes() {
        let run = |workers: usize| -> Vec<u64> {
            let pool = WorkerPool::new(workers);
            let mut out = vec![0u64; 4096];
            let views = crate::util::shard::DisjointChunks::new(&mut out, 64);
            pool.parallel_for(64, &|s| {
                // each unit owns a disjoint 64-element chunk
                for (j, v) in views.view(s).iter_mut().enumerate() {
                    *v = (s as u64) * 1_000_003 + j as u64;
                }
            });
            drop(views);
            out
        };
        let expect = run(0);
        for workers in [1, 2, default_workers()] {
            assert_eq!(run(workers), expect, "pool size {workers} diverged");
        }
    }

    #[test]
    fn nested_parallel_for_does_not_deadlock() {
        let pool = WorkerPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(4, &|_outer| {
            pool.parallel_for(8, &|i| {
                sum.fetch_add(i + 1, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4 * (8 * 9 / 2));
    }

    #[test]
    fn borrowing_the_stack_is_fine() {
        // the whole point of the transmute: bodies may borrow locals
        let data: Vec<usize> = (0..512).collect();
        let pool = WorkerPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(data.len(), &|i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 511 * 512 / 2);
    }

    #[test]
    fn body_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        // panic on late indices so helpers are guaranteed a share of them;
        // whichever thread hits one, parallel_for must panic exactly once
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(64, &|i| {
                if i >= 32 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(result.is_err(), "parallel_for must propagate body panics");
        // pool must still be usable after a panicked batch
        let sum = AtomicUsize::new(0);
        pool.parallel_for(16, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 15 * 16 / 2);
    }
}
