//! Tiny declarative CLI argument parser (clap is not in the offline vendor
//! set). Supports `--flag`, `--key value`, `--key=value`, positional args,
//! repeated flags, and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub repeated: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, usize>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }
    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(0) > 0
    }
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse {s:?}")),
        }
    }
    pub fn get_or<T: std::str::FromStr + Clone>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse::<T>(name)?.unwrap_or(default))
    }
}

/// A command with a fixed argument specification.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, specs: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, takes_value: false, default: None, repeated: false });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, takes_value: true, default: None, repeated: false });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
            repeated: false,
        });
        self
    }

    pub fn opt_repeated(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, takes_value: true, default: None, repeated: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "options:");
        for spec in &self.specs {
            let v = if spec.takes_value { " <value>" } else { "" };
            let d = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{v:<12} {}{d}", spec.name, spec.help);
        }
        s
    }

    /// Parse `argv` (not including the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    let entry = out.values.entry(key.to_string()).or_default();
                    if spec.repeated {
                        // keep defaults out of repeated accumulation
                        if spec.default.is_some() && entry.len() == 1 && out.flags.get(key).is_none()
                        {
                            entry.clear();
                        }
                        entry.push(val);
                    } else {
                        *entry = vec![val];
                    }
                    *out.flags.entry(key.to_string()).or_default() += 1;
                } else {
                    if inline.is_some() {
                        return Err(format!("--{key} does not take a value"));
                    }
                    *out.flags.entry(key.to_string()).or_default() += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("test", "t")
            .flag("verbose", "chatty")
            .opt("steps", "how many")
            .opt_default("out", "out.csv", "sink")
            .opt_repeated("variant", "which")
    }

    #[test]
    fn parses_mixed() {
        let a = cmd()
            .parse(&argv(&["--verbose", "--steps", "10", "pos1", "--variant=x", "--variant", "y"]))
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get("steps"), Some("10"));
        assert_eq!(a.get("out"), Some("out.csv"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_all("variant"), &["x".to_string(), "y".to_string()]);
    }

    #[test]
    fn default_applies() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("out"), Some("out.csv"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn typed_access() {
        let a = cmd().parse(&argv(&["--steps", "42"])).unwrap();
        assert_eq!(a.get_or("steps", 0usize).unwrap(), 42);
        assert_eq!(a.get_or("missingdefaults", 7usize).unwrap(), 7);
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
        assert!(cmd().parse(&argv(&["--steps"])).is_err());
        assert!(cmd().parse(&argv(&["--verbose=1"])).is_err());
        let bad = cmd().parse(&argv(&["--steps", "abc"])).unwrap();
        assert!(bad.get_or("steps", 0usize).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
    }
}
