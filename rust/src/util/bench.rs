//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Cargo bench targets use `harness = false` and drive this: warmup,
//! calibrated iteration counts, and median/p10/p90 reporting over wall
//! clock. Good enough to rank implementations and catch regressions; the
//! end-to-end numbers that matter for the paper's tables come from the
//! experiment drivers, not from here.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p10 {}, p90 {}, {} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Benchmark `f`, auto-calibrating the per-sample iteration count so each
/// sample takes ~`target_sample`. Returns robust percentiles over samples.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, Duration::from_millis(30), 20, &mut f)
}

/// Variant for slow bodies (e.g. whole simulated training steps).
pub fn bench_slow<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, Duration::from_millis(200), 8, &mut f)
}

fn bench_config<F: FnMut()>(
    name: &str,
    target_sample: Duration,
    samples: usize,
    f: &mut F,
) -> BenchResult {
    // warmup + calibration
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt >= target_sample / 4 || iters >= 1 << 28 {
            let scale = target_sample.as_secs_f64() / dt.as_secs_f64().max(1e-9);
            iters = ((iters as f64 * scale).ceil() as u64).max(1);
            break;
        }
        iters *= 4;
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: percentile(&per_iter, 50.0),
        p10_ns: percentile(&per_iter, 10.0),
        p90_ns: percentile(&per_iter, 90.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let r = bench_config(
            "noop-ish",
            Duration::from_millis(2),
            5,
            &mut || {
                acc = acc.wrapping_add(std::hint::black_box(1));
            },
        );
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn ordering_detects_slower_body() {
        // black_box the loop bound so the sums cannot const-fold
        let fast = bench_config("fast", Duration::from_millis(2), 5, &mut || {
            let n = std::hint::black_box(10u64);
            std::hint::black_box((0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(31)));
        });
        let slow = bench_config("slow", Duration::from_millis(2), 5, &mut || {
            let n = std::hint::black_box(100_000u64);
            std::hint::black_box((0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(31)));
        });
        assert!(slow.median_ns > fast.median_ns);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("us"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
