//! Verified-disjoint shard views: the crate's single audited `unsafe` module.
//!
//! Every parallel kernel in this repo follows the same shape: one flat buffer
//! is carved into non-overlapping per-unit regions, each worker writes only
//! its own region, and a serial fixed-order merge (or the disjointness itself)
//! makes the result bitwise-deterministic across pool sizes. Historically each
//! kernel re-derived that carve with raw pointers (`SendPtr` +
//! `from_raw_parts_mut`) and a comment asserting disjointness. This module
//! replaces all of those sites with two checked abstractions:
//!
//! - [`DisjointChunks`]: contiguous equal-width chunks (the last one clamped),
//!   one per unit — the per-shard / per-tile / per-expert-slab layout.
//! - [`StridedViews`]: a `(outer, inner)` unit grid over an
//!   `outer x rows x inner x width` buffer, where unit `(o, t)` owns column
//!   `t` of outer block `o` — the per-(expert, I-tile) weight-gradient layout
//!   used by the tiled FFN backward pass. Crucially, two units of the same
//!   outer block get *disjoint* views (they interleave by rows), which the old
//!   raw-pointer code could not express: it materialised overlapping full
//!   `&mut` slices per unit, which is undefined behavior under the aliasing
//!   rules even though the written ranges never overlapped.
//!
//! Both hand out `&'a mut [T]` views tied to the borrow of the original
//! buffer, so the borrow checker enforces the views die before the buffer is
//! reused. Disjointness across units is enforced three ways:
//!
//! 1. by construction (the index arithmetic below, each line audited);
//! 2. in debug builds, by a per-unit claim bitmap — claiming the same unit
//!    twice panics, so any accidental overlap trips the determinism tests;
//! 3. in CI, by Miri (stacked borrows) and ThreadSanitizer runs over the
//!    pool/ffn/fused/dispatch test subset.
//!
//! The rest of the crate is `#![forbid(unsafe_code)]` per-module, and the
//! `m6t lint-unsafe` budget scanner pins this file's `unsafe` count against
//! `rust/unsafe_allowlist.txt`. To add a new parallel kernel, express its
//! layout with one of these views (or extend this module) — never add
//! `unsafe` elsewhere.
#![allow(unsafe_code)]

use std::marker::PhantomData;

#[cfg(debug_assertions)]
use std::sync::atomic::{AtomicBool, Ordering};

/// Claim bitmap used by the debug overlap checker: one flag per unit,
/// flipped exactly once by `view(u)`.
#[cfg(debug_assertions)]
fn new_claim_map(units: usize) -> Vec<AtomicBool> {
    (0..units).map(|_| AtomicBool::new(false)).collect()
}

#[cfg(debug_assertions)]
fn claim(map: &[AtomicBool], unit: usize, what: &str) {
    assert!(
        !map[unit].swap(true, Ordering::Relaxed),
        "{what}: unit {unit} claimed twice (overlapping views)"
    );
}

/// Carves one `&mut [T]` into `ceil(len / chunk)` non-overlapping contiguous
/// views of `chunk` elements each (the last view clamped to the buffer end).
///
/// `view(u)` may be called from any thread (the struct is `Sync`); each unit
/// index must be claimed at most once per carve, which debug builds enforce
/// at runtime.
pub struct DisjointChunks<'a, T> {
    base: *mut T,
    len: usize,
    chunk: usize,
    units: usize,
    #[cfg(debug_assertions)]
    claimed: Vec<AtomicBool>,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: `DisjointChunks` holds a raw pointer only so distinct units can be
// handed to distinct threads; `view` derives a fresh `&mut [T]` per unit and
// the unit regions never overlap (by construction, checked in debug builds).
// Sending or sharing the carve itself is therefore as safe as sending the
// original `&mut [T]` would be.
unsafe impl<T: Send> Send for DisjointChunks<'_, T> {}
// SAFETY: see the `Send` impl above — `&DisjointChunks` only exposes `view`,
// which yields non-overlapping `&mut` regions of a `Send` element type.
unsafe impl<T: Send> Sync for DisjointChunks<'_, T> {}

impl<'a, T> DisjointChunks<'a, T> {
    /// Carve `buf` into chunks of `chunk` elements. `chunk` must be non-zero;
    /// an empty `buf` yields zero units.
    pub fn new(buf: &'a mut [T], chunk: usize) -> Self {
        assert!(chunk > 0, "DisjointChunks: chunk width must be non-zero");
        let len = buf.len();
        let units = len.div_ceil(chunk);
        Self {
            base: buf.as_mut_ptr(),
            len,
            chunk,
            units,
            #[cfg(debug_assertions)]
            claimed: new_claim_map(units),
            _marker: PhantomData,
        }
    }

    /// Number of units (views) this carve produces.
    pub fn units(&self) -> usize {
        self.units
    }

    /// The view owned by unit `u`: elements `[u * chunk, min((u + 1) * chunk, len))`.
    ///
    /// Panics if `u` is out of range, and (in debug builds) if `u` was
    /// already claimed.
    // The returned lifetime is 'a (the original buffer borrow), deliberately
    // unrelated to the `&self` borrow: distinct units alias distinct memory.
    #[allow(clippy::mut_from_ref)]
    pub fn view(&self, u: usize) -> &'a mut [T] {
        assert!(u < self.units, "DisjointChunks: unit {u} out of range ({} units)", self.units);
        #[cfg(debug_assertions)]
        claim(&self.claimed, u, "DisjointChunks");
        let start = u * self.chunk;
        let end = (start + self.chunk).min(self.len);
        // SAFETY: `start < len` (u < units = ceil(len / chunk) and chunk > 0)
        // and `end <= len`, so the range lies inside the original buffer,
        // which outlives 'a. Unit ranges [u*chunk, (u+1)*chunk) are pairwise
        // disjoint by construction and each unit is claimed at most once
        // (checked in debug builds), so no two live `&mut` views alias.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(start), end - start) }
    }
}

/// Carves an `outer x rows x inner x width` buffer into an `(outer, inner)`
/// grid of strided views: unit `u = o * inner + t` owns, for every
/// `r in 0..rows`, the `width`-element run starting at
/// `((o * rows + r) * inner + t) * width`.
///
/// This is the per-(expert, I-tile) weight-gradient layout: `outer` experts,
/// `rows` output rows per expert, `inner` tiles, `width` columns per tile.
/// Two tiles of the same expert interleave by rows but never overlap.
pub struct StridedViews<'a, T> {
    base: *mut T,
    outer: usize,
    rows: usize,
    inner: usize,
    width: usize,
    #[cfg(debug_assertions)]
    claimed: Vec<AtomicBool>,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: same argument as `DisjointChunks` — `view` yields per-unit regions
// whose index sets are pairwise disjoint (proved in `view`'s SAFETY comment,
// cross-checked against a naive index-set oracle in tests/shard_views.rs),
// so handing units to other threads is as safe as sending the buffer itself.
unsafe impl<T: Send> Send for StridedViews<'_, T> {}
// SAFETY: see the `Send` impl above.
unsafe impl<T: Send> Sync for StridedViews<'_, T> {}

impl<'a, T> StridedViews<'a, T> {
    /// Carve `buf`, which must be exactly `outer * rows * inner * width`
    /// elements, into `outer * inner` strided views.
    pub fn new(buf: &'a mut [T], outer: usize, rows: usize, inner: usize, width: usize) -> Self {
        assert_eq!(
            buf.len(),
            outer * rows * inner * width,
            "StridedViews: buffer length must equal outer * rows * inner * width"
        );
        Self {
            base: buf.as_mut_ptr(),
            outer,
            rows,
            inner,
            width,
            #[cfg(debug_assertions)]
            claimed: new_claim_map(outer * inner),
            _marker: PhantomData,
        }
    }

    /// Number of units (views) this carve produces.
    pub fn units(&self) -> usize {
        self.outer * self.inner
    }

    /// The view owned by unit `u = o * inner + t`.
    ///
    /// Panics if `u` is out of range, and (in debug builds) if `u` was
    /// already claimed.
    pub fn view(&self, u: usize) -> StridedView<'a, T> {
        let units = self.units();
        assert!(u < units, "StridedViews: unit {u} out of range ({units} units)");
        #[cfg(debug_assertions)]
        claim(&self.claimed, u, "StridedViews");
        let o = u / self.inner;
        let t = u % self.inner;
        let stride = self.inner * self.width;
        // SAFETY: row r of unit (o, t) covers flat indices
        // [((o*rows + r)*inner + t)*width, +width). Two units agreeing on any
        // index would need equal o (outer blocks are disjoint), equal r (rows
        // within a block are disjoint runs of `stride`), and equal t (columns
        // within a row are disjoint `width` runs) — i.e. be the same unit.
        // o < outer and t < inner keep the base offset in bounds, and each
        // unit is claimed at most once (checked in debug builds), so no two
        // live views alias. Row bounds are checked in `StridedView::row`.
        let base = unsafe { self.base.add(o * self.rows * stride + t * self.width) };
        StridedView { base, rows: self.rows, stride, width: self.width, _marker: PhantomData }
    }
}

/// One unit of a [`StridedViews`] carve: `rows` non-contiguous runs of
/// `width` elements, `stride` apart. Not `Send`/`Sync` — it is constructed
/// on the worker thread that owns it, via the `Sync` carve.
pub struct StridedView<'a, T> {
    base: *mut T,
    rows: usize,
    stride: usize,
    width: usize,
    _marker: PhantomData<&'a mut [T]>,
}

impl<T> StridedView<'_, T> {
    /// Number of rows in this view.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row `r` of this view: `width` contiguous elements at offset
    /// `r * stride` from the view base.
    pub fn row(&mut self, r: usize) -> &mut [T] {
        assert!(r < self.rows, "StridedView: row {r} out of range ({} rows)", self.rows);
        // SAFETY: `base` points at flat index ((o*rows)*inner + t)*width of
        // the original buffer (see `StridedViews::view`), so `base + r*stride`
        // with r < rows starts a `width` run that stays inside the buffer
        // (worst case ends at ((o*rows + rows - 1)*inner + t + 1)*width
        // <= outer*rows*inner*width). The run lies wholly inside this unit's
        // disjoint index set, and the `&mut self` receiver prevents two live
        // row borrows from this view from coexisting.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(r * self.stride), self.width) }
    }
}

/// Erase the scope lifetime of a worker-pool body so it can be stored in the
/// pool's shared job slot.
///
/// This is the one lifetime transmute in the crate, relocated here from
/// `util::pool` so that module can forbid `unsafe`. The contract is the
/// pool's latch protocol (see `util::pool`): `parallel_for` publishes the
/// body, wakes the workers, and does not return until every worker has
/// signalled completion through the latch — so the `'static` view never
/// outlives the real `'scope` borrow it was created from.
///
/// Callers must uphold exactly that: the erased reference must not be used
/// after `parallel_for` returns. The pool clears the job slot before
/// returning, which Miri checks on every run.
pub(crate) fn erase_body_lifetime<'scope>(
    body: &'scope (dyn Fn(usize) + Sync),
) -> &'static (dyn Fn(usize) + Sync) {
    // SAFETY: lifetime-only transmute (the pointee type is unchanged). The
    // caller (util::pool::parallel_for) blocks on the completion latch until
    // no worker can still hold this reference, and clears the shared job
    // slot before returning, so the 'static alias is dead before 'scope ends.
    unsafe {
        std::mem::transmute::<&'scope (dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
            body,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_exactly_once() {
        let mut buf = vec![0u32; 10];
        let views = DisjointChunks::new(&mut buf, 4);
        assert_eq!(views.units(), 3);
        for u in 0..views.units() {
            for x in views.view(u).iter_mut() {
                *x += 1 + u as u32;
            }
        }
        assert_eq!(buf, vec![1, 1, 1, 1, 2, 2, 2, 2, 3, 3]);
    }

    #[test]
    fn chunks_clamp_last() {
        let mut buf = vec![0u8; 5];
        let views = DisjointChunks::new(&mut buf, 3);
        assert_eq!(views.view(0).len(), 3);
        assert_eq!(views.view(1).len(), 2);
    }

    #[test]
    fn empty_buffer_zero_units() {
        let mut buf: Vec<u64> = Vec::new();
        let views = DisjointChunks::new(&mut buf, 7);
        assert_eq!(views.units(), 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "claimed twice")]
    fn double_claim_panics() {
        let mut buf = vec![0i32; 8];
        let views = DisjointChunks::new(&mut buf, 4);
        let _a = views.view(1);
        let _b = views.view(1);
    }

    #[test]
    fn strided_units_cover_exactly_once() {
        let (outer, rows, inner, width) = (2, 3, 2, 4);
        let mut buf = vec![0u32; outer * rows * inner * width];
        let views = StridedViews::new(&mut buf, outer, rows, inner, width);
        assert_eq!(views.units(), outer * inner);
        for u in 0..views.units() {
            let mut v = views.view(u);
            for r in 0..v.rows() {
                for x in v.row(r).iter_mut() {
                    *x += 1;
                }
            }
        }
        assert!(buf.iter().all(|&x| x == 1), "every index written exactly once");
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "claimed twice")]
    fn strided_double_claim_panics() {
        let mut buf = vec![0i64; 2 * 2 * 2 * 2];
        let views = StridedViews::new(&mut buf, 2, 2, 2, 2);
        let _a = views.view(3);
        let _b = views.view(3);
    }
}
