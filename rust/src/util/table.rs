//! Plain-text table and CSV rendering for experiment outputs.
//!
//! Every bench/figure driver prints the same rows the paper reports; this
//! module keeps the formatting consistent and writes the machine-readable
//! CSV next to the human-readable table.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render as an aligned monospace table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV with minimal quoting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }
}

/// Format helpers used across the experiment drivers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn si(x: f64) -> String {
    // engineering notation: 1.2k / 3.4M / 5.6G
    let (v, suffix) = if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "x"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer"));
        let lines: Vec<&str> = r.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "p\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"p\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(1234.0), "1.23k");
        assert_eq!(si(2.5e9), "2.50G");
        assert_eq!(si(12.0), "12.00");
    }
}
