//! Zero-dependency substrates: JSON, PRNG, CLI parsing, statistics, tables.
//!
//! The offline build environment only vendors the `xla` + `anyhow` crates,
//! so the pieces a production launcher would normally pull from serde /
//! clap / rand / criterion live here, with their own test suites.

pub mod bench;
pub mod cli;
pub mod json;
pub mod lint;
pub mod pool;
pub mod rng;
pub mod shard;
pub mod stats;
pub mod table;
