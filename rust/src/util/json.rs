//! Minimal JSON parser/writer.
//!
//! The build environment is offline and `serde`/`serde_json` are not in the
//! vendored crate set, so the coordinator carries its own JSON support for
//! the artifact manifest (read) and metric sinks (write). The parser is a
//! strict recursive-descent implementation with byte-offset error reporting;
//! the writer escapes per RFC 8259.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers keep their `f64` form; the manifest only
/// stores integers that are exactly representable.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Path lookup: `get("variants")` on objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }
    /// Lookup that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::new(format!("missing required key {key:?}"), 0))
    }
    /// Required string field — for rebuilding rows from stored documents.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError::new(format!("key {key:?} is not a string"), 0))
    }
    /// Required numeric field; see [`Value::req_str`].
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError::new(format!("key {key:?} is not a number"), 0))
    }
    /// Required non-negative integer field; see [`Value::req_str`].
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| JsonError::new(format!("key {key:?} is not a non-negative integer"), 0))
    }
    /// Required u64 field; see [`Value::req_str`].
    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        Ok(self.req_usize(key)? as u64)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl JsonError {
    fn new(msg: impl Into<String>, offset: usize) -> Self {
        Self { msg: msg.into(), offset }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(JsonError::new("trailing data after document", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::new(format!("expected {:?}", c as char), self.i))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(JsonError::new(format!("unexpected byte {:?}", c as char), self.i)),
            None => Err(JsonError::new("unexpected end of input", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::new(format!("invalid literal, expected {s}"), self.i))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(JsonError::new("expected ',' or '}'", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(out)),
                _ => return Err(JsonError::new("expected ',' or ']'", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(JsonError::new("unterminated string", self.i)),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(JsonError::new("lone high surrogate", self.i));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| JsonError::new("invalid codepoint", self.i))?);
                    }
                    _ => return Err(JsonError::new("bad escape", self.i)),
                },
                Some(c) if c < 0x20 => {
                    return Err(JsonError::new("control char in string", self.i))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-by-byte
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(JsonError::new("truncated utf-8", self.i));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| JsonError::new("invalid utf-8", start))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| JsonError::new("eof in \\u", self.i))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| JsonError::new("bad hex digit", self.i))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| JsonError::new(format!("bad number {text:?}"), start))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// --------------------------------------------------------------------------
// writer
// --------------------------------------------------------------------------

/// Serialize a [`Value`] compactly.
pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::String(s) => write_escaped(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Value::Object(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the metric sinks.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Number(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::String(v.into())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::String("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::String("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::String("😀".into()));
    }

    #[test]
    fn parses_raw_utf8() {
        assert_eq!(parse("\"汉字\"").unwrap(), Value::String("汉字".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\x01\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(write(&Value::Number(42.0)), "42");
        assert_eq!(write(&Value::Number(0.5)), "0.5");
    }
}
