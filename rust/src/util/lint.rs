//! `m6t lint-unsafe` — the crate's unsafe-budget ratchet.
//!
//! A std-only scanner (no syn, no external parser) that walks the Rust
//! sources, counts `unsafe` tokens outside comments and literals, and
//! compares them against the checked-in allowlist
//! (`rust/unsafe_allowlist.txt`). The budget is exact in both directions:
//! a new site fails until the allowlist is consciously edited, and a
//! removed site fails until the budget is ratcheted *down*, so the
//! allowlist always states the audited truth. Every counted site must
//! also carry an adjacent `// SAFETY:` comment — on the same line, or in
//! the contiguous `//` comment block directly above it.
//!
//! The tokenizer is deliberately small: it blanks line comments, nested
//! block comments, string / raw-string / char literals (lifetimes are
//! left alone), then matches the word `unsafe` on identifier boundaries.
//! That is exact for the rustfmt'd code in this repository; it does not
//! try to handle macro-generated `unsafe` or pathological token pastes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Directories (relative to the repo root) that are scanned. Vendored
/// code under `third_party/` is intentionally outside the budget.
pub const SCAN_ROOTS: [&str; 3] = ["rust", "benches", "examples"];

/// Directory names skipped wherever they appear (build output, vendored
/// trees, test fixtures).
const SKIP_DIRS: [&str; 3] = ["target", "third_party", "fixtures"];

/// One scanned file: the token count plus the 1-based lines of counted
/// tokens that have no adjacent `// SAFETY:` comment.
struct FileScan {
    count: usize,
    missing_safety: Vec<usize>,
}

/// The outcome of a full scan. Violations are collected (not failed
/// one-by-one) so a single run reports everything to fix.
pub struct Report {
    pub files_scanned: usize,
    pub unsafe_sites: usize,
    pub violations: Vec<String>,
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// If `b[i]` starts a raw string (`r"` / `r#"` / `r##"` ...), the number
/// of hashes; `None` otherwise.
fn raw_string_hashes(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some(j - i - 1)
    } else {
        None
    }
}

/// True when `b[i]` is the closing `"` of a raw string with `hashes`
/// trailing `#`s.
fn raw_string_closes(b: &[u8], i: usize, hashes: usize) -> bool {
    b[i] == b'"'
        && b[i + 1..].len() >= hashes
        && b[i + 1..i + 1 + hashes].iter().all(|&c| c == b'#')
}

/// Blank a quoted string body starting just after the opening quote,
/// keeping newlines so line numbers survive.
fn blank_string_body(b: &[u8], i: &mut usize, out: &mut Vec<u8>) {
    while *i < b.len() {
        match b[*i] {
            b'\\' if *i + 1 < b.len() => {
                out.push(b' ');
                out.push(if b[*i + 1] == b'\n' { b'\n' } else { b' ' });
                *i += 2;
            }
            b'"' => {
                out.push(b' ');
                *i += 1;
                return;
            }
            b'\n' => {
                out.push(b'\n');
                *i += 1;
            }
            _ => {
                out.push(b' ');
                *i += 1;
            }
        }
    }
}

/// A copy of `src` with comments and literals blanked to spaces (newlines
/// kept), so a word search over it sees only real tokens. Output length
/// and line structure match the input exactly.
fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'/' if b.get(i + 1) == Some(&b'/') => {
                while i < b.len() && b[i] != b'\n' {
                    out.push(b' ');
                    i += 1;
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                out.extend_from_slice(b"  ");
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else {
                        out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                        i += 1;
                    }
                }
            }
            b'"' => {
                out.push(b' ');
                i += 1;
                blank_string_body(b, &mut i, &mut out);
            }
            b'r' if (i == 0 || !is_ident_byte(b[i - 1])) && raw_string_hashes(b, i).is_some() => {
                let hashes = raw_string_hashes(b, i).unwrap();
                for _ in 0..hashes + 2 {
                    out.push(b' ');
                }
                i += hashes + 2;
                while i < b.len() {
                    if raw_string_closes(b, i, hashes) {
                        for _ in 0..hashes + 1 {
                            out.push(b' ');
                        }
                        i += hashes + 1;
                        break;
                    }
                    out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            b'\'' => {
                if b.get(i + 1) == Some(&b'\\') {
                    // escaped char literal: blank through the closing quote
                    out.extend_from_slice(b"  ");
                    i += 2;
                    while i < b.len() && b[i] != b'\'' {
                        let step = if b[i] == b'\\' && i + 1 < b.len() { 2 } else { 1 };
                        for _ in 0..step {
                            out.push(b' ');
                        }
                        i += step;
                    }
                    if i < b.len() {
                        out.push(b' ');
                        i += 1;
                    }
                } else if b.get(i + 2) == Some(&b'\'') && b.get(i + 1) != Some(&b'\'') {
                    // simple one-byte char literal 'x'
                    out.extend_from_slice(b"   ");
                    i += 3;
                } else {
                    // lifetime or loop label: the tick is plain code
                    out.push(b'\'');
                    i += 1;
                }
            }
            other => {
                out.push(other);
                i += 1;
            }
        }
    }
    String::from_utf8(out).expect("stripped source stays valid UTF-8")
}

/// Does line `idx` (0-based, in the original source) carry an adjacent
/// `SAFETY` marker: on the line itself, or in the contiguous `//` comment
/// block directly above it?
fn has_adjacent_safety(lines: &[&str], idx: usize) -> bool {
    if lines.get(idx).is_some_and(|l| l.contains("SAFETY")) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let t = lines[k].trim_start();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains("SAFETY") {
            return true;
        }
    }
    false
}

fn scan(src: &str) -> FileScan {
    let stripped = strip(src);
    let orig: Vec<&str> = src.lines().collect();
    let mut count = 0;
    let mut missing_safety = Vec::new();
    for (idx, line) in stripped.lines().enumerate() {
        let bytes = line.as_bytes();
        let mut from = 0;
        while let Some(pos) = line[from..].find("unsafe") {
            let at = from + pos;
            let end = at + "unsafe".len();
            from = end;
            let left_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
            let right_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
            if !(left_ok && right_ok) {
                continue;
            }
            count += 1;
            if !has_adjacent_safety(&orig, idx) {
                missing_safety.push(idx + 1);
            }
        }
    }
    FileScan { count, missing_safety }
}

/// Parse the allowlist: `<path> <count>` per line, `#` comments, blanks.
fn parse_allowlist(text: &str, path: &Path) -> Result<BTreeMap<String, usize>> {
    let mut map = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(file), Some(count), None) = (it.next(), it.next(), it.next()) else {
            bail!("{}:{}: expected `<path> <count>`, got {raw:?}", path.display(), ln + 1);
        };
        let count: usize = count
            .parse()
            .with_context(|| format!("{}:{}: bad count {count:?}", path.display(), ln + 1))?;
        if map.insert(file.to_string(), count).is_some() {
            bail!("{}:{}: duplicate entry for {file}", path.display(), ln + 1);
        }
    }
    Ok(map)
}

/// Recursively collect `.rs` files under `dir`, sorted for deterministic
/// reports, skipping [`SKIP_DIRS`].
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("listing {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        let name = e.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_ref()) {
                collect_rs_files(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan [`SCAN_ROOTS`] under `root` against the allowlist at
/// `allowlist`. The returned report carries every violation; an empty
/// `violations` means the budget holds exactly.
pub fn run(root: &Path, allowlist: &Path) -> Result<Report> {
    let text = std::fs::read_to_string(allowlist)
        .with_context(|| format!("reading allowlist {}", allowlist.display()))?;
    let mut budget = parse_allowlist(&text, allowlist)?;
    let mut files = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs_files(&dir, &mut files)?;
        }
    }
    let mut violations = Vec::new();
    let mut unsafe_sites = 0;
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let file = scan(&src);
        unsafe_sites += file.count;
        for line in &file.missing_safety {
            violations.push(format!(
                "{rel}:{line}: `unsafe` without an adjacent `// SAFETY:` comment"
            ));
        }
        match budget.remove(&rel) {
            None if file.count > 0 => violations.push(format!(
                "{rel}: {} `unsafe` site(s) outside the audited budget — express the \
                 layout via util::shard instead of adding a new allowlist entry",
                file.count
            )),
            Some(allowed) if allowed != file.count => violations.push(format!(
                "{rel}: {} `unsafe` site(s) but the allowlist says {allowed} — ratchet \
                 {} to match the audited count",
                file.count,
                allowlist.display()
            )),
            _ => {}
        }
    }
    for (path, allowed) in budget {
        violations.push(format!(
            "{path}: allowlisted ({allowed} site(s)) but no such file was scanned — stale entry"
        ));
    }
    Ok(Report { files_scanned: files.len(), unsafe_sites, violations })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_literals_and_identifiers_do_not_count() {
        let src = "#![allow(unsafe_code)]\n\
                   // a comment mentioning unsafe code\n\
                   let s = \"unsafe in a string\";\n\
                   let e = \"escaped quote \\\" then unsafe\";\n\
                   let c = 'u';\n\
                   fn lt<'a>(x: &'a u32) -> &'a u32 { x }\n\
                   /* block unsafe /* nested unsafe */ still a comment */\n\
                   let n = do_unsafe_things();\n";
        let f = scan(src);
        assert_eq!(f.count, 0, "only real tokens may count");
    }

    #[test]
    fn counts_real_sites_and_flags_missing_safety() {
        let src = "// SAFETY: the pointer is valid for the whole call.\n\
                   let a = unsafe { *p };\n\
                   let b = unsafe { *q };\n";
        let f = scan(src);
        assert_eq!(f.count, 2);
        assert_eq!(f.missing_safety, vec![3], "line 3 has no adjacent SAFETY comment");
    }

    #[test]
    fn safety_walk_spans_the_whole_comment_block() {
        let src = "// SAFETY: a long justification\n\
                   // that continues over several lines\n\
                   // before the site itself.\n\
                   let a = unsafe { *p };\n";
        assert!(scan(src).missing_safety.is_empty());
    }

    #[test]
    fn raw_strings_chars_and_same_line_safety() {
        let src = "let r = r#\"unsafe\"#;\n\
                   let t = '\\n';\n\
                   let u = unsafe { f() }; // SAFETY: covered on this line\n";
        let f = scan(src);
        assert_eq!(f.count, 1);
        assert!(f.missing_safety.is_empty(), "same-line SAFETY must count");
    }

    #[test]
    fn allowlist_parses_comments_and_rejects_junk() {
        let p = Path::new("unsafe_allowlist.txt");
        let m = parse_allowlist("# header\nrust/src/util/shard.rs 8\n\n", p).unwrap();
        assert_eq!(m.get("rust/src/util/shard.rs"), Some(&8));
        assert!(parse_allowlist("rust/a.rs\n", p).is_err(), "missing count");
        assert!(parse_allowlist("rust/a.rs eight\n", p).is_err(), "non-numeric count");
        assert!(parse_allowlist("rust/a.rs 1 extra\n", p).is_err(), "trailing junk");
        assert!(
            parse_allowlist("rust/a.rs 1\nrust/a.rs 2\n", p).is_err(),
            "duplicate entries must be rejected"
        );
    }

    /// The real repository budget, enforced by plain `cargo test`: the
    /// allowlist is confined to `util::shard` and matches it exactly.
    #[test]
    fn the_repo_budget_holds() {
        let rust_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = rust_dir.parent().expect("crate lives one level under the repo root");
        let report = run(root, &root.join("rust/unsafe_allowlist.txt")).unwrap();
        assert!(report.violations.is_empty(), "{:#?}", report.violations);
        assert!(report.unsafe_sites > 0, "the shard module's sites must be visible");
    }
}
