//! Deterministic PRNG for the data pipeline, simulators, and property tests.
//!
//! SplitMix64 seeding + xoshiro256** core: fast, high quality, and — unlike
//! external crates — available offline. Every consumer of randomness in the
//! repo takes an explicit [`Rng`] so corpora, routing traces, and test cases
//! are exactly reproducible from a seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (the jax `fold_in` idiom).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV offset
        for s in self.s {
            h = (h ^ s).wrapping_mul(0x100000001b3);
        }
        Rng::new(h ^ data.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from Zipf(s) over [0, n) — used for skewed routing workloads.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on precomputed-free harmonic approximation: rejection
        // sampling keeps it allocation-free for the hot loop.
        loop {
            let u = self.uniform();
            let v = self.uniform();
            let x = ((n as f64).powf(1.0 - s).mul_add(u, 1.0 - u)).powf(1.0 / (1.0 - s));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                let ratio = (k as f64 / x).powf(s);
                if v * ratio <= 1.0 {
                    return k - 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of tolerance");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0]);
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(19);
        let mut counts = vec![0usize; 16];
        for _ in 0..20_000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[0] > counts[15] * 4, "{counts:?}");
    }

    #[test]
    fn fold_in_streams_differ() {
        let base = Rng::new(23);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
