//! Streaming statistics helpers shared by metrics, the cluster simulator,
//! and the benchmark harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Coefficient of variation c_v = sigma / mu over a slice — the paper's
/// load-balance metric (§3.1, after Shazeer et al. 2017). Returns 0 for an
/// all-zero or empty slice (a degenerate but fully "balanced" load).
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// Exponential moving average, bias-corrected like Adam's first moment so
/// early values are not dragged toward zero.
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta));
        Self { beta, value: 0.0, steps: 0 }
    }
    pub fn push(&mut self, x: f64) {
        self.steps += 1;
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
    }
    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.value / (1.0 - self.beta.powi(self.steps as i32))
    }
}

/// Percentile over a scratch copy, linearly interpolated between the two
/// bracketing order statistics (the "linear"/type-7 rule). p in [0, 100].
///
/// The pre-serving-runtime version rounded to the nearest rank, which on
/// tiny samples biased tails by up to half a sample gap (e.g. the median
/// of `[1, 2]` came out as 2.0, and a 12-step timing series could not
/// distinguish p95 from p100); interpolation makes small-sample
/// percentiles exact and monotone in `p`.
///
/// NaN-tolerant: `f64::total_cmp` sorts NaNs to the end instead of
/// panicking the way `partial_cmp().unwrap()` used to — a NaN-poisoned
/// latency series degrades the top percentiles rather than killing the
/// whole report.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0).clamp(0.0, 1.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = (lo + 1).min(v.len() - 1);
    let frac = rank - lo as f64;
    if frac == 0.0 {
        v[lo]
    } else {
        v[lo] + frac * (v[hi] - v[lo])
    }
}

/// Median of a timing series. Thin [`percentile`] wrapper so every
/// harness spells "p50" the same way (interpolated, NaN-tolerant).
pub fn p50(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Tail latency of a timing series; see [`p50`].
pub fn p95(xs: &[f64]) -> f64 {
    percentile(xs, 95.0)
}

/// Tail latency of a latency series (serve-sim's SLO percentile); see
/// [`p50`].
pub fn p99(xs: &[f64]) -> f64 {
    percentile(xs, 99.0)
}

/// Extreme-tail latency (p99.9); see [`p50`]. Only meaningful once the
/// series holds on the order of a thousand samples — below that it
/// interpolates between the top two order statistics.
pub fn p999(xs: &[f64]) -> f64 {
    percentile(xs, 99.9)
}

/// Normalize a raw per-step timing series for percentile reads: drop the
/// first `warmup` samples (cold caches, lazy init) and sort ascending.
/// Every bench harness used to hand-roll this skip-sort pair.
pub fn timing_series(samples: impl IntoIterator<Item = f64>, warmup: usize) -> Vec<f64> {
    let mut ms: Vec<f64> = samples.into_iter().skip(warmup).collect();
    ms.sort_by(f64::total_cmp);
    ms
}

/// Time `f` with one untimed warmup call followed by `reps` timed calls;
/// returns the sorted per-call milliseconds (feed to [`p50`] / [`p95`]).
pub fn measure_fn_ms(reps: usize, mut f: impl FnMut()) -> Vec<f64> {
    f();
    let raw = (0..reps).map(|_| {
        let t0 = std::time::Instant::now();
        f();
        t0.elapsed().as_secs_f64() * 1e3
    });
    timing_series(raw, 0)
}

/// Least-squares fit of y = a + b x. Returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > 1e-12, "degenerate x values in linear fit");
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 4.0).abs() < 1e-12);
        assert!((w.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_balanced_is_zero() {
        assert_eq!(coefficient_of_variation(&[5.0, 5.0, 5.0]), 0.0);
        assert_eq!(coefficient_of_variation(&[]), 0.0);
        assert_eq!(coefficient_of_variation(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn cv_imbalanced_grows() {
        let even = coefficient_of_variation(&[10.0, 10.0, 10.0, 10.0]);
        let skew = coefficient_of_variation(&[40.0, 0.0, 0.0, 0.0]);
        assert!(skew > even);
        assert!((skew - (3.0f64).sqrt()).abs() < 1e-9); // sigma/mu for one-hot
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.push(3.0);
        }
        assert!((e.get() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ema_bias_correction_early() {
        let mut e = Ema::new(0.99);
        e.push(5.0);
        assert!((e.get() - 5.0).abs() < 1e-9, "first value should be exact");
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        // off-rank percentiles interpolate: p25 of five samples sits a
        // quarter of the way between the 1st and 2nd order statistics
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates_tiny_samples() {
        // regression: nearest-rank rounded the median of [1, 2] up to 2.0
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.5);
        assert_eq!(percentile(&[10.0, 20.0, 30.0, 40.0], 50.0), 25.0);
        assert_eq!(percentile(&[7.0], 99.9), 7.0);
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 120.0), 2.0);
    }

    #[test]
    fn tail_percentiles_pin_exact_values_on_known_series() {
        // 1..=100: rank r maps to value r+1, so p99 = 99 + 0.01 * 99
        let xs: Vec<f64> = (1..=100).map(f64::from).collect();
        assert!((p99(&xs) - 99.01).abs() < 1e-9, "p99 {}", p99(&xs));
        assert!((p999(&xs) - 99.901).abs() < 1e-9, "p999 {}", p999(&xs));
        assert_eq!(p50(&xs), 50.5);
        // 0..=1000: the ranks land exactly on order statistics
        let ys: Vec<f64> = (0..=1000).map(f64::from).collect();
        assert!((p99(&ys) - 990.0).abs() < 1e-9);
        assert!((p999(&ys) - 999.0).abs() < 1e-9);
        // percentiles are monotone in p
        assert!(p50(&ys) <= p95(&ys) && p95(&ys) <= p99(&ys) && p99(&ys) <= p999(&ys));
    }

    #[test]
    fn percentile_tolerates_nan() {
        // regression: partial_cmp().unwrap() panicked on NaN input
        let xs = [f64::NAN, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0, "finite values sort below NaN");
        assert_eq!(percentile(&xs, 50.0), 2.5, "median interpolates the finite middle");
        assert!(percentile(&xs, 100.0).is_nan(), "NaN occupies the top rank");
        assert!(percentile(&xs, 99.0).is_nan(), "interpolating against NaN degrades");
        // all-NaN input still must not panic
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn timing_series_skips_warmup_and_sorts() {
        let ms = timing_series([9.0, 3.0, 1.0, 2.0], 1);
        assert_eq!(ms, vec![1.0, 2.0, 3.0]);
        assert_eq!(p50(&ms), 2.0);
        assert!(timing_series([5.0], 1).is_empty());
    }

    #[test]
    fn measure_fn_ms_calls_warmup_plus_reps() {
        let mut calls = 0;
        let ms = measure_fn_ms(4, || calls += 1);
        assert_eq!(calls, 5, "one warmup call plus four timed reps");
        assert_eq!(ms.len(), 4);
        assert!(ms.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
    }
}
