//! Overlap/topology bench: the link-level, overlap-aware cluster model
//! (`cluster::topology`) swept over {base, large, xlarge-sim geometries}
//! x {top1, top2, 2top1} x D in {4, 8, 16} x {flat, hierarchical}
//! topologies.
//!
//! Shared by `m6t bench --overlap` (and the CI smoke + regression gate);
//! writes the tracked trajectory `BENCH_overlap.json`. Each cell runs a
//! few [`ShardedRun`] steps and records the serial-vs-overlapped cluster
//! step time, the overlap efficiency (fraction of link-model comm hidden
//! behind compute), and the bottleneck link (which worker pair carries
//! the exchange). Every cell also re-derives the serial number through a
//! [`StepInputs`] run and insists on bitwise equality — the
//! `--no-overlap` baseline can never silently drift from the pre-overlap
//! model.
//!
//! The grid is declared as a [`SweepSpec`] and driven through the
//! [`Engine`]'s content-addressed store (the timing bench binary forces
//! re-measurement).
//!
//! The two top-level regression fields:
//!  * `min_overlap_speedup` — minimum serial/overlapped ratio over every
//!    cell; the model guarantees >= 1.0 (the serial schedule is always
//!    admissible), so a value below 1.0 means the cost model broke;
//!  * `max_bottleneck_link_share` — how concentrated the worst cell's
//!    exchange is on a single link (1.0 = one link is the whole story).

use anyhow::{bail, ensure, Context as _, Result};

use crate::cluster::{table2_hardware, ObservedTraffic, StepInputs};
use crate::config::{CapacityMode, ModelConfig, Routing};
use crate::metrics::RunLog;
use crate::runtime::native::registry;
use crate::runtime::shard::ShardedRun;
use crate::sweep::{self, Cell, Engine, SweepOutcome, SweepSpec};
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::stats::{p50, timing_series};
use crate::util::table::{f2, Table};

/// Code-relevant version tag in every overlap cell's store address.
pub const STORE_VERSION: &str = "overlap-v1";

/// Store version for the placement cells (the `placement` sweep kind) —
/// bump when the search or the row semantics change.
pub const PLACEMENT_STORE_VERSION: &str = "placement-v1";

/// The benched geometries: the sim-scale E = 16 / 32 / 64 twins.
const GEOMETRIES: [&str; 3] = ["base-sim", "large-sim", "xlarge-sim"];

/// Workers per node in the hierarchical cells (the flat cells use 1).
pub const HIER_WORKERS_PER_NODE: usize = 4;

/// The benched grid as a declarative spec: 3 geometries x 3 strategies x
/// D in {4, 8, 16} x workers-per-node in {1, 4} — 54 cells, last axis
/// fastest.
pub fn spec(steps: usize) -> SweepSpec {
    SweepSpec::new("overlap", "overlap")
        .steps(steps)
        .axis("model", sweep::strs(&GEOMETRIES))
        .axis("strategy", sweep::strs(&["top1@kx", "top2@1x", "2top1@1x"]))
        .axis("workers", sweep::nums(&[4, 8, 16]))
        .axis("workers_per_node", sweep::nums(&[1, HIER_WORKERS_PER_NODE]))
}

/// The placement grid: skewed sim geometries on the hierarchical nodes4
/// topology at D in {4, 8}, full greedy+swap search. Flat topologies are
/// excluded — with every link priced equally the search can still
/// localize traffic, but the tiered testbed is where the co-location
/// question the bench answers actually arises.
pub fn placement_spec(steps: usize) -> SweepSpec {
    SweepSpec::new("placement", "placement")
        .steps(steps)
        .axis("model", sweep::strs(&["base-sim", "large-sim"]))
        .axis("workers", sweep::nums(&[4, 8]))
}

/// Materialize a placement cell into its config.
fn placement_cell_config(cell: &Cell) -> Result<(ModelConfig, usize)> {
    let geo = cell.req_str("model")?;
    let Some(cfg) = registry().into_iter().find(|c| c.name == geo) else {
        bail!("placement cell: unknown geometry {geo:?}");
    };
    let workers = cell.req_usize("workers")?;
    Ok((cfg, workers))
}

/// Fold the resolved config into a placement cell before hashing.
pub fn resolve_placement_cell(cell: &Cell) -> Result<Cell> {
    let (cfg, _) = placement_cell_config(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&cfg));
    Ok(resolved)
}

/// Materialize a spec-level cell into the config the runtime consumes.
fn cell_config(cell: &Cell) -> Result<(ModelConfig, usize, usize)> {
    let geo = cell.req_str("model")?;
    let Some(base) = registry().into_iter().find(|c| c.name == geo) else {
        bail!("overlap cell: unknown geometry {geo:?}");
    };
    let (routing, mode) = sweep::parse_strategy(cell.req_str("strategy")?)?;
    let workers = cell.req_usize("workers")?;
    let wpn = cell.req_usize("workers_per_node")?;
    let mut cfg = base;
    cfg.name = format!("{geo}-{}", routing.name());
    cfg.routing = routing;
    cfg.capacity_mode = mode;
    Ok((cfg, workers, wpn))
}

/// Fold the fully-resolved model config into the cell before hashing.
pub fn resolve_cell(cell: &Cell) -> Result<Cell> {
    let (cfg, _, _) = cell_config(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&cfg));
    Ok(resolved)
}

/// The benched grid in legacy form; kept as the oracle the spec-based
/// expansion is tested against.
pub fn cases() -> Vec<(ModelConfig, usize, usize)> {
    let mut out = Vec::new();
    for cell in spec(12).expand().expect("builtin overlap spec expands") {
        out.push(cell_config(&cell).expect("builtin overlap cell resolves"));
    }
    out
}

/// One measured (geometry, strategy, D, topology) cell.
#[derive(Debug, Clone)]
pub struct OverlapBenchRow {
    pub model: String,
    pub strategy: String,
    pub workers: usize,
    pub topology: String,
    pub workers_per_node: usize,
    pub tokens_per_worker: usize,
    /// measured all-to-all MB per step (all 4 directions)
    pub a2a_mb_step: f64,
    /// bytes on the most-loaded link / total cross bytes (one direction)
    pub bottleneck_link_share: f64,
    pub bottleneck_src: usize,
    pub bottleneck_dst: usize,
    /// pre-overlap serial observed cluster ms (the `--no-overlap` oracle)
    pub serial_ms: f64,
    /// link-level pipelined cluster ms
    pub overlapped_ms: f64,
    /// fraction of link-model comm hidden behind compute
    pub overlap_efficiency: f64,
    /// median measured host ms per sharded step
    pub host_ms: f64,
}

impl OverlapBenchRow {
    /// Serial / overlapped (>= 1.0 by construction) over the row's
    /// recorded fields — the per-row regression field the CI gate floors
    /// at 1.0. Same convention as
    /// [`DispatchSummary::overlap_speedup`](crate::moe::DispatchSummary::overlap_speedup),
    /// which the live summary carries.
    pub fn overlap_speedup(&self) -> f64 {
        if self.overlapped_ms > 0.0 {
            self.serial_ms / self.overlapped_ms
        } else {
            1.0
        }
    }
}

/// Execute one cell: `steps` measured sharded steps plus the bitwise
/// serial-oracle and overlap-monotonicity checks.
pub fn run_cell(cell: &Cell) -> Result<Value> {
    let (cfg, workers, wpn) = cell_config(cell)?;
    let steps = cell.req_usize("steps")?.max(1);
    let seed = cell.req_u64("seed")?;
    let hw = table2_hardware();
    let mut run = ShardedRun::new(&cfg, workers)?;
    run.set_workers_per_node(wpn);
    let topo = run.topology();
    let mut log = RunLog::new(format!("{}-d{workers}-{}", cfg.name, topo.name()));
    // one extra leading step carries the cold allocations, matching
    // the other bench harnesses' warmup discard
    run.train(steps as i64 + 1, seed, &mut log, false)?;
    let ms = timing_series(log.records.iter().map(|r| r.ms_per_step), 1);
    let host_ms = p50(&ms);
    let last = log.last().expect("at least one recorded step");
    let dsp = last.dispatch.as_ref().expect("sharded records carry dispatch");

    // the serial baseline must BE the pre-overlap observed model
    // (the run's own config carries workers = D, which the simulator
    // reads for the latency hop count)
    let run_cfg = run.info().config.clone();
    let observed = ObservedTraffic {
        a2a_bytes_per_layer: dsp.a2a_bytes_per_layer,
        shard_balance: dsp.shard_balance,
    };
    let oracle = StepInputs::new(&run_cfg, &hw)
        .routing(cfg.routing)
        .capacity_mode(cfg.capacity_mode)
        .observed(&observed)
        .run()
        .serial_ms();
    ensure!(
        dsp.observed_ms.to_bits() == oracle.to_bits(),
        "{} D={workers} {}: serial baseline drifted from the StepInputs serial oracle",
        cfg.name,
        topo.name()
    );
    ensure!(
        dsp.observed_overlap_ms <= dsp.observed_ms,
        "{} D={workers} {}: overlap made the step slower",
        cfg.name,
        topo.name()
    );

    let row = OverlapBenchRow {
        model: cfg.name.clone(),
        strategy: cfg.routing.name(),
        workers,
        topology: topo.name(),
        workers_per_node: wpn,
        tokens_per_worker: cfg.tokens_per_batch(),
        a2a_mb_step: dsp.a2a_bytes_step / 1e6,
        bottleneck_link_share: dsp.bottleneck_link_share(),
        bottleneck_src: dsp.bottleneck_src,
        bottleneck_dst: dsp.bottleneck_dst,
        serial_ms: dsp.observed_ms,
        overlapped_ms: dsp.observed_overlap_ms,
        overlap_efficiency: dsp.overlap_efficiency,
        host_ms,
    };
    eprintln!(
        "[bench] {} D={} {}: serial {:.1} ms -> overlapped {:.1} ms ({:.2}x, eff {:.2}), link share {:.2}",
        row.model,
        row.workers,
        row.topology,
        row.serial_ms,
        row.overlapped_ms,
        row.overlap_speedup(),
        row.overlap_efficiency,
        row.bottleneck_link_share
    );
    Ok(row_json(&row))
}

/// One measured placement cell: the greedy+swap search against the
/// identity layout on the hierarchical topology, same step, same traffic.
#[derive(Debug, Clone)]
pub struct PlacementBenchRow {
    pub model: String,
    pub workers: usize,
    pub workers_per_node: usize,
    /// identity-layout bottleneck share of the exact byte total
    pub identity_share: f64,
    /// placed-layout bottleneck share (same denominator)
    pub placed_share: f64,
    /// placed − identity; the CI gate floors this at <= 0
    pub share_delta: f64,
    /// identity / placed bottleneck seconds on the step-summed traffic
    /// (>= 1.0 structurally: the search falls back to identity)
    pub placement_gain: f64,
    /// link-level pipelined cluster ms under the placed layout
    pub overlapped_ms: f64,
}

/// Execute one placement cell: one sharded run on nodes4 with the full
/// greedy+swap search active, recording how the placed layout priced
/// against identity on the run's own measured traffic.
pub fn run_placement_cell(cell: &Cell) -> Result<Value> {
    let (cfg, workers) = placement_cell_config(cell)?;
    let steps = cell.req_usize("steps")?.max(1);
    let seed = cell.req_u64("seed")?;
    let mut run = ShardedRun::new(&cfg, workers)?;
    run.set_workers_per_node(HIER_WORKERS_PER_NODE);
    run.set_placement(crate::cluster::PlacementStrategy::Swap);
    let mut log = RunLog::new(format!("{}-placed-d{workers}", cfg.name));
    run.train(steps as i64 + 1, seed, &mut log, false)?;
    let last = log.last().expect("at least one recorded step");
    let dsp = last.dispatch.as_ref().expect("sharded records carry dispatch");
    let identity_share = dsp.bottleneck_link_share();
    let row = PlacementBenchRow {
        model: cfg.name.clone(),
        workers,
        workers_per_node: HIER_WORKERS_PER_NODE,
        identity_share,
        placed_share: dsp.placed_link_share,
        share_delta: dsp.placed_link_share - identity_share,
        placement_gain: dsp.placement_gain,
        overlapped_ms: dsp.observed_overlap_ms,
    };
    eprintln!(
        "[bench] {} D={} placement: gain {:.3}x, link share {:.3} -> {:.3} (delta {:+.3})",
        row.model,
        row.workers,
        row.placement_gain,
        row.identity_share,
        row.placed_share,
        row.share_delta
    );
    Ok(placement_row_json(&row))
}

/// Run the placement grid through the sweep engine.
pub fn run_placement_suite(
    engine: &Engine,
    steps: usize,
) -> Result<(Vec<PlacementBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&placement_spec(steps), &sweep::PlacementRunner)?;
    let rows = placement_rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed placement rows from a sweep outcome.
pub fn placement_rows_from(outcome: &SweepOutcome) -> Result<Vec<PlacementBenchRow>> {
    outcome.outcomes.iter().map(|o| placement_row_from_json(&o.result)).collect()
}

/// Run the full grid through the sweep engine, `steps` measured sharded
/// steps per cell; previously-completed cells come back from the store.
pub fn run_suite(engine: &Engine, steps: usize) -> Result<(Vec<OverlapBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&spec(steps), &sweep::OverlapRunner)?;
    let rows = rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed rows from a sweep outcome's stored documents.
pub fn rows_from(outcome: &SweepOutcome) -> Result<Vec<OverlapBenchRow>> {
    outcome.outcomes.iter().map(|o| row_from_json(&o.result)).collect()
}

/// Minimum overlap speedup over every cell — the CI gate's floor (1.0 is
/// structural; below it the cost model broke). 0 when there are no rows,
/// so an empty JSON fails the gate instead of passing it.
pub fn min_overlap_speedup(rows: &[OverlapBenchRow]) -> f64 {
    let min = rows.iter().map(OverlapBenchRow::overlap_speedup).fold(f64::INFINITY, f64::min);
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Worst-cell bottleneck concentration.
pub fn max_bottleneck_link_share(rows: &[OverlapBenchRow]) -> f64 {
    rows.iter().map(|r| r.bottleneck_link_share).fold(0.0f64, f64::max)
}

/// Minimum placement gain over the placement cells — the CI gate floors
/// this at 1.0 (structural: the search falls back to identity). 0 when
/// there are no rows, so an empty suite fails the gate.
pub fn min_placement_gain(rows: &[PlacementBenchRow]) -> f64 {
    let min = rows.iter().map(|r| r.placement_gain).fold(f64::INFINITY, f64::min);
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Worst placed − identity bottleneck-share delta — the CI gate floors
/// this at <= 0. 1 (a failing delta) when there are no rows.
pub fn max_placement_share_delta(rows: &[PlacementBenchRow]) -> f64 {
    let max = rows.iter().map(|r| r.share_delta).fold(f64::NEG_INFINITY, f64::max);
    if max.is_finite() {
        max
    } else {
        1.0
    }
}

/// Human-readable table over the placement suite.
pub fn render_placement_table(rows: &[PlacementBenchRow]) -> Table {
    let mut t = Table::new(
        "topology-aware placement vs identity layout (nodes4, greedy+swap)",
        &["model", "D", "wpn", "gain", "share id", "share placed", "delta", "overlap ms"],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.workers.to_string(),
            r.workers_per_node.to_string(),
            format!("{}x", f2(r.placement_gain)),
            f2(r.identity_share),
            f2(r.placed_share),
            f2(r.share_delta),
            f2(r.overlapped_ms),
        ]);
    }
    t
}

/// One placement row as its stored (and emitted) JSON object.
fn placement_row_json(r: &PlacementBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("workers", num(r.workers as f64)),
        ("workers_per_node", num(r.workers_per_node as f64)),
        ("identity_share", num(r.identity_share)),
        ("placed_share", num(r.placed_share)),
        ("share_delta", num(r.share_delta)),
        ("placement_gain", num(r.placement_gain)),
        ("overlapped_ms", num(r.overlapped_ms)),
    ])
}

/// Inverse of `placement_row_json`, for rows recalled from the store.
pub fn placement_row_from_json(v: &Value) -> Result<PlacementBenchRow> {
    Ok(PlacementBenchRow {
        model: v.req_str("model")?.to_string(),
        workers: v.req_usize("workers")?,
        workers_per_node: v.req_usize("workers_per_node")?,
        identity_share: v.req_f64("identity_share")?,
        placed_share: v.req_f64("placed_share")?,
        share_delta: v.req_f64("share_delta")?,
        placement_gain: v.req_f64("placement_gain")?,
        overlapped_ms: v.req_f64("overlapped_ms")?,
    })
}

/// Human-readable table over the suite.
pub fn render_table(rows: &[OverlapBenchRow], steps: usize) -> Table {
    let mut t = Table::new(
        format!("overlap-aware link model vs serial aggregate, {steps} steps/cell"),
        &[
            "model",
            "D",
            "topo",
            "a2a MB/step",
            "link share",
            "serial ms",
            "overlap ms",
            "speedup",
            "eff",
        ],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.workers.to_string(),
            r.topology.clone(),
            f2(r.a2a_mb_step),
            f2(r.bottleneck_link_share),
            f2(r.serial_ms),
            f2(r.overlapped_ms),
            format!("{}x", f2(r.overlap_speedup())),
            f2(r.overlap_efficiency),
        ]);
    }
    t
}

/// One row as its stored (and emitted) JSON object: the per-cell result
/// document in the experiment store and the element of `rows` in
/// `BENCH_overlap.json`.
fn row_json(r: &OverlapBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("strategy", s(r.strategy.clone())),
        ("workers", num(r.workers as f64)),
        ("topology", s(r.topology.clone())),
        ("workers_per_node", num(r.workers_per_node as f64)),
        ("tokens_per_worker", num(r.tokens_per_worker as f64)),
        ("a2a_mb_per_step", num(r.a2a_mb_step)),
        ("bottleneck_link_share", num(r.bottleneck_link_share)),
        ("bottleneck_src", num(r.bottleneck_src as f64)),
        ("bottleneck_dst", num(r.bottleneck_dst as f64)),
        ("serial_ms", num(r.serial_ms)),
        ("overlapped_ms", num(r.overlapped_ms)),
        ("overlap_speedup", num(r.overlap_speedup())),
        ("overlap_efficiency", num(r.overlap_efficiency)),
        ("host_ms_per_step", num(r.host_ms)),
    ])
}

/// Inverse of `row_json`, for rows recalled from the store.
pub fn row_from_json(v: &Value) -> Result<OverlapBenchRow> {
    Ok(OverlapBenchRow {
        model: v.req_str("model")?.to_string(),
        strategy: v.req_str("strategy")?.to_string(),
        workers: v.req_usize("workers")?,
        topology: v.req_str("topology")?.to_string(),
        workers_per_node: v.req_usize("workers_per_node")?,
        tokens_per_worker: v.req_usize("tokens_per_worker")?,
        a2a_mb_step: v.req_f64("a2a_mb_per_step")?,
        bottleneck_link_share: v.req_f64("bottleneck_link_share")?,
        bottleneck_src: v.req_usize("bottleneck_src")?,
        bottleneck_dst: v.req_usize("bottleneck_dst")?,
        serial_ms: v.req_f64("serial_ms")?,
        overlapped_ms: v.req_f64("overlapped_ms")?,
        overlap_efficiency: v.req_f64("overlap_efficiency")?,
        host_ms: v.req_f64("host_ms_per_step")?,
    })
}

/// Serialize the suite to the tracked trajectory JSON. The placement
/// regression fields (`min_placement_gain` >= 1.0,
/// `max_placement_share_delta` <= 0.0) only appear when placement cells
/// ran, so the overlap-only path keeps its document shape.
pub fn to_json(rows: &[OverlapBenchRow], placement: &[PlacementBenchRow], steps: usize) -> Value {
    let items: Vec<Value> = rows.iter().map(row_json).collect();
    let placed_items: Vec<Value> = placement.iter().map(placement_row_json).collect();
    let mut fields = vec![
        ("bench", s("overlap")),
        ("steps_per_cell", num(steps as f64)),
        ("min_overlap_speedup", num(min_overlap_speedup(rows))),
        ("max_bottleneck_link_share", num(max_bottleneck_link_share(rows))),
        ("rows", arr(items)),
        ("placement_rows", arr(placed_items)),
    ];
    if !placement.is_empty() {
        fields.push(("min_placement_gain", num(min_placement_gain(placement))));
        fields.push(("max_placement_share_delta", num(max_placement_share_delta(placement))));
    }
    obj(fields)
}

/// Write `BENCH_overlap.json` (or wherever `path` points).
pub fn write_json(
    rows: &[OverlapBenchRow],
    placement: &[PlacementBenchRow],
    steps: usize,
    path: &str,
) -> Result<()> {
    let text = json_write(&to_json(rows, placement, steps)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_issue_matrix() {
        let cs = cases();
        assert_eq!(cs.len(), 54, "3 geometries x 3 strategies x 3 D x 2 topologies");
        for (cfg, workers, wpn) in &cs {
            assert_eq!(cfg.num_experts % workers, 0, "{}: unshardable at D={workers}", cfg.name);
            assert!(*wpn == 1 || *wpn == HIER_WORKERS_PER_NODE);
        }
        assert!(cs.iter().any(|(c, d, w)| c.name == "xlarge-sim-2top1" && *d == 16 && *w == 4));
        assert!(cs.iter().any(|(c, d, w)| c.name == "base-sim-top1" && *d == 4 && *w == 1));
    }

    #[test]
    fn rows_round_trip_through_the_store_document() {
        let row = OverlapBenchRow {
            model: "xlarge-sim-top1".into(),
            strategy: "top1".into(),
            workers: 8,
            topology: "nodes4".into(),
            workers_per_node: 4,
            tokens_per_worker: 512,
            a2a_mb_step: 3.5,
            bottleneck_link_share: 0.25,
            bottleneck_src: 2,
            bottleneck_dst: 5,
            serial_ms: 200.0,
            overlapped_ms: 160.0,
            overlap_efficiency: 0.9,
            host_ms: 1.5,
        };
        let back = row_from_json(&row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![OverlapBenchRow {
            model: "xlarge-sim-top1".into(),
            strategy: "top1".into(),
            workers: 8,
            topology: "nodes4".into(),
            workers_per_node: 4,
            tokens_per_worker: 512,
            a2a_mb_step: 3.5,
            bottleneck_link_share: 0.25,
            bottleneck_src: 2,
            bottleneck_dst: 5,
            serial_ms: 200.0,
            overlapped_ms: 160.0,
            overlap_efficiency: 0.9,
            host_ms: 1.5,
        }];
        let v = to_json(&rows, &[sample_placement_row()], 4);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("overlap"));
        assert_eq!(v.get("min_overlap_speedup").and_then(|x| x.as_f64()), Some(1.25));
        assert_eq!(v.get("max_bottleneck_link_share").and_then(|x| x.as_f64()), Some(0.25));
        let items = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(items[0].get("overlap_speedup").and_then(|x| x.as_f64()), Some(1.25));
        assert_eq!(items[0].get("topology").and_then(|x| x.as_str()), Some("nodes4"));
        // the placement rows and both gated floors ride along
        let placed = v.get("placement_rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(placed.len(), 1);
        assert_eq!(v.get("min_placement_gain").and_then(|x| x.as_f64()), Some(1.3));
        assert_eq!(v.get("max_placement_share_delta").and_then(|x| x.as_f64()), Some(-0.1));
        // without placement cells the floors stay absent
        let bare = to_json(&rows, &[], 4);
        assert!(bare.get("min_placement_gain").is_none());
        assert!(bare.get("max_placement_share_delta").is_none());
    }

    fn sample_placement_row() -> PlacementBenchRow {
        PlacementBenchRow {
            model: "large-sim".into(),
            workers: 8,
            workers_per_node: 4,
            identity_share: 0.35,
            placed_share: 0.25,
            share_delta: -0.1,
            placement_gain: 1.3,
            overlapped_ms: 150.0,
        }
    }

    #[test]
    fn placement_spec_is_four_hierarchical_cells() {
        let cells = placement_spec(4).expand().unwrap();
        assert_eq!(cells.len(), 4, "2 geometries x D in {{4, 8}}");
        let mut keys = std::collections::BTreeSet::new();
        for cell in &cells {
            let (cfg, workers) = placement_cell_config(cell).unwrap();
            assert_eq!(cfg.num_experts % workers, 0);
            let resolved = resolve_placement_cell(cell).unwrap();
            assert!(resolved.req_str("cfg.name").is_ok());
            assert!(keys.insert(resolved.canonical()), "duplicate placement cell address");
        }
    }

    #[test]
    fn placement_rows_round_trip_through_the_store_document() {
        let row = sample_placement_row();
        let back = placement_row_from_json(&placement_row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
    }

    #[test]
    fn empty_suite_fails_the_gate() {
        assert_eq!(min_overlap_speedup(&[]), 0.0);
        assert_eq!(max_bottleneck_link_share(&[]), 0.0);
        assert_eq!(min_placement_gain(&[]), 0.0, "empty placement suite must fail the floor");
        assert_eq!(max_placement_share_delta(&[]), 1.0);
    }
}
