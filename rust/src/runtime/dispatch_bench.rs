//! Sharded-dispatch bench: the expert-parallel runtime measured over
//! {base, 10B geometry twins} x {top1, top2, 2top1} x D in {1, 4, 8}.
//!
//! Shared by `m6t bench --dispatch` (and the CI smoke step); writes the
//! tracked perf/behavior trajectory `BENCH_dispatch.json`. Each cell runs
//! a few [`ShardedRun`] steps and records what the single-router
//! idealization cannot see: cross-worker load c_v, per-shard drop rates,
//! measured all-to-all bytes, and the cluster model's analytic-vs-
//! observed step-time gap.
//!
//! The grid is declared as a [`SweepSpec`] and driven through the
//! [`Engine`]'s content-addressed store: a cell whose resolved config has
//! already completed is recalled instead of re-run (`--force` opts out).

use anyhow::{bail, Context as _, Result};

use crate::config::{CapacityMode, ComputeMode, ModelConfig, Routing};
use crate::metrics::RunLog;
use crate::runtime::shard::ShardedRun;
use crate::sweep::{self, Cell, Engine, SweepOutcome, SweepSpec};
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::stats::{p50, timing_series};
use crate::util::table::{f1, f2, Table};

/// Code-relevant version tag baked into every dispatch cell's store
/// address — bump when the measurement or row semantics change.
pub const STORE_VERSION: &str = "dispatch-v1";

/// Store version for the elastic-capacity cells (the `elastic` sweep
/// kind) — bump when the controller law or the row semantics change.
pub const ELASTIC_STORE_VERSION: &str = "elastic-v1";

/// Sim-scale twin of the paper's Base geometry (Table 5: 5 layers,
/// E = 32) — small hidden sizes so a cell runs in milliseconds.
pub fn base_twin() -> ModelConfig {
    ModelConfig {
        name: "base-twin".into(),
        vocab_size: 2048,
        hidden: 64,
        intermediate: 256,
        layers: 5,
        heads: 4,
        head_dim: 16,
        patch_dim: 128,
        num_experts: 32,
        routing: Routing::TopK(1),
        capacity_factor: 1.25,
        capacity_mode: CapacityMode::TimesK,
        aux_loss_coef: 0.0,
        moe_attention: false,
        attn_num_experts: 4,
        batch: 8,
        patches: 16,
        text_len: 48,
        optimizer: "adamw".into(),
        lr: 1e-3,
        warmup: 100,
        init_std: 0.02,
        weight_decay: 0.01,
        compute: ComputeMode::Simulated,
        workers: 1,
    }
}

/// Sim-scale twin of the 10B geometry (Table 5: 10 layers, E = 128).
pub fn ten_b_twin() -> ModelConfig {
    let mut c = base_twin();
    c.name = "10B-twin".into();
    c.layers = 10;
    c.num_experts = 128;
    c
}

/// The benched grid as a declarative spec: {base, 10B twins} x
/// {top1@kx, top2@1x, 2top1@1x} x D in {1, 4, 8}, last axis fastest —
/// the same cell order the hand-rolled loop produced.
pub fn spec(steps: usize) -> SweepSpec {
    SweepSpec::new("dispatch", "dispatch")
        .steps(steps)
        .axis("model", sweep::strs(&["base-twin", "10B-twin"]))
        .axis("strategy", sweep::strs(&["top1@kx", "top2@1x", "2top1@1x"]))
        .axis("workers", sweep::nums(&[1, 4, 8]))
}

/// The elastic-capacity grid: the skewed base twin (top1@kx, aux = 0 so
/// the router bias — and with it the hot experts — persists) at D in
/// {4, 8}. The saturated strategies (top2@1x and friends) are excluded:
/// with every expert at or over capacity there is no padding to harvest,
/// so elastic is a no-op there by construction.
pub fn elastic_spec(steps: usize) -> SweepSpec {
    SweepSpec::new("elastic", "elastic")
        .steps(steps)
        .axis("model", sweep::strs(&["base-twin"]))
        .axis("workers", sweep::nums(&[4, 8]))
}

/// Materialize an elastic cell into its config (top1@kx base twin).
fn elastic_cell_config(cell: &Cell) -> Result<(ModelConfig, usize)> {
    let cfg = match cell.req_str("model")? {
        "base-twin" => base_twin(),
        other => bail!("elastic cell: unknown model {other:?}"),
    };
    let workers = cell.req_usize("workers")?;
    Ok((cfg, workers))
}

/// Fold the resolved config into an elastic cell before hashing.
pub fn resolve_elastic_cell(cell: &Cell) -> Result<Cell> {
    let (cfg, _) = elastic_cell_config(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&cfg));
    Ok(resolved)
}

/// Materialize a spec-level cell into the config the runtime consumes.
fn cell_config(cell: &Cell) -> Result<(ModelConfig, usize)> {
    let base = match cell.req_str("model")? {
        "base-twin" => base_twin(),
        "10B-twin" => ten_b_twin(),
        other => bail!("dispatch cell: unknown model {other:?}"),
    };
    let (routing, mode) = sweep::parse_strategy(cell.req_str("strategy")?)?;
    let workers = cell.req_usize("workers")?;
    let mut cfg = base;
    cfg.name = format!("{}-{}", cfg.name, routing.name());
    cfg.routing = routing;
    cfg.capacity_mode = mode;
    Ok((cfg, workers))
}

/// Fold the fully-resolved model config into the cell before hashing —
/// an edit to the twin geometries re-addresses every affected cell.
pub fn resolve_cell(cell: &Cell) -> Result<Cell> {
    let (cfg, _) = cell_config(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&cfg));
    Ok(resolved)
}

/// The benched grid in legacy form; kept as the oracle the spec-based
/// expansion is tested against.
pub fn cases() -> Vec<(ModelConfig, usize)> {
    let mut out = Vec::new();
    for cell in spec(12).expand().expect("builtin dispatch spec expands") {
        out.push(cell_config(&cell).expect("builtin dispatch cell resolves"));
    }
    out
}

/// One measured (model, strategy, D) cell.
#[derive(Debug, Clone)]
pub struct DispatchBenchRow {
    pub model: String,
    pub strategy: String,
    pub workers: usize,
    pub tokens_per_worker: usize,
    pub capacity: usize,
    /// median measured host ms per sharded step
    pub host_ms: f64,
    /// cross-worker load c_v (last step)
    pub shard_cv: f64,
    /// dropped / demanded tokens (last step)
    pub drop_rate: f64,
    /// measured all-to-all MB per step (all 4 directions)
    pub a2a_mb_step: f64,
    /// cluster model, analytic O(ECM) traffic
    pub analytic_ms: f64,
    /// cluster model, observed traffic + shard imbalance
    pub observed_ms: f64,
}

/// One measured elastic-vs-static cell: the same model, seed, and data
/// stream stepped twice — once under the static Eq.-2 capacity, once
/// under the elastic controller at the identical slot budget.
#[derive(Debug, Clone)]
pub struct ElasticBenchRow {
    pub model: String,
    pub workers: usize,
    /// static Eq.-2 per-expert capacity (and the elastic budget's base)
    pub capacity: usize,
    /// mean dropped/demanded over the measured steps, static capacities
    pub static_drop_rate: f64,
    /// same steps, elastic capacities — the equal-budget comparison
    pub elastic_drop_rate: f64,
    /// elastic − static; the CI gate floors this at <= 0
    pub drop_delta: f64,
    /// mean unused-slot fraction, static
    pub static_padding: f64,
    /// mean unused-slot fraction, elastic (same slot total per layer)
    pub elastic_padding: f64,
    /// capacity span the controller settled on (last measured step)
    pub cap_min: usize,
    pub cap_max: usize,
}

/// Mean drop fraction over the measured records (the cold leading step
/// is excluded: the controller has no history there, so both twins run
/// the static capacities and the comparison would be diluted).
fn mean_drop(log: &RunLog) -> f64 {
    let measured: Vec<f64> = log
        .records
        .iter()
        .skip(1)
        .filter_map(|r| r.dispatch.as_ref().map(|d| d.drop_fraction))
        .collect();
    if measured.is_empty() {
        return 0.0;
    }
    measured.iter().sum::<f64>() / measured.len() as f64
}

/// Unused-slot fraction from a mean drop rate: kept tokens fill
/// `routed · (1 − drop)` of the `L·D·E·C` slots — the slot total both
/// twins share, which is what makes the padding numbers comparable.
fn padding_from_drop(cfg: &ModelConfig, workers: usize, capacity: usize, drop: f64) -> f64 {
    let routed =
        (cfg.layers * cfg.tokens_per_batch() * cfg.routing.k().max(1) as usize * workers) as f64;
    let slots = (cfg.layers * workers * cfg.num_experts * capacity) as f64;
    (1.0 - routed * (1.0 - drop) / slots).max(0.0)
}

/// Execute one elastic cell: static and elastic [`ShardedRun::train`]
/// over the identical seed and batch stream, `steps` measured steps each.
pub fn run_elastic_cell(cell: &Cell) -> Result<Value> {
    let (cfg, workers) = elastic_cell_config(cell)?;
    let steps = cell.req_usize("steps")?.max(2);
    let seed = cell.req_u64("seed")?;

    let static_run = ShardedRun::new(&cfg, workers)?;
    let mut static_log = RunLog::new(format!("{}-static-d{workers}", cfg.name));
    static_run.train(steps as i64 + 1, seed, &mut static_log, false)?;

    let mut elastic_run = ShardedRun::new(&cfg, workers)?;
    elastic_run.set_elastic_capacity(true)?;
    let mut elastic_log = RunLog::new(format!("{}-elastic-d{workers}", cfg.name));
    elastic_run.train(steps as i64 + 1, seed, &mut elastic_log, false)?;

    let capacity = static_run.info().capacity;
    let static_drop = mean_drop(&static_log);
    let elastic_drop = mean_drop(&elastic_log);
    let last = elastic_log.last().and_then(|r| r.dispatch.clone()).expect("dispatch series");
    let row = ElasticBenchRow {
        model: cfg.name.clone(),
        workers,
        capacity,
        static_drop_rate: static_drop,
        elastic_drop_rate: elastic_drop,
        drop_delta: elastic_drop - static_drop,
        static_padding: padding_from_drop(&cfg, workers, capacity, static_drop),
        elastic_padding: padding_from_drop(&cfg, workers, capacity, elastic_drop),
        cap_min: last.capacity_min,
        cap_max: last.capacity_max,
    };
    eprintln!(
        "[bench] {} D={} elastic: drop {:.3} -> {:.3} (delta {:+.3}), caps {}..{} (C={})",
        row.model,
        row.workers,
        row.static_drop_rate,
        row.elastic_drop_rate,
        row.drop_delta,
        row.cap_min,
        row.cap_max,
        row.capacity
    );
    Ok(elastic_row_json(&row))
}

/// Run the elastic grid through the sweep engine.
pub fn run_elastic_suite(
    engine: &Engine,
    steps: usize,
) -> Result<(Vec<ElasticBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&elastic_spec(steps), &sweep::ElasticRunner)?;
    let rows = elastic_rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed elastic rows from a sweep outcome.
pub fn elastic_rows_from(outcome: &SweepOutcome) -> Result<Vec<ElasticBenchRow>> {
    outcome.outcomes.iter().map(|o| elastic_row_from_json(&o.result)).collect()
}

/// Execute one cell: `steps` measured sharded steps driven through
/// [`ShardedRun::train`] — the same stepping loop (and the same
/// worker-batch consumption order) the real runs use, so the bench can
/// never silently measure a different data stream.
pub fn run_cell(cell: &Cell) -> Result<Value> {
    let (cfg, workers) = cell_config(cell)?;
    let steps = cell.req_usize("steps")?.max(1);
    let seed = cell.req_u64("seed")?;
    let run = ShardedRun::new(&cfg, workers)?;
    let mut log = RunLog::new(format!("{}-d{workers}", cfg.name));
    // one extra leading step, excluded from the median: it carries the
    // cold scratch/pool allocations, and the other two measurement
    // harnesses (measure_step_series, step_bench) discard a warmup
    // step too — the three suites must report comparable numbers
    run.train(steps as i64 + 1, seed, &mut log, false)?;
    let ms = timing_series(log.records.iter().map(|r| r.ms_per_step), 1);
    let host_ms = p50(&ms);
    let last = log.last().expect("at least one recorded step");
    let dsp = last.dispatch.as_ref().expect("sharded records carry dispatch");
    let row = DispatchBenchRow {
        model: cfg.name.clone(),
        strategy: cfg.routing.name(),
        workers,
        tokens_per_worker: cfg.tokens_per_batch(),
        capacity: run.info().capacity,
        host_ms,
        shard_cv: dsp.shard_load_cv,
        drop_rate: dsp.drop_fraction,
        a2a_mb_step: dsp.a2a_bytes_step / 1e6,
        analytic_ms: last.sim_ms,
        observed_ms: dsp.observed_ms,
    };
    eprintln!(
        "[bench] {} D={}: host {:.2} ms, shard-cv {:.3}, drop {:.3}, a2a {:.2} MB, cluster {:.1} -> {:.1} ms",
        row.model,
        row.workers,
        row.host_ms,
        row.shard_cv,
        row.drop_rate,
        row.a2a_mb_step,
        row.analytic_ms,
        row.observed_ms
    );
    Ok(row_json(&row))
}

/// Run the full grid through the sweep engine, `steps` measured sharded
/// steps per cell; previously-completed cells come back from the store.
pub fn run_suite(engine: &Engine, steps: usize) -> Result<(Vec<DispatchBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&spec(steps), &sweep::DispatchRunner)?;
    let rows = rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed rows from a sweep outcome's stored documents.
pub fn rows_from(outcome: &SweepOutcome) -> Result<Vec<DispatchBenchRow>> {
    outcome.outcomes.iter().map(|o| row_from_json(&o.result)).collect()
}

/// Human-readable table over the suite.
pub fn render_table(rows: &[DispatchBenchRow]) -> Table {
    let mut t = Table::new(
        "sharded dispatch: measured exchange vs analytic cluster estimate",
        &[
            "model",
            "D",
            "T/worker",
            "C",
            "host ms",
            "shard c_v",
            "drop",
            "a2a MB/step",
            "analytic ms",
            "observed ms",
        ],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.workers.to_string(),
            r.tokens_per_worker.to_string(),
            r.capacity.to_string(),
            f2(r.host_ms),
            f2(r.shard_cv),
            f2(r.drop_rate),
            f2(r.a2a_mb_step),
            f1(r.analytic_ms),
            f1(r.observed_ms),
        ]);
    }
    t
}

/// Human-readable table over the elastic suite.
pub fn render_elastic_table(rows: &[ElasticBenchRow]) -> Table {
    let mut t = Table::new(
        "elastic capacity: drop/padding vs the static Eq.-2 allocation (equal slot budget)",
        &[
            "model",
            "D",
            "C",
            "drop static",
            "drop elastic",
            "delta",
            "pad static",
            "pad elastic",
            "caps",
        ],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.workers.to_string(),
            r.capacity.to_string(),
            f2(r.static_drop_rate),
            f2(r.elastic_drop_rate),
            f2(r.drop_delta),
            f2(r.static_padding),
            f2(r.elastic_padding),
            format!("{}..{}", r.cap_min, r.cap_max),
        ]);
    }
    t
}

/// One elastic row as its stored (and emitted) JSON object.
fn elastic_row_json(r: &ElasticBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("workers", num(r.workers as f64)),
        ("capacity", num(r.capacity as f64)),
        ("static_drop_rate", num(r.static_drop_rate)),
        ("elastic_drop_rate", num(r.elastic_drop_rate)),
        ("drop_delta", num(r.drop_delta)),
        ("static_padding", num(r.static_padding)),
        ("elastic_padding", num(r.elastic_padding)),
        ("cap_min", num(r.cap_min as f64)),
        ("cap_max", num(r.cap_max as f64)),
    ])
}

/// Inverse of `elastic_row_json`, for rows recalled from the store.
pub fn elastic_row_from_json(v: &Value) -> Result<ElasticBenchRow> {
    Ok(ElasticBenchRow {
        model: v.req_str("model")?.to_string(),
        workers: v.req_usize("workers")?,
        capacity: v.req_usize("capacity")?,
        static_drop_rate: v.req_f64("static_drop_rate")?,
        elastic_drop_rate: v.req_f64("elastic_drop_rate")?,
        drop_delta: v.req_f64("drop_delta")?,
        static_padding: v.req_f64("static_padding")?,
        elastic_padding: v.req_f64("elastic_padding")?,
        cap_min: v.req_usize("cap_min")?,
        cap_max: v.req_usize("cap_max")?,
    })
}

/// One row as its stored (and emitted) JSON object. This is the per-cell
/// result document in the experiment store, and the element of the
/// `rows` array in `BENCH_dispatch.json` — one serialization for both.
fn row_json(r: &DispatchBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("strategy", s(r.strategy.clone())),
        ("workers", num(r.workers as f64)),
        ("tokens_per_worker", num(r.tokens_per_worker as f64)),
        ("capacity", num(r.capacity as f64)),
        ("host_ms_per_step", num(r.host_ms)),
        ("shard_load_cv", num(r.shard_cv)),
        ("drop_rate", num(r.drop_rate)),
        ("a2a_mb_per_step", num(r.a2a_mb_step)),
        ("cluster_analytic_ms", num(r.analytic_ms)),
        ("cluster_observed_ms", num(r.observed_ms)),
    ])
}

/// Inverse of `row_json`, for rows recalled from the store.
pub fn row_from_json(v: &Value) -> Result<DispatchBenchRow> {
    Ok(DispatchBenchRow {
        model: v.req_str("model")?.to_string(),
        strategy: v.req_str("strategy")?.to_string(),
        workers: v.req_usize("workers")?,
        tokens_per_worker: v.req_usize("tokens_per_worker")?,
        capacity: v.req_usize("capacity")?,
        host_ms: v.req_f64("host_ms_per_step")?,
        shard_cv: v.req_f64("shard_load_cv")?,
        drop_rate: v.req_f64("drop_rate")?,
        a2a_mb_step: v.req_f64("a2a_mb_per_step")?,
        analytic_ms: v.req_f64("cluster_analytic_ms")?,
        observed_ms: v.req_f64("cluster_observed_ms")?,
    })
}

/// Serialize the suite to the tracked trajectory JSON. The top-level
/// `max_elastic_drop_delta` (worst elastic − static drop-rate delta over
/// the elastic cells) is the number the CI gate floors at <= 0: elastic
/// must never drop more tokens than static at the same slot budget.
pub fn to_json(rows: &[DispatchBenchRow], elastic: &[ElasticBenchRow], steps: usize) -> Value {
    let items: Vec<Value> = rows.iter().map(row_json).collect();
    let elastic_items: Vec<Value> = elastic.iter().map(elastic_row_json).collect();
    let max_delta = elastic.iter().map(|r| r.drop_delta).fold(f64::NEG_INFINITY, f64::max);
    let mut fields = vec![
        ("bench", s("dispatch")),
        ("steps_per_cell", num(steps as f64)),
        ("rows", arr(items)),
        ("elastic_rows", arr(elastic_items)),
    ];
    if !elastic.is_empty() {
        fields.push(("max_elastic_drop_delta", num(max_delta)));
    }
    obj(fields)
}

/// Write `BENCH_dispatch.json` (or wherever `path` points).
pub fn write_json(
    rows: &[DispatchBenchRow],
    elastic: &[ElasticBenchRow],
    steps: usize,
    path: &str,
) -> Result<()> {
    let text = json_write(&to_json(rows, elastic, steps)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_issue_matrix() {
        let cs = cases();
        assert_eq!(cs.len(), 18, "2 models x 3 strategies x 3 worker counts");
        for (cfg, workers) in &cs {
            assert_eq!(cfg.num_experts % workers, 0, "{}: unshardable at D={workers}", cfg.name);
        }
        assert!(cs.iter().any(|(c, d)| c.name == "10B-twin-2top1" && *d == 8));
        assert!(cs.iter().any(|(c, d)| c.name == "base-twin-top2" && *d == 1));
    }

    #[test]
    fn spec_cells_resolve_and_address_uniquely() {
        let spec = spec(4);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 18);
        let mut keys = std::collections::BTreeSet::new();
        for cell in &cells {
            let resolved = resolve_cell(cell).unwrap();
            assert_eq!(resolved.req_usize("steps").unwrap(), 4);
            assert!(resolved.req_str("cfg.name").is_ok(), "resolved cell carries the config");
            assert!(keys.insert(resolved.canonical()), "duplicate cell address");
        }
    }

    #[test]
    fn rows_round_trip_through_the_store_document() {
        let row = DispatchBenchRow {
            model: "base-twin-top1".into(),
            strategy: "top1".into(),
            workers: 4,
            tokens_per_worker: 512,
            capacity: 20,
            host_ms: 1.5,
            shard_cv: 0.3,
            drop_rate: 0.01,
            a2a_mb_step: 2.5,
            analytic_ms: 100.0,
            observed_ms: 80.0,
        };
        let back = row_from_json(&row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
    }

    fn sample_elastic_row() -> ElasticBenchRow {
        ElasticBenchRow {
            model: "base-twin".into(),
            workers: 4,
            capacity: 20,
            static_drop_rate: 0.2,
            elastic_drop_rate: 0.05,
            drop_delta: -0.15,
            static_padding: 0.5,
            elastic_padding: 0.4,
            cap_min: 3,
            cap_max: 61,
        }
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![DispatchBenchRow {
            model: "base-twin-top1".into(),
            strategy: "top1".into(),
            workers: 4,
            tokens_per_worker: 512,
            capacity: 20,
            host_ms: 1.5,
            shard_cv: 0.3,
            drop_rate: 0.01,
            a2a_mb_step: 2.5,
            analytic_ms: 100.0,
            observed_ms: 80.0,
        }];
        let v = to_json(&rows, &[sample_elastic_row()], 4);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("dispatch"));
        let items = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].get("workers").and_then(|w| w.as_f64()), Some(4.0));
        assert_eq!(
            items[0].get("cluster_observed_ms").and_then(|w| w.as_f64()),
            Some(80.0)
        );
        // the elastic rows and the gated top-level floor ride along
        let el = v.get("elastic_rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(el.len(), 1);
        assert_eq!(
            v.get("max_elastic_drop_delta").and_then(|x| x.as_f64()),
            Some(-0.15)
        );
        // without elastic cells the floor is absent, not a fake -inf
        let bare = to_json(&rows, &[], 4);
        assert!(bare.get("max_elastic_drop_delta").is_none());
        assert_eq!(bare.get("elastic_rows").and_then(|r| r.as_array()).map(|a| a.len()), Some(0));
    }

    #[test]
    fn elastic_spec_is_two_skewed_cells() {
        let cells = elastic_spec(4).expand().unwrap();
        assert_eq!(cells.len(), 2, "base-twin x D in {{4, 8}}");
        let mut keys = std::collections::BTreeSet::new();
        for cell in &cells {
            let (cfg, workers) = elastic_cell_config(cell).unwrap();
            assert_eq!(cfg.aux_loss_coef, 0.0, "the skew must persist for elastic to act on");
            assert_eq!(cfg.num_experts % workers, 0);
            let resolved = resolve_elastic_cell(cell).unwrap();
            assert!(resolved.req_str("cfg.name").is_ok());
            assert!(keys.insert(resolved.canonical()), "duplicate elastic cell address");
        }
    }

    #[test]
    fn elastic_rows_round_trip_through_the_store_document() {
        let row = sample_elastic_row();
        let back = elastic_row_from_json(&elastic_row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
    }

    #[test]
    fn padding_accounts_the_shared_slot_total() {
        let cfg = base_twin(); // T = 512, E = 32, k = 1, C = 20, L = 5
        // zero drops: 512 of E*C = 640 slots used per (worker, layer)
        let pad = padding_from_drop(&cfg, 4, 20, 0.0);
        assert!((pad - 0.2).abs() < 1e-12, "1 - 512/640, got {pad}");
        // dropping 25% leaves 384 used slots
        let pad = padding_from_drop(&cfg, 4, 20, 0.25);
        assert!((pad - 0.4).abs() < 1e-12, "1 - 384/640, got {pad}");
    }
}
