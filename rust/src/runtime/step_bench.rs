//! End-to-end step-throughput bench: the fused parallel (worker x layer)
//! sharded step measured against the pre-fusion serial two-pass baseline
//! **in the same run**, over {base, large, xlarge-sim geometries} x
//! {top1, top2, 2top1, 4top1} x D in {1, 4, 8}.
//!
//! Shared by `m6t bench --step` and `cargo bench --bench step`; writes
//! the tracked perf trajectory `BENCH_step.json`. Every cell first
//! cross-checks that [`StepMode::Fused`] and [`StepMode::TwoPass`] emit
//! bitwise-identical StepStats, dispatch summaries, and per-layer plans,
//! so the bench doubles as a parity smoke; it then reports p50/p95 step
//! latency, steps/sec, routed-tokens/sec, the baseline-vs-fused speedup
//! (the machine-readable regression signal), and the gate-matrix bytes
//! per step the fused path never materializes.
//!
//! The grid is declared as a [`SweepSpec`] and driven through the
//! [`Engine`]'s content-addressed store; the timing bench binary passes
//! a `force` engine because a timing tool must re-measure.

use std::time::Instant;

use anyhow::{bail, ensure, Context as _, Result};

use crate::config::{CapacityMode, ModelConfig, Routing};
use crate::data::{Batch, Batcher, Split};
use crate::runtime::native::registry;
use crate::runtime::shard::{ShardedRun, StepMode};
use crate::sweep::{self, Cell, Engine, SweepOutcome, SweepSpec};
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::stats::{p50, p95, timing_series};
use crate::util::table::{f2, Table};

/// Code-relevant version tag in every step cell's store address.
pub const STORE_VERSION: &str = "step-v1";

/// The benched geometries: the sim-scale E = 16 / 32 / 64 twins from the
/// native registry (xlarge-sim is the acceptance gate's E = 64 row).
const GEOMETRIES: [&str; 3] = ["base-sim", "large-sim", "xlarge-sim"];

/// The benched grid as a declarative spec: 3 geometries x 4 strategies x
/// D in {1, 4, 8}, last axis fastest.
pub fn spec(steps: usize) -> SweepSpec {
    SweepSpec::new("step", "step")
        .steps(steps)
        .axis("model", sweep::strs(&GEOMETRIES))
        .axis("strategy", sweep::strs(&["top1@kx", "top2@1x", "2top1@1x", "4top1@1x"]))
        .axis("workers", sweep::nums(&[1, 4, 8]))
}

/// Materialize a spec-level cell into the config the runtime consumes.
fn cell_config(cell: &Cell) -> Result<(ModelConfig, usize)> {
    let geo = cell.req_str("model")?;
    let Some(base) = registry().into_iter().find(|c| c.name == geo) else {
        bail!("step cell: unknown geometry {geo:?}");
    };
    let (routing, mode) = sweep::parse_strategy(cell.req_str("strategy")?)?;
    let workers = cell.req_usize("workers")?;
    let mut cfg = base;
    cfg.name = format!("{geo}-{}", routing.name());
    cfg.routing = routing;
    cfg.capacity_mode = mode;
    Ok((cfg, workers))
}

/// Fold the fully-resolved model config into the cell before hashing.
pub fn resolve_cell(cell: &Cell) -> Result<Cell> {
    let (cfg, _) = cell_config(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&cfg));
    Ok(resolved)
}

/// The benched grid in legacy form; kept as the oracle the spec-based
/// expansion is tested against.
pub fn cases() -> Vec<(ModelConfig, usize)> {
    let mut out = Vec::new();
    for cell in spec(12).expand().expect("builtin step spec expands") {
        out.push(cell_config(&cell).expect("builtin step cell resolves"));
    }
    out
}

/// One measured (geometry, strategy, D) cell: fused and baseline timed
/// over the same data stream in the same process.
#[derive(Debug, Clone)]
pub struct StepBenchRow {
    pub model: String,
    pub strategy: String,
    pub workers: usize,
    pub layers: usize,
    pub experts: usize,
    pub tokens_per_worker: usize,
    /// token-slot routings per global step: D * L * T * k_eff
    pub routed_per_step: u64,
    /// f32 gate-matrix bytes the two-pass path streams through per step
    /// (D * L * T * E * 4) and the fused path never materializes
    pub gate_bytes_avoided: u64,
    pub fused_p50_ms: f64,
    pub fused_p95_ms: f64,
    pub baseline_p50_ms: f64,
    pub baseline_p95_ms: f64,
}

impl StepBenchRow {
    pub fn fused_steps_per_sec(&self) -> f64 {
        1e3 / self.fused_p50_ms
    }
    pub fn baseline_steps_per_sec(&self) -> f64 {
        1e3 / self.baseline_p50_ms
    }
    pub fn fused_routed_tokens_per_sec(&self) -> f64 {
        self.routed_per_step as f64 * 1e3 / self.fused_p50_ms
    }
    pub fn baseline_routed_tokens_per_sec(&self) -> f64 {
        self.routed_per_step as f64 * 1e3 / self.baseline_p50_ms
    }
    /// Baseline-vs-fused speedup on p50 step time (> 1 = fused faster) —
    /// the machine-readable regression field.
    pub fn speedup(&self) -> f64 {
        self.baseline_p50_ms / self.fused_p50_ms
    }
}

/// Time `steps` sharded steps in `mode` (after one warmup step), on the
/// exact batch stream `ShardedRun::train` would consume. Returns the
/// sorted series (feed to [`p50`] / [`p95`]).
fn measure(run: &ShardedRun, mode: StepMode, steps: usize, seed: u64) -> Result<Vec<f64>> {
    let cfg = run.info().config.clone();
    let d = run.workers();
    let mut state = run.init_state(seed)?;
    let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
    let mut ms = Vec::with_capacity(steps);
    for i in 0..steps + 1 {
        let mut batches: Vec<Batch> = Vec::with_capacity(d);
        for _ in 0..d {
            batches.push(batcher.next_batch());
        }
        let t0 = Instant::now();
        let (next, _stats, _plans) = run.step_detailed_mode(state, &batches, mode)?;
        if i > 0 {
            ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        state = next;
    }
    Ok(timing_series(ms, 0))
}

/// Parity smoke: one step in each mode from the same state and batches
/// must agree bitwise in stats, dispatch summary, and per-layer plans.
fn assert_modes_agree(run: &ShardedRun, seed: u64) -> Result<()> {
    let cfg = run.info().config.clone();
    let d = run.workers();
    let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
    let mut batches: Vec<Batch> = Vec::with_capacity(d);
    for _ in 0..d {
        batches.push(batcher.next_batch());
    }
    let init = run.init_state(seed)?;
    let (_, a, pa) = run.step_detailed_mode(init, &batches, StepMode::Fused)?;
    let init = run.init_state(seed)?;
    let (_, b, pb) = run.step_detailed_mode(init, &batches, StepMode::TwoPass)?;
    let same = a.loss.to_bits() == b.loss.to_bits()
        && a.load.len() == b.load.len()
        && a.load.iter().zip(&b.load).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.dropped.iter().zip(&b.dropped).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.dispatch == b.dispatch
        && pa == pb;
    ensure!(same, "fused vs two-pass divergence on {} at D={d}", cfg.name);
    Ok(())
}

/// Execute one cell: parity-check, then `steps` measured steps per mode.
pub fn run_cell(cell: &Cell) -> Result<Value> {
    let (cfg, workers) = cell_config(cell)?;
    let steps = cell.req_usize("steps")?.max(1);
    let seed = cell.req_u64("seed")?;
    let run = ShardedRun::new(&cfg, workers)?;
    assert_modes_agree(&run, seed)?;
    let fused = measure(&run, StepMode::Fused, steps, seed)?;
    let baseline = measure(&run, StepMode::TwoPass, steps, seed)?;
    let tokens = cfg.tokens_per_batch();
    let k_eff = cfg.routing.k().min(cfg.num_experts as u32).max(1) as usize;
    let row = StepBenchRow {
        model: cfg.name.clone(),
        strategy: cfg.routing.name(),
        workers,
        layers: cfg.layers,
        experts: cfg.num_experts,
        tokens_per_worker: tokens,
        routed_per_step: (workers * cfg.layers * tokens * k_eff) as u64,
        gate_bytes_avoided: (workers * cfg.layers * tokens * cfg.num_experts * 4) as u64,
        fused_p50_ms: p50(&fused),
        fused_p95_ms: p95(&fused),
        baseline_p50_ms: p50(&baseline),
        baseline_p95_ms: p95(&baseline),
    };
    eprintln!(
        "[bench] {} D={}: fused {:.3} ms (p95 {:.3}), baseline {:.3} ms, {:.2}x, {:.2} Mtok/s routed",
        row.model,
        row.workers,
        row.fused_p50_ms,
        row.fused_p95_ms,
        row.baseline_p50_ms,
        row.speedup(),
        row.fused_routed_tokens_per_sec() / 1e6
    );
    Ok(row_json(&row))
}

/// Run the full grid through the sweep engine, `steps` measured steps per
/// (cell, mode); previously-completed cells come back from the store.
pub fn run_suite(engine: &Engine, steps: usize) -> Result<(Vec<StepBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&spec(steps), &sweep::StepRunner)?;
    let rows = rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed rows from a sweep outcome's stored documents.
pub fn rows_from(outcome: &SweepOutcome) -> Result<Vec<StepBenchRow>> {
    outcome.outcomes.iter().map(|o| row_from_json(&o.result)).collect()
}

/// Minimum fused speedup over the acceptance slice: xlarge-sim (E = 64)
/// at D >= 4 — the regression gate the JSON surfaces at top level.
pub fn xlarge_min_speedup(rows: &[StepBenchRow]) -> f64 {
    let min = rows
        .iter()
        .filter(|r| r.model.starts_with("xlarge-sim") && r.workers >= 4)
        .map(StepBenchRow::speedup)
        .fold(f64::INFINITY, f64::min);
    // 0 (not inf) when the slice is absent, so the JSON stays valid
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Human-readable table over the suite.
pub fn render_table(rows: &[StepBenchRow], steps: usize) -> Table {
    let mut t = Table::new(
        format!("sharded step: fused grid vs two-pass serial baseline, {steps} steps/cell"),
        &[
            "model",
            "D",
            "T/worker",
            "fused p50 ms",
            "fused p95 ms",
            "base p50 ms",
            "speedup",
            "routed Mtok/s",
        ],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.workers.to_string(),
            r.tokens_per_worker.to_string(),
            f2(r.fused_p50_ms),
            f2(r.fused_p95_ms),
            f2(r.baseline_p50_ms),
            format!("{}x", f2(r.speedup())),
            f2(r.fused_routed_tokens_per_sec() / 1e6),
        ]);
    }
    t
}

/// One row as its stored (and emitted) JSON object: the per-cell result
/// document in the experiment store and the element of `rows` in
/// `BENCH_step.json`. Derived rates are serialized too (the historical
/// schema carries them), and recomputed on read.
fn row_json(r: &StepBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("strategy", s(r.strategy.clone())),
        ("workers", num(r.workers as f64)),
        ("layers", num(r.layers as f64)),
        ("experts", num(r.experts as f64)),
        ("tokens_per_worker", num(r.tokens_per_worker as f64)),
        ("routed_tokens_per_step", num(r.routed_per_step as f64)),
        ("gate_bytes_avoided_per_step", num(r.gate_bytes_avoided as f64)),
        ("fused_p50_ms", num(r.fused_p50_ms)),
        ("fused_p95_ms", num(r.fused_p95_ms)),
        ("baseline_p50_ms", num(r.baseline_p50_ms)),
        ("baseline_p95_ms", num(r.baseline_p95_ms)),
        ("fused_steps_per_sec", num(r.fused_steps_per_sec())),
        ("baseline_steps_per_sec", num(r.baseline_steps_per_sec())),
        ("fused_routed_tokens_per_sec", num(r.fused_routed_tokens_per_sec())),
        ("baseline_routed_tokens_per_sec", num(r.baseline_routed_tokens_per_sec())),
        ("speedup", num(r.speedup())),
    ])
}

/// Inverse of `row_json`, for rows recalled from the store.
pub fn row_from_json(v: &Value) -> Result<StepBenchRow> {
    Ok(StepBenchRow {
        model: v.req_str("model")?.to_string(),
        strategy: v.req_str("strategy")?.to_string(),
        workers: v.req_usize("workers")?,
        layers: v.req_usize("layers")?,
        experts: v.req_usize("experts")?,
        tokens_per_worker: v.req_usize("tokens_per_worker")?,
        routed_per_step: v.req_u64("routed_tokens_per_step")?,
        gate_bytes_avoided: v.req_u64("gate_bytes_avoided_per_step")?,
        fused_p50_ms: v.req_f64("fused_p50_ms")?,
        fused_p95_ms: v.req_f64("fused_p95_ms")?,
        baseline_p50_ms: v.req_f64("baseline_p50_ms")?,
        baseline_p95_ms: v.req_f64("baseline_p95_ms")?,
    })
}

/// Serialize the suite to the tracked trajectory JSON.
pub fn to_json(rows: &[StepBenchRow], steps: usize) -> Value {
    let items: Vec<Value> = rows.iter().map(row_json).collect();
    obj(vec![
        ("bench", s("step")),
        ("steps_per_cell", num(steps as f64)),
        ("xlarge_min_speedup_d4_plus", num(xlarge_min_speedup(rows))),
        ("rows", arr(items)),
    ])
}

/// Write `BENCH_step.json` (or wherever `path` points).
pub fn write_json(rows: &[StepBenchRow], steps: usize, path: &str) -> Result<()> {
    let text = json_write(&to_json(rows, steps)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_issue_matrix() {
        let cs = cases();
        assert_eq!(cs.len(), 36, "3 geometries x 4 strategies x 3 worker counts");
        for (cfg, workers) in &cs {
            assert_eq!(cfg.num_experts % workers, 0, "{}: unshardable at D={workers}", cfg.name);
            let z = cfg.routing.prototypes().max(1) as usize;
            assert_eq!(cfg.num_experts % z, 0, "{}: E not divisible by prototypes", cfg.name);
        }
        assert!(cs.iter().any(|(c, d)| c.name == "xlarge-sim-4top1" && *d == 8));
        assert!(cs.iter().any(|(c, d)| c.name == "base-sim-top1" && *d == 1));
    }

    #[test]
    fn modes_agree_on_a_sharded_cell() {
        let mut cfg =
            registry().into_iter().find(|c| c.name == "base-sim").expect("registry geometry");
        cfg.routing = Routing::TopK(2);
        cfg.capacity_mode = CapacityMode::Times1;
        let run = ShardedRun::new(&cfg, 4).unwrap();
        assert_modes_agree(&run, 7).unwrap();
    }

    #[test]
    fn rows_round_trip_through_the_store_document() {
        let row = StepBenchRow {
            model: "xlarge-sim-top1".into(),
            strategy: "top1".into(),
            workers: 4,
            layers: 8,
            experts: 64,
            tokens_per_worker: 512,
            routed_per_step: 4 * 8 * 512,
            gate_bytes_avoided: 4 * 8 * 512 * 64 * 4,
            fused_p50_ms: 2.0,
            fused_p95_ms: 2.5,
            baseline_p50_ms: 4.0,
            baseline_p95_ms: 5.0,
        };
        let back = row_from_json(&row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
        assert_eq!(back.speedup(), row.speedup());
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![StepBenchRow {
            model: "xlarge-sim-top1".into(),
            strategy: "top1".into(),
            workers: 4,
            layers: 8,
            experts: 64,
            tokens_per_worker: 512,
            routed_per_step: 4 * 8 * 512,
            gate_bytes_avoided: 4 * 8 * 512 * 64 * 4,
            fused_p50_ms: 2.0,
            fused_p95_ms: 2.5,
            baseline_p50_ms: 4.0,
            baseline_p95_ms: 5.0,
        }];
        let v = to_json(&rows, 8);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("step"));
        assert_eq!(v.get("xlarge_min_speedup_d4_plus").and_then(|x| x.as_f64()), Some(2.0));
        let items = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].get("speedup").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(
            items[0].get("gate_bytes_avoided_per_step").and_then(|x| x.as_f64()),
            Some((4 * 8 * 512 * 64 * 4) as f64)
        );
    }
}
