//! Expert-FFN kernel bench: the cache-tiled `moe::ffn` kernels against
//! the naive strided-dot baseline, over three geometries x pool sizes
//! {0 (serial), 2, default}. Shared by `m6t bench --ffn`; writes the
//! tracked perf trajectory `BENCH_ffn.json`.
//!
//! Every cell first cross-checks tiled-vs-naive forward parity
//! (max relative diff, asserted < 1e-4), so the bench doubles as a
//! numerics smoke; it then reports p50 latency for the naive forward,
//! the tiled forward, and a full tiled train application (forward +
//! rematerializing backward), plus GFLOP/s, tokens/sec, and the
//! naive-vs-tiled speedup. The JSON's `min_tiled_speedup` field is the
//! CI regression gate (>= 1.0 is structural — the tiled kernel exists
//! to beat the textbook loop order).
//!
//! The grid is declared as a [`SweepSpec`] and driven through the
//! [`Engine`]'s content-addressed store. Each cell is self-contained:
//! the naive baseline is re-measured per cell (it ignores the pool), so
//! a cell recalled from the store carries its own speedup denominator.

use anyhow::{bail, ensure, Context as _, Result};

use crate::moe::ffn::{self, FfnShape};
use crate::sweep::{self, Cell, Engine, ParamValue, SweepOutcome, SweepSpec};
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::pool::{self, WorkerPool};
use crate::util::rng::Rng;
use crate::util::stats::{measure_fn_ms, p50};
use crate::util::table::{f2, Table};

/// Code-relevant version tag in every ffn cell's store address.
pub const STORE_VERSION: &str = "ffn-v1";

/// One benched FFN geometry (E, C, M, I).
#[derive(Debug, Clone, Copy)]
pub struct FfnGeometry {
    pub name: &'static str,
    pub experts: usize,
    pub capacity: usize,
    pub hidden: usize,
    pub intermediate: usize,
}

/// The benched geometries: the base-sim expert slab, a mid-size twin,
/// and a wide-intermediate shape that exercises multi-tile experts.
pub const GEOMETRIES: [FfnGeometry; 3] = [
    FfnGeometry { name: "sim-base", experts: 16, capacity: 40, hidden: 64, intermediate: 256 },
    FfnGeometry { name: "mid", experts: 8, capacity: 64, hidden: 256, intermediate: 1024 },
    FfnGeometry { name: "wide-i", experts: 4, capacity: 64, hidden: 128, intermediate: 2048 },
];

/// The benched pool sizes: serial (0 workers), a fixed 2-worker pool,
/// and the machine default — deduplicated on small hosts.
pub fn pool_sizes() -> Vec<usize> {
    let mut v = vec![0usize, 2, pool::default_workers()];
    v.sort_unstable();
    v.dedup();
    v
}

/// The benched grid as a declarative spec: 3 geometries x the host's
/// pool sizes, last axis fastest. `reps` rides in the spec's `steps`.
pub fn spec(reps: usize) -> SweepSpec {
    let names: Vec<&str> = GEOMETRIES.iter().map(|g| g.name).collect();
    SweepSpec::new("ffn", "ffn")
        .steps(reps)
        .axis("geometry", sweep::strs(&names))
        .axis("workers", sweep::nums(&pool_sizes()))
}

/// Materialize a spec-level cell: the geometry, its registry index (the
/// data-seed discriminator), and the pool size.
fn cell_config(cell: &Cell) -> Result<(FfnGeometry, usize, usize)> {
    let name = cell.req_str("geometry")?;
    let Some(gi) = GEOMETRIES.iter().position(|g| g.name == name) else {
        bail!("ffn cell: unknown geometry {name:?}");
    };
    let workers = cell.req_usize("workers")?;
    Ok((GEOMETRIES[gi], gi, workers))
}

/// Fold the resolved geometry (including the code-derived tiling) into
/// the cell before hashing — a change to the slab shapes or the tile
/// sizing re-addresses every affected cell.
pub fn resolve_cell(cell: &Cell) -> Result<Cell> {
    let (geo, gi, _) = cell_config(cell)?;
    let shape = FfnShape::new(geo.experts, geo.capacity, geo.hidden, geo.intermediate)?;
    let mut resolved = cell.clone();
    resolved.set("ffn.experts", ParamValue::Num(geo.experts as f64));
    resolved.set("ffn.capacity", ParamValue::Num(geo.capacity as f64));
    resolved.set("ffn.hidden", ParamValue::Num(geo.hidden as f64));
    resolved.set("ffn.intermediate", ParamValue::Num(geo.intermediate as f64));
    resolved.set("ffn.i_block", ParamValue::Num(shape.i_block as f64));
    resolved.set("ffn.tiles_per_expert", ParamValue::Num(shape.n_tiles() as f64));
    resolved.set("ffn.seed_index", ParamValue::Num(gi as f64));
    Ok(resolved)
}

/// One measured (geometry, pool size) cell. The naive baseline ignores
/// the pool but is measured in-cell, so each row's speedup is
/// self-contained.
#[derive(Debug, Clone)]
pub struct FfnBenchRow {
    pub geometry: String,
    pub experts: usize,
    pub capacity: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub i_block: usize,
    pub tiles_per_expert: usize,
    pub workers: usize,
    pub naive_p50_ms: f64,
    pub tiled_fwd_p50_ms: f64,
    /// forward + rematerializing backward (the training application)
    pub tiled_train_p50_ms: f64,
    /// tiled-vs-naive forward parity on this cell's data
    pub max_rel_diff: f64,
}

impl FfnBenchRow {
    fn fwd_flops(&self) -> f64 {
        let (e, c, m, i) = (
            self.experts as f64,
            self.capacity as f64,
            self.hidden as f64,
            self.intermediate as f64,
        );
        e * (2.0 * c * m * i + 2.0 * c * i * m)
    }
    /// Tiled forward throughput in GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.fwd_flops() / (self.tiled_fwd_p50_ms * 1e6)
    }
    /// Naive-vs-tiled forward speedup (> 1 = tiled faster) — the
    /// machine-readable regression field.
    pub fn speedup(&self) -> f64 {
        self.naive_p50_ms / self.tiled_fwd_p50_ms
    }
    /// Expert-slab tokens trained per second (one fwd+bwd per token).
    pub fn tokens_per_sec(&self) -> f64 {
        (self.experts * self.capacity) as f64 * 1e3 / self.tiled_train_p50_ms
    }
}

fn fill(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() as f32) * scale).collect()
}

/// Execute one cell: parity-check tiled vs naive on this cell's data,
/// then `reps` measured calls per kernel.
pub fn run_cell(cell: &Cell) -> Result<Value> {
    let (geo, gi, workers) = cell_config(cell)?;
    let reps = cell.req_usize("steps")?.max(1);
    let shape = FfnShape::new(geo.experts, geo.capacity, geo.hidden, geo.intermediate)?;
    let mut rng = Rng::new(0x5EED ^ ((gi as u64 + 1) << 8));
    let x = fill(&mut rng, shape.x_len(), 1.0);
    let w1 = fill(&mut rng, shape.w1_len(), 0.05);
    let w2 = fill(&mut rng, shape.w2_len(), 0.05);
    let g = fill(&mut rng, shape.x_len(), 0.01);

    let mut out_naive = vec![0.0f32; shape.x_len()];
    let mut h_scratch = Vec::new();
    let naive_ms = p50(&measure_fn_ms(reps, || {
        ffn::fwd_naive(shape, &x, &w1, &w2, &mut out_naive, &mut h_scratch);
    }));

    let pool = WorkerPool::new(workers);
    let mut out = vec![0.0f32; shape.x_len()];
    let mut partial = Vec::new();
    let fwd_ms = p50(&measure_fn_ms(reps, || {
        let inputs = ffn::FfnInputs { x: &x, w1: &w1, w2: &w2 };
        ffn::fwd_tiled(&pool, shape, inputs, &mut out, &mut partial);
    }));
    let max_rel_diff = out
        .iter()
        .zip(&out_naive)
        .map(|(&a, &b)| ((a - b).abs() / b.abs().max(1.0)) as f64)
        .fold(0.0, f64::max);
    ensure!(
        max_rel_diff < 1e-4,
        "tiled vs naive forward diverged on {} at {} workers: {max_rel_diff}",
        geo.name,
        workers
    );
    let mut dw1 = vec![0.0f32; shape.w1_len()];
    let mut dw2 = vec![0.0f32; shape.w2_len()];
    let train_ms = p50(&measure_fn_ms(reps, || {
        let inputs = ffn::FfnInputs { x: &x, w1: &w1, w2: &w2 };
        ffn::fwd_tiled(&pool, shape, inputs, &mut out, &mut partial);
        let grads = ffn::FfnGrads { dw1: &mut dw1, dw2: &mut dw2, dx: None };
        ffn::bwd_tiled(&pool, shape, inputs, &g, grads, &mut partial);
    }));
    let row = FfnBenchRow {
        geometry: geo.name.to_string(),
        experts: geo.experts,
        capacity: geo.capacity,
        hidden: geo.hidden,
        intermediate: geo.intermediate,
        i_block: shape.i_block,
        tiles_per_expert: shape.n_tiles(),
        workers,
        naive_p50_ms: naive_ms,
        tiled_fwd_p50_ms: fwd_ms,
        tiled_train_p50_ms: train_ms,
        max_rel_diff,
    };
    eprintln!(
        "[bench] ffn {} W={}: naive {:.3} ms, tiled {:.3} ms ({:.2}x, {:.1} GFLOP/s), \
         train {:.3} ms ({:.0} tok/s)",
        row.geometry,
        row.workers,
        row.naive_p50_ms,
        row.tiled_fwd_p50_ms,
        row.speedup(),
        row.gflops(),
        row.tiled_train_p50_ms,
        row.tokens_per_sec()
    );
    Ok(row_json(&row))
}

/// Run the full grid through the sweep engine, `reps` measured calls per
/// (cell, kernel); previously-completed cells come back from the store.
pub fn run_suite(engine: &Engine, reps: usize) -> Result<(Vec<FfnBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&spec(reps), &sweep::FfnRunner)?;
    let rows = rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed rows from a sweep outcome's stored documents.
pub fn rows_from(outcome: &SweepOutcome) -> Result<Vec<FfnBenchRow>> {
    outcome.outcomes.iter().map(|o| row_from_json(&o.result)).collect()
}

/// Minimum tiled-vs-naive speedup over the whole grid — the regression
/// gate the JSON surfaces at top level. 0 (not inf) on an empty suite,
/// so the JSON stays valid.
pub fn min_tiled_speedup(rows: &[FfnBenchRow]) -> f64 {
    let min = rows.iter().map(FfnBenchRow::speedup).fold(f64::INFINITY, f64::min);
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Human-readable table over the suite.
pub fn render_table(rows: &[FfnBenchRow], reps: usize) -> Table {
    let mut t = Table::new(
        format!("expert FFN: tiled kernel vs naive loop order, {reps} reps/cell"),
        &[
            "geometry",
            "ExCxMxI",
            "W",
            "naive p50 ms",
            "tiled p50 ms",
            "train p50 ms",
            "GFLOP/s",
            "speedup",
            "tok/s",
        ],
    );
    for r in rows {
        t.row(vec![
            r.geometry.clone(),
            format!("{}x{}x{}x{}", r.experts, r.capacity, r.hidden, r.intermediate),
            r.workers.to_string(),
            f2(r.naive_p50_ms),
            f2(r.tiled_fwd_p50_ms),
            f2(r.tiled_train_p50_ms),
            f2(r.gflops()),
            format!("{}x", f2(r.speedup())),
            format!("{:.0}", r.tokens_per_sec()),
        ]);
    }
    t
}

/// One row as its stored (and emitted) JSON object: the per-cell result
/// document in the experiment store and the element of `rows` in
/// `BENCH_ffn.json`.
fn row_json(r: &FfnBenchRow) -> Value {
    obj(vec![
        ("geometry", s(r.geometry.clone())),
        ("experts", num(r.experts as f64)),
        ("capacity", num(r.capacity as f64)),
        ("hidden", num(r.hidden as f64)),
        ("intermediate", num(r.intermediate as f64)),
        ("i_block", num(r.i_block as f64)),
        ("tiles_per_expert", num(r.tiles_per_expert as f64)),
        ("workers", num(r.workers as f64)),
        ("naive_p50_ms", num(r.naive_p50_ms)),
        ("tiled_fwd_p50_ms", num(r.tiled_fwd_p50_ms)),
        ("tiled_train_p50_ms", num(r.tiled_train_p50_ms)),
        ("gflops", num(r.gflops())),
        ("speedup", num(r.speedup())),
        ("tokens_per_sec", num(r.tokens_per_sec())),
        ("max_rel_diff", num(r.max_rel_diff)),
    ])
}

/// Inverse of `row_json`, for rows recalled from the store.
pub fn row_from_json(v: &Value) -> Result<FfnBenchRow> {
    Ok(FfnBenchRow {
        geometry: v.req_str("geometry")?.to_string(),
        experts: v.req_usize("experts")?,
        capacity: v.req_usize("capacity")?,
        hidden: v.req_usize("hidden")?,
        intermediate: v.req_usize("intermediate")?,
        i_block: v.req_usize("i_block")?,
        tiles_per_expert: v.req_usize("tiles_per_expert")?,
        workers: v.req_usize("workers")?,
        naive_p50_ms: v.req_f64("naive_p50_ms")?,
        tiled_fwd_p50_ms: v.req_f64("tiled_fwd_p50_ms")?,
        tiled_train_p50_ms: v.req_f64("tiled_train_p50_ms")?,
        max_rel_diff: v.req_f64("max_rel_diff")?,
    })
}

/// Serialize the suite to the tracked trajectory JSON.
pub fn to_json(rows: &[FfnBenchRow], reps: usize) -> Value {
    let items: Vec<Value> = rows.iter().map(row_json).collect();
    obj(vec![
        ("bench", s("ffn")),
        ("reps_per_cell", num(reps as f64)),
        ("min_tiled_speedup", num(min_tiled_speedup(rows))),
        ("rows", arr(items)),
    ])
}

/// Write `BENCH_ffn.json` (or wherever `path` points).
pub fn write_json(rows: &[FfnBenchRow], reps: usize, path: &str) -> Result<()> {
    let text = json_write(&to_json(rows, reps)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometries_are_tileable() {
        for g in GEOMETRIES {
            let shape = FfnShape::new(g.experts, g.capacity, g.hidden, g.intermediate).unwrap();
            assert_eq!(shape.intermediate % shape.i_block, 0, "{}", g.name);
            assert!(shape.n_tiles() >= 1, "{}", g.name);
        }
        // wide-i must actually exercise multi-tile experts
        let w = GEOMETRIES[2];
        let shape = FfnShape::new(w.experts, w.capacity, w.hidden, w.intermediate).unwrap();
        assert!(shape.n_tiles() >= 2, "wide-i should span several I-tiles");
    }

    #[test]
    fn pool_sizes_start_serial_and_dedupe() {
        let sizes = pool_sizes();
        assert_eq!(sizes[0], 0, "serial baseline first");
        let mut sorted = sizes.clone();
        sorted.dedup();
        assert_eq!(sorted, sizes, "pool sizes must be unique");
    }

    #[test]
    fn spec_covers_every_geometry_and_pool_size() {
        let cells = spec(4).expand().unwrap();
        assert_eq!(cells.len(), GEOMETRIES.len() * pool_sizes().len());
        for cell in &cells {
            let resolved = resolve_cell(cell).unwrap();
            assert!(resolved.req_usize("ffn.i_block").unwrap() >= 1);
            let (geo, gi, _) = cell_config(cell).unwrap();
            assert_eq!(GEOMETRIES[gi].name, geo.name);
        }
    }

    #[test]
    fn rows_round_trip_through_the_store_document() {
        let row = FfnBenchRow {
            geometry: "mid".into(),
            experts: 8,
            capacity: 64,
            hidden: 256,
            intermediate: 1024,
            i_block: 512,
            tiles_per_expert: 2,
            workers: 2,
            naive_p50_ms: 4.0,
            tiled_fwd_p50_ms: 1.0,
            tiled_train_p50_ms: 3.0,
            max_rel_diff: 1e-7,
        };
        let back = row_from_json(&row_json(&row)).unwrap();
        assert_eq!(format!("{back:?}"), format!("{row:?}"));
        assert_eq!(back.speedup(), row.speedup());
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![FfnBenchRow {
            geometry: "mid".into(),
            experts: 8,
            capacity: 64,
            hidden: 256,
            intermediate: 1024,
            i_block: 512,
            tiles_per_expert: 2,
            workers: 2,
            naive_p50_ms: 4.0,
            tiled_fwd_p50_ms: 1.0,
            tiled_train_p50_ms: 3.0,
            max_rel_diff: 1e-7,
        }];
        let v = to_json(&rows, 8);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("ffn"));
        assert_eq!(v.get("min_tiled_speedup").and_then(|x| x.as_f64()), Some(4.0));
        let items = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].get("speedup").and_then(|x| x.as_f64()), Some(4.0));
        let toks = items[0].get("tokens_per_sec").and_then(|x| x.as_f64()).unwrap();
        assert!((toks - 8.0 * 64.0 * 1e3 / 3.0).abs() < 1e-6);
    }
}
