//! Expert-parallel multi-worker runtime: D data-parallel workers, each
//! routing its *own* local batch with per-worker capacity
//! `C = k·T_local/E·γ` (Eq. 2 at local scope), exchanging tokens with the
//! E/D-expert shards over an all-to-all whose traffic is accounted
//! exactly ([`moe::dispatch`](crate::moe::dispatch)).
//!
//! [`ShardedRun`] executes D `NativeBackend`-style worker steps per global
//! step: per (worker, layer), gate generation and the routing argmax run
//! as token-shard work units on the persistent [`WorkerPool`] — the same
//! decomposition, and therefore the same bitwise-determinism contract
//! across pool sizes, as `NativeBackend::step`
//! (`rust/tests/pool_determinism.rs`). Worker 0's RNG streams are
//! *identical* to the single-worker backend's, and every global aggregate
//! is computed in the same operation order, so at D = 1 the emitted
//! [`StepStats`] reproduce `NativeBackend::step` bit for bit — the
//! contract `rust/tests/dispatch_properties.rs` pins.
//!
//! Each step also emits a [`DispatchSummary`]: per-worker drop counts,
//! per-shard received/dropped tokens, the cross-worker load c_v, and the
//! *measured* all-to-all bytes that [`simulate_step_observed`] consumes
//! in place of the cluster model's analytic O(ECM) estimate.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::{Backend, StateRepr, StepStats, TrainState};
use super::manifest::VariantInfo;
use super::native::{
    batch_hash, fill_gates, hash_f32s, law_from_leaf, NativeBackend, LAYER_SEED_MIX,
    NOISE_SEED_MIX, STEP_SEED_MIX,
};
use crate::cluster::{simulate_step_observed, table2_hardware, HardwareModel, ObservedTraffic};
use crate::config::ModelConfig;
use crate::data::{Batch, Batcher, Split};
use crate::metrics::RunLog;
use crate::moe::{DispatchPlan, DispatchSummary, RouteOutput, RouterSpec, RoutingEngine};
use crate::util::pool::{self, WorkerPool};
use crate::util::rng::Rng;
use crate::util::stats::coefficient_of_variation;

/// Constant separating per-worker RNG streams. Worker 0 folds in zero, so
/// its streams are bitwise identical to `NativeBackend::step`'s.
const WORKER_SEED_MIX: u64 = 0xA24B_AED4_963E_E407;

/// Per-run reusable routing buffers (see `StepScratch` in `native`).
#[derive(Default)]
struct ShardScratch {
    engine: RoutingEngine,
    gates: Vec<f32>,
    route_out: RouteOutput,
}

/// The expert-parallel execution driver: D workers over one shared
/// (data-parallel-synchronized) train state.
pub struct ShardedRun {
    native: NativeBackend,
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
    hw: HardwareModel,
    scratch: Mutex<ShardScratch>,
}

impl ShardedRun {
    /// Driver for `cfg` sharded over `workers` expert-parallel workers
    /// (the config's own `workers` field is overridden). Requires E to
    /// divide into equal shards.
    pub fn new(cfg: &ModelConfig, workers: usize) -> Result<Self> {
        Self::build(cfg, workers, None)
    }

    /// Driver pinned to a specific pool — how the determinism tests
    /// assert bitwise-identical output across pool sizes.
    pub fn with_pool(cfg: &ModelConfig, workers: usize, pool: Arc<WorkerPool>) -> Result<Self> {
        Self::build(cfg, workers, Some(pool))
    }

    fn build(cfg: &ModelConfig, workers: usize, pool: Option<Arc<WorkerPool>>) -> Result<Self> {
        if workers == 0 {
            bail!("sharded run needs at least one worker");
        }
        if cfg.num_experts % workers != 0 {
            bail!(
                "experts {} not divisible by workers {workers}: expert shards must be equal",
                cfg.num_experts
            );
        }
        let mut cfg_d = cfg.clone();
        cfg_d.workers = workers;
        let native = match &pool {
            Some(p) => NativeBackend::with_pool(&cfg_d, Arc::clone(p)),
            None => NativeBackend::new(&cfg_d),
        };
        let engine = match &pool {
            Some(p) => RoutingEngine::with_pool(Arc::clone(p)),
            None => RoutingEngine::new(),
        };
        Ok(Self {
            native,
            workers,
            pool,
            hw: table2_hardware(),
            scratch: Mutex::new(ShardScratch {
                engine,
                gates: Vec::new(),
                route_out: RouteOutput::default(),
            }),
        })
    }

    pub fn info(&self) -> &VariantInfo {
        self.native.info()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Analytic (pre-observation) cluster prediction for one step at this
    /// worker count.
    pub fn analytic_step_ms(&self) -> f64 {
        self.native.simulated_step_ms()
    }

    /// Fresh train state — identical to the single-worker backend's
    /// (worker replicas are data-parallel-synchronized, so one state
    /// vector represents all of them).
    pub fn init_state(&self, seed: i32) -> Result<TrainState> {
        self.native.init_state(seed)
    }

    /// One global step over `batches` (one local batch per worker).
    pub fn step(&self, state: TrainState, batches: &[Batch]) -> Result<(TrainState, StepStats)> {
        let (state, stats, _plans) = self.step_detailed(state, batches)?;
        Ok((state, stats))
    }

    /// [`ShardedRun::step`] plus the per-layer [`DispatchPlan`]s — the
    /// form the invariant tests and the dispatch bench consume.
    pub fn step_detailed(
        &self,
        state: TrainState,
        batches: &[Batch],
    ) -> Result<(TrainState, StepStats, Vec<DispatchPlan>)> {
        let info = self.native.info();
        let cfg = &info.config;
        let d = self.workers;
        if batches.len() != d {
            bail!("sharded step got {} batches for {d} workers", batches.len());
        }
        let TrainState { step, repr } = state;
        let mut leaves = match repr {
            StateRepr::Host(leaves) => leaves,
            #[cfg(feature = "pjrt")]
            StateRepr::Device(_) => bail!("sharded runtime received a device-resident state"),
        };
        let law = law_from_leaf(&leaves[0])?;
        let tokens = cfg.tokens_per_batch();
        let experts = cfg.num_experts;
        let layers = cfg.layers;
        let capacity = info.capacity;
        let prototypes = cfg.routing.prototypes().max(1) as usize;

        let mut guard = self.scratch.lock().expect("shard scratch poisoned");
        let ShardScratch { engine, gates, route_out } = &mut *guard;
        let pool_ref = self.pool.as_deref().unwrap_or_else(pool::global);
        let bias = &leaves[1];
        let spec = RouterSpec { routing: cfg.routing, num_experts: experts, capacity };
        gates.resize(tokens * experts, 0.0);

        // every worker routes its own local batch: per-(worker, layer)
        // kept and demanded counts, accumulated serially in worker order
        // while each phase's token shards run on the pool — the exact
        // per-phase decomposition of NativeBackend::step, repeated D
        // times with per-worker RNG streams.
        let mut wl_load = vec![0u32; d * layers * experts];
        let mut wl_demand = vec![0u32; d * layers * experts];
        let mut wl_dropped = vec![0u32; d * layers];
        let mut total_dropped = 0u64;
        let mut noise_sum = 0.0f64;
        let state_hash = hash_f32s(&leaves[0]);
        for w in 0..d {
            let base_seed = state_hash
                ^ (step as u64).wrapping_mul(STEP_SEED_MIX)
                ^ batch_hash(&batches[w])
                ^ (w as u64).wrapping_mul(WORKER_SEED_MIX);
            for l in 0..layers {
                let layer_seed = base_seed ^ (l as u64 + 1).wrapping_mul(LAYER_SEED_MIX);
                let bias_row = &bias[l * experts..(l + 1) * experts];
                fill_gates(
                    pool_ref,
                    gates.as_mut_slice(),
                    layer_seed,
                    bias_row,
                    tokens,
                    experts,
                    prototypes,
                );
                engine.route_counts_into(gates.as_slice(), tokens, &spec, route_out);
                let at = (w * layers + l) * experts;
                wl_load[at..at + experts].copy_from_slice(&route_out.load);
                wl_demand[at..at + experts].copy_from_slice(&route_out.demand);
                wl_dropped[w * layers + l] = route_out.dropped;
                total_dropped += route_out.dropped as u64;
            }
            let mut noise = Rng::new(base_seed ^ NOISE_SEED_MIX);
            noise_sum += noise.normal();
        }
        drop(guard);

        // global aggregates, in NativeBackend::step's operation order so
        // D = 1 reproduces its StepStats bitwise
        let mut load = vec![0f32; layers * experts];
        let mut dropped = vec![0f32; layers];
        let mut cv_sum = 0.0;
        let mut cv_row: Vec<f64> = Vec::with_capacity(experts);
        for l in 0..layers {
            cv_row.clear();
            for e in 0..experts {
                let mut sum = 0u32;
                for w in 0..d {
                    sum += wl_load[(w * layers + l) * experts + e];
                }
                load[l * experts + e] = sum as f32;
                cv_row.push(sum as f64);
            }
            let mut drop_sum = 0u32;
            for w in 0..d {
                drop_sum += wl_dropped[w * layers + l];
            }
            dropped[l] = drop_sum as f32;
            cv_sum += coefficient_of_variation(&cv_row);
        }
        let mean_cv = cv_sum / layers.max(1) as f64;
        let k_eff = cfg.routing.k().min(experts as u32).max(1) as usize;
        let routed = (layers * tokens * k_eff * d) as f64;
        let drop_frac = total_dropped as f64 / routed.max(1.0);

        let s_next = (step + 1) as f64;
        let noise_mean = noise_sum / d as f64;
        let loss = law.predict(s_next) + 0.02 * drop_frac + 0.01 * noise_mean;
        let grad_norm = law.a * law.b * s_next.powf(-law.b - 1.0) * 50.0 + 0.5;

        // data-parallel replicas stay synchronized: the aux balancing
        // decay applies once per global step, exactly as at D = 1
        if cfg.aux_loss_coef > 0.0 {
            for v in leaves[1].iter_mut() {
                *v *= 0.95;
            }
        }

        // one DispatchPlan per layer, then the step-level summary with
        // the observed-traffic cluster prediction
        let mut plans = Vec::with_capacity(layers);
        for l in 0..layers {
            let mut send = vec![0u32; d * experts];
            let mut demand = vec![0u32; d * experts];
            for w in 0..d {
                let at = (w * layers + l) * experts;
                send[w * experts..(w + 1) * experts]
                    .copy_from_slice(&wl_load[at..at + experts]);
                demand[w * experts..(w + 1) * experts]
                    .copy_from_slice(&wl_demand[at..at + experts]);
            }
            plans.push(DispatchPlan::new(d, experts, capacity, cfg.hidden, send, demand));
        }
        let mut summary = DispatchSummary::from_plans(&plans);
        let observed = ObservedTraffic {
            a2a_bytes_per_layer: summary.a2a_bytes_per_layer,
            shard_balance: summary.shard_balance,
        };
        summary.observed_ms =
            simulate_step_observed(cfg, cfg.routing, cfg.capacity_mode, &self.hw, &observed)
                .total_ms();

        let stats = StepStats {
            loss: loss as f32,
            aux_loss: (cfg.aux_loss_coef * mean_cv) as f32,
            grad_norm: grad_norm as f32,
            load,
            layers,
            experts,
            dropped,
            sim_step_ms: self.native.simulated_step_ms(),
            dispatch: Some(summary),
        };
        Ok((TrainState { step: step + 1, repr: StateRepr::Host(leaves) }, stats, plans))
    }

    /// Drive `steps` global steps from a fresh init, one local batch per
    /// worker per step (worker `w` consumes batch `s·D + w`, so D = 1
    /// replays the single-worker data stream exactly). Records every
    /// step — including the per-worker dispatch series — in `log`.
    pub fn train(
        &self,
        steps: i64,
        seed: u64,
        log: &mut RunLog,
        verbose: bool,
    ) -> Result<TrainState> {
        let state = self.init_state(seed as i32)?;
        self.train_from(state, steps, seed, log, verbose)
    }

    /// Continue training from an existing state (resume-aware: the batch
    /// cursor skips everything all D workers already consumed).
    pub fn train_from(
        &self,
        mut state: TrainState,
        steps: i64,
        seed: u64,
        log: &mut RunLog,
        verbose: bool,
    ) -> Result<TrainState> {
        let info = self.native.info();
        let cfg = info.config.clone();
        let d = self.workers;
        let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
        batcher.seek(state.step as u64 * (cfg.batch * d) as u64);
        let mut batches: Vec<Batch> = Vec::with_capacity(d);
        let end_step = state.step + steps;
        while state.step < end_step {
            batches.clear();
            for _ in 0..d {
                batches.push(batcher.next_batch());
            }
            let t0 = Instant::now();
            let (next, stats) = self.step(state, &batches)?;
            state = next;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let step_now = state.step - 1;
            log.push(step_now, &stats, ms)?;
            if verbose && step_now % 50 == 0 {
                let (cv, a2a_mb) = stats
                    .dispatch
                    .as_ref()
                    .map(|s| (s.shard_load_cv, s.a2a_bytes_step / 1e6))
                    .unwrap_or((0.0, 0.0));
                eprintln!(
                    "[{}|D={d}] step {:>5} loss {:.4} drop {:>5.0} shard-cv {:.3} a2a {:.2} MB {:.0} ms",
                    info.name,
                    step_now,
                    stats.loss,
                    stats.total_dropped(),
                    cv,
                    a2a_mb,
                    ms
                );
            }
        }
        Ok(state)
    }

    /// Teacher-forced eval PPL over `n` paired eval batches (cursor reset,
    /// identical data across strategies and worker counts).
    pub fn eval_ppl(&self, state: &TrainState, n: usize, seed: u64) -> Result<f64> {
        let cfg = &self.native.info().config;
        let mut batcher = Batcher::for_config(cfg, Split::Eval, seed);
        batcher.seek(0);
        let mut sum_nll = 0.0;
        let mut count = 0.0;
        for _ in 0..n {
            let batch = batcher.next_batch();
            let (nll, c) = self.native.eval(state, &batch)?;
            sum_nll += nll;
            count += c;
        }
        Ok((sum_nll / count.max(1.0)).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::registry;

    fn sim_cfg(name: &str) -> ModelConfig {
        registry().into_iter().find(|c| c.name == name).expect("registry variant")
    }

    #[test]
    fn rejects_unshardable_geometry() {
        let cfg = sim_cfg("base-sim"); // E = 16
        assert!(ShardedRun::new(&cfg, 0).is_err());
        assert!(ShardedRun::new(&cfg, 3).is_err(), "16 % 3 != 0");
        assert!(ShardedRun::new(&cfg, 8).is_ok());
    }

    #[test]
    fn step_requires_one_batch_per_worker() {
        let cfg = sim_cfg("base-sim");
        let run = ShardedRun::new(&cfg, 4).unwrap();
        let state = run.init_state(7).unwrap();
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 7);
        let batches = vec![batcher.next_batch()];
        assert!(run.step(state, &batches).is_err());
    }

    #[test]
    fn sharded_step_emits_conserved_dispatch() {
        let cfg = sim_cfg("large-sim"); // E = 32, 8 layers
        let d = 4;
        let run = ShardedRun::new(&cfg, d).unwrap();
        let state = run.init_state(11).unwrap();
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 11);
        let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
        let (next, stats, plans) = run.step_detailed(state, &batches).unwrap();
        assert_eq!(next.step, 1);
        assert_eq!(plans.len(), cfg.layers);
        let summary = stats.dispatch.as_ref().expect("sharded stats carry dispatch");
        assert_eq!(summary.workers, d);
        // routed-slot conservation per worker per layer
        let tokens = cfg.tokens_per_batch() as u64;
        let k_eff = cfg.routing.k().max(1) as u64;
        for plan in &plans {
            let kept = plan.kept_per_worker();
            let drops = plan.dropped_per_worker();
            for w in 0..d {
                assert_eq!(kept[w] + drops[w], tokens * k_eff);
            }
        }
        // global StepStats load equals the per-shard receive totals
        let stats_total: f64 = stats.load.iter().map(|&x| x as f64).sum();
        let recv_total: f64 = summary.per_shard_recv.iter().sum();
        assert_eq!(stats_total, recv_total);
        assert!(summary.observed_ms > 0.0);
    }
}
