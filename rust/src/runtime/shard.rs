//! Expert-parallel multi-worker runtime: D data-parallel workers, each
//! routing its *own* local batch with per-worker capacity
//! `C = k·T_local/E·γ` (Eq. 2 at local scope), exchanging tokens with the
//! E/D-expert shards over an all-to-all whose traffic is accounted
//! exactly ([`moe::dispatch`](crate::moe::dispatch)).
//!
//! [`ShardedRun`] executes D `NativeBackend`-style worker steps per
//! global step. The default [`StepMode::Fused`] path dispatches the
//! **entire D x L (worker, layer) grid** — further split into token
//! tiles — as independent work units on the persistent [`WorkerPool`]:
//! each unit owns its own RNG stream (derived from its `(worker, layer,
//! tile)` coordinates), generates and routes one cache-resident gate
//! tile through the fused counts kernel ([`moe::fused`]), and writes a
//! disjoint demand histogram; histograms merge exactly, so the step is
//! bitwise identical across pool sizes (`rust/tests/pool_determinism.rs`,
//! `rust/tests/fused_routing.rs`). The pre-fusion serial two-pass path
//! ([`StepMode::TwoPass`]: materialize each (worker, layer) gate matrix,
//! then route it with the engine) is kept callable as the throughput
//! baseline `m6t bench --step` measures against and as the bitwise
//! oracle the tests compare to. Worker 0's RNG streams are *identical*
//! to the single-worker backend's, and every global aggregate is
//! computed in the same operation order, so at D = 1 the emitted
//! [`StepStats`] reproduce `NativeBackend::step` bit for bit — the
//! contract `rust/tests/dispatch_properties.rs` pins.
//!
//! Each step also emits a [`DispatchSummary`]: per-worker drop counts,
//! per-shard received/dropped tokens, the cross-worker load c_v, and the
//! *measured* all-to-all bytes that the cluster model's [`StepInputs`]
//! run consumes in place of the analytic O(ECM) estimate.
#![forbid(unsafe_code)]

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::{Backend, StateRepr, StepStats, TrainState};
use super::manifest::VariantInfo;
use super::native::{
    batch_hash, fill_gates, hash_f32s, law_from_leaf, real_train_step, route_grid_counts,
    GridCountsOut, GridSpec, NativeBackend, RealScratch, RoutedLoads, LAYER_SEED_MIX,
    NOISE_SEED_MIX, STEP_SEED_MIX,
};
use crate::cluster::placement::{self, PlacementStrategy};
use crate::cluster::topology::layer_bottleneck_seconds;
use crate::cluster::{table2_hardware, HardwareModel, ObservedTraffic, StepInputs, Topology};
use crate::config::{ComputeMode, ModelConfig};
use crate::data::{Batch, Batcher, Split};
use crate::metrics::RunLog;
use crate::moe::capacity::{self, ElasticCapacity};
use crate::moe::{DispatchPlan, DispatchSummary, RouteOutput, RouterSpec, RoutingEngine};
use crate::util::pool::{self, WorkerPool};
use crate::util::rng::Rng;
use crate::util::stats::coefficient_of_variation;

/// Constant separating per-worker RNG streams. Worker 0 folds in zero, so
/// its streams are bitwise identical to `NativeBackend::step`'s.
const WORKER_SEED_MIX: u64 = 0xA24B_AED4_963E_E407;

/// Which implementation routes the (worker x layer) grid of one step.
/// Both modes are bitwise identical in everything they emit — StepStats,
/// dispatch summary, and per-layer plans (`rust/tests/fused_routing.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Fused single-pass counts kernel over the full parallel
    /// D x L x tile work-unit grid — the default hot path.
    Fused,
    /// The pre-fusion path: a serial (worker, layer) double loop, each
    /// cell materializing its gate matrix (`fill_gates`) and re-reading
    /// it through the routing engine. Kept as the throughput baseline
    /// `m6t bench --step` measures the fused path against, and as the
    /// bitwise oracle for the determinism tests.
    TwoPass,
}

/// Per-run reusable routing buffers (see `StepScratch` in `native`).
/// Everything the sharded hot loop touches per step that does *not*
/// escape into [`StepStats`] lives here, so `train` steps are
/// allocation-free after warmup. (`StepStats::load`/`dropped` and the
/// returned plan list are the step's owned output and necessarily fresh;
/// the plans' big count matrices are recycled through `plan_pool`.)
#[derive(Default)]
struct ShardScratch {
    // two-pass baseline
    engine: RoutingEngine,
    gates: Vec<f32>,
    route_out: RouteOutput,
    // fused grid
    partial: Vec<u32>,
    // shared per-step state
    worker_seeds: Vec<u64>,
    /// D x L x E kept counts, row-major
    wl_load: Vec<u32>,
    /// D x L x E pre-capacity demand, row-major
    wl_demand: Vec<u32>,
    /// D x L dropped-selection counts
    wl_dropped: Vec<u32>,
    cv_row: Vec<f64>,
    /// D x D per-layer link-byte accumulator for the topology cost model
    link_layer: Vec<u64>,
    /// per-layer one-direction link-bottleneck comm, ms
    layer_comm_ms: Vec<f64>,
    /// recycled `DispatchPlan`s: [`ShardedRun::step`] returns each step's
    /// plans here so the next step reuses their send/demand vectors
    plan_pool: Vec<DispatchPlan>,
    /// elastic per-(layer, shard) capacity controller (None = static Eq.-2
    /// capacities, the bitwise-pinned default path)
    elastic: Option<ElasticCapacity>,
    /// L x E max-over-workers demand scratch the controller observes
    demand_max: Vec<u32>,
    /// D x D step-summed *full* (diagonal included) byte matrix the
    /// placement search optimizes over
    full_step: Vec<u64>,
    /// real-compute slabs/grads (empty for simulated variants)
    real: RealScratch,
}

/// The expert-parallel execution driver: D workers over one shared
/// (data-parallel-synchronized) train state.
pub struct ShardedRun {
    native: NativeBackend,
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
    hw: HardwareModel,
    /// workers-per-node grouping for the link-level comm model; defaults
    /// to the hardware model's grouping (flat on the paper's testbed)
    topology: Topology,
    /// expert-shard -> worker assignment strategy for the comm model
    /// (Identity = shard s lives on worker s, the pinned default)
    placement: PlacementStrategy,
    scratch: Mutex<ShardScratch>,
}

impl ShardedRun {
    /// Driver for `cfg` sharded over `workers` expert-parallel workers
    /// (the config's own `workers` field is overridden). Requires E to
    /// divide into equal shards.
    pub fn new(cfg: &ModelConfig, workers: usize) -> Result<Self> {
        Self::build(cfg, workers, None)
    }

    /// Driver pinned to a specific pool — how the determinism tests
    /// assert bitwise-identical output across pool sizes.
    pub fn with_pool(cfg: &ModelConfig, workers: usize, pool: Arc<WorkerPool>) -> Result<Self> {
        Self::build(cfg, workers, Some(pool))
    }

    fn build(cfg: &ModelConfig, workers: usize, pool: Option<Arc<WorkerPool>>) -> Result<Self> {
        if workers == 0 {
            bail!("sharded run needs at least one worker");
        }
        if cfg.num_experts % workers != 0 {
            bail!(
                "experts {} not divisible by workers {workers}: expert shards must be equal",
                cfg.num_experts
            );
        }
        let mut cfg_d = cfg.clone();
        cfg_d.workers = workers;
        let native = match &pool {
            Some(p) => NativeBackend::with_pool(&cfg_d, Arc::clone(p)),
            None => NativeBackend::new(&cfg_d),
        };
        let engine = match &pool {
            Some(p) => RoutingEngine::with_pool(Arc::clone(p)),
            None => RoutingEngine::new(),
        };
        let hw = table2_hardware();
        let topology = Topology::new(workers, hw.workers_per_node);
        Ok(Self {
            native,
            workers,
            pool,
            hw,
            topology,
            placement: PlacementStrategy::Identity,
            scratch: Mutex::new(ShardScratch { engine, ..ShardScratch::default() }),
        })
    }

    pub fn info(&self) -> &VariantInfo {
        self.native.info()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The workers-per-node grouping the link-level comm model prices
    /// this run's all-to-all against.
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Regroup the workers `wpn` per node (1 = flat). Only the comm cost
    /// model changes — routing, dispatch accounting, and every StepStats
    /// series are topology-independent. The hardware model's grouping
    /// field is kept in lockstep so the two never disagree.
    pub fn set_workers_per_node(&mut self, wpn: usize) {
        self.hw.workers_per_node = wpn.max(1);
        self.topology = Topology::new(self.workers, self.hw.workers_per_node);
    }

    /// Switch the per-(layer, shard) capacities from static Eq.-2 to the
    /// elastic controller (or back). While the controller is cold the
    /// step stays bitwise identical to the static path; once it has
    /// observed a step it re-clamps demand under the same global slot
    /// budget (`D x E x C` slots per layer). Only the simulated-compute
    /// variants are supported: the real-compute FFN slabs are sized for
    /// the static Eq.-2 capacity.
    pub fn set_elastic_capacity(&mut self, on: bool) -> Result<()> {
        let info = self.native.info();
        let mut guard = self.scratch.lock().expect("shard scratch poisoned");
        if !on {
            guard.elastic = None;
            return Ok(());
        }
        if info.config.compute == ComputeMode::Real {
            bail!(
                "elastic capacity is simulated-compute only: the real FFN slabs \
                 are sized for the static Eq.-2 capacity"
            );
        }
        guard.elastic = Some(ElasticCapacity::new(
            info.config.layers,
            info.config.num_experts,
            self.workers,
            info.capacity,
        )?);
        Ok(())
    }

    /// Set the expert-shard -> worker assignment strategy the comm model
    /// prices the all-to-all under. Routing, dispatch accounting, and
    /// every StepStats series are placement-independent — only
    /// `layer_comm_ms`, the overlap model, and the placement fields of
    /// the [`DispatchSummary`] change.
    pub fn set_placement(&mut self, strategy: PlacementStrategy) {
        self.placement = strategy;
    }

    /// Analytic (pre-observation) cluster prediction for one step at this
    /// worker count.
    pub fn analytic_step_ms(&self) -> f64 {
        self.native.simulated_step_ms()
    }

    /// Fresh train state — identical to the single-worker backend's
    /// (worker replicas are data-parallel-synchronized, so one state
    /// vector represents all of them).
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        self.native.init_state(seed)
    }

    /// One global step over `batches` (one local batch per worker).
    pub fn step(&self, state: TrainState, batches: &[Batch]) -> Result<(TrainState, StepStats)> {
        let (state, stats, plans) = self.step_detailed(state, batches)?;
        // the train loop never reads the plans: recycle their count
        // matrices so the hot loop stays allocation-free after warmup
        let mut guard = self.scratch.lock().expect("shard scratch poisoned");
        guard.plan_pool.extend(plans);
        Ok((state, stats))
    }

    /// [`ShardedRun::step`] plus the per-layer [`DispatchPlan`]s — the
    /// form the invariant tests and the dispatch bench consume. Routes
    /// through the fused parallel grid ([`StepMode::Fused`]).
    pub fn step_detailed(
        &self,
        state: TrainState,
        batches: &[Batch],
    ) -> Result<(TrainState, StepStats, Vec<DispatchPlan>)> {
        self.step_detailed_mode(state, batches, StepMode::Fused)
    }

    /// [`ShardedRun::step_detailed`] with an explicit [`StepMode`] — how
    /// the step bench times fused against the two-pass baseline in one
    /// run, and how the tests pin the two modes bitwise identical.
    pub fn step_detailed_mode(
        &self,
        state: TrainState,
        batches: &[Batch],
        mode: StepMode,
    ) -> Result<(TrainState, StepStats, Vec<DispatchPlan>)> {
        let info = self.native.info();
        let cfg = &info.config;
        let d = self.workers;
        if batches.len() != d {
            bail!("sharded step got {} batches for {d} workers", batches.len());
        }
        let TrainState { step, repr } = state;
        let mut leaves = match repr {
            StateRepr::Host(leaves) => leaves,
            #[cfg(feature = "pjrt")]
            StateRepr::Device(_) => bail!("sharded runtime received a device-resident state"),
        };
        let law = law_from_leaf(&leaves[0])?;
        let tokens = cfg.tokens_per_batch();
        let experts = cfg.num_experts;
        let layers = cfg.layers;
        let capacity = info.capacity;
        let prototypes = cfg.routing.prototypes().max(1) as usize;

        let mut guard = self.scratch.lock().expect("shard scratch poisoned");
        let scratch = &mut *guard;
        let pool_ref = self.pool.as_deref().unwrap_or_else(pool::global);
        let bias = &leaves[1];
        let state_hash = hash_f32s(&leaves[0]);
        scratch.worker_seeds.clear();
        scratch.worker_seeds.extend((0..d).map(|w| {
            state_hash
                ^ (step as u64).wrapping_mul(STEP_SEED_MIX)
                ^ batch_hash(&batches[w])
                ^ (w as u64).wrapping_mul(WORKER_SEED_MIX)
        }));
        let n = d * layers * experts;
        if scratch.wl_load.len() < n {
            scratch.wl_load.resize(n, 0);
            scratch.wl_demand.resize(n, 0);
        }
        if scratch.wl_dropped.len() < d * layers {
            scratch.wl_dropped.resize(d * layers, 0);
        }

        // every worker routes its own local batch: per-(worker, layer)
        // kept and demanded counts. The fused mode dispatches the whole
        // D x L x tile grid as independent pool work units (each a pure
        // function of its coordinates, merged exactly); the two-pass
        // baseline walks the grid serially, materializing each cell's
        // gate matrix. Same counts bitwise either way.
        match mode {
            StepMode::Fused => {
                let ShardScratch { partial, worker_seeds, wl_load, wl_demand, wl_dropped, .. } =
                    &mut *scratch;
                route_grid_counts(
                    pool_ref,
                    worker_seeds,
                    bias,
                    GridSpec {
                        tokens,
                        experts,
                        layers,
                        prototypes,
                        routing: cfg.routing,
                        capacity,
                    },
                    partial,
                    GridCountsOut {
                        wl_demand: &mut wl_demand[..n],
                        wl_load: &mut wl_load[..n],
                        wl_dropped: &mut wl_dropped[..d * layers],
                    },
                );
            }
            StepMode::TwoPass => {
                let ShardScratch {
                    engine,
                    gates,
                    route_out,
                    worker_seeds,
                    wl_load,
                    wl_demand,
                    wl_dropped,
                    ..
                } = &mut *scratch;
                let spec = RouterSpec { routing: cfg.routing, num_experts: experts, capacity };
                // resize-once guard: fill_gates overwrites every cell, so
                // re-zeroing an already-large buffer would be pure waste
                if gates.len() < tokens * experts {
                    gates.resize(tokens * experts, 0.0);
                }
                let gates = &mut gates[..tokens * experts];
                for w in 0..d {
                    for l in 0..layers {
                        let layer_seed =
                            worker_seeds[w] ^ (l as u64 + 1).wrapping_mul(LAYER_SEED_MIX);
                        let bias_row = &bias[l * experts..(l + 1) * experts];
                        fill_gates(
                            pool_ref, gates, layer_seed, bias_row, tokens, experts, prototypes,
                        );
                        engine.route_counts_into(gates, tokens, &spec, route_out);
                        let at = (w * layers + l) * experts;
                        wl_load[at..at + experts].copy_from_slice(&route_out.load);
                        wl_demand[at..at + experts].copy_from_slice(&route_out.demand);
                        wl_dropped[w * layers + l] = route_out.dropped;
                    }
                }
            }
        }

        // elastic capacity: re-clamp this step's demand under last step's
        // per-(layer, shard) capacities, then feed the controller this
        // step's demand. Applying before observing keeps the loop causal
        // (capacities derive only from strictly earlier steps), and a
        // cold controller leaves the static counts untouched — bitwise.
        let mut elastic_applied = false;
        let mut cap_min = capacity;
        let mut cap_max = capacity;
        if scratch.elastic.is_some() {
            let ShardScratch { elastic, wl_load, wl_demand, wl_dropped, demand_max, .. } =
                &mut *scratch;
            let el = elastic.as_mut().expect("elastic checked Some");
            let eps = experts / d;
            if el.ready() {
                elastic_applied = true;
                cap_min = el.min_cap();
                cap_max = el.max_cap();
                for w in 0..d {
                    for l in 0..layers {
                        let at = (w * layers + l) * experts;
                        wl_dropped[w * layers + l] = capacity::apply_caps(
                            &wl_demand[at..at + experts],
                            el.caps_layer(l),
                            eps,
                            &mut wl_load[at..at + experts],
                        );
                    }
                }
            }
            demand_max.clear();
            demand_max.resize(layers * experts, 0);
            for w in 0..d {
                for l in 0..layers {
                    let at = (w * layers + l) * experts;
                    for e in 0..experts {
                        let i = l * experts + e;
                        demand_max[i] = demand_max[i].max(wl_demand[at + e]);
                    }
                }
            }
            el.observe(demand_max);
        }

        // drop totals + per-worker loss noise, in worker order — the
        // exact accumulation order (and RNG streams) of both modes
        let mut total_dropped = 0u64;
        let mut noise_sum = 0.0f64;
        for w in 0..d {
            for l in 0..layers {
                total_dropped += scratch.wl_dropped[w * layers + l] as u64;
            }
            let mut noise = Rng::new(scratch.worker_seeds[w] ^ NOISE_SEED_MIX);
            noise_sum += noise.normal();
        }

        // global aggregates, in NativeBackend::step's operation order so
        // D = 1 reproduces its StepStats bitwise
        let mut load = vec![0f32; layers * experts];
        let mut dropped = vec![0f32; layers];
        let mut cv_sum = 0.0;
        for l in 0..layers {
            scratch.cv_row.clear();
            for e in 0..experts {
                let mut sum = 0u32;
                for w in 0..d {
                    sum += scratch.wl_load[(w * layers + l) * experts + e];
                }
                load[l * experts + e] = sum as f32;
                scratch.cv_row.push(sum as f64);
            }
            let mut drop_sum = 0u32;
            for w in 0..d {
                drop_sum += scratch.wl_dropped[w * layers + l];
            }
            dropped[l] = drop_sum as f32;
            cv_sum += coefficient_of_variation(&scratch.cv_row);
        }
        let mean_cv = cv_sum / layers.max(1) as f64;
        let k_eff = cfg.routing.k().min(experts as u32).max(1) as usize;
        let routed = (layers * tokens * k_eff * d) as f64;
        let drop_frac = total_dropped as f64 / routed.max(1.0);

        let s_next = (step + 1) as f64;
        let noise_mean = noise_sum / d as f64;
        let (loss, grad_norm) = if cfg.compute == ComputeMode::Real {
            // real expert compute over the full (worker, layer) grid —
            // the same shared kernel path as NativeBackend::step, so
            // D = 1 reproduces the single-worker run bitwise
            let ShardScratch { worker_seeds, wl_load, real, .. } = &mut *scratch;
            real_train_step(
                pool_ref,
                cfg,
                capacity,
                &mut leaves,
                RoutedLoads { worker_seeds: worker_seeds.as_slice(), wl_load: &wl_load[..n] },
                step,
                real,
            )?
        } else {
            let loss = law.predict(s_next) + 0.02 * drop_frac + 0.01 * noise_mean;
            let grad_norm = law.a * law.b * s_next.powf(-law.b - 1.0) * 50.0 + 0.5;
            (loss, grad_norm)
        };

        // data-parallel replicas stay synchronized: the aux balancing
        // decay applies once per global step, exactly as at D = 1
        if cfg.aux_loss_coef > 0.0 {
            for v in leaves[1].iter_mut() {
                *v *= 0.95;
            }
        }

        // one DispatchPlan per layer, then the step-level summary with
        // the observed-traffic cluster prediction. Count matrices come
        // out of the recycled pool when `step()` has returned earlier
        // plans, so steady-state training allocates nothing here.
        let mut plans = Vec::with_capacity(layers);
        for l in 0..layers {
            let (mut send, mut demand) = match scratch.plan_pool.pop() {
                Some(p) => (p.send, p.demand),
                None => (Vec::new(), Vec::new()),
            };
            send.clear();
            demand.clear();
            for w in 0..d {
                let at = (w * layers + l) * experts;
                send.extend_from_slice(&scratch.wl_load[at..at + experts]);
                demand.extend_from_slice(&scratch.wl_demand[at..at + experts]);
            }
            plans.push(DispatchPlan::new(d, experts, capacity, cfg.hidden, send, demand));
        }
        // topology-aware placement: search the step-summed *full* byte
        // matrix (diagonal included — local traffic goes remote under a
        // permutation) for an expert-shard -> worker assignment, then
        // price every layer under it. Identity short-circuits to the
        // pinned default path verbatim.
        let assign: Option<Vec<usize>> = if self.placement != PlacementStrategy::Identity && d > 1
        {
            if scratch.full_step.len() < d * d {
                scratch.full_step.resize(d * d, 0);
            }
            let full = &mut scratch.full_step[..d * d];
            full.fill(0);
            for plan in &plans {
                plan.add_full_bytes_matrix_into(full);
            }
            Some(placement::search(full, d, &self.topology, &self.hw, self.placement))
        } else {
            None
        };
        // per-layer link-bottleneck comm for the overlap model: each
        // layer's byte matrix priced on its own (every layer synchronizes
        // at its own all-to-all, so layer matrices are never summed here)
        if scratch.link_layer.len() < d * d {
            scratch.link_layer.resize(d * d, 0);
        }
        scratch.layer_comm_ms.clear();
        for plan in &plans {
            let link = &mut scratch.link_layer[..d * d];
            link.fill(0);
            match &assign {
                Some(a) => plan.add_placed_bytes_matrix_into(a, link),
                None => plan.add_bytes_matrix_into(link),
            }
            let ms = layer_bottleneck_seconds(link, &self.topology, &self.hw) * 1e3;
            scratch.layer_comm_ms.push(ms);
        }
        let mut summary = DispatchSummary::from_plans(&plans);
        if scratch.elastic.is_some() {
            summary.elastic = elastic_applied;
            summary.capacity_min = cap_min;
            summary.capacity_max = cap_max;
        }
        if let Some(a) = &assign {
            let full = &scratch.full_step[..d * d];
            let identity = placement::identity(d);
            let (id_cost, _) = placement::assignment_cost(full, &identity, &self.topology, &self.hw);
            let (pl_cost, pl_bytes) = placement::assignment_cost(full, a, &self.topology, &self.hw);
            summary.placement_gain = if pl_cost > 0.0 { id_cost / pl_cost } else { 1.0 };
            summary.placed_link_share = if summary.a2a_bytes_total > 0.0 {
                pl_bytes as f64 / summary.a2a_bytes_total
            } else {
                0.0
            };
        }
        let observed = ObservedTraffic {
            a2a_bytes_per_layer: summary.a2a_bytes_per_layer,
            shard_balance: summary.shard_balance,
        };
        let priced = StepInputs::new(cfg, &self.hw)
            .observed(&observed)
            .layer_comm_ms(&scratch.layer_comm_ms)
            .run();
        let overlap = priced.overlap.expect("layer comm supplied, pipeline must run");
        summary.observed_ms = priced.serial_ms();
        summary.observed_overlap_ms = overlap.overlapped_ms;
        summary.overlap_efficiency = overlap.overlap_efficiency;
        drop(guard);

        let stats = StepStats {
            loss: loss as f32,
            aux_loss: (cfg.aux_loss_coef * mean_cv) as f32,
            grad_norm: grad_norm as f32,
            load,
            layers,
            experts,
            dropped,
            sim_step_ms: self.native.simulated_step_ms(),
            dispatch: Some(summary),
        };
        Ok((TrainState { step: step + 1, repr: StateRepr::Host(leaves) }, stats, plans))
    }

    /// Drive `steps` global steps from a fresh init, one local batch per
    /// worker per step (worker `w` consumes batch `s·D + w`, so D = 1
    /// replays the single-worker data stream exactly). Records every
    /// step — including the per-worker dispatch series — in `log`.
    pub fn train(
        &self,
        steps: i64,
        seed: u64,
        log: &mut RunLog,
        verbose: bool,
    ) -> Result<TrainState> {
        let state = self.init_state(seed)?;
        self.train_from(state, steps, seed, log, verbose)
    }

    /// Continue training from an existing state (resume-aware: the batch
    /// cursor skips everything all D workers already consumed).
    pub fn train_from(
        &self,
        mut state: TrainState,
        steps: i64,
        seed: u64,
        log: &mut RunLog,
        verbose: bool,
    ) -> Result<TrainState> {
        let info = self.native.info();
        let cfg = info.config.clone();
        let d = self.workers;
        let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
        // batch-cursor math stays in checked u64: the old
        // `step * (batch * d)` usize product could overflow when resuming
        // a long run at high D (and silently wrap the data stream)
        let consumed = match (cfg.batch as u64)
            .checked_mul(d as u64)
            .and_then(|per_step| (state.step.max(0) as u64).checked_mul(per_step))
        {
            Some(c) => c,
            None => bail!(
                "batch cursor overflow: cannot resume {} at step {} with D={d}",
                info.name,
                state.step
            ),
        };
        batcher.seek(consumed);
        let mut batches: Vec<Batch> = Vec::with_capacity(d);
        let end_step = state.step + steps;
        while state.step < end_step {
            batches.clear();
            for _ in 0..d {
                batches.push(batcher.next_batch());
            }
            let t0 = Instant::now();
            let (next, stats) = self.step(state, &batches)?;
            state = next;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let step_now = state.step - 1;
            log.push(step_now, &stats, ms)?;
            if verbose && step_now % 50 == 0 {
                let (cv, a2a_mb) = stats
                    .dispatch
                    .as_ref()
                    .map(|s| (s.shard_load_cv, s.a2a_bytes_step / 1e6))
                    .unwrap_or((0.0, 0.0));
                eprintln!(
                    "[{}|D={d}] step {:>5} loss {:.4} drop {:>5.0} shard-cv {:.3} a2a {:.2} MB {:.0} ms",
                    info.name,
                    step_now,
                    stats.loss,
                    stats.total_dropped(),
                    cv,
                    a2a_mb,
                    ms
                );
            }
        }
        Ok(state)
    }

    /// Teacher-forced eval PPL over `n` paired eval batches (cursor reset,
    /// identical data across strategies and worker counts).
    pub fn eval_ppl(&self, state: &TrainState, n: usize, seed: u64) -> Result<f64> {
        let cfg = &self.native.info().config;
        let mut batcher = Batcher::for_config(cfg, Split::Eval, seed);
        batcher.seek(0);
        let mut sum_nll = 0.0;
        let mut count = 0.0;
        for _ in 0..n {
            let batch = batcher.next_batch();
            let (nll, c) = self.native.eval(state, &batch)?;
            sum_nll += nll;
            count += c;
        }
        Ok((sum_nll / count.max(1.0)).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::registry;

    fn sim_cfg(name: &str) -> ModelConfig {
        registry().into_iter().find(|c| c.name == name).expect("registry variant")
    }

    #[test]
    fn rejects_unshardable_geometry() {
        let cfg = sim_cfg("base-sim"); // E = 16
        assert!(ShardedRun::new(&cfg, 0).is_err());
        assert!(ShardedRun::new(&cfg, 3).is_err(), "16 % 3 != 0");
        assert!(ShardedRun::new(&cfg, 8).is_ok());
    }

    #[test]
    fn step_requires_one_batch_per_worker() {
        let cfg = sim_cfg("base-sim");
        let run = ShardedRun::new(&cfg, 4).unwrap();
        let state = run.init_state(7).unwrap();
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 7);
        let batches = vec![batcher.next_batch()];
        assert!(run.step(state, &batches).is_err());
    }

    #[test]
    fn train_from_rejects_batch_cursor_overflow() {
        // regression: resuming at an absurd step count used to overflow
        // the usize batch-cursor product and silently wrap the stream
        let cfg = sim_cfg("base-sim");
        let run = ShardedRun::new(&cfg, 4).unwrap();
        let mut state = run.init_state(3).unwrap();
        state.step = i64::MAX;
        let mut log = RunLog::new("overflow-test".to_string());
        let err = run.train_from(state, 0, 3, &mut log, false);
        assert!(err.is_err(), "cursor overflow must surface, not wrap");
    }

    #[test]
    fn sharded_step_emits_conserved_dispatch() {
        let cfg = sim_cfg("large-sim"); // E = 32, 8 layers
        let d = 4;
        let run = ShardedRun::new(&cfg, d).unwrap();
        let state = run.init_state(11).unwrap();
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 11);
        let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
        let (next, stats, plans) = run.step_detailed(state, &batches).unwrap();
        assert_eq!(next.step, 1);
        assert_eq!(plans.len(), cfg.layers);
        let summary = stats.dispatch.as_ref().expect("sharded stats carry dispatch");
        assert_eq!(summary.workers, d);
        // routed-slot conservation per worker per layer
        let tokens = cfg.tokens_per_batch() as u64;
        let k_eff = cfg.routing.k().max(1) as u64;
        for plan in &plans {
            let kept = plan.kept_per_worker();
            let drops = plan.dropped_per_worker();
            for w in 0..d {
                assert_eq!(kept[w] + drops[w], tokens * k_eff);
            }
        }
        // global StepStats load equals the per-shard receive totals
        let stats_total: f64 = stats.load.iter().map(|&x| x as f64).sum();
        let recv_total: f64 = summary.per_shard_recv.iter().sum();
        assert_eq!(stats_total, recv_total);
        assert!(summary.observed_ms > 0.0);
        // the overlap model is filled in and can only help
        assert!(summary.observed_overlap_ms > 0.0);
        assert!(summary.observed_overlap_ms <= summary.observed_ms);
        assert!(summary.overlap_speedup() >= 1.0);
        assert!((0.0..=1.0).contains(&summary.overlap_efficiency));
        assert!((0.0..=1.0).contains(&summary.bottleneck_link_share()));
    }

    /// Drive `steps` global steps by hand (same batch stream as `train`),
    /// returning the summed drop count and every step's stats.
    fn drive_steps(
        run: &ShardedRun,
        cfg: &ModelConfig,
        seed: u64,
        steps: usize,
    ) -> (f64, Vec<StepStats>) {
        let d = run.workers();
        let mut state = run.init_state(seed).unwrap();
        let mut batcher = Batcher::for_config(cfg, Split::Train, seed);
        let mut all = Vec::with_capacity(steps);
        let mut drops = 0.0;
        for _ in 0..steps {
            let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
            let (next, stats) = run.step(state, &batches).unwrap();
            state = next;
            drops += stats.total_dropped();
            all.push(stats);
        }
        (drops, all)
    }

    #[test]
    fn elastic_capacity_rejects_real_compute() {
        let cfg = sim_cfg("base-sim-real");
        let mut run = ShardedRun::new(&cfg, 4).unwrap();
        let err = run.set_elastic_capacity(true);
        assert!(err.is_err(), "elastic capacity must bail on ComputeMode::Real");
    }

    #[test]
    fn cold_elastic_controller_is_bitwise_static() {
        // step 1: the controller has observed nothing, so the elastic run
        // must reproduce the static step bit for bit
        let cfg = sim_cfg("base-sim"); // aux = 0: persistent router bias
        let d = 4;
        let static_run = ShardedRun::new(&cfg, d).unwrap();
        let mut elastic_run = ShardedRun::new(&cfg, d).unwrap();
        elastic_run.set_elastic_capacity(true).unwrap();
        let (_, s) = drive_steps(&static_run, &cfg, 21, 1);
        let (_, e) = drive_steps(&elastic_run, &cfg, 21, 1);
        assert_eq!(s[0].loss.to_bits(), e[0].loss.to_bits());
        let (ds, de) = (s[0].dispatch.as_ref().unwrap(), e[0].dispatch.as_ref().unwrap());
        assert_eq!(ds.a2a_bytes_step, de.a2a_bytes_step);
        assert!(!de.elastic, "cold controller must not claim to have reshaped");
        assert_eq!(de.capacity_min, de.capacity_max, "cold step stays at static C");
    }

    #[test]
    fn elastic_capacity_cuts_drops_at_equal_budget() {
        // base-sim's router bias never decays (aux = 0), so the same
        // experts stay hot every step: the controller must harvest cold
        // shards' slots and strictly cut the realized drop count
        let cfg = sim_cfg("base-sim");
        let d = 4;
        let steps = 6;
        let static_run = ShardedRun::new(&cfg, d).unwrap();
        let mut elastic_run = ShardedRun::new(&cfg, d).unwrap();
        elastic_run.set_elastic_capacity(true).unwrap();
        let (static_drops, s) = drive_steps(&static_run, &cfg, 33, steps);
        let (elastic_drops, e) = drive_steps(&elastic_run, &cfg, 33, steps);
        assert!(static_drops > 0.0, "the skewed twin must overflow the static capacity");
        assert!(
            elastic_drops < static_drops,
            "elastic must strictly cut drops: {elastic_drops} vs {static_drops}"
        );
        let c = static_run.info().capacity;
        for (i, stats) in e.iter().enumerate().skip(1) {
            let sum = stats.dispatch.as_ref().unwrap();
            assert!(sum.elastic, "warm controller reshapes from step 2 on");
            assert!(sum.capacity_min >= 1 && sum.capacity_min <= c);
            assert!(sum.capacity_max >= c, "the hot shard grows, step {i}");
            assert!(sum.capacity_max > sum.capacity_min, "slots actually moved");
        }
        // the static twin never sets the elastic fields
        for stats in &s {
            let sum = stats.dispatch.as_ref().unwrap();
            assert!(!sum.elastic);
            assert_eq!(sum.capacity_min, c);
            assert_eq!(sum.capacity_max, c);
        }
    }

    #[test]
    fn placement_changes_comm_pricing_only() {
        let cfg = sim_cfg("large-sim"); // E = 32, 8 layers
        let d = 8;
        let step_once = |strategy: PlacementStrategy| {
            let mut run = ShardedRun::new(&cfg, d).unwrap();
            run.set_workers_per_node(4);
            run.set_placement(strategy);
            let state = run.init_state(17).unwrap();
            let mut batcher = Batcher::for_config(&cfg, Split::Train, 17);
            let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
            let (_, stats) = run.step(state, &batches).unwrap();
            stats
        };
        let id = step_once(PlacementStrategy::Identity);
        let sw = step_once(PlacementStrategy::Swap);
        // routing, dispatch accounting, and the loss are placement-free
        assert_eq!(id.loss.to_bits(), sw.loss.to_bits());
        let (di, ds) = (id.dispatch.as_ref().unwrap(), sw.dispatch.as_ref().unwrap());
        assert_eq!(di.a2a_bytes_step, ds.a2a_bytes_step);
        // identity reports the trivial placement
        assert_eq!(di.placement_gain, 1.0);
        assert_eq!(di.placed_link_share, di.bottleneck_link_share());
        // the search's dominance rule makes both bounds structural
        assert!(ds.placement_gain >= 1.0, "search never loses to identity");
        assert!(
            ds.placed_link_share <= di.bottleneck_link_share(),
            "placed bottleneck share never exceeds identity's"
        );
    }

    #[test]
    fn placement_is_deterministic_across_pool_sizes() {
        // the search runs single-threaded on merged counts, so the pool
        // size cannot leak into the assignment or its pricing
        let cfg = sim_cfg("large-sim");
        let d = 8;
        let step_once = |threads: usize| {
            let pool = Arc::new(WorkerPool::new(threads));
            let mut run = ShardedRun::with_pool(&cfg, d, pool).unwrap();
            run.set_workers_per_node(4);
            run.set_placement(PlacementStrategy::Swap);
            let state = run.init_state(29).unwrap();
            let mut batcher = Batcher::for_config(&cfg, Split::Train, 29);
            let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
            let (_, stats) = run.step(state, &batches).unwrap();
            stats
        };
        let a = step_once(1);
        let b = step_once(3);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        let (da, db) = (a.dispatch.as_ref().unwrap(), b.dispatch.as_ref().unwrap());
        assert_eq!(da.placement_gain.to_bits(), db.placement_gain.to_bits());
        assert_eq!(da.placed_link_share.to_bits(), db.placed_link_share.to_bits());
        assert_eq!(da.observed_overlap_ms.to_bits(), db.observed_overlap_ms.to_bits());
    }

    #[test]
    fn topology_changes_comm_model_only() {
        let cfg = sim_cfg("large-sim");
        let d = 8;
        let step_once = |wpn: usize| {
            let mut run = ShardedRun::new(&cfg, d).unwrap();
            run.set_workers_per_node(wpn);
            let state = run.init_state(13).unwrap();
            let mut batcher = Batcher::for_config(&cfg, Split::Train, 13);
            let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
            let (_, stats) = run.step(state, &batches).unwrap();
            stats
        };
        let flat = step_once(1);
        let hier = step_once(4);
        // routing and dispatch accounting are topology-independent
        assert_eq!(flat.loss.to_bits(), hier.loss.to_bits());
        let (df, dh) =
            (flat.dispatch.as_ref().unwrap(), hier.dispatch.as_ref().unwrap());
        assert_eq!(df.a2a_bytes_step, dh.a2a_bytes_step);
        assert_eq!(df.max_link_bytes, dh.max_link_bytes);
        // the serial observed model never saw the topology either
        assert_eq!(df.observed_ms.to_bits(), dh.observed_ms.to_bits());
        // faster intra-node links can only shrink the overlapped time
        assert!(dh.observed_overlap_ms <= df.observed_overlap_ms);
    }
}
