//! Runtime layer: the pluggable [`Backend`] execution contract, the
//! artifact manifest, the zero-artifact [`NativeBackend`], the
//! expert-parallel [`ShardedRun`] driver with its dispatch bench, and —
//! behind the `pjrt` cargo feature — the PJRT engine with
//! device-resident state.
//!
//! See `backend` for the trait surface, `native` for the pure-Rust
//! runtime, `shard` for the multi-worker all-to-all execution layer,
//! `manifest` for the python<->rust buffer-order contract, and `engine`
//! (feature `pjrt`) for the XLA execution model.

pub mod backend;
pub mod dispatch_bench;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod ffn_bench;
pub mod manifest;
pub mod native;
pub mod optim;
pub mod overlap_bench;
pub mod shard;
pub mod step_bench;

pub use backend::{
    measure_step_ms, measure_step_series, Backend, BackendProvider, StateRepr, StepStats,
    TrainState,
};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, PjrtProvider, VariantRuntime};
pub use manifest::{Manifest, TensorSpec, VariantInfo};
pub use native::{NativeBackend, NativeProvider};
pub use shard::{ShardedRun, StepMode};
