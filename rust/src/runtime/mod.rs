//! PJRT runtime: artifact manifest + engine with device-resident train
//! state. See `engine` for the execution model and `manifest` for the
//! python<->rust buffer-order contract.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, StepStats, TrainState, VariantRuntime};
pub use manifest::{Manifest, TensorSpec, VariantInfo};
