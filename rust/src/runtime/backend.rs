//! The pluggable runtime surface: every execution engine — the pure-Rust
//! [`NativeBackend`](super::native::NativeBackend) and the feature-gated
//! PJRT engine — exposes the same `init_state / step / eval / checkpoint`
//! contract through [`Backend`], and is constructed by a
//! [`BackendProvider`] that owns the variant registry (the artifact
//! manifest for PJRT, the built-in config registry for native).
//!
//! The coordinator, the experiment runner, and every figure/table driver
//! talk only to these traits; swapping backends never touches them.

use std::time::Instant;

use anyhow::Result;

use super::manifest::VariantInfo;
use crate::data::{Batch, Batcher, Split};
use crate::moe::DispatchSummary;
use crate::util::stats::{p50, timing_series};

/// Scalar + load statistics returned by one train step.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss: f32,
    pub aux_loss: f32,
    pub grad_norm: f32,
    /// (layers, experts) kept-token counts, row-major
    pub load: Vec<f32>,
    pub layers: usize,
    pub experts: usize,
    /// per-layer dropped-token counts
    pub dropped: Vec<f32>,
    /// simulated cluster ms/step for this variant's paper-scale twin
    /// (0 when the backend measures real hardware instead of modelling it)
    pub sim_step_ms: f64,
    /// expert-parallel dispatch accounting for this step — per-worker /
    /// per-shard series, measured all-to-all bytes, the bottleneck link
    /// (max per-link bytes), and the serial-vs-overlapped cluster
    /// predictions from the link-level topology model
    /// (`cluster::topology`). `None` on single-router backends; filled
    /// by the sharded runtime ([`ShardedRun`](super::shard::ShardedRun)).
    pub dispatch: Option<DispatchSummary>,
}

impl StepStats {
    /// Per-layer coefficient of variation of effective compute load —
    /// the paper's Fig-1 metric.
    pub fn cv_per_layer(&self) -> Vec<f64> {
        (0..self.layers)
            .map(|l| {
                let row: Vec<f64> = self.load[l * self.experts..(l + 1) * self.experts]
                    .iter()
                    .map(|&x| x as f64)
                    .collect();
                crate::util::stats::coefficient_of_variation(&row)
            })
            .collect()
    }
    pub fn total_dropped(&self) -> f64 {
        self.dropped.iter().map(|&x| x as f64).sum()
    }
}

/// Where a train state physically lives. The host representation is the
/// manifest-ordered leaf vector (also the checkpoint format); the device
/// representation is PJRT buffers and only exists under `--features pjrt`.
pub enum StateRepr {
    Host(Vec<Vec<f32>>),
    #[cfg(feature = "pjrt")]
    Device(Vec<xla::PjRtBuffer>),
}

/// Backend-agnostic train state: an opaque representation plus the step
/// counter. Produced and consumed only through [`Backend`] methods.
pub struct TrainState {
    pub step: i64,
    pub repr: StateRepr,
}

/// One loaded variant, ready to run — the execution contract extracted
/// from the old PJRT-only `VariantRuntime`.
pub trait Backend {
    /// Static description of the variant (config, capacity, leaf layout).
    fn info(&self) -> &VariantInfo;

    /// Seed -> fresh train state. Deterministic per seed; the full 64 bits
    /// participate (a regression pinned by `runtime_integration.rs` — the
    /// old `i32` surface silently truncated the upper half).
    fn init_state(&self, seed: u64) -> Result<TrainState>;

    /// One train step: consumes the state, returns the advanced state and
    /// the step statistics.
    fn step(&self, state: TrainState, batch: &Batch) -> Result<(TrainState, StepStats)>;

    /// Teacher-forced eval on one batch: (sum_nll, token_count). Pure in
    /// (state, batch) so paired comparisons across strategies are exact.
    fn eval(&self, state: &TrainState, batch: &Batch) -> Result<(f64, f64)>;

    /// Pull the full state to host leaves (checkpointing).
    fn state_to_host(&self, state: &TrainState) -> Result<Vec<Vec<f32>>>;

    /// Restore host leaves into a runnable state.
    fn state_from_host(&self, leaves: &[Vec<f32>], step: i64) -> Result<TrainState>;
}

/// Wall-clock ms of `samples` bare `step()` calls after `warmup` steps
/// (sorted ascending), plus the stats of the last sampled step — the one
/// shared measurement methodology behind `m6t bench`, the `step_latency`
/// bench, and the step-throughput suite (`runtime::step_bench`), which
/// derives its p50/p95 from the same series shape.
pub fn measure_step_series(
    backend: &dyn Backend,
    seed: u64,
    warmup: usize,
    samples: usize,
) -> Result<(Vec<f64>, StepStats)> {
    let cfg = backend.info().config.clone();
    let mut state = backend.init_state(seed)?;
    let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
    for _ in 0..warmup {
        let batch = batcher.next_batch();
        let (next, _stats) = backend.step(state, &batch)?;
        state = next;
    }
    let mut ms: Vec<f64> = Vec::with_capacity(samples.max(1));
    let mut last_stats = None;
    for _ in 0..samples.max(1) {
        let batch = batcher.next_batch();
        let t0 = Instant::now();
        let (next, stats) = backend.step(state, &batch)?;
        ms.push(t0.elapsed().as_secs_f64() * 1e3);
        state = next;
        last_stats = Some(stats);
    }
    Ok((timing_series(ms, 0), last_stats.expect("at least one sample")))
}

/// Median wall-clock ms of `samples` bare `step()` calls after `warmup`
/// steps — [`measure_step_series`] reduced to its p50.
pub fn measure_step_ms(
    backend: &dyn Backend,
    seed: u64,
    warmup: usize,
    samples: usize,
) -> Result<(f64, StepStats)> {
    let (ms, stats) = measure_step_series(backend, seed, warmup, samples)?;
    Ok((p50(&ms), stats))
}

/// A source of runnable variants: resolves names to [`VariantInfo`] and
/// constructs [`Backend`]s. Implemented by `NativeProvider` (built-in
/// registry, zero artifacts) and `PjrtProvider` (artifact manifest).
pub trait BackendProvider {
    /// All variant names this provider can load, sorted.
    fn names(&self) -> Vec<String>;

    /// Static description of one variant.
    fn info(&self, name: &str) -> Result<VariantInfo>;

    /// Construct a ready-to-run backend for one variant.
    fn load(&self, name: &str) -> Result<Box<dyn Backend>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cv_per_layer_splits_rows() {
        let stats = StepStats {
            loss: 1.0,
            aux_loss: 0.0,
            grad_norm: 1.0,
            load: vec![4.0, 4.0, 8.0, 0.0],
            layers: 2,
            experts: 2,
            dropped: vec![0.0, 0.0],
            sim_step_ms: 0.0,
            dispatch: None,
        };
        let cv = stats.cv_per_layer();
        assert_eq!(cv.len(), 2);
        assert_eq!(cv[0], 0.0, "balanced layer");
        assert!(cv[1] > 0.9, "one-hot layer is maximally skewed");
        assert_eq!(stats.total_dropped(), 0.0);
    }
}
