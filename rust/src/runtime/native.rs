//! Pure-Rust native backend: runs every variant with **zero artifacts**.
//!
//! Instead of executing lowered HLO, the native backend composes the
//! repo's own analytic machinery into a deterministic training simulacrum:
//!  * per-layer routing statistics come from the fused counts-only
//!    routing kernel ([`moe::fused`]) over seeded gate logits plus a
//!    persistent per-expert router bias (the state that makes balance
//!    dynamics visible); the step dispatches layer x token-tile work
//!    units onto the persistent [`WorkerPool`] (`util::pool`) via
//!    [`route_grid_counts`], each unit generating and routing one
//!    cache-resident gate tile — the global gate matrix is never
//!    materialized (the two-pass `fill_gates` + engine path survives as
//!    the sharded runtime's bench baseline and bitwise oracle);
//!  * the loss trajectory follows a [`scaling::PowerLaw`] whose floor
//!    encodes the paper's qualitative findings (larger models lower, k > 1
//!    helps with diminishing returns, prototyping helps more at scale,
//!    token drops and MoE attention hurt, the aux loss buys balance but
//!    not quality);
//!  * step latency is the calibrated Whale cluster model's prediction for
//!    the variant's configuration ([`cluster::simulate_step`]).
//!
//! Variants with [`ComputeMode::Real`] (the `-real` registry twins)
//! replace the PowerLaw loss with **actual expert compute**: the routed
//! per-expert token counts fill a seeded `(E, C, M)` input slab, the
//! tiled FFN kernels ([`moe::ffn`]) run the forward and backward GEMMs
//! on the pool, the loss is the measured MSE against a scaled-copy
//! regression target, and AdamW/Adafactor ([`runtime::optim`]) update
//! real weight leaves. Routing, seeds, and stats aggregation are shared
//! with the simulated path, and the sharded runtime calls the same
//! [`real_train_step`] so D = 1 reproduces this backend bitwise.
//!
//! Everything is a pure function of (state leaves, step, batch), so
//! checkpoint round-trips reproduce runs bitwise — the property the
//! integration tests pin down.
#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::backend::{Backend, BackendProvider, StateRepr, StepStats, TrainState};
use super::manifest::{DType, TensorSpec, VariantInfo};
use crate::cluster::{simulate_step, table2_hardware};
use crate::config::{paper, CapacityMode, ComputeMode, ModelConfig, Routing};
use crate::data::Batch;
use crate::moe::capacity;
use crate::moe::ffn::{self, FfnGrads, FfnInputs, FfnShape};
use crate::moe::fused;
use crate::runtime::optim;
use crate::scaling::PowerLaw;
use crate::util::pool::{self, WorkerPool};
use crate::util::shard::DisjointChunks;
use crate::util::rng::Rng;
use crate::util::stats::coefficient_of_variation;

/// Leaf index of the first real FFN weight (leaves 0/1 are always the
/// loss-law params and the router bias, in every compute mode).
pub(crate) const REAL_WEIGHT_LEAF0: usize = 2;

/// Leaf index of layer `l`'s up-projection `w1 (E, M, I)`.
pub(crate) fn w1_leaf(l: usize) -> usize {
    REAL_WEIGHT_LEAF0 + 2 * l
}
/// Leaf index of layer `l`'s down-projection `w2 (E, I, M)`.
pub(crate) fn w2_leaf(l: usize) -> usize {
    REAL_WEIGHT_LEAF0 + 2 * l + 1
}
/// Leaf index of the first optimizer leaf (4 per layer, after all
/// weights): AdamW packs `[m_w1, v_w1, m_w2, v_w2]`, Adafactor packs
/// `[vr_w1, vc_w1, vr_w2, vc_w2]`.
pub(crate) fn opt_leaf0(layers: usize) -> usize {
    REAL_WEIGHT_LEAF0 + 2 * layers
}

/// Synthesize the manifest entry a native variant would have had: the
/// state layout is [loss-law params, router bias], plus — for
/// [`ComputeMode::Real`] — per-layer expert FFN weights followed by
/// their optimizer leaves. The bookkeeping counts mirror the python/rust
/// accounting contract.
pub fn variant_info(cfg: &ModelConfig) -> VariantInfo {
    let (e, m, i) = (cfg.num_experts, cfg.hidden, cfg.intermediate);
    let mut state_leaves = vec![
        TensorSpec { name: "loss_law".into(), shape: vec![3], dtype: DType::F32 },
        TensorSpec {
            name: "router_bias".into(),
            shape: vec![cfg.layers, cfg.num_experts],
            dtype: DType::F32,
        },
    ];
    if cfg.compute == ComputeMode::Real {
        for l in 0..cfg.layers {
            state_leaves.push(TensorSpec {
                name: format!("layer{l}/ffn_w1"),
                shape: vec![e, m, i],
                dtype: DType::F32,
            });
            state_leaves.push(TensorSpec {
                name: format!("layer{l}/ffn_w2"),
                shape: vec![e, i, m],
                dtype: DType::F32,
            });
        }
    }
    let n_params = state_leaves.len();
    if cfg.compute == ComputeMode::Real {
        if cfg.optimizer == "adafactor" {
            // factored second moments: per-row / per-column means over
            // each expert's matrix (sublinear memory, the 1T recipe)
            for l in 0..cfg.layers {
                for (w, rows, cols) in [("ffn_w1", m, i), ("ffn_w2", i, m)] {
                    state_leaves.push(TensorSpec {
                        name: format!("opt/layer{l}/{w}/vr"),
                        shape: vec![e, rows],
                        dtype: DType::F32,
                    });
                    state_leaves.push(TensorSpec {
                        name: format!("opt/layer{l}/{w}/vc"),
                        shape: vec![e, cols],
                        dtype: DType::F32,
                    });
                }
            }
        } else {
            for l in 0..cfg.layers {
                for (w, rows, cols) in [("ffn_w1", m, i), ("ffn_w2", i, m)] {
                    for mom in ["m", "v"] {
                        state_leaves.push(TensorSpec {
                            name: format!("opt/layer{l}/{w}/{mom}"),
                            shape: vec![e, rows, cols],
                            dtype: DType::F32,
                        });
                    }
                }
            }
        }
    }
    let n_state = state_leaves.len();
    VariantInfo {
        name: cfg.name.clone(),
        dir: Default::default(),
        config: cfg.clone(),
        init_hlo: Default::default(),
        step_hlo: Default::default(),
        eval_hlo: Default::default(),
        n_params,
        n_opt: n_state - n_params,
        n_state,
        param_count: cfg.param_count(),
        capacity: cfg.capacity(),
        state_leaves,
        step_inputs: Vec::new(),
        step_outputs: Vec::new(),
        eval_outputs: Vec::new(),
    }
}

/// Achievable loss floor of a config — the place the paper's qualitative
/// claims are encoded (see module docs).
fn loss_floor(cfg: &ModelConfig) -> f64 {
    let params = cfg.param_count() as f64;
    let base = 1.1 + (2e7 / params).powf(0.08);
    let k_eff = cfg.routing.k().min(cfg.num_experts as u32).max(1) as f64;
    // k > 1 helps, with diminishing returns (Fig 3)
    let k_gain = 0.05 * (1.0 - 1.0 / k_eff);
    // prototyping's extra edge grows with expert count (Fig 5)
    let proto_gain = if cfg.routing.prototypes() > 1 {
        0.002 * (cfg.num_experts as f64).ln()
    } else {
        0.0
    };
    // balance does not buy quality: the aux loss costs a little (Fig 1) —
    // sized to dominate the drop-penalty relief that balancing also brings
    let aux_pen = if cfg.aux_loss_coef > 0.0 { 0.02 } else { 0.0 };
    // MoE attention hurts; prototyping mitigates (Fig 4)
    let attn_pen = if cfg.moe_attention {
        if cfg.routing.prototypes() > 1 {
            0.03
        } else {
            0.06
        }
    } else {
        0.0
    };
    (base * (1.0 - k_gain - proto_gain) + aux_pen + attn_pen).max(0.2)
}

/// Constant mixed into the step seed (`base_seed` below). Shared with the
/// sharded runtime (`runtime::shard`), whose worker 0 must reproduce this
/// backend's exact RNG streams.
pub(crate) const STEP_SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;
/// Constant deriving per-layer seeds from the step seed.
pub(crate) const LAYER_SEED_MIX: u64 = 0x517C_C1B7_2722_0A95;
/// Constant deriving the loss-noise stream from the step seed.
pub(crate) const NOISE_SEED_MIX: u64 = 0xD1B5_4A32_D192_ED03;
/// Constant deriving each expert's input-slab stream (real compute) from
/// the layer seed.
pub(crate) const SLAB_SEED_MIX: u64 = 0xE703_37A4_2F29_1D5B;

/// Regression target of the real-compute objective: the FFN learns
/// `y = TARGET_SCALE * x` on its dispatched tokens, so the loss is a
/// genuine measured quantity that actually descends under the optimizer.
pub(crate) const TARGET_SCALE: f32 = 0.25;

fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

pub(crate) fn hash_f32s(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        h = (h ^ x.to_bits() as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

pub(crate) fn batch_hash(batch: &Batch) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in &batch.tokens {
        h = (h ^ t as u32 as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

pub(crate) fn law_from_leaf(leaf: &[f32]) -> Result<PowerLaw> {
    if leaf.len() != 3 {
        bail!("loss-law leaf has {} elements, expected 3", leaf.len());
    }
    Ok(PowerLaw { l_inf: leaf[0] as f64, a: leaf[1] as f64, b: leaf[2] as f64 })
}

/// Tokens per gate-generation work unit — one fused tile. Fixed (not
/// derived from pool size) so the per-shard RNG streams — and therefore
/// every routed gate — are identical no matter how many workers run them,
/// and shared with [`moe::fused`] so the materialized and fused paths
/// consume the same streams.
const GEN_SHARD_TOKENS: usize = fused::TILE_TOKENS;

/// Below this many gate cells the pool handoff costs more than the
/// RNG + softmax work it spreads; generate serially instead. The serial
/// path is bitwise identical.
const MIN_GEN_PARALLEL_WORK: usize = 4096;

/// Fill one layer's gate matrix: seeded per-shard logits + persistent
/// router bias, softmaxed in place per prototype group. Token shards run
/// as independent work units on the pool; each shard derives its own RNG
/// stream from (layer seed, shard index), so the result is a pure
/// function of the seed regardless of scheduling. Each shard is exactly
/// one [`fused::gen_tile_gates`] tile — the single source of truth that
/// keeps this two-pass materializer bitwise in lockstep with the fused
/// counts kernel (pinned by `rust/tests/fused_routing.rs`).
pub fn fill_gates(
    pool_ref: &WorkerPool,
    gates: &mut [f32],
    layer_seed: u64,
    bias_row: &[f32],
    tokens: usize,
    experts: usize,
    prototypes: usize,
) {
    let shards = fused::tiles_for(tokens);
    // each shard owns the disjoint token range [t0, t1) of the gate matrix
    let views = DisjointChunks::new(&mut gates[..tokens * experts], GEN_SHARD_TOKENS * experts);
    debug_assert_eq!(views.units(), shards);
    let body = |s: usize| {
        let buf = views.view(s);
        let rows = buf.len() / experts;
        fused::gen_tile_gates(buf, layer_seed, s, bias_row, rows, experts, prototypes);
    };
    pool::run_shards(Some(pool_ref), shards, tokens * experts, MIN_GEN_PARALLEL_WORK, &body);
}

/// Problem geometry of one routed (worker x layer) grid — everything
/// [`route_grid_counts`] needs beyond the seeds, bias, and buffers.
#[derive(Clone, Copy)]
pub(crate) struct GridSpec {
    pub tokens: usize,
    pub experts: usize,
    pub layers: usize,
    pub prototypes: usize,
    pub routing: Routing,
    pub capacity: usize,
}

/// Output buffers of [`route_grid_counts`]: row-major
/// `[worker][layer][expert]` demand/kept-load histograms plus per
/// `[worker][layer]` dropped totals.
pub(crate) struct GridCountsOut<'a> {
    pub wl_demand: &'a mut [u32],
    pub wl_load: &'a mut [u32],
    pub wl_dropped: &'a mut [u32],
}

/// Route a full (worker x layer) grid through the fused counts kernel:
/// every `(worker, layer, tile)` triple is an independent work unit on
/// the pool, emitting its per-expert demand histogram into a disjoint
/// slice of `partial`; the histograms are then merged per (worker, layer)
/// in fixed tile order and capacity-clamped into `wl_load` / `wl_demand`
/// / `wl_dropped` (row-major `[worker][layer][expert]` and
/// `[worker][layer]`).
///
/// Determinism: the unit decomposition depends only on the problem shape,
/// each unit is a pure function of `(worker_seeds[w], layer, tile)`, and
/// the merge is exact u32 addition — so the outputs are bitwise identical
/// across pool sizes and to the serial two-pass path. `worker_seeds`
/// carries one step seed per worker (the native backend passes exactly
/// one); layer seeds are derived with [`LAYER_SEED_MIX`] exactly as the
/// two-pass path does.
pub(crate) fn route_grid_counts(
    pool_ref: &WorkerPool,
    worker_seeds: &[u64],
    bias: &[f32],
    spec: GridSpec,
    partial: &mut Vec<u32>,
    out: GridCountsOut<'_>,
) {
    let GridSpec { tokens, experts, layers, prototypes, routing, capacity } = spec;
    let GridCountsOut { wl_demand, wl_load, wl_dropped } = out;
    let d = worker_seeds.len();
    assert_eq!(bias.len(), layers * experts, "bias shape mismatch");
    assert_eq!(wl_demand.len(), d * layers * experts, "wl_demand shape mismatch");
    assert_eq!(wl_load.len(), d * layers * experts, "wl_load shape mismatch");
    assert_eq!(wl_dropped.len(), d * layers, "wl_dropped shape mismatch");
    let tiles = fused::tiles_for(tokens);
    if tiles == 0 {
        // zero tokens route nothing — keep the merge below simple
        wl_demand.fill(0);
        wl_load.fill(0);
        wl_dropped.fill(0);
        return;
    }
    let units = d * layers * tiles;
    if partial.len() < units * experts {
        partial.resize(units * experts, 0);
    }
    {
        // unit `u` owns the disjoint range [u * experts, (u + 1) * experts)
        // of `partial`; the pool joins every unit before the merge reads it
        let views = DisjointChunks::new(&mut partial[..units * experts], experts);
        let body = |u: usize| {
            let w = u / (layers * tiles);
            let rem = u % (layers * tiles);
            let l = rem / tiles;
            let s = rem % tiles;
            let layer_seed = worker_seeds[w] ^ (l as u64 + 1).wrapping_mul(LAYER_SEED_MIX);
            let bias_row = &bias[l * experts..(l + 1) * experts];
            let rows = fused::TILE_TOKENS.min(tokens - s * fused::TILE_TOKENS);
            let demand = views.view(u);
            demand.fill(0);
            fused::with_thread_scratch(|sc| {
                fused::tile_demand(
                    sc, layer_seed, s, rows, bias_row, experts, prototypes, routing, demand,
                );
            });
        };
        pool::run_shards(
            Some(pool_ref),
            units,
            d * layers * tokens * experts,
            MIN_GEN_PARALLEL_WORK,
            &body,
        );
    }
    // exact merge: per (worker, layer), sum the tile histograms in tile
    // order, then capacity-clamp — kept_e = min(demand_e, C), so the
    // merged counts equal what routing the whole layer at once produces.
    // The clamp goes through the per-shard kernel with one uniform
    // all-experts shard: bitwise the same counts as
    // `fused::counts_from_demand` (pinned in `moe::capacity`'s tests),
    // and the exact static oracle the elastic controller's re-clamp in
    // `runtime::shard` is measured against.
    for w in 0..d {
        for l in 0..layers {
            let at = (w * layers + l) * experts;
            let unit0 = (w * layers + l) * tiles;
            {
                let dst = &mut wl_demand[at..at + experts];
                dst.copy_from_slice(&partial[unit0 * experts..(unit0 + 1) * experts]);
                for s in 1..tiles {
                    let src = &partial[(unit0 + s) * experts..(unit0 + s + 1) * experts];
                    for (acc, &x) in dst.iter_mut().zip(src) {
                        *acc += x;
                    }
                }
            }
            wl_dropped[w * layers + l] = capacity::apply_caps(
                &wl_demand[at..at + experts],
                &[capacity as u32],
                experts,
                &mut wl_load[at..at + experts],
            );
        }
    }
}

/// Reusable buffers for the real-compute path: input/output/gradient
/// slabs, the FFN kernels' tile partials, per-worker and worker-summed
/// weight gradients, and optimizer update scratch. Lives inside
/// [`StepScratch`] (and the sharded runtime's scratch) so the hot path is
/// allocation-free after warmup.
#[derive(Default)]
pub(crate) struct RealScratch {
    /// forward slabs + FFN tile partials (what [`real_layer_forward`] needs)
    slabs: SlabScratch,
    /// one worker's weight grads for the current layer
    dw1: Vec<f32>,
    dw2: Vec<f32>,
    /// worker-summed weight grads for the current layer
    gw1: Vec<f32>,
    gw2: Vec<f32>,
    /// optimizer update scratch (Adafactor's `u`)
    opt_u: Vec<f32>,
}

/// The per-layer forward working set: input/output/gradient slabs plus
/// the FFN kernels' tile partials.
#[derive(Default)]
pub(crate) struct SlabScratch {
    /// (E, C, M) seeded input slab
    x: Vec<f32>,
    /// (E, C, M) FFN output
    y: Vec<f32>,
    /// (E, C, M) loss gradient dL/dy
    g: Vec<f32>,
    /// tile partials for [`ffn::fwd_tiled`] / [`ffn::bwd_tiled`]
    partial: Vec<f32>,
}

/// Fill one layer's `(E, C, M)` input slab: expert `e` gets
/// `min(load_e, C)` rows of seeded unit normals (its own RNG stream, so
/// the slab is a pure function of `(layer_seed, loads)` regardless of
/// scheduling); padding rows stay zero. One expert per pool unit.
fn fill_slab(
    pool_ref: &WorkerPool,
    x: &mut [f32],
    layer_seed: u64,
    loads: &[u32],
    capacity: usize,
    m: usize,
) {
    let experts = loads.len();
    assert_eq!(x.len(), experts * capacity * m, "slab shape mismatch");
    if x.is_empty() {
        return;
    }
    x.fill(0.0);
    // expert `e_idx` owns the disjoint (C, M) block starting at
    // e_idx * capacity * m; the pool joins every unit before reads
    let views = DisjointChunks::new(x, capacity * m);
    let body = |e_idx: usize| {
        let rows = (loads[e_idx] as usize).min(capacity);
        if rows == 0 {
            return;
        }
        let mut rng = Rng::new(layer_seed ^ (e_idx as u64 + 1).wrapping_mul(SLAB_SEED_MIX));
        let dst = &mut views.view(e_idx)[..rows * m];
        for v in dst.iter_mut() {
            *v = rng.normal() as f32;
        }
    };
    pool::run_shards(Some(pool_ref), experts, experts * capacity * m, MIN_GEN_PARALLEL_WORK, &body);
}

/// One worker-layer of real forward compute: fill the routed slab, run
/// the tiled FFN, and measure the regression loss
/// `mean((y - TARGET_SCALE * x)^2)` over the active (routed) rows,
/// writing `dL/dy` into `sc.g`. Returns the mean loss; padding rows carry
/// zero gradient so dropped tokens contribute nothing.
fn real_layer_forward(
    pool_ref: &WorkerPool,
    shape: FfnShape,
    layer_seed: u64,
    loads: &[u32],
    w1: &[f32],
    w2: &[f32],
    sc: &mut SlabScratch,
) -> f64 {
    let (c, m) = (shape.capacity, shape.hidden);
    let SlabScratch { x, y, g, partial } = sc;
    x.clear();
    x.resize(shape.x_len(), 0.0);
    y.clear();
    y.resize(shape.x_len(), 0.0);
    g.clear();
    g.resize(shape.x_len(), 0.0);
    fill_slab(pool_ref, x, layer_seed, loads, c, m);
    ffn::fwd_tiled(pool_ref, shape, FfnInputs { x: x.as_slice(), w1, w2 }, y, partial);
    let active: usize = loads.iter().map(|&v| (v as usize).min(c)).sum();
    let denom = (active * m).max(1) as f32;
    let mut lsum = 0.0f64;
    for (e_idx, &load) in loads.iter().enumerate() {
        let rows = (load as usize).min(c);
        let at = e_idx * c * m;
        for idx in at..at + rows * m {
            let r = y[idx] - TARGET_SCALE * x[idx];
            lsum += r as f64 * r as f64;
            g[idx] = 2.0 * r / denom;
        }
    }
    lsum / denom as f64
}

/// The routed-grid inputs of one real-compute pass: one step seed per
/// worker plus the matching `[worker][layer][expert]` kept counts from
/// [`route_grid_counts`].
pub(crate) struct RoutedLoads<'a> {
    pub worker_seeds: &'a [u64],
    pub wl_load: &'a [u32],
}

/// One full real training step over every (worker, layer): forward +
/// backward through the tiled FFN kernels, gradients averaged across
/// workers (data parallelism over the grid's routed loads), then the
/// configured optimizer update. Shared by [`NativeBackend::step`]
/// (`worker_seeds.len() == 1`) and the sharded runtime, whose D = 1 case
/// therefore reproduces the native backend bitwise (`x / 1.0 == x`).
///
/// `routed.wl_load` is row-major `[worker][layer][expert]` kept counts
/// from [`route_grid_counts`]. Returns `(mean loss, grad L2 norm)`.
pub(crate) fn real_train_step(
    pool_ref: &WorkerPool,
    cfg: &ModelConfig,
    capacity: usize,
    leaves: &mut [Vec<f32>],
    routed: RoutedLoads<'_>,
    step: i64,
    sc: &mut RealScratch,
) -> Result<(f64, f64)> {
    let RoutedLoads { worker_seeds, wl_load } = routed;
    let (e, m, i) = (cfg.num_experts, cfg.hidden, cfg.intermediate);
    let layers = cfg.layers;
    let d = worker_seeds.len();
    assert_eq!(wl_load.len(), d * layers * e, "wl_load shape mismatch");
    let shape = FfnShape::new(e, capacity, m, i)?;
    let lr = optim::lr_schedule(cfg.lr, cfg.warmup, step);
    let wd = cfg.weight_decay as f32;
    let opt0 = opt_leaf0(layers);
    if leaves.len() <= opt0 {
        bail!("real compute needs {} state leaves, got {}", opt0 + 4 * layers, leaves.len());
    }
    let mut loss_sum = 0.0f64;
    let mut grad_sq = 0.0f64;
    for l in 0..layers {
        sc.gw1.clear();
        sc.gw1.resize(shape.w1_len(), 0.0);
        sc.gw2.clear();
        sc.gw2.resize(shape.w2_len(), 0.0);
        sc.dw1.resize(shape.w1_len(), 0.0);
        sc.dw2.resize(shape.w2_len(), 0.0);
        let mut layer_loss = 0.0f64;
        for (w, &wseed) in worker_seeds.iter().enumerate() {
            let layer_seed = wseed ^ (l as u64 + 1).wrapping_mul(LAYER_SEED_MIX);
            let loads = &wl_load[(w * layers + l) * e..(w * layers + l + 1) * e];
            layer_loss += real_layer_forward(
                pool_ref,
                shape,
                layer_seed,
                loads,
                &leaves[w1_leaf(l)],
                &leaves[w2_leaf(l)],
                &mut sc.slabs,
            );
            ffn::bwd_tiled(
                pool_ref,
                shape,
                FfnInputs { x: &sc.slabs.x, w1: &leaves[w1_leaf(l)], w2: &leaves[w2_leaf(l)] },
                &sc.slabs.g,
                FfnGrads { dw1: &mut sc.dw1, dw2: &mut sc.dw2, dx: None },
                &mut sc.slabs.partial,
            );
            // accumulate in worker order (deterministic association)
            for (acc, &v) in sc.gw1.iter_mut().zip(&sc.dw1) {
                *acc += v;
            }
            for (acc, &v) in sc.gw2.iter_mut().zip(&sc.dw2) {
                *acc += v;
            }
        }
        loss_sum += layer_loss / d as f64;
        // average the data-parallel grads; exact no-op at d = 1
        for v in sc.gw1.iter_mut() {
            *v /= d as f32;
        }
        for v in sc.gw2.iter_mut() {
            *v /= d as f32;
        }
        for &v in sc.gw1.iter().chain(sc.gw2.iter()) {
            grad_sq += v as f64 * v as f64;
        }
        // optimizer update: params and opt leaves via split borrows
        let (params, opt) = leaves.split_at_mut(opt0);
        let (pw1s, pw2s) = params.split_at_mut(w2_leaf(l));
        let p_w1 = &mut pw1s[w1_leaf(l)];
        let p_w2 = &mut pw2s[0];
        let (o_w1, o_w2) = opt[4 * l..4 * l + 4].split_at_mut(2);
        let (oa, ob) = o_w1.split_at_mut(1);
        let (oc, od) = o_w2.split_at_mut(1);
        if cfg.optimizer == "adafactor" {
            optim::adafactor_update_factored(
                p_w1, &sc.gw1, &mut oa[0], &mut ob[0], e, m, i, step, lr, wd, &mut sc.opt_u,
            );
            optim::adafactor_update_factored(
                p_w2, &sc.gw2, &mut oc[0], &mut od[0], e, i, m, step, lr, wd, &mut sc.opt_u,
            );
        } else {
            optim::adamw_update(p_w1, &sc.gw1, &mut oa[0], &mut ob[0], step, lr, wd);
            optim::adamw_update(p_w2, &sc.gw2, &mut oc[0], &mut od[0], step, lr, wd);
        }
    }
    Ok((loss_sum / layers.max(1) as f64, grad_sq.sqrt()))
}

/// Forward-only real compute for eval: the measured regression loss over
/// the routed loads, averaged across workers and layers. No state is
/// touched.
pub(crate) fn real_forward_loss(
    pool_ref: &WorkerPool,
    cfg: &ModelConfig,
    capacity: usize,
    leaves: &[Vec<f32>],
    routed: RoutedLoads<'_>,
    sc: &mut RealScratch,
) -> Result<f64> {
    let RoutedLoads { worker_seeds, wl_load } = routed;
    let (e, m, i) = (cfg.num_experts, cfg.hidden, cfg.intermediate);
    let layers = cfg.layers;
    let d = worker_seeds.len();
    assert_eq!(wl_load.len(), d * layers * e, "wl_load shape mismatch");
    let shape = FfnShape::new(e, capacity, m, i)?;
    if leaves.len() <= w2_leaf(layers.saturating_sub(1)) {
        bail!("real compute needs weight leaves through {}", w2_leaf(layers - 1));
    }
    let mut loss_sum = 0.0f64;
    for l in 0..layers {
        let mut layer_loss = 0.0f64;
        for (w, &wseed) in worker_seeds.iter().enumerate() {
            let layer_seed = wseed ^ (l as u64 + 1).wrapping_mul(LAYER_SEED_MIX);
            let loads = &wl_load[(w * layers + l) * e..(w * layers + l + 1) * e];
            layer_loss += real_layer_forward(
                pool_ref,
                shape,
                layer_seed,
                loads,
                &leaves[w1_leaf(l)],
                &leaves[w2_leaf(l)],
                &mut sc.slabs,
            );
        }
        loss_sum += layer_loss / d as f64;
    }
    Ok(loss_sum / layers.max(1) as f64)
}

/// Per-step reusable buffers. `step` takes `&self`, so these live behind
/// a lock: the fused grid's partial histograms and the merged per-layer
/// counts must survive across steps for the hot path to be
/// allocation-free after warmup (per-tile gate scratch is thread-local
/// inside [`moe::fused`]).
#[derive(Default)]
struct StepScratch {
    /// per-(layer, tile) demand histograms, `units x E`
    partial: Vec<u32>,
    /// merged per-layer demand / kept load, `layers x E`
    wl_demand: Vec<u32>,
    wl_load: Vec<u32>,
    /// per-layer dropped-selection counts
    wl_dropped: Vec<u32>,
    /// real-compute slabs/grads (empty for simulated variants)
    real: RealScratch,
}

/// The native execution engine for one variant.
pub struct NativeBackend {
    info: VariantInfo,
    sim_step_ms: f64,
    /// injected worker pool; `None` means the process-wide pool
    pool: Option<Arc<WorkerPool>>,
    scratch: Mutex<StepScratch>,
}

impl NativeBackend {
    pub fn new(cfg: &ModelConfig) -> Self {
        let sim_step_ms =
            simulate_step(cfg, cfg.routing, cfg.capacity_mode, &table2_hardware()).total_ms();
        Self {
            info: variant_info(cfg),
            sim_step_ms,
            pool: None,
            scratch: Mutex::new(StepScratch::default()),
        }
    }

    /// Backend pinned to a specific pool — how the determinism tests
    /// assert bitwise-identical [`StepStats`] across pool sizes.
    pub fn with_pool(cfg: &ModelConfig, pool: Arc<WorkerPool>) -> Self {
        let mut backend = Self::new(cfg);
        backend.pool = Some(pool);
        backend
    }

    fn pool(&self) -> &WorkerPool {
        self.pool.as_deref().unwrap_or_else(pool::global)
    }

    /// Calibrated cluster-model prediction for this variant's step time.
    pub fn simulated_step_ms(&self) -> f64 {
        self.sim_step_ms
    }

    fn host_leaves<'a>(&self, state: &'a TrainState) -> Result<&'a Vec<Vec<f32>>> {
        match &state.repr {
            StateRepr::Host(leaves) => Ok(leaves),
            #[cfg(feature = "pjrt")]
            StateRepr::Device(_) => bail!("native backend received a device-resident state"),
        }
    }
}

impl Backend for NativeBackend {
    fn info(&self) -> &VariantInfo {
        &self.info
    }

    fn init_state(&self, seed: u64) -> Result<TrainState> {
        let cfg = &self.info.config;
        let mut rng = Rng::new(hash_str(&cfg.name) ^ seed);
        let floor = loss_floor(cfg);
        // jitter the floor only slightly (±0.1%): seeds must vary the init,
        // but cross-variant loss comparisons ride on the encoded floor gaps
        let l_inf = floor * (1.0 + 0.002 * (rng.uniform() - 0.5));
        // a pins loss(1) to ln(vocab): an untrained model scores ~uniform
        let a = ((cfg.vocab_size as f64).ln() - l_inf).max(0.5);
        let b = 0.35;
        // bias std 0.4 over unit-variance gate noise: visibly skewed load
        // (c_v ~ 0.4-0.6) without drop rates that would dominate the loss
        let bias: Vec<f32> = (0..cfg.layers * cfg.num_experts)
            .map(|_| (rng.normal() * 0.4) as f32)
            .collect();
        let mut leaves = vec![vec![l_inf as f32, a as f32, b as f32], bias];
        if cfg.compute == ComputeMode::Real {
            // real FFN weights continue the same init stream: per layer,
            // w1 (E, M, I) then w2 (E, I, M), N(0, init_std^2)
            let (e, m, i) = (cfg.num_experts, cfg.hidden, cfg.intermediate);
            for _ in 0..cfg.layers {
                for len in [e * m * i, e * i * m] {
                    leaves.push((0..len).map(|_| (rng.normal() * cfg.init_std) as f32).collect());
                }
            }
            // zero-initialized optimizer moments, per the manifest layout
            for spec in &self.info.state_leaves[opt_leaf0(cfg.layers)..] {
                leaves.push(vec![0.0; spec.elements()]);
            }
        }
        Ok(TrainState { step: 0, repr: StateRepr::Host(leaves) })
    }

    fn step(&self, state: TrainState, batch: &Batch) -> Result<(TrainState, StepStats)> {
        let cfg = &self.info.config;
        let TrainState { step, repr } = state;
        let mut leaves = match repr {
            StateRepr::Host(leaves) => leaves,
            #[cfg(feature = "pjrt")]
            StateRepr::Device(_) => bail!("native backend received a device-resident state"),
        };
        let law = law_from_leaf(&leaves[0])?;
        let tokens = cfg.tokens_per_batch();
        let experts = cfg.num_experts;
        let layers = cfg.layers;
        let capacity = self.info.capacity;
        let prototypes = cfg.routing.prototypes().max(1) as usize;
        let base_seed = hash_f32s(&leaves[0])
            ^ (step as u64).wrapping_mul(STEP_SEED_MIX)
            ^ batch_hash(batch);

        // route every layer through the fused counts kernel: each
        // (layer, token-tile) pair is an independent work unit on the
        // persistent pool, generating and routing one cache-resident gate
        // tile — the counts path never materializes a T x E gate matrix.
        // Tile histograms merge exactly, so the result is bitwise
        // identical across pool sizes and to the two-pass oracle.
        let mut scratch_guard = self.scratch.lock().expect("step scratch poisoned");
        let StepScratch { partial, wl_demand, wl_load, wl_dropped, real } = &mut *scratch_guard;
        let pool_ref = self.pool();
        let bias = &leaves[1];
        let n = layers * experts;
        if wl_demand.len() < n {
            wl_demand.resize(n, 0);
            wl_load.resize(n, 0);
        }
        if wl_dropped.len() < layers {
            wl_dropped.resize(layers, 0);
        }
        route_grid_counts(
            pool_ref,
            &[base_seed],
            bias,
            GridSpec { tokens, experts, layers, prototypes, routing: cfg.routing, capacity },
            partial,
            GridCountsOut {
                wl_demand: &mut wl_demand[..n],
                wl_load: &mut wl_load[..n],
                wl_dropped: &mut wl_dropped[..layers],
            },
        );

        // aggregate in the exact operation order of the old per-layer
        // loop, so the emitted StepStats stay bitwise stable
        let mut load = vec![0f32; layers * experts];
        let mut dropped = vec![0f32; layers];
        let mut total_dropped = 0u64;
        let mut cv_sum = 0.0;
        let mut cv_row: Vec<f64> = Vec::with_capacity(experts);
        for l in 0..layers {
            let row = &wl_load[l * experts..(l + 1) * experts];
            for (dst, &v) in load[l * experts..(l + 1) * experts].iter_mut().zip(row) {
                *dst = v as f32;
            }
            dropped[l] = wl_dropped[l] as f32;
            total_dropped += wl_dropped[l] as u64;
            cv_row.clear();
            cv_row.extend(row.iter().map(|&x| x as f64));
            cv_sum += coefficient_of_variation(&cv_row);
        }
        let mean_cv = cv_sum / layers.max(1) as f64;
        let k_eff = cfg.routing.k().min(experts as u32).max(1) as usize;
        let routed = (layers * tokens * k_eff) as f64;
        let drop_frac = total_dropped as f64 / routed.max(1.0);

        let s_next = (step + 1) as f64;
        let (loss, grad_norm) = if cfg.compute == ComputeMode::Real {
            // actual expert compute: routed loads fill seeded slabs, the
            // tiled FFN runs forward + backward, the optimizer updates
            // real weight leaves, and the loss is the measured MSE
            real_train_step(
                pool_ref,
                cfg,
                capacity,
                &mut leaves,
                RoutedLoads { worker_seeds: &[base_seed], wl_load: &wl_load[..n] },
                step,
                real,
            )?
        } else {
            let mut noise = Rng::new(base_seed ^ NOISE_SEED_MIX);
            let loss = law.predict(s_next) + 0.02 * drop_frac + 0.01 * noise.normal();
            let grad_norm = law.a * law.b * s_next.powf(-law.b - 1.0) * 50.0 + 0.5;
            (loss, grad_norm)
        };

        // the aux balancing loss drives the router bias toward uniform —
        // balance improves, quality does not (its cost sits in the floor)
        if cfg.aux_loss_coef > 0.0 {
            for v in leaves[1].iter_mut() {
                *v *= 0.95;
            }
        }

        let stats = StepStats {
            loss: loss as f32,
            aux_loss: (cfg.aux_loss_coef * mean_cv) as f32,
            grad_norm: grad_norm as f32,
            load,
            layers,
            experts,
            dropped,
            sim_step_ms: self.sim_step_ms,
            dispatch: None,
        };
        Ok((TrainState { step: step + 1, repr: StateRepr::Host(leaves) }, stats))
    }

    fn eval(&self, state: &TrainState, batch: &Batch) -> Result<(f64, f64)> {
        let cfg = &self.info.config;
        let leaves = self.host_leaves(state)?;
        let count = (batch.batch * batch.text_len) as f64;
        if cfg.compute == ComputeMode::Real {
            // measured forward loss over this batch's routed loads —
            // deterministic in (state, batch), no jitter needed
            let tokens = cfg.tokens_per_batch();
            let experts = cfg.num_experts;
            let layers = cfg.layers;
            let capacity = self.info.capacity;
            let prototypes = cfg.routing.prototypes().max(1) as usize;
            let base_seed = hash_f32s(&leaves[0])
                ^ (state.step as u64).wrapping_mul(STEP_SEED_MIX)
                ^ batch_hash(batch);
            let mut guard = self.scratch.lock().expect("step scratch poisoned");
            let StepScratch { partial, wl_demand, wl_load, wl_dropped, real } = &mut *guard;
            let pool_ref = self.pool();
            let n = layers * experts;
            if wl_demand.len() < n {
                wl_demand.resize(n, 0);
                wl_load.resize(n, 0);
            }
            if wl_dropped.len() < layers {
                wl_dropped.resize(layers, 0);
            }
            route_grid_counts(
                pool_ref,
                &[base_seed],
                &leaves[1],
                GridSpec { tokens, experts, layers, prototypes, routing: cfg.routing, capacity },
                partial,
                GridCountsOut {
                    wl_demand: &mut wl_demand[..n],
                    wl_load: &mut wl_load[..n],
                    wl_dropped: &mut wl_dropped[..layers],
                },
            );
            let seeds = [base_seed];
            let routed = RoutedLoads { worker_seeds: &seeds, wl_load: &wl_load[..n] };
            let nll = real_forward_loss(pool_ref, cfg, capacity, leaves, routed, real)?;
            return Ok((nll * count, count));
        }
        let law = law_from_leaf(&leaves[0])?;
        // deterministic in (state, batch): paired eval across strategies
        let jitter = ((batch_hash(batch) % 1000) as f64 / 1000.0 - 0.5) * 0.01;
        let nll = law.predict((state.step + 1) as f64) + 0.05 + jitter;
        Ok((nll * count, count))
    }

    fn state_to_host(&self, state: &TrainState) -> Result<Vec<Vec<f32>>> {
        Ok(self.host_leaves(state)?.clone())
    }

    fn state_from_host(&self, leaves: &[Vec<f32>], step: i64) -> Result<TrainState> {
        if leaves.len() != self.info.n_state {
            bail!("checkpoint has {} leaves, expected {}", leaves.len(), self.info.n_state);
        }
        for (leaf, spec) in leaves.iter().zip(&self.info.state_leaves) {
            if leaf.len() != spec.elements() {
                bail!(
                    "leaf {:?} has {} elements, expected {}",
                    spec.name,
                    leaf.len(),
                    spec.elements()
                );
            }
        }
        Ok(TrainState { step, repr: StateRepr::Host(leaves.to_vec()) })
    }
}

fn variant(base: &ModelConfig, name: &str, routing: Routing, mode: CapacityMode) -> ModelConfig {
    let mut cfg = base.clone();
    cfg.name = name.to_string();
    cfg.routing = routing;
    cfg.capacity_mode = mode;
    cfg
}

/// The base-sim scale twin: small enough that every figure driver trains
/// it in seconds on a laptop CPU.
fn sim_base() -> ModelConfig {
    ModelConfig {
        name: "base-sim".into(),
        vocab_size: 2048,
        hidden: 64,
        intermediate: 256,
        layers: 4,
        heads: 4,
        head_dim: 16,
        patch_dim: 128,
        num_experts: 16,
        routing: Routing::TopK(1),
        capacity_factor: 1.25,
        capacity_mode: CapacityMode::TimesK,
        aux_loss_coef: 0.0,
        moe_attention: false,
        attn_num_experts: 4,
        batch: 8,
        patches: 16,
        text_len: 48,
        optimizer: "adamw".into(),
        lr: 1e-3,
        warmup: 100,
        init_std: 0.02,
        weight_decay: 0.01,
        compute: ComputeMode::Simulated,
        workers: 1,
    }
}

/// Every natively runnable variant: the sim-scale twins the figure/table
/// drivers train, plus the paper-scale base strategies for the CLI demo.
pub fn registry() -> Vec<ModelConfig> {
    let base = sim_base();
    let mut out = vec![base.clone()];

    let mut aux = base.clone();
    aux.name = "base-sim-aux".into();
    aux.aux_loss_coef = 0.01;
    out.push(aux);

    for (k, tag) in [(2u32, "top2"), (4, "top4")] {
        for (mode, cap) in [(CapacityMode::TimesK, "capk"), (CapacityMode::Times1, "cap1")] {
            let name = format!("base-sim-{tag}-{cap}");
            out.push(variant(&base, &name, Routing::TopK(k), mode));
        }
    }
    for (k, tag) in [(2u32, "2top1"), (4, "4top1")] {
        for (mode, cap) in [(CapacityMode::TimesK, "capk"), (CapacityMode::Times1, "cap1")] {
            let name = format!("base-sim-{tag}-{cap}");
            out.push(variant(&base, &name, Routing::Prototype(k), mode));
        }
    }

    let mut moeattn = base.clone();
    moeattn.name = "base-sim-moeattn".into();
    moeattn.moe_attention = true;
    out.push(moeattn.clone());
    let mut moeattn2 = moeattn.clone();
    moeattn2.name = "base-sim-moeattn-2top1".into();
    moeattn2.routing = Routing::Prototype(2);
    out.push(moeattn2);

    let mut deep = base.clone();
    deep.name = "deep-sim".into();
    deep.layers = 12;
    deep.num_experts = 8;
    out.push(deep.clone());
    let mut deep_attn = deep.clone();
    deep_attn.name = "deep-sim-moeattn".into();
    deep_attn.moe_attention = true;
    out.push(deep_attn.clone());
    let mut deep_attn2 = deep_attn.clone();
    deep_attn2.name = "deep-sim-moeattn-2top1".into();
    deep_attn2.routing = Routing::Prototype(2);
    out.push(deep_attn2);

    let mut large = base.clone();
    large.name = "large-sim".into();
    large.layers = 8;
    large.num_experts = 32;
    out.push(large.clone());
    out.push(variant(&large, "large-sim-top2-cap1", Routing::TopK(2), CapacityMode::Times1));
    out.push(variant(&large, "large-sim-2top1-cap1", Routing::Prototype(2), CapacityMode::Times1));
    out.push(variant(&large, "large-sim-4top1-cap1", Routing::Prototype(4), CapacityMode::Times1));

    let mut xlarge = base.clone();
    xlarge.name = "xlarge-sim".into();
    xlarge.layers = 8;
    xlarge.num_experts = 64;
    out.push(xlarge.clone());
    out.push(variant(
        &xlarge,
        "xlarge-sim-2top1-cap1",
        Routing::Prototype(2),
        CapacityMode::Times1,
    ));

    // real-compute twins: actual per-expert GEMM FFN + optimizer updates
    // (lr/warmup tuned so the measured loss visibly descends in ~40 steps)
    let mut real = base.clone();
    real.name = "base-sim-real".into();
    real.compute = ComputeMode::Real;
    real.lr = 2e-3;
    real.warmup = 20;
    out.push(real.clone());
    let mut real_af = real.clone();
    real_af.name = "base-sim-real-af".into();
    real_af.optimizer = "adafactor".into();
    real_af.lr = 5e-3;
    out.push(real_af);

    let mut e2e = base.clone();
    e2e.name = "e2e-100m".into();
    e2e.vocab_size = 8192;
    e2e.hidden = 256;
    e2e.intermediate = 1024;
    e2e.layers = 8;
    e2e.heads = 8;
    e2e.head_dim = 32;
    e2e.patch_dim = 256;
    e2e.num_experts = 32;
    out.push(e2e);

    // paper-scale base rows (Table 2 geometry) for `m6t run` / `m6t bench`
    let pbase = paper::base();
    out.push(variant(&pbase, "base-top1", Routing::TopK(1), CapacityMode::TimesK));
    out.push(variant(&pbase, "base-top2", Routing::TopK(2), CapacityMode::Times1));
    out.push(variant(&pbase, "base-top4", Routing::TopK(4), CapacityMode::Times1));
    out.push(variant(&pbase, "base-2top1", Routing::Prototype(2), CapacityMode::Times1));
    out.push(variant(&pbase, "base-4top1", Routing::Prototype(4), CapacityMode::Times1));

    out
}

/// Built-in variant registry: zero artifacts, pure Rust.
pub struct NativeProvider {
    variants: BTreeMap<String, ModelConfig>,
}

impl NativeProvider {
    pub fn new() -> Self {
        let variants = registry().into_iter().map(|c| (c.name.clone(), c)).collect();
        Self { variants }
    }

    fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.variants.get(name).ok_or_else(|| {
            anyhow!(
                "unknown native variant {name:?}; available: {:?}",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl Default for NativeProvider {
    fn default() -> Self {
        Self::new()
    }
}

impl BackendProvider for NativeProvider {
    fn names(&self) -> Vec<String> {
        self.variants.keys().cloned().collect()
    }

    fn info(&self, name: &str) -> Result<VariantInfo> {
        Ok(variant_info(self.config(name)?))
    }

    fn load(&self, name: &str) -> Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(self.config(name)?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_divisible() {
        let regs = registry();
        let mut names: Vec<&str> = regs.iter().map(|c| c.name.as_str()).collect();
        let n = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate variant names");
        for cfg in &regs {
            let z = cfg.routing.prototypes() as usize;
            assert_eq!(cfg.num_experts % z, 0, "{}: E not divisible by prototypes", cfg.name);
            assert!(cfg.routing.k() as usize <= cfg.num_experts, "{}", cfg.name);
        }
    }

    #[test]
    fn floor_encodes_paper_ordering() {
        let base = sim_base();
        let mut top2 = base.clone();
        top2.routing = Routing::TopK(2);
        let mut top4 = base.clone();
        top4.routing = Routing::TopK(4);
        let f1 = loss_floor(&base);
        let f2 = loss_floor(&top2);
        let f4 = loss_floor(&top4);
        assert!(f2 < f1, "k=2 must beat k=1");
        assert!(f4 < f2, "k=4 must beat k=2");
        assert!(f1 - f2 > f2 - f4, "diminishing returns in k");

        let mut proto2 = base.clone();
        proto2.routing = Routing::Prototype(2);
        assert!(loss_floor(&proto2) < f2, "prototyping edges out top-k at equal k");

        let mut big = base.clone();
        big.name = "big".into();
        big.num_experts = 64;
        big.layers = 8;
        assert!(loss_floor(&big) < f1, "more params, lower floor");

        let mut aux = base.clone();
        aux.aux_loss_coef = 0.01;
        assert!(loss_floor(&aux) > f1, "balance does not buy quality");
    }

    #[test]
    fn provider_rejects_unknown() {
        let p = NativeProvider::new();
        assert!(p.load("no-such-variant").is_err());
        assert!(p.info("base-sim").is_ok());
        assert!(p.names().len() >= 24);
    }
}
