//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the rust runtime (which wires device buffers from it).
//!
//! The manifest pins the *flat* argument/result orders of each lowered HLO
//! module, so the coordinator never needs to reconstruct the jax pytree —
//! train state is an opaque ordered vector of device buffers.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::{self, Value};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?} in manifest"),
        }
    }
    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }
    fn from_json(v: &Value) -> Result<Self> {
        let name = v.req("name")?.as_str().unwrap_or("?").to_string();
        let shape = v
            .req("shape")?
            .as_array()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.req("dtype")?.as_str().unwrap_or(""))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One lowered variant: config + file paths + buffer layout.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub init_hlo: PathBuf,
    pub step_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub n_params: usize,
    pub n_opt: usize,
    pub n_state: usize,
    pub param_count: u64,
    pub capacity: usize,
    pub state_leaves: Vec<TensorSpec>,
    pub step_inputs: Vec<TensorSpec>,
    pub step_outputs: Vec<TensorSpec>,
    pub eval_outputs: Vec<TensorSpec>,
}

impl VariantInfo {
    /// Total train-state bytes kept device-resident.
    pub fn state_bytes(&self) -> usize {
        self.state_leaves.iter().map(|l| l.bytes()).sum()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub variants: BTreeMap<String, VariantInfo>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc = json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let variants_json = doc
            .req("variants")
            .map_err(|e| anyhow!("{e}"))?
            .as_object()
            .ok_or_else(|| anyhow!("variants is not an object"))?;

        let mut variants = BTreeMap::new();
        for (name, v) in variants_json {
            let entry = Self::parse_variant(name, v, &root)
                .with_context(|| format!("variant {name:?}"))?;
            variants.insert(name.clone(), entry);
        }
        Ok(Manifest { root, variants })
    }

    fn parse_variant(name: &str, v: &Value, root: &Path) -> Result<VariantInfo> {
        let dir = root.join(name);
        let files = v.req("files").map_err(|e| anyhow!("{e}"))?;
        let file = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(
                files
                    .get(key)
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("missing file entry {key:?}"))?,
            ))
        };
        let config = ModelConfig::from_manifest(v.req("config").map_err(|e| anyhow!("{e}"))?)?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)
                .map_err(|e| anyhow!("{e}"))?
                .as_array()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let info = VariantInfo {
            name: name.to_string(),
            init_hlo: file("init")?,
            step_hlo: file("step")?,
            eval_hlo: file("eval")?,
            dir,
            config,
            n_params: v.req("n_params").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            n_opt: v.req("n_opt").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            n_state: v.req("n_state").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            param_count: v
                .req("param_count")
                .map_err(|e| anyhow!("{e}"))?
                .as_f64()
                .unwrap_or(0.0) as u64,
            capacity: v.req("capacity").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            state_leaves: specs("state_leaves")?,
            step_inputs: specs("step_inputs")?,
            step_outputs: specs("step_outputs")?,
            eval_outputs: specs("eval_outputs")?,
        };
        if info.n_state != info.n_params + info.n_opt {
            bail!(
                "inconsistent state counts: {} != {} + {}",
                info.n_state,
                info.n_params,
                info.n_opt
            );
        }
        if info.state_leaves.len() != info.n_state {
            bail!(
                "state_leaves len {} != n_state {}",
                info.state_leaves.len(),
                info.n_state
            );
        }
        Ok(info)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants.get(name).ok_or_else(|| {
            anyhow!(
                "unknown variant {name:?}; available: {:?}",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn tensor_spec_math() {
        let t = TensorSpec { name: "x".into(), shape: vec![4, 8, 2], dtype: DType::F32 };
        assert_eq!(t.elements(), 64);
        assert_eq!(t.bytes(), 256);
    }

    // Manifest::load against real artifacts is covered by the integration
    // tests in rust/tests/ (requires `make artifacts`).
}
