//! PJRT execution engine (cargo feature `pjrt`): loads HLO-text artifacts,
//! compiles them on the CPU client, and runs train/eval steps with
//! **device-resident state**.
//!
//! The train state (parameters + optimizer moments) never round-trips
//! through the host: `step()` feeds the previous step's output buffers
//! straight back via `execute_b` (the vendored xla crate is patched to set
//! `ExecuteOptions::untuple_result`, so multi-output modules return flat
//! per-output buffers). Only the batch goes in and the scalar metrics +
//! per-layer load vectors come out — a few hundred bytes per step.
//!
//! Offline builds compile against `third_party/xla-stub`, which
//! type-checks this module but fails at runtime; swap in the vendored
//! crate to execute real artifacts (DESIGN.md §Backends).

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{Backend, BackendProvider, StateRepr, StepStats, TrainState};
use super::manifest::{DType, Manifest, VariantInfo};
use crate::data::Batch;

/// One compiled variant, ready to run.
pub struct VariantRuntime {
    pub info: VariantInfo,
    init: xla::PjRtLoadedExecutable,
    step: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub compile_seconds: f64,
}

/// The PJRT engine; owns the client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let text_path = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(text_path)
            .map_err(wrap)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("compiling {path:?}"))
    }

    /// Load + compile all three modules of a variant.
    pub fn load(&self, info: &VariantInfo) -> Result<VariantRuntime> {
        let t0 = Instant::now();
        let init = self.compile_file(&info.init_hlo)?;
        let step = self.compile_file(&info.step_hlo)?;
        let eval = self.compile_file(&info.eval_hlo)?;
        Ok(VariantRuntime {
            info: info.clone(),
            init,
            step,
            eval,
            client: self.client.clone(),
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

impl VariantRuntime {
    /// Upload the batch to device buffers.
    ///
    /// Uses `BufferFromHostBuffer` with `kImmutableOnlyDuringCall` semantics:
    /// the copy completes before the call returns, so no host memory needs to
    /// outlive the call. (The literal-based upload path,
    /// `BufferFromHostLiteral`, schedules `CopyFromLiteral` asynchronously on
    /// the 0.5.1 TFRT CPU client and intermittently crossed copy lambdas with
    /// later uploads — observed as a `literal.size_bytes() == b->size()`
    /// check crash.)
    fn batch_buffers(&self, batch: &Batch) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let cfg = &self.info.config;
        if batch.batch != cfg.batch || batch.text_len != cfg.text_len {
            bail!(
                "batch geometry {}x{} does not match config {}x{}",
                batch.batch,
                batch.text_len,
                cfg.batch,
                cfg.text_len
            );
        }
        let pb = self
            .client
            .buffer_from_host_buffer(
                &batch.patch_features,
                &[batch.batch, batch.patches, batch.patch_dim],
                None,
            )
            .map_err(wrap)?;
        let tb = self
            .client
            .buffer_from_host_buffer(&batch.tokens, &[batch.batch, batch.text_len], None)
            .map_err(wrap)?;
        Ok((pb, tb))
    }

    fn device_buffers<'a>(&self, state: &'a TrainState) -> Result<&'a Vec<xla::PjRtBuffer>> {
        match &state.repr {
            StateRepr::Device(buffers) => Ok(buffers),
            StateRepr::Host(_) => bail!("PJRT backend received a host-resident state"),
        }
    }
}

impl Backend for VariantRuntime {
    fn info(&self) -> &VariantInfo {
        &self.info
    }

    /// Run the init module: seed -> fresh device-resident train state.
    /// The lowered init takes an i32 seed, so the 64-bit seed is folded
    /// (xor of halves) instead of truncated — the upper bits still vary
    /// the stream.
    fn init_state(&self, seed: u64) -> Result<TrainState> {
        let seed_lit = xla::Literal::scalar((seed ^ (seed >> 32)) as u32 as i32);
        let outs = self.init.execute::<xla::Literal>(&[seed_lit]).map_err(wrap)?;
        let buffers = into_single_replica(outs)?;
        if buffers.len() != self.info.n_state {
            bail!(
                "init returned {} buffers, manifest says {}",
                buffers.len(),
                self.info.n_state
            );
        }
        Ok(TrainState { step: 0, repr: StateRepr::Device(buffers) })
    }

    /// One train step: consumes the state, returns the advanced state and
    /// the step statistics. Parameters stay on device.
    fn step(&self, state: TrainState, batch: &Batch) -> Result<(TrainState, StepStats)> {
        let (pb, tb) = self.batch_buffers(batch)?;
        let step_i32 = [state.step as i32];
        let sb = self
            .client
            .buffer_from_host_buffer(&step_i32, &[], None)
            .map_err(wrap)?;

        let state_buffers = self.device_buffers(&state)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(state_buffers.len() + 3);
        args.extend(state_buffers.iter());
        args.push(&sb);
        args.push(&pb);
        args.push(&tb);

        let outs = self.step.execute_b::<&xla::PjRtBuffer>(&args).map_err(wrap)?;
        let mut bufs = into_single_replica(outs)?;
        let expect = self.info.n_state + self.info.step_outputs.len();
        if bufs.len() != expect {
            bail!("step returned {} buffers, expected {}", bufs.len(), expect);
        }
        let extras = bufs.split_off(self.info.n_state);
        let cfg = &self.info.config;
        let stats = StepStats {
            loss: scalar_f32(&extras[0])?,
            aux_loss: scalar_f32(&extras[1])?,
            grad_norm: scalar_f32(&extras[2])?,
            load: vec_f32(&extras[3])?,
            layers: cfg.layers,
            experts: cfg.num_experts,
            dropped: vec_f32(&extras[4])?,
            sim_step_ms: 0.0,
            dispatch: None,
        };
        Ok((
            TrainState { step: state.step + 1, repr: StateRepr::Device(bufs) },
            stats,
        ))
    }

    /// Teacher-forced eval on one batch: (sum_nll, token_count).
    fn eval(&self, state: &TrainState, batch: &Batch) -> Result<(f64, f64)> {
        let (pb, tb) = self.batch_buffers(batch)?;
        let state_buffers = self.device_buffers(state)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.info.n_params + 2);
        args.extend(state_buffers[..self.info.n_params].iter());
        args.push(&pb);
        args.push(&tb);
        let outs = self.eval.execute_b::<&xla::PjRtBuffer>(&args).map_err(wrap)?;
        let bufs = into_single_replica(outs)?;
        if bufs.len() != 2 {
            bail!("eval returned {} buffers, expected 2", bufs.len());
        }
        Ok((scalar_f32(&bufs[0])? as f64, scalar_f32(&bufs[1])? as f64))
    }

    /// Pull the full state to host (checkpointing).
    fn state_to_host(&self, state: &TrainState) -> Result<Vec<Vec<f32>>> {
        self.device_buffers(state)?
            .iter()
            .zip(&self.info.state_leaves)
            .map(|(b, spec)| match spec.dtype {
                DType::F32 => vec_f32(b),
                DType::I32 => {
                    // i32 leaves (none today) round-trip bit-exactly via f32 reinterpret
                    bail!("i32 state leaves not supported in checkpoints yet")
                }
            })
            .collect()
    }

    /// Restore a host checkpoint into device buffers.
    fn state_from_host(&self, leaves: &[Vec<f32>], step: i64) -> Result<TrainState> {
        if leaves.len() != self.info.n_state {
            bail!("checkpoint has {} leaves, expected {}", leaves.len(), self.info.n_state);
        }
        let mut buffers = Vec::with_capacity(leaves.len());
        for (data, spec) in leaves.iter().zip(&self.info.state_leaves) {
            if data.len() != spec.elements() {
                bail!(
                    "leaf {:?} has {} elements, expected {}",
                    spec.name,
                    data.len(),
                    spec.elements()
                );
            }
            buffers.push(
                self.client
                    .buffer_from_host_buffer(data, &spec.shape, None)
                    .map_err(wrap)?,
            );
        }
        Ok(TrainState { step, repr: StateRepr::Device(buffers) })
    }
}

/// Artifact-backed provider: the PJRT engine plus the manifest registry.
pub struct PjrtProvider {
    engine: Engine,
    manifest: Manifest,
}

impl PjrtProvider {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Self { engine: Engine::cpu()?, manifest: Manifest::load(artifacts_dir)? })
    }

    pub fn platform(&self) -> String {
        self.engine.platform()
    }
}

impl BackendProvider for PjrtProvider {
    fn names(&self) -> Vec<String> {
        self.manifest.variants.keys().cloned().collect()
    }

    fn info(&self, name: &str) -> Result<VariantInfo> {
        Ok(self.manifest.variant(name)?.clone())
    }

    fn load(&self, name: &str) -> Result<Box<dyn Backend>> {
        let info = self.manifest.variant(name)?;
        Ok(Box::new(self.engine.load(info)?))
    }
}

fn into_single_replica(outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::PjRtBuffer>> {
    let mut it = outs.into_iter();
    let first = it.next().ok_or_else(|| anyhow!("no replica outputs"))?;
    Ok(first)
}

fn scalar_f32(b: &xla::PjRtBuffer) -> Result<f32> {
    let lit = b.to_literal_sync().map_err(wrap)?;
    Ok(lit.to_vec::<f32>().map_err(wrap)?[0])
}

fn vec_f32(b: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    let lit = b.to_literal_sync().map_err(wrap)?;
    lit.to_vec::<f32>().map_err(wrap)
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}
