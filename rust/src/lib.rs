//! # m6t — M6-T: Exploring Sparse Expert Models and Beyond, reproduced
//!
//! A three-layer reproduction of Yang et al. (2021):
//!
//! * **L1** — Pallas kernels for the MoE hot spots (expert-batched FFN,
//!   prototype routing), authored in `python/compile/kernels/`;
//! * **L2** — the M6-style multimodal MoE transformer + optimizers in JAX
//!   (`python/compile/`), AOT-lowered to HLO text once per experiment
//!   variant;
//! * **L3** — this crate: the coordinator that owns the synthetic corpus,
//!   a pluggable [`runtime::Backend`] execution layer (a pure-Rust
//!   [`runtime::NativeBackend`] that runs with zero artifacts, and a PJRT
//!   engine with device-resident train state behind the `pjrt` cargo
//!   feature), the routing analytics (c_v load balance), the analytical
//!   FLOPs model, the Whale cluster simulator, and every table/figure
//!   driver.
//!
//! Python never runs on the request path: the default build is fully
//! self-contained, and with `--features pjrt` + compiled artifacts the
//! same `m6t` binary executes the lowered HLO instead.
//!
//! See DESIGN.md for the backend architecture, feature flags, and the
//! per-experiment index.

// The crate's unsafe budget is a single audited module: every raw-pointer
// sharding trick lives behind `util::shard`, which opts back in with a
// module-level `#![allow(unsafe_code)]`. `deny` (not `forbid`) so that one
// override is legal; the hot-path modules additionally `forbid` locally,
// and `m6t lint-unsafe` ratchets the site count in CI.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod flops;
pub mod metrics;
pub mod moe;
pub mod runtime;
pub mod scaling;
pub mod serve;
pub mod sweep;
pub mod testing;
pub mod util;
