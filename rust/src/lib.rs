//! # m6t — M6-T: Exploring Sparse Expert Models and Beyond, reproduced
//!
//! A three-layer reproduction of Yang et al. (2021):
//!
//! * **L1** — Pallas kernels for the MoE hot spots (expert-batched FFN,
//!   prototype routing), authored in `python/compile/kernels/`;
//! * **L2** — the M6-style multimodal MoE transformer + optimizers in JAX
//!   (`python/compile/`), AOT-lowered to HLO text once per experiment
//!   variant;
//! * **L3** — this crate: the coordinator that owns the synthetic corpus,
//!   a pluggable [`runtime::Backend`] execution layer (a pure-Rust
//!   [`runtime::NativeBackend`] that runs with zero artifacts, and a PJRT
//!   engine with device-resident train state behind the `pjrt` cargo
//!   feature), the routing analytics (c_v load balance), the analytical
//!   FLOPs model, the Whale cluster simulator, and every table/figure
//!   driver.
//!
//! Python never runs on the request path: the default build is fully
//! self-contained, and with `--features pjrt` + compiled artifacts the
//! same `m6t` binary executes the lowered HLO instead.
//!
//! See DESIGN.md for the backend architecture, feature flags, and the
//! per-experiment index.

// Index-heavy numerical code over flat row-major buffers: ranged loops
// with explicit (t, e) indexing are the house style, and manual ceil-div
// keeps the MSRV below `usize::div_ceil`. CI runs clippy with -D warnings;
// these two lints are the deliberate exceptions.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod flops;
pub mod metrics;
pub mod moe;
pub mod runtime;
pub mod scaling;
pub mod testing;
pub mod util;
