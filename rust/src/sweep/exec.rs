//! Cell executors: the bridge between a kind-agnostic [`Cell`] and the
//! machinery that actually runs it.
//!
//! A [`CellRunner`] does two jobs. `resolve` expands a spec-level cell
//! (e.g. `model=base-twin, strategy=top2@1x, workers=4`) into the fully
//! resolved form the store hashes — folding in every `cfg.*` field via
//! [`crate::sweep::spec::config_cell`], so a registry edit changes the
//! address instead of aliasing a stale result. `run` executes the cell
//! and returns its result document (one BENCH row, one training curve).
//!
//! `version` is the code-relevant tag baked into every address: bump it
//! when the measurement or its semantics change, and every old result
//! becomes unreachable (and gc-able) instead of silently wrong.

use anyhow::{bail, Result};

use crate::experiments;
use crate::runtime::{dispatch_bench, ffn_bench, overlap_bench, step_bench};
use crate::serve::bench as serve_bench;
use crate::sweep::spec::Cell;
use crate::util::json::Value;

pub trait CellRunner {
    fn kind(&self) -> &'static str;
    fn version(&self) -> &'static str;
    fn resolve(&self, cell: &Cell) -> Result<Cell>;
    fn run(&self, cell: &Cell) -> Result<Value>;
}

pub struct DispatchRunner;

impl CellRunner for DispatchRunner {
    fn kind(&self) -> &'static str {
        "dispatch"
    }
    fn version(&self) -> &'static str {
        dispatch_bench::STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        dispatch_bench::resolve_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        dispatch_bench::run_cell(cell)
    }
}

pub struct StepRunner;

impl CellRunner for StepRunner {
    fn kind(&self) -> &'static str {
        "step"
    }
    fn version(&self) -> &'static str {
        step_bench::STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        step_bench::resolve_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        step_bench::run_cell(cell)
    }
}

pub struct OverlapRunner;

impl CellRunner for OverlapRunner {
    fn kind(&self) -> &'static str {
        "overlap"
    }
    fn version(&self) -> &'static str {
        overlap_bench::STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        overlap_bench::resolve_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        overlap_bench::run_cell(cell)
    }
}

pub struct ElasticRunner;

impl CellRunner for ElasticRunner {
    fn kind(&self) -> &'static str {
        "elastic"
    }
    fn version(&self) -> &'static str {
        dispatch_bench::ELASTIC_STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        dispatch_bench::resolve_elastic_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        dispatch_bench::run_elastic_cell(cell)
    }
}

pub struct PlacementRunner;

impl CellRunner for PlacementRunner {
    fn kind(&self) -> &'static str {
        "placement"
    }
    fn version(&self) -> &'static str {
        overlap_bench::PLACEMENT_STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        overlap_bench::resolve_placement_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        overlap_bench::run_placement_cell(cell)
    }
}

pub struct FfnRunner;

impl CellRunner for FfnRunner {
    fn kind(&self) -> &'static str {
        "ffn"
    }
    fn version(&self) -> &'static str {
        ffn_bench::STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        ffn_bench::resolve_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        ffn_bench::run_cell(cell)
    }
}

pub struct ServeRunner;

impl CellRunner for ServeRunner {
    fn kind(&self) -> &'static str {
        "serve"
    }
    fn version(&self) -> &'static str {
        serve_bench::STORE_VERSION
    }
    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        serve_bench::resolve_cell(cell)
    }
    fn run(&self, cell: &Cell) -> Result<Value> {
        serve_bench::run_cell(cell)
    }
}

/// The built-in executor for a spec `kind`. Training cells
/// ([`experiments::Runner`]) need a backend provider and are constructed
/// directly rather than through this registry.
pub fn runner_for(kind: &str) -> Result<Box<dyn CellRunner>> {
    match kind {
        "dispatch" => Ok(Box::new(DispatchRunner)),
        "step" => Ok(Box::new(StepRunner)),
        "overlap" => Ok(Box::new(OverlapRunner)),
        "ffn" => Ok(Box::new(FfnRunner)),
        "elastic" => Ok(Box::new(ElasticRunner)),
        "placement" => Ok(Box::new(PlacementRunner)),
        "serve" => Ok(Box::new(ServeRunner)),
        "train" => bail!(
            "train sweeps need a backend provider; use `m6t run` / experiments::Runner ({})",
            experiments::runner::STORE_VERSION
        ),
        other => bail!(
            "no executor for sweep kind {other:?} (dispatch, step, overlap, ffn, elastic, placement, serve)"
        ),
    }
}
