//! Declarative sweep specifications: a named param grid (`fixed` values
//! plus the cartesian product of `axes`) that expands into flat [`Cell`]s.
//!
//! Cells are ordered maps so their canonical serialization — and therefore
//! the store's content address — is independent of spec field order. The
//! executor's `resolve` step folds the *fully resolved* model config into
//! each cell before hashing (see [`config_cell`]), so editing a registry
//! variant changes every affected address instead of silently reusing
//! stale results.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{CapacityMode, ModelConfig, Routing};
use crate::util::json::{self, arr, num, obj, s, Value};

/// One scalar parameter value. Numbers stay `f64` (matching the JSON
/// layer), which round-trips bit-exactly through the canonical form.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl ParamValue {
    pub fn to_json(&self) -> Value {
        match self {
            ParamValue::Str(x) => s(x.clone()),
            ParamValue::Num(n) => num(*n),
            ParamValue::Bool(b) => Value::Bool(*b),
        }
    }

    pub fn from_json(v: &Value) -> Result<ParamValue> {
        match v {
            Value::String(x) => Ok(ParamValue::Str(x.clone())),
            Value::Number(n) => Ok(ParamValue::Num(*n)),
            Value::Bool(b) => Ok(ParamValue::Bool(*b)),
            other => bail!("param values must be scalars, got {other:?}"),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::Str(x) => write!(f, "{x}"),
            ParamValue::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => {
                write!(f, "{}", *n as i64)
            }
            ParamValue::Num(n) => write!(f, "{n}"),
            ParamValue::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// String axis values for spec builders.
pub fn strs(xs: &[&str]) -> Vec<ParamValue> {
    xs.iter().map(|x| ParamValue::Str((*x).to_string())).collect()
}

/// Integer axis values for spec builders.
pub fn nums(xs: &[usize]) -> Vec<ParamValue> {
    xs.iter().map(|&x| ParamValue::Num(x as f64)).collect()
}

/// One fully-expanded grid point: a flat, ordered param map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Cell(pub BTreeMap<String, ParamValue>);

impl Cell {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: &str, v: ParamValue) {
        self.0.insert(key.to_string(), v);
    }

    pub fn get(&self, key: &str) -> Option<&ParamValue> {
        self.0.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }

    /// Fold `other`'s entries in (overwriting on collision).
    pub fn merge(&mut self, other: &Cell) {
        for (k, v) in &other.0 {
            self.0.insert(k.clone(), v.clone());
        }
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(ParamValue::Str(x)) => Ok(x),
            Some(other) => bail!("cell param {key:?} is not a string: {other}"),
            None => bail!("cell is missing param {key:?}"),
        }
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(ParamValue::Num(n)) => Ok(*n),
            Some(other) => bail!("cell param {key:?} is not a number: {other}"),
            None => bail!("cell is missing param {key:?}"),
        }
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        let n = self.req_f64(key)?;
        ensure!(
            n >= 0.0 && n.fract() == 0.0,
            "cell param {key:?} is not a non-negative integer: {n}"
        );
        Ok(n as usize)
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        Ok(self.req_usize(key)? as u64)
    }

    pub fn to_json(&self) -> Value {
        Value::Object(self.0.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
    }

    pub fn from_json(v: &Value) -> Result<Cell> {
        let m = v.as_object().ok_or_else(|| anyhow!("cell must be a JSON object"))?;
        let mut out = BTreeMap::new();
        for (k, x) in m {
            out.insert(k.clone(), ParamValue::from_json(x)?);
        }
        Ok(Cell(out))
    }

    /// The canonical serialized form (sorted keys, shortest-roundtrip
    /// floats) — the exact byte string the store's content address hashes.
    pub fn canonical(&self) -> String {
        json::write(&self.to_json())
    }
}

/// One swept dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Axis {
    pub name: String,
    pub values: Vec<ParamValue>,
}

/// Keys the expansion owns; specs may not sweep or fix them.
pub const RESERVED_KEYS: [&str; 2] = ["steps", "seed"];

/// A declarative parameter grid: `fixed` params plus the cartesian
/// product of `axes` (last axis fastest, matching the nesting order of
/// the hand-rolled loops this engine replaced), with `steps` and `seed`
/// folded into every cell.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    /// Cell family — selects the executor (`dispatch`, `step`, ...).
    pub kind: String,
    /// Measured steps (or reps) per cell.
    pub steps: usize,
    pub seed: u64,
    pub fixed: Cell,
    pub axes: Vec<Axis>,
}

impl SweepSpec {
    pub fn new(name: &str, kind: &str) -> Self {
        Self {
            name: name.to_string(),
            kind: kind.to_string(),
            steps: 12,
            seed: 42,
            fixed: Cell::new(),
            axes: Vec::new(),
        }
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn fix(mut self, key: &str, v: ParamValue) -> Self {
        self.fixed.set(key, v);
        self
    }

    pub fn axis(mut self, name: &str, values: Vec<ParamValue>) -> Self {
        self.axes.push(Axis { name: name.to_string(), values });
        self
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "sweep spec needs a non-empty name");
        ensure!(!self.kind.is_empty(), "sweep spec {:?} needs a non-empty kind", self.name);
        ensure!(self.steps >= 1, "sweep spec {:?}: steps must be >= 1", self.name);
        for key in RESERVED_KEYS {
            ensure!(
                !self.fixed.contains(key),
                "sweep spec {:?}: fixed param {key:?} shadows a reserved key",
                self.name
            );
        }
        let mut seen = BTreeSet::new();
        for axis in &self.axes {
            ensure!(!axis.name.is_empty(), "sweep spec {:?}: axis with empty name", self.name);
            ensure!(
                !axis.values.is_empty(),
                "sweep spec {:?}: axis {:?} has no values",
                self.name,
                axis.name
            );
            ensure!(
                seen.insert(axis.name.as_str()),
                "sweep spec {:?}: duplicate axis {:?}",
                self.name,
                axis.name
            );
            ensure!(
                !self.fixed.contains(&axis.name),
                "sweep spec {:?}: axis {:?} collides with a fixed param",
                self.name,
                axis.name
            );
            ensure!(
                !RESERVED_KEYS.contains(&axis.name.as_str()),
                "sweep spec {:?}: axis {:?} shadows a reserved key",
                self.name,
                axis.name
            );
        }
        Ok(())
    }

    /// Expand to the full cartesian grid, last axis fastest.
    pub fn expand(&self) -> Result<Vec<Cell>> {
        self.validate()?;
        let mut base = self.fixed.clone();
        base.set("steps", ParamValue::Num(self.steps as f64));
        base.set("seed", ParamValue::Num(self.seed as f64));
        let mut cells = vec![base];
        for axis in &self.axes {
            let mut next = Vec::with_capacity(cells.len() * axis.values.len());
            for cell in &cells {
                for v in &axis.values {
                    let mut c = cell.clone();
                    c.set(&axis.name, v.clone());
                    next.push(c);
                }
            }
            cells = next;
        }
        Ok(cells)
    }

    /// Compact per-cell progress label over the axis coordinates.
    pub fn label(&self, cell: &Cell) -> String {
        if self.axes.is_empty() {
            return self.name.clone();
        }
        self.axes
            .iter()
            .map(|a| match cell.get(&a.name) {
                Some(v) => format!("{v}"),
                None => "?".to_string(),
            })
            .collect::<Vec<_>>()
            .join("/")
    }

    pub fn to_json(&self) -> Value {
        let axes: Vec<Value> = self
            .axes
            .iter()
            .map(|a| {
                obj(vec![
                    ("name", s(a.name.clone())),
                    ("values", arr(a.values.iter().map(ParamValue::to_json).collect())),
                ])
            })
            .collect();
        obj(vec![
            ("name", s(self.name.clone())),
            ("kind", s(self.kind.clone())),
            ("steps", num(self.steps as f64)),
            ("seed", num(self.seed as f64)),
            ("fixed", self.fixed.to_json()),
            ("axes", arr(axes)),
        ])
    }

    /// Strict deserialization: unknown keys, non-scalar values, empty or
    /// duplicate axes, and reserved-key collisions are all rejected — a
    /// typo in a spec file must fail loudly, not silently drop an axis.
    pub fn from_json(v: &Value) -> Result<SweepSpec> {
        let m = v.as_object().ok_or_else(|| anyhow!("sweep spec must be a JSON object"))?;
        for key in m.keys() {
            ensure!(
                matches!(key.as_str(), "name" | "kind" | "steps" | "seed" | "fixed" | "axes"),
                "sweep spec has unknown key {key:?}"
            );
        }
        let name = m
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("sweep spec needs a string \"name\""))?;
        let kind = m
            .get("kind")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("sweep spec {name:?} needs a string \"kind\""))?;
        let steps = match m.get("steps") {
            None => 12,
            Some(x) => x
                .as_usize()
                .ok_or_else(|| anyhow!("sweep spec {name:?}: \"steps\" must be an integer"))?,
        };
        let seed = match m.get("seed") {
            None => 42,
            Some(x) => x
                .as_usize()
                .ok_or_else(|| anyhow!("sweep spec {name:?}: \"seed\" must be an integer"))?
                as u64,
        };
        let fixed = match m.get("fixed") {
            None => Cell::new(),
            Some(x) => Cell::from_json(x)?,
        };
        let mut axes = Vec::new();
        if let Some(av) = m.get("axes") {
            let list = av
                .as_array()
                .ok_or_else(|| anyhow!("sweep spec {name:?}: \"axes\" must be an array"))?;
            for a in list {
                let am = a
                    .as_object()
                    .ok_or_else(|| anyhow!("sweep spec {name:?}: each axis must be an object"))?;
                let axis_name = am
                    .get("name")
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow!("sweep spec {name:?}: axis needs a string \"name\""))?;
                for key in am.keys() {
                    ensure!(
                        matches!(key.as_str(), "name" | "values"),
                        "sweep spec {name:?}: axis {axis_name:?} has unknown key {key:?}"
                    );
                }
                let values = am
                    .get("values")
                    .and_then(|x| x.as_array())
                    .ok_or_else(|| {
                        anyhow!("sweep spec {name:?}: axis {axis_name:?} needs a \"values\" array")
                    })?
                    .iter()
                    .map(ParamValue::from_json)
                    .collect::<Result<Vec<_>>>()?;
                axes.push(Axis { name: axis_name.to_string(), values });
            }
        }
        let spec = SweepSpec {
            name: name.to_string(),
            kind: kind.to_string(),
            steps,
            seed,
            fixed,
            axes,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn parse(text: &str) -> Result<SweepSpec> {
        let doc = json::parse(text).map_err(|e| anyhow!("sweep spec: {e}"))?;
        Self::from_json(&doc)
    }
}

/// Parse a routing-strategy coordinate like `top2@1x` or `2top1@kx` into
/// the (routing, capacity-mode) pair it names. Every spec coordinate is
/// explicit about capacity so a cell's address can never depend on an
/// implicit default.
pub fn parse_strategy(text: &str) -> Result<(Routing, CapacityMode)> {
    let (r, c) = text
        .split_once('@')
        .ok_or_else(|| anyhow!("strategy {text:?} must look like \"top1@kx\" or \"2top1@1x\""))?;
    let routing =
        Routing::parse(r).ok_or_else(|| anyhow!("strategy {text:?}: unknown routing {r:?}"))?;
    let mode = match c {
        "kx" | "k" => CapacityMode::TimesK,
        "1x" | "1" => CapacityMode::Times1,
        other => bail!("strategy {text:?}: unknown capacity mode {other:?} (kx or 1x)"),
    };
    Ok((routing, mode))
}

/// The canonical spelling [`parse_strategy`] round-trips.
pub fn strategy_name(routing: Routing, mode: CapacityMode) -> String {
    format!("{}@{}", routing.name(), mode.name())
}

/// Flatten a fully-resolved [`ModelConfig`] into `cfg.*` cell params, so
/// a cell's content address covers every field that shapes its
/// computation. The exhaustive destructuring is deliberate: adding a
/// config field without extending the fingerprint is a compile error —
/// exactly the stale-cache bug class the store exists to kill.
pub fn config_cell(cfg: &ModelConfig) -> Cell {
    let ModelConfig {
        name,
        vocab_size,
        hidden,
        intermediate,
        layers,
        heads,
        head_dim,
        patch_dim,
        num_experts,
        routing,
        capacity_factor,
        capacity_mode,
        aux_loss_coef,
        moe_attention,
        attn_num_experts,
        batch,
        patches,
        text_len,
        optimizer,
        lr,
        warmup,
        init_std,
        weight_decay,
        compute,
        workers,
    } = cfg;
    let mut c = Cell::new();
    c.set("cfg.name", ParamValue::Str(name.clone()));
    c.set("cfg.vocab_size", ParamValue::Num(*vocab_size as f64));
    c.set("cfg.hidden", ParamValue::Num(*hidden as f64));
    c.set("cfg.intermediate", ParamValue::Num(*intermediate as f64));
    c.set("cfg.layers", ParamValue::Num(*layers as f64));
    c.set("cfg.heads", ParamValue::Num(*heads as f64));
    c.set("cfg.head_dim", ParamValue::Num(*head_dim as f64));
    c.set("cfg.patch_dim", ParamValue::Num(*patch_dim as f64));
    c.set("cfg.num_experts", ParamValue::Num(*num_experts as f64));
    c.set("cfg.routing", ParamValue::Str(routing.name()));
    c.set("cfg.capacity_factor", ParamValue::Num(*capacity_factor));
    c.set("cfg.capacity_mode", ParamValue::Str(capacity_mode.name().to_string()));
    c.set("cfg.aux_loss_coef", ParamValue::Num(*aux_loss_coef));
    c.set("cfg.moe_attention", ParamValue::Bool(*moe_attention));
    c.set("cfg.attn_num_experts", ParamValue::Num(*attn_num_experts as f64));
    c.set("cfg.batch", ParamValue::Num(*batch as f64));
    c.set("cfg.patches", ParamValue::Num(*patches as f64));
    c.set("cfg.text_len", ParamValue::Num(*text_len as f64));
    c.set("cfg.optimizer", ParamValue::Str(optimizer.clone()));
    c.set("cfg.lr", ParamValue::Num(*lr));
    c.set("cfg.warmup", ParamValue::Num(*warmup as f64));
    c.set("cfg.init_std", ParamValue::Num(*init_std));
    c.set("cfg.weight_decay", ParamValue::Num(*weight_decay));
    c.set("cfg.compute", ParamValue::Str(compute.name().to_string()));
    c.set("cfg.workers", ParamValue::Num(*workers as f64));
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_last_axis_fastest() {
        let spec = SweepSpec::new("t", "k")
            .steps(2)
            .axis("outer", strs(&["a", "b"]))
            .axis("inner", nums(&[1, 2, 3]));
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 6);
        assert_eq!(cells[0].req_str("outer").unwrap(), "a");
        assert_eq!(cells[0].req_usize("inner").unwrap(), 1);
        assert_eq!(cells[2].req_usize("inner").unwrap(), 3);
        assert_eq!(cells[3].req_str("outer").unwrap(), "b");
        for c in &cells {
            assert_eq!(c.req_usize("steps").unwrap(), 2);
            assert_eq!(c.req_u64("seed").unwrap(), 42);
        }
    }

    #[test]
    fn strategy_names_round_trip() {
        for text in ["top1@kx", "top2@1x", "2top1@1x", "4top1@kx"] {
            let (routing, mode) = parse_strategy(text).unwrap();
            assert_eq!(strategy_name(routing, mode), text);
        }
        assert!(parse_strategy("top1").is_err());
        assert!(parse_strategy("top1@2x").is_err());
        assert!(parse_strategy("nope@kx").is_err());
    }

    #[test]
    fn config_cell_sees_every_field() {
        let base = crate::runtime::dispatch_bench::base_twin();
        let a = config_cell(&base);
        let mut edited = base.clone();
        edited.capacity_factor = 2.0;
        let b = config_cell(&edited);
        assert_ne!(a.canonical(), b.canonical());
        assert_eq!(a.canonical(), config_cell(&base).canonical());
    }

    #[test]
    fn labels_follow_axis_order() {
        let spec = SweepSpec::new("t", "k").axis("m", strs(&["x"])).axis("d", nums(&[4]));
        let cells = spec.expand().unwrap();
        assert_eq!(spec.label(&cells[0]), "x/4");
    }
}
