//! Shared output reporter: every sweep-backed subcommand renders its
//! summary through one of three formats (`stream` keeps the historical
//! aligned-table stdout, `json` emits the machine document, `markdown`
//! emits a pipe table), so adding a format is one match arm here instead
//! of five per-harness printf forks.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, arr, obj, s, Value};
use crate::util::table::Table;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputFormat {
    /// Historical behavior: aligned monospace table on stdout.
    Stream,
    /// The full machine-readable document (or the table if none).
    Json,
    /// GitHub-flavored pipe table.
    Markdown,
}

impl OutputFormat {
    pub fn parse(text: &str) -> Result<OutputFormat> {
        match text {
            "stream" => Ok(OutputFormat::Stream),
            "json" => Ok(OutputFormat::Json),
            "markdown" | "md" => Ok(OutputFormat::Markdown),
            other => bail!("unknown output format {other:?} (stream, json, markdown)"),
        }
    }
}

/// Render a [`Table`] as a GitHub-flavored markdown pipe table.
pub fn markdown_table(table: &Table) -> String {
    let mut out = String::new();
    if !table.title.is_empty() {
        let _ = writeln!(out, "### {}", table.title);
        let _ = writeln!(out);
    }
    let _ = writeln!(out, "| {} |", table.header.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        table.header.iter().map(|h| "-".repeat(h.len().max(3) + 2)).collect::<Vec<_>>().join("|")
    );
    for row in &table.rows {
        let _ = writeln!(out, "| {} |", row.join(" | "));
    }
    out
}

/// A [`Table`] as a JSON document (for subcommands that have no richer
/// native document to emit under `--output-format json`).
pub fn table_json(table: &Table) -> Value {
    let rows: Vec<Value> = table
        .rows
        .iter()
        .map(|row| {
            Value::Object(
                table
                    .header
                    .iter()
                    .zip(row)
                    .map(|(h, c)| (h.clone(), Value::String(c.clone())))
                    .collect(),
            )
        })
        .collect();
    obj(vec![("title", s(table.title.clone())), ("rows", arr(rows))])
}

/// Print `table` in `format`. Under `Json`, `doc` (the subcommand's
/// native machine document, e.g. the full BENCH_*.json) wins over the
/// table projection when present.
pub fn emit(format: OutputFormat, table: &Table, doc: Option<&Value>) {
    match format {
        OutputFormat::Stream => print!("{}", table.render()),
        OutputFormat::Markdown => print!("{}", markdown_table(table)),
        OutputFormat::Json => {
            let fallback;
            let v = match doc {
                Some(d) => d,
                None => {
                    fallback = table_json(table);
                    &fallback
                }
            };
            println!("{}", json::write(v));
        }
    }
}

/// Write a JSON document (newline-terminated) to `path`.
pub fn write_doc(doc: &Value, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent).with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    fs::write(path, format!("{}\n", json::write(doc)))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parse() {
        assert_eq!(OutputFormat::parse("stream").unwrap(), OutputFormat::Stream);
        assert_eq!(OutputFormat::parse("md").unwrap(), OutputFormat::Markdown);
        assert_eq!(OutputFormat::parse("json").unwrap(), OutputFormat::Json);
        assert!(OutputFormat::parse("yaml").is_err());
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("demo", &["name", "x"]);
        t.row(vec!["a".into(), "1".into()]);
        let md = markdown_table(&t);
        assert!(md.starts_with("### demo"));
        assert!(md.contains("| name | x |"));
        assert!(md.contains("| a | 1 |"));
    }

    #[test]
    fn table_projects_to_json() {
        let mut t = Table::new("demo", &["name", "x"]);
        t.row(vec!["a".into(), "1".into()]);
        let v = table_json(&t);
        let rows = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").and_then(Value::as_str), Some("a"));
    }
}
