//! Content-addressed experiment store.
//!
//! Layout: `<results>/store/<kind>/<key>/{cell.json, result.json}` where
//! `key` is a 128-bit digest over `"{kind}\n{version}\n{canonical cell}"`.
//! `result.json` is written last (via temp + rename), so its presence is
//! the completion marker: a cell directory without a parseable result is
//! treated as absent, which is exactly what makes interrupted sweeps
//! resumable — re-running the spec skips finished cells and re-executes
//! the partial one.
//!
//! `gc` prunes directories whose keys no longer appear in any supplied
//! spec, and only scans the kinds those specs cover, so a bench-only gc
//! can never touch training runs. Modeled on repx's lab/run/gc design.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::sweep::spec::Cell;
use crate::util::json::{self, Value};

/// 64-bit FNV-1a over `bytes`, seeded with `basis`. The store key runs
/// two passes with independent bases for a 128-bit address — FNV because
/// the vendored dependency set has no hash crates, and collision
/// resistance against *accidental* config aliasing (not adversaries) is
/// all a local experiment cache needs.
fn fnv1a(bytes: &[u8], basis: u64) -> u64 {
    let mut h = basis;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content address for a resolved cell: 32 hex chars over kind, code
/// version tag, and the canonical (sorted-key) cell serialization.
pub fn cell_key(kind: &str, version: &str, resolved: &Cell) -> String {
    let payload = format!("{kind}\n{version}\n{}", resolved.canonical());
    let a = fnv1a(payload.as_bytes(), 0xcbf2_9ce4_8422_2325);
    let b = fnv1a(payload.as_bytes(), 0x9e37_79b9_7f4a_7c15);
    format!("{a:016x}{b:016x}")
}

/// What a `gc` pass saw and did (or would do, under `--dry-run`).
#[derive(Debug)]
pub struct GcReport {
    pub scanned: usize,
    pub kept: usize,
    pub pruned: Vec<PathBuf>,
    pub dry_run: bool,
}

/// On-disk store handle rooted at `<results>/store`.
#[derive(Debug, Clone)]
pub struct Store {
    root: PathBuf,
}

impl Store {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn cell_dir(&self, kind: &str, key: &str) -> PathBuf {
        self.root.join(kind).join(key)
    }

    /// Completed result for `key`, or `None` if the cell was never run,
    /// was interrupted mid-write, or left an unparseable file behind.
    pub fn lookup(&self, kind: &str, key: &str) -> Option<Value> {
        let path = self.cell_dir(kind, key).join("result.json");
        let text = fs::read_to_string(path).ok()?;
        json::parse(&text).ok()
    }

    /// Record a completed cell. `cell.json` (provenance: the resolved
    /// params behind the key) lands first; `result.json` lands last and
    /// atomically, because it doubles as the completion marker.
    pub fn insert(&self, kind: &str, key: &str, resolved: &Cell, result: &Value) -> Result<()> {
        let dir = self.cell_dir(kind, key);
        fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        write_atomic(&dir.join("cell.json"), &format!("{}\n", resolved.canonical()))?;
        write_atomic(&dir.join("result.json"), &format!("{}\n", json::write(result)))?;
        Ok(())
    }

    /// Prune cell directories whose `(kind, key)` is not in `live`.
    /// Only the kinds named in `kinds` are scanned at all: a key can only
    /// be declared dead by a spec set that actually covers its family.
    pub fn gc(
        &self,
        live: &BTreeSet<(String, String)>,
        kinds: &BTreeSet<String>,
        dry_run: bool,
    ) -> Result<GcReport> {
        let mut report = GcReport { scanned: 0, kept: 0, pruned: Vec::new(), dry_run };
        for kind in kinds {
            let kind_dir = self.root.join(kind);
            let entries = match fs::read_dir(&kind_dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for entry in entries {
                let entry = entry.with_context(|| format!("scanning {}", kind_dir.display()))?;
                if !entry.path().is_dir() {
                    continue;
                }
                report.scanned += 1;
                let key = entry.file_name().to_string_lossy().into_owned();
                if live.contains(&(kind.clone(), key)) {
                    report.kept += 1;
                } else {
                    if !dry_run {
                        fs::remove_dir_all(entry.path())
                            .with_context(|| format!("pruning {}", entry.path().display()))?;
                    }
                    report.pruned.push(entry.path());
                }
            }
        }
        Ok(report)
    }
}

/// Write via sibling temp file + rename so a crash mid-write can never
/// leave a truncated-but-parseable file where a completed one should be.
fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::ParamValue;

    fn cell(pairs: &[(&str, f64)]) -> Cell {
        let mut c = Cell::new();
        for (k, v) in pairs {
            c.set(k, ParamValue::Num(*v));
        }
        c
    }

    #[test]
    fn keys_depend_on_kind_version_and_content() {
        let a = cell(&[("x", 1.0), ("y", 2.0)]);
        let b = cell(&[("y", 2.0), ("x", 1.0)]);
        assert_eq!(cell_key("k", "v1", &a), cell_key("k", "v1", &b));
        assert_ne!(cell_key("k", "v1", &a), cell_key("k", "v2", &a));
        assert_ne!(cell_key("k", "v1", &a), cell_key("j", "v1", &a));
        assert_ne!(cell_key("k", "v1", &a), cell_key("k", "v1", &cell(&[("x", 1.0), ("y", 3.0)])));
        let key = cell_key("k", "v1", &a);
        assert_eq!(key.len(), 32);
        assert!(key.chars().all(|c| c.is_ascii_hexdigit()));
    }
}
