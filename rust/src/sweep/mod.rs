//! The sweep engine: one declarative grid driver behind every bench
//! harness, figure/table driver, and training-run cache in the repo.
//!
//! A [`SweepSpec`] names a cell family (`kind`), fixed params, and axes;
//! [`Engine::run_spec`] expands it, resolves each cell through its
//! [`CellRunner`], and serves each from the content-addressed [`Store`]
//! — executing only cells whose address has never completed. Re-invoking
//! an identical sweep is therefore zero re-runs, an interrupted sweep
//! resumes by skipping finished cells, and editing any config field or
//! bumping a runner's version tag re-runs exactly the affected cells.
//! See DESIGN.md §"Sweep driver & experiment store".

pub mod exec;
pub mod report;
pub mod spec;
pub mod store;

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, ensure, Result};

use crate::util::json::{arr, obj, s, Value};

pub use exec::{
    runner_for, CellRunner, DispatchRunner, ElasticRunner, FfnRunner, OverlapRunner,
    PlacementRunner, ServeRunner, StepRunner,
};
pub use report::OutputFormat;
pub use spec::{
    config_cell, nums, parse_strategy, strategy_name, strs, Axis, Cell, ParamValue, SweepSpec,
    RESERVED_KEYS,
};
pub use store::{cell_key, GcReport, Store};

/// Engine-wide version tag folded into every address (alongside the
/// per-runner tag): bump to invalidate the whole store at once.
pub const ENGINE_VERSION: &str = "sweep-v1";

/// One executed-or-cached cell from a sweep.
#[derive(Debug)]
pub struct CellOutcome {
    pub cell: Cell,
    pub key: String,
    pub cached: bool,
    pub result: Value,
}

/// Everything a finished sweep knows about itself.
#[derive(Debug)]
pub struct SweepOutcome {
    pub spec_name: String,
    pub kind: String,
    pub outcomes: Vec<CellOutcome>,
}

impl SweepOutcome {
    pub fn hits(&self) -> usize {
        self.outcomes.iter().filter(|o| o.cached).count()
    }

    pub fn executed(&self) -> usize {
        self.outcomes.len() - self.hits()
    }
}

/// Store-backed sweep executor.
pub struct Engine {
    store: Store,
    force: bool,
    verbose: bool,
}

impl Engine {
    /// The store lives at `<results>/store`, next to the run artifacts
    /// the experiment drivers already write under `results/`.
    pub fn new(results_dir: impl AsRef<Path>) -> Self {
        Self { store: Store::new(results_dir.as_ref().join("store")), force: false, verbose: true }
    }

    /// Re-execute cells even when their address has a completed result
    /// (timing tools that must re-measure set this).
    pub fn force(mut self, force: bool) -> Self {
        self.force = force;
        self
    }

    pub fn verbose(mut self, verbose: bool) -> Self {
        self.verbose = verbose;
        self
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    /// Resolve, address, and run (or recall) one cell.
    pub fn run_cell(
        &self,
        runner: &dyn CellRunner,
        cell: &Cell,
        label: &str,
    ) -> Result<CellOutcome> {
        let resolved = runner.resolve(cell)?;
        let key = combined_key(runner, &resolved);
        if !self.force {
            if let Some(result) = self.store.lookup(runner.kind(), &key) {
                if self.verbose {
                    eprintln!("[sweep] {} {}: cached ({})", runner.kind(), label, &key[..12]);
                }
                return Ok(CellOutcome { cell: resolved, key, cached: true, result });
            }
        }
        let result = runner.run(cell)?;
        self.store.insert(runner.kind(), &key, &resolved, &result)?;
        Ok(CellOutcome { cell: resolved, key, cached: false, result })
    }

    /// Expand `spec` and run every cell through `runner`.
    pub fn run_spec(&self, spec: &SweepSpec, runner: &dyn CellRunner) -> Result<SweepOutcome> {
        ensure!(
            spec.kind == runner.kind(),
            "spec {:?} has kind {:?} but the executor runs {:?}",
            spec.name,
            spec.kind,
            runner.kind()
        );
        let cells = spec.expand()?;
        let mut outcomes = Vec::with_capacity(cells.len());
        for cell in &cells {
            outcomes.push(self.run_cell(runner, cell, &spec.label(cell))?);
        }
        let outcome =
            SweepOutcome { spec_name: spec.name.clone(), kind: spec.kind.clone(), outcomes };
        if self.verbose {
            eprintln!(
                "[sweep] {}: {} cells — {} cached, {} executed (store {})",
                outcome.spec_name,
                outcome.outcomes.len(),
                outcome.hits(),
                outcome.executed(),
                self.store.root().display()
            );
        }
        Ok(outcome)
    }
}

/// The full store address of a cell: engine version, runner version,
/// kind, and the resolved cell content.
fn combined_key(runner: &dyn CellRunner, resolved: &Cell) -> String {
    cell_key(runner.kind(), &format!("{ENGINE_VERSION}/{}", runner.version()), resolved)
}

/// Address a spec-level cell without running it.
pub fn address(runner: &dyn CellRunner, cell: &Cell) -> Result<String> {
    Ok(combined_key(runner, &runner.resolve(cell)?))
}

/// Every `(kind, key)` a spec can produce — the liveness set for gc.
pub fn live_keys(spec: &SweepSpec, runner: &dyn CellRunner) -> Result<BTreeSet<(String, String)>> {
    ensure!(
        spec.kind == runner.kind(),
        "spec {:?} has kind {:?} but the executor runs {:?}",
        spec.name,
        spec.kind,
        runner.kind()
    );
    let mut live = BTreeSet::new();
    for cell in spec.expand()? {
        live.insert((spec.kind.clone(), address(runner, &cell)?));
    }
    Ok(live)
}

/// Append the engine's provenance block to a bench document. It rides as
/// one *extra* top-level key, so every historical field keeps its exact
/// name and meaning for the CI regression gate.
pub fn attach_provenance(doc: &mut Value, outcome: &SweepOutcome) {
    let cells: Vec<Value> = outcome
        .outcomes
        .iter()
        .map(|o| obj(vec![("key", s(o.key.clone())), ("cached", Value::Bool(o.cached))]))
        .collect();
    let block = obj(vec![
        ("engine", s(ENGINE_VERSION)),
        ("kind", s(outcome.kind.clone())),
        ("spec", s(outcome.spec_name.clone())),
        ("cells", arr(cells)),
    ]);
    if let Value::Object(m) = doc {
        m.insert("provenance".to_string(), block);
    }
}

/// Names accepted by `m6t sweep <name>` without a spec file.
pub const BUILTIN_SPECS: [&str; 7] =
    ["dispatch", "step", "overlap", "ffn", "elastic", "placement", "serve"];

/// The builtin spec behind each `m6t bench --*` mode (and `m6t
/// serve-sim`). `steps` overrides the per-family default (12 measured
/// steps; 8 reps for ffn; 6 profile steps for serve).
pub fn builtin_spec(name: &str, steps: Option<usize>) -> Result<SweepSpec> {
    use crate::runtime::{dispatch_bench, ffn_bench, overlap_bench, step_bench};
    let spec = match name {
        "dispatch" => dispatch_bench::spec(steps.unwrap_or(12)),
        "step" => step_bench::spec(steps.unwrap_or(12)),
        "overlap" => overlap_bench::spec(steps.unwrap_or(12)),
        "ffn" => ffn_bench::spec(steps.unwrap_or(8)),
        "elastic" => dispatch_bench::elastic_spec(steps.unwrap_or(12)),
        "placement" => overlap_bench::placement_spec(steps.unwrap_or(12)),
        "serve" => crate::serve::bench::spec(steps.unwrap_or(6)),
        other => bail!(
            "unknown builtin sweep {other:?} (dispatch, step, overlap, ffn, elastic, placement, serve)"
        ),
    };
    Ok(spec)
}
