//! Training metrics: step records, EMA loss, per-layer c_v series, and
//! CSV/JSONL sinks consumed by the figure drivers and EXPERIMENTS.md.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::moe::DispatchSummary;
use crate::runtime::StepStats;
use crate::util::json::{arr, num, obj, s, write as jwrite, Value};
use crate::util::stats::Ema;

/// The one loss-smoothing constant: [`RunLog::ema_loss`] and the Fig-6
/// convergence-crossing detector [`RunLog::steps_to_loss`] must agree on
/// when a target is reached, so they share this beta (they used to run
/// 0.95 vs 0.9 and disagreed).
pub const LOSS_EMA_BETA: f64 = 0.95;

/// One recorded training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: i64,
    pub loss: f64,
    pub aux_loss: f64,
    pub grad_norm: f64,
    pub cv_per_layer: Vec<f64>,
    pub dropped: f64,
    pub dropped_per_layer: Vec<f64>,
    pub ms_per_step: f64,
    /// simulated cluster ms/step (0 on measured-hardware backends)
    pub sim_ms: f64,
    /// expert-parallel dispatch series (sharded runtime only): per-worker
    /// drops, per-shard receive totals, cross-worker c_v, measured a2a
    /// bytes, observed cluster ms
    pub dispatch: Option<DispatchSummary>,
}

/// In-memory run log + optional JSONL sink.
pub struct RunLog {
    pub name: String,
    pub records: Vec<StepRecord>,
    ema: Ema,
    sink: Option<fs::File>,
    pub sink_path: Option<PathBuf>,
}

impl RunLog {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            records: Vec::new(),
            ema: Ema::new(LOSS_EMA_BETA),
            sink: None,
            sink_path: None,
        }
    }

    /// Also record every step in a JSONL file under `dir`, truncating any
    /// existing file — for *fresh* runs. A resumed run must use
    /// [`RunLog::with_sink_append`] or it destroys its recorded history.
    pub fn with_sink(self, dir: impl AsRef<Path>) -> Result<Self> {
        self.with_sink_opts(dir, false)
    }

    /// Append-mode sink for resumed runs: prior recorded lines survive
    /// and new steps continue the same JSONL series. Callers that know
    /// the resume step must use [`RunLog::with_sink_resume`] instead, or
    /// re-running the overlap range double-logs it.
    pub fn with_sink_append(self, dir: impl AsRef<Path>) -> Result<Self> {
        self.with_sink_opts(dir, true)
    }

    /// Append-mode sink for a run resuming at `resume_step`: on open, any
    /// previously recorded line with `step >= resume_step` is dropped
    /// (those steps are about to be re-executed and re-logged), so
    /// resuming the same checkpoint twice cannot duplicate the
    /// overlapping step range — the JSONL step column stays strictly
    /// monotone. Lines that don't parse as records are preserved
    /// untouched rather than destroyed.
    pub fn with_sink_resume(self, dir: impl AsRef<Path>, resume_step: i64) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join(format!("{}.jsonl", self.name));
        if path.exists() {
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {path:?} for resume truncation"))?;
            let mut kept = String::with_capacity(text.len());
            for line in text.lines() {
                let stale = crate::util::json::parse(line)
                    .ok()
                    .and_then(|v| v.get("step").and_then(|s| s.as_i64()))
                    .is_some_and(|step| step >= resume_step);
                if !stale {
                    kept.push_str(line);
                    kept.push('\n');
                }
            }
            fs::write(&path, kept)
                .with_context(|| format!("truncating {path:?} at step {resume_step}"))?;
        }
        self.with_sink_opts(dir, true)
    }

    fn with_sink_opts(mut self, dir: impl AsRef<Path>, append: bool) -> Result<Self> {
        fs::create_dir_all(&dir)?;
        let path = dir.as_ref().join(format!("{}.jsonl", self.name));
        let file = if append {
            fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening {path:?} for append"))?
        } else {
            fs::File::create(&path).with_context(|| format!("creating {path:?}"))?
        };
        self.sink = Some(file);
        self.sink_path = Some(path);
        Ok(self)
    }

    pub fn push(&mut self, step: i64, stats: &StepStats, ms: f64) -> Result<()> {
        let rec = StepRecord {
            step,
            loss: stats.loss as f64,
            aux_loss: stats.aux_loss as f64,
            grad_norm: stats.grad_norm as f64,
            cv_per_layer: stats.cv_per_layer(),
            dropped: stats.total_dropped(),
            dropped_per_layer: stats.dropped.iter().map(|&x| x as f64).collect(),
            ms_per_step: ms,
            sim_ms: stats.sim_step_ms,
            dispatch: stats.dispatch.clone(),
        };
        self.ema.push(rec.loss);
        if let Some(f) = &mut self.sink {
            let mut fields = vec![
                ("step", num(rec.step as f64)),
                ("loss", num(rec.loss)),
                ("aux_loss", num(rec.aux_loss)),
                ("grad_norm", num(rec.grad_norm)),
                ("cv", arr(rec.cv_per_layer.iter().map(|&x| num(x)).collect())),
                ("dropped", num(rec.dropped)),
                ("ms", num(rec.ms_per_step)),
                ("sim_ms", num(rec.sim_ms)),
            ];
            if let Some(dsp) = &rec.dispatch {
                fields.push(("workers", num(dsp.workers as f64)));
                fields.push(("shard_cv", num(dsp.shard_load_cv)));
                fields.push(("a2a_bytes", num(dsp.a2a_bytes_step)));
                fields.push(("max_link_bytes", num(dsp.max_link_bytes)));
                fields.push(("observed_ms", num(dsp.observed_ms)));
                fields.push(("overlap_ms", num(dsp.observed_overlap_ms)));
                fields.push(("overlap_eff", num(dsp.overlap_efficiency)));
                // elastic-capacity + placement series: the capacity span
                // the controller assigned this step and how the placed
                // layout priced against the identity layout
                fields.push(("elastic", num(if dsp.elastic { 1.0 } else { 0.0 })));
                fields.push(("cap_min", num(dsp.capacity_min as f64)));
                fields.push(("cap_max", num(dsp.capacity_max as f64)));
                fields.push(("placement_gain", num(dsp.placement_gain)));
                fields.push(("placed_link_share", num(dsp.placed_link_share)));
                fields.push((
                    "worker_dropped",
                    arr(dsp.per_worker_dropped.iter().map(|&x| num(x)).collect()),
                ));
                fields.push((
                    "shard_recv",
                    arr(dsp.per_shard_recv.iter().map(|&x| num(x)).collect()),
                ));
            }
            let v = obj(fields);
            writeln!(f, "{}", jwrite(&v))?;
        }
        self.records.push(rec);
        Ok(())
    }

    pub fn ema_loss(&self) -> f64 {
        self.ema.get()
    }

    pub fn last(&self) -> Option<&StepRecord> {
        self.records.last()
    }

    /// Log-perplexity curve as (step, loss) pairs — the paper's y-axis
    /// ("training log perplexity" == mean token NLL).
    pub fn loss_curve(&self) -> Vec<(i64, f64)> {
        self.records.iter().map(|r| (r.step, r.loss)).collect()
    }

    /// Mean loss over the trailing `n` records — convergence-level proxy.
    pub fn tail_loss(&self, n: usize) -> f64 {
        let take = self.records.len().min(n.max(1));
        if take == 0 {
            return f64::NAN;
        }
        let s: f64 = self.records[self.records.len() - take..]
            .iter()
            .map(|r| r.loss)
            .sum();
        s / take as f64
    }

    /// First step whose EMA-smoothed loss dips below `target` — used for
    /// the Fig-6 convergence-speedup factor. None if never reached.
    /// Smooths with [`LOSS_EMA_BETA`], the same beta as [`RunLog::ema_loss`],
    /// so the crossing detector and the reported EMA agree about when a
    /// target is reached.
    pub fn steps_to_loss(&self, target: f64) -> Option<i64> {
        let mut ema = Ema::new(LOSS_EMA_BETA);
        for r in &self.records {
            ema.push(r.loss);
            if ema.get() <= target {
                return Some(r.step);
            }
        }
        None
    }

    /// Mean c_v of a layer over the trailing n records.
    pub fn tail_cv(&self, layer: usize, n: usize) -> f64 {
        let take = self.records.len().min(n.max(1));
        if take == 0 {
            return f64::NAN;
        }
        let s: f64 = self.records[self.records.len() - take..]
            .iter()
            .map(|r| r.cv_per_layer.get(layer).copied().unwrap_or(f64::NAN))
            .sum();
        s / take as f64
    }

    /// Summary object for EXPERIMENTS.md.
    pub fn summary(&self) -> Value {
        obj(vec![
            ("name", s(self.name.clone())),
            ("steps", num(self.records.len() as f64)),
            ("final_loss", num(self.tail_loss(20))),
            ("ema_loss", num(self.ema_loss())),
            (
                "mean_ms",
                num({
                    let n = self.records.len().max(1);
                    self.records.iter().map(|r| r.ms_per_step).sum::<f64>() / n as f64
                }),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(loss: f32, layers: usize, experts: usize) -> StepStats {
        StepStats {
            loss,
            aux_loss: 0.1,
            grad_norm: 1.0,
            load: vec![1.0; layers * experts],
            layers,
            experts,
            dropped: vec![0.0; layers],
            sim_step_ms: 0.0,
            dispatch: None,
        }
    }

    #[test]
    fn records_accumulate() {
        let mut log = RunLog::new("t");
        for i in 0..10 {
            log.push(i, &stats(5.0 - i as f32 * 0.1, 2, 4), 100.0).unwrap();
        }
        assert_eq!(log.records.len(), 10);
        assert!(log.tail_loss(3) < 5.0);
        assert_eq!(log.loss_curve().len(), 10);
        assert_eq!(log.last().unwrap().step, 9);
    }

    #[test]
    fn steps_to_loss_finds_crossing() {
        let mut log = RunLog::new("t");
        for i in 0..50 {
            log.push(i, &stats(5.0 - i as f32 * 0.1, 1, 2), 1.0).unwrap();
        }
        let hit = log.steps_to_loss(3.0).unwrap();
        // raw loss crosses 3.0 at step 20; the 0.95-EMA lags behind it
        assert!((25..40).contains(&hit), "hit at {hit}");
        assert_eq!(log.steps_to_loss(-1.0), None);
    }

    #[test]
    fn crossing_detector_agrees_with_reported_ema() {
        // satellite regression: steps_to_loss used beta 0.9 while ema_loss
        // used 0.95 — the detector crossed targets the reported EMA had
        // not reached. With one shared beta, the final reported EMA is
        // reached exactly at the final step, never earlier.
        let mut log = RunLog::new("t");
        for i in 0..60 {
            log.push(i, &stats(4.0 - i as f32 * 0.05, 1, 2), 1.0).unwrap();
        }
        let final_ema = log.ema_loss();
        assert_eq!(
            log.steps_to_loss(final_ema),
            Some(59),
            "a strictly decreasing EMA reaches its own final value only at the last step"
        );
        // and any earlier crossing the detector reports is one the
        // replayed reported-EMA sequence actually made
        let target = 3.0;
        let hit = log.steps_to_loss(target).unwrap();
        let mut ema = Ema::new(LOSS_EMA_BETA);
        for r in &log.records[..=hit as usize] {
            ema.push(r.loss);
        }
        assert!(ema.get() <= target, "detector crossed before the reported EMA did");
    }

    #[test]
    fn balanced_load_cv_zero() {
        let mut log = RunLog::new("t");
        log.push(0, &stats(1.0, 2, 4), 1.0).unwrap();
        assert_eq!(log.tail_cv(0, 1), 0.0);
        assert_eq!(log.tail_cv(1, 1), 0.0);
    }

    #[test]
    fn jsonl_sink_writes() {
        let dir = std::env::temp_dir().join("m6t-metrics-test");
        let mut log = RunLog::new("sink").with_sink(&dir).unwrap();
        log.push(0, &stats(2.0, 1, 2), 3.0).unwrap();
        let path = log.sink_path.clone().unwrap();
        drop(log);
        let text = fs::read_to_string(path).unwrap();
        assert!(text.contains("\"loss\":2"));
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn append_sink_preserves_prior_history() {
        // satellite regression: with_sink used File::create even on
        // resume, truncating the recorded history of the original run
        let dir = std::env::temp_dir().join("m6t-metrics-append-test");
        let _ = fs::remove_dir_all(&dir);
        let mut log = RunLog::new("resumable").with_sink(&dir).unwrap();
        log.push(0, &stats(5.0, 1, 2), 1.0).unwrap();
        log.push(1, &stats(4.0, 1, 2), 1.0).unwrap();
        let path = log.sink_path.clone().unwrap();
        drop(log);

        // "resume": a fresh RunLog over the same sink in append mode
        let mut resumed = RunLog::new("resumable").with_sink_append(&dir).unwrap();
        resumed.push(2, &stats(3.0, 1, 2), 1.0).unwrap();
        drop(resumed);

        let text = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "prior lines must survive the resume");
        assert!(lines[0].contains("\"step\":0"), "{}", lines[0]);
        assert!(lines[1].contains("\"step\":1"), "{}", lines[1]);
        assert!(lines[2].contains("\"step\":2"), "{}", lines[2]);

        // a fresh (non-append) sink still truncates
        let mut fresh = RunLog::new("resumable").with_sink(&dir).unwrap();
        fresh.push(0, &stats(9.0, 1, 2), 1.0).unwrap();
        drop(fresh);
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1, "create mode truncates");
        let _ = fs::remove_dir_all(dir);
    }

    fn sink_steps(path: &Path) -> Vec<i64> {
        fs::read_to_string(path)
            .unwrap()
            .lines()
            .map(|l| {
                crate::util::json::parse(l)
                    .unwrap()
                    .get("step")
                    .and_then(|s| s.as_i64())
                    .expect("record has a step")
            })
            .collect()
    }

    #[test]
    fn resume_sink_drops_overlapping_steps() {
        // satellite regression (found in PR 4 review): plain append on
        // resume re-logged the overlapping step range, so resuming the
        // same checkpoint twice produced a non-monotone step column
        let dir = std::env::temp_dir().join("m6t-metrics-resume-test");
        let _ = fs::remove_dir_all(&dir);
        let mut log = RunLog::new("ck").with_sink(&dir).unwrap();
        for i in 0..5 {
            log.push(i, &stats(5.0 - i as f32 * 0.1, 1, 2), 1.0).unwrap();
        }
        let path = log.sink_path.clone().unwrap();
        drop(log);

        // "resume from a step-3 checkpoint" twice: both re-run steps 3..5
        for _ in 0..2 {
            let mut resumed = RunLog::new("ck").with_sink_resume(&dir, 3).unwrap();
            for i in 3..5 {
                resumed.push(i, &stats(4.0 - i as f32 * 0.1, 1, 2), 1.0).unwrap();
            }
            drop(resumed);
            let steps = sink_steps(&path);
            assert_eq!(steps, vec![0, 1, 2, 3, 4], "step column must stay monotone");
        }

        // resuming at a step past the end is a pure append
        let mut tail = RunLog::new("ck").with_sink_resume(&dir, 5).unwrap();
        tail.push(5, &stats(3.0, 1, 2), 1.0).unwrap();
        drop(tail);
        assert_eq!(sink_steps(&path), vec![0, 1, 2, 3, 4, 5]);

        // resuming a run with no prior sink just creates the file
        let mut fresh = RunLog::new("ck-none").with_sink_resume(&dir, 7).unwrap();
        fresh.push(7, &stats(2.0, 1, 2), 1.0).unwrap();
        let fresh_path = fresh.sink_path.clone().unwrap();
        drop(fresh);
        assert_eq!(sink_steps(&fresh_path), vec![7]);
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn dispatch_series_reach_the_sink() {
        let dir = std::env::temp_dir().join("m6t-metrics-dispatch-test");
        let _ = fs::remove_dir_all(&dir);
        let mut s = stats(2.0, 1, 2);
        s.dispatch = Some(DispatchSummary {
            workers: 4,
            layers: 1,
            shard_load_cv: 0.25,
            shard_balance: 1.5,
            per_worker_dropped: vec![1.0, 2.0, 3.0, 4.0],
            per_shard_recv: vec![10.0, 20.0, 30.0, 40.0],
            per_shard_dropped: vec![0.0; 4],
            a2a_bytes_per_layer: 1024.0,
            a2a_bytes_total: 1024.0,
            a2a_bytes_step: 4096.0,
            cross_fraction: 0.75,
            drop_fraction: 0.1,
            max_link_bytes: 512.0,
            bottleneck_src: 2,
            bottleneck_dst: 0,
            observed_ms: 123.0,
            observed_overlap_ms: 100.0,
            overlap_efficiency: 0.8,
            elastic: true,
            capacity_min: 12,
            capacity_max: 28,
            placement_gain: 1.25,
            placed_link_share: 0.4,
        });
        let mut log = RunLog::new("dsp").with_sink(&dir).unwrap();
        log.push(0, &s, 1.0).unwrap();
        let path = log.sink_path.clone().unwrap();
        assert_eq!(log.last().unwrap().dispatch.as_ref().unwrap().workers, 4);
        drop(log);
        let text = fs::read_to_string(path).unwrap();
        let keys = [
            "\"workers\":4",
            "\"shard_cv\":0.25",
            "\"observed_ms\":123",
            "\"overlap_ms\":100",
            "\"overlap_eff\":0.8",
            "\"max_link_bytes\":512",
            "\"elastic\":1",
            "\"cap_min\":12",
            "\"cap_max\":28",
            "\"placement_gain\":1.25",
            "\"placed_link_share\":0.4",
            "\"worker_dropped\"",
            "\"shard_recv\"",
        ];
        for key in keys {
            assert!(text.contains(key), "missing {key} in {text}");
        }
        let _ = fs::remove_dir_all(dir);
    }
}
