//! Training metrics: step records, EMA loss, per-layer c_v series, and
//! CSV/JSONL sinks consumed by the figure drivers and EXPERIMENTS.md.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::runtime::StepStats;
use crate::util::json::{arr, num, obj, s, write as jwrite, Value};
use crate::util::stats::Ema;

/// One recorded training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: i64,
    pub loss: f64,
    pub aux_loss: f64,
    pub grad_norm: f64,
    pub cv_per_layer: Vec<f64>,
    pub dropped: f64,
    pub dropped_per_layer: Vec<f64>,
    pub ms_per_step: f64,
    /// simulated cluster ms/step (0 on measured-hardware backends)
    pub sim_ms: f64,
}

/// In-memory run log + optional JSONL sink.
pub struct RunLog {
    pub name: String,
    pub records: Vec<StepRecord>,
    ema: Ema,
    sink: Option<fs::File>,
    pub sink_path: Option<PathBuf>,
}

impl RunLog {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            records: Vec::new(),
            ema: Ema::new(0.95),
            sink: None,
            sink_path: None,
        }
    }

    /// Also append every record to a JSONL file under `dir`.
    pub fn with_sink(mut self, dir: impl AsRef<Path>) -> Result<Self> {
        fs::create_dir_all(&dir)?;
        let path = dir.as_ref().join(format!("{}.jsonl", self.name));
        let file = fs::File::create(&path).with_context(|| format!("creating {path:?}"))?;
        self.sink = Some(file);
        self.sink_path = Some(path);
        Ok(self)
    }

    pub fn push(&mut self, step: i64, stats: &StepStats, ms: f64) -> Result<()> {
        let rec = StepRecord {
            step,
            loss: stats.loss as f64,
            aux_loss: stats.aux_loss as f64,
            grad_norm: stats.grad_norm as f64,
            cv_per_layer: stats.cv_per_layer(),
            dropped: stats.total_dropped(),
            dropped_per_layer: stats.dropped.iter().map(|&x| x as f64).collect(),
            ms_per_step: ms,
            sim_ms: stats.sim_step_ms,
        };
        self.ema.push(rec.loss);
        if let Some(f) = &mut self.sink {
            let v = obj(vec![
                ("step", num(rec.step as f64)),
                ("loss", num(rec.loss)),
                ("aux_loss", num(rec.aux_loss)),
                ("grad_norm", num(rec.grad_norm)),
                ("cv", arr(rec.cv_per_layer.iter().map(|&x| num(x)).collect())),
                ("dropped", num(rec.dropped)),
                ("ms", num(rec.ms_per_step)),
                ("sim_ms", num(rec.sim_ms)),
            ]);
            writeln!(f, "{}", jwrite(&v))?;
        }
        self.records.push(rec);
        Ok(())
    }

    pub fn ema_loss(&self) -> f64 {
        self.ema.get()
    }

    pub fn last(&self) -> Option<&StepRecord> {
        self.records.last()
    }

    /// Log-perplexity curve as (step, loss) pairs — the paper's y-axis
    /// ("training log perplexity" == mean token NLL).
    pub fn loss_curve(&self) -> Vec<(i64, f64)> {
        self.records.iter().map(|r| (r.step, r.loss)).collect()
    }

    /// Mean loss over the trailing `n` records — convergence-level proxy.
    pub fn tail_loss(&self, n: usize) -> f64 {
        let take = self.records.len().min(n.max(1));
        if take == 0 {
            return f64::NAN;
        }
        let s: f64 = self.records[self.records.len() - take..]
            .iter()
            .map(|r| r.loss)
            .sum();
        s / take as f64
    }

    /// First step whose EMA-smoothed loss dips below `target` — used for
    /// the Fig-6 convergence-speedup factor. None if never reached.
    pub fn steps_to_loss(&self, target: f64) -> Option<i64> {
        let mut ema = Ema::new(0.9);
        for r in &self.records {
            ema.push(r.loss);
            if ema.get() <= target {
                return Some(r.step);
            }
        }
        None
    }

    /// Mean c_v of a layer over the trailing n records.
    pub fn tail_cv(&self, layer: usize, n: usize) -> f64 {
        let take = self.records.len().min(n.max(1));
        if take == 0 {
            return f64::NAN;
        }
        let s: f64 = self.records[self.records.len() - take..]
            .iter()
            .map(|r| r.cv_per_layer.get(layer).copied().unwrap_or(f64::NAN))
            .sum();
        s / take as f64
    }

    /// Summary object for EXPERIMENTS.md.
    pub fn summary(&self) -> Value {
        obj(vec![
            ("name", s(self.name.clone())),
            ("steps", num(self.records.len() as f64)),
            ("final_loss", num(self.tail_loss(20))),
            ("ema_loss", num(self.ema_loss())),
            (
                "mean_ms",
                num({
                    let n = self.records.len().max(1);
                    self.records.iter().map(|r| r.ms_per_step).sum::<f64>() / n as f64
                }),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(loss: f32, layers: usize, experts: usize) -> StepStats {
        StepStats {
            loss,
            aux_loss: 0.1,
            grad_norm: 1.0,
            load: vec![1.0; layers * experts],
            layers,
            experts,
            dropped: vec![0.0; layers],
            sim_step_ms: 0.0,
        }
    }

    #[test]
    fn records_accumulate() {
        let mut log = RunLog::new("t");
        for i in 0..10 {
            log.push(i, &stats(5.0 - i as f32 * 0.1, 2, 4), 100.0).unwrap();
        }
        assert_eq!(log.records.len(), 10);
        assert!(log.tail_loss(3) < 5.0);
        assert_eq!(log.loss_curve().len(), 10);
        assert_eq!(log.last().unwrap().step, 9);
    }

    #[test]
    fn steps_to_loss_finds_crossing() {
        let mut log = RunLog::new("t");
        for i in 0..50 {
            log.push(i, &stats(5.0 - i as f32 * 0.1, 1, 2), 1.0).unwrap();
        }
        let hit = log.steps_to_loss(3.0).unwrap();
        assert!((15..30).contains(&hit), "hit at {hit}");
        assert_eq!(log.steps_to_loss(-1.0), None);
    }

    #[test]
    fn balanced_load_cv_zero() {
        let mut log = RunLog::new("t");
        log.push(0, &stats(1.0, 2, 4), 1.0).unwrap();
        assert_eq!(log.tail_cv(0, 1), 0.0);
        assert_eq!(log.tail_cv(1, 1), 0.0);
    }

    #[test]
    fn jsonl_sink_writes() {
        let dir = std::env::temp_dir().join("m6t-metrics-test");
        let mut log = RunLog::new("sink").with_sink(&dir).unwrap();
        log.push(0, &stats(2.0, 1, 2), 3.0).unwrap();
        let path = log.sink_path.clone().unwrap();
        drop(log);
        let text = fs::read_to_string(path).unwrap();
        assert!(text.contains("\"loss\":2"));
        let _ = fs::remove_dir_all(dir);
    }
}
