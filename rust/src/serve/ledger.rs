//! Per-request latency accounting for the serving simulation.
//!
//! The admission loop records every request's full timeline (arrival,
//! batch launch, completion) plus the per-batch schedule; the
//! [`Ledger::summary`] fold turns those into the tail-latency and SLO
//! fields `BENCH_serve.json` reports. Percentiles come from
//! [`crate::util::stats`]'s interpolated `p50`/`p99`/`p999`, so the p99.9
//! of a 512-request cell is a real interpolated order statistic, not a
//! nearest-rank rounding artifact.

use crate::util::stats::{p50, p99, p999};

/// One served request's timeline, all in simulated milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    /// Index into the arrival trace (admission is FIFO, so ids ascend).
    pub id: usize,
    pub arrival_ms: f64,
    /// When the batch carrying this request launched.
    pub start_ms: f64,
    /// When that batch completed; the whole batch finishes together.
    pub done_ms: f64,
    /// Size of the batch this request rode in.
    pub batch: usize,
}

impl RequestRecord {
    /// Time spent queued before the batch launched.
    pub fn queue_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }

    /// End-to-end latency: queueing plus service.
    pub fn latency_ms(&self) -> f64 {
        self.done_ms - self.arrival_ms
    }
}

/// One engine batch as scheduled by admission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchRecord {
    pub start_ms: f64,
    pub done_ms: f64,
    pub size: usize,
}

/// Everything one simulated run recorded.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub requests: Vec<RequestRecord>,
    pub batches: Vec<BatchRecord>,
}

/// The latency distribution of one run against one SLO.
#[derive(Debug, Clone, Copy)]
pub struct LatencySummary {
    pub requests: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub max_ms: f64,
    pub mean_queue_ms: f64,
    /// Requests per batch: how much continuous batching actually packed.
    pub mean_batch: f64,
    /// Fraction of requests whose end-to-end latency met the SLO.
    pub slo_attainment: f64,
    /// When the last batch drained.
    pub makespan_ms: f64,
}

impl Ledger {
    /// Fold the ledger into its latency summary. Panics on an empty
    /// ledger — a cell with zero requests is a driver bug, not a result.
    pub fn summary(&self, slo_ms: f64) -> LatencySummary {
        assert!(!self.requests.is_empty(), "summary over an empty ledger");
        assert!(slo_ms > 0.0, "the SLO must be positive");
        let lat: Vec<f64> = self.requests.iter().map(RequestRecord::latency_ms).collect();
        let n = lat.len() as f64;
        let within = lat.iter().filter(|&&l| l <= slo_ms).count();
        LatencySummary {
            requests: self.requests.len(),
            p50_ms: p50(&lat),
            p99_ms: p99(&lat),
            p999_ms: p999(&lat),
            max_ms: lat.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean_queue_ms: self.requests.iter().map(RequestRecord::queue_ms).sum::<f64>() / n,
            mean_batch: n / self.batches.len() as f64,
            slo_attainment: within as f64 / n,
            makespan_ms: self.batches.last().map_or(0.0, |b| b.done_ms),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Ledger {
        let mut ledger = Ledger::default();
        // two batches: [0, 1] served 2..5, [2] served 5..9
        for (id, arrival_ms) in [(0usize, 0.0f64), (1, 1.0)] {
            ledger.requests.push(RequestRecord {
                id,
                arrival_ms,
                start_ms: 2.0,
                done_ms: 5.0,
                batch: 2,
            });
        }
        ledger.requests.push(RequestRecord {
            id: 2,
            arrival_ms: 4.0,
            start_ms: 5.0,
            done_ms: 9.0,
            batch: 1,
        });
        ledger.batches.push(BatchRecord { start_ms: 2.0, done_ms: 5.0, size: 2 });
        ledger.batches.push(BatchRecord { start_ms: 5.0, done_ms: 9.0, size: 1 });
        ledger
    }

    #[test]
    fn summary_folds_the_timeline() {
        let sum = sample().summary(5.0);
        assert_eq!(sum.requests, 3);
        // latencies: 5.0, 4.0, 5.0
        assert_eq!(sum.p50_ms, 5.0);
        assert_eq!(sum.max_ms, 5.0);
        assert_eq!(sum.slo_attainment, 1.0);
        assert!((sum.mean_batch - 1.5).abs() < 1e-12);
        assert!((sum.mean_queue_ms - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(sum.makespan_ms, 9.0);
        // a tighter SLO drops the two 5 ms requests
        let tight = sample().summary(4.5);
        assert!((tight.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn record_accessors_decompose_latency() {
        let r = RequestRecord { id: 0, arrival_ms: 1.0, start_ms: 3.0, done_ms: 7.0, batch: 4 };
        assert_eq!(r.queue_ms(), 2.0);
        assert_eq!(r.latency_ms(), 6.0);
    }
}
