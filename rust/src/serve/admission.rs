//! Continuous-batching admission: the policy loop between the arrival
//! trace and the engine.
//!
//! The engine serves one batch at a time (a full sharded step across all
//! D workers is one service unit; data parallelism is folded into the
//! service model, not modelled as independent servers). Admission is
//! FIFO with a classic max-wait / max-batch policy:
//!
//! * a batch launches the moment it would be **full** (`max_batch`
//!   requests have arrived), or
//! * when the **oldest** waiting request has been queued for
//!   `max_wait_ms`, whichever comes first —
//! * but never before the engine is free.
//!
//! Two invariants fall out of the loop shape and are pinned by property
//! tests: no batch exceeds `max_batch`, and no batch starts later than
//! `max(engine_free, oldest_arrival + max_wait_ms)` — a request is never
//! left waiting past its deadline while the engine sits idle.

use crate::serve::ledger::{BatchRecord, Ledger, RequestRecord};

/// The two-knob continuous-batching policy.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Largest admissible batch (the engine's full batch, B x D).
    pub max_batch: usize,
    /// Longest the oldest waiting request may queue before the batch
    /// launches anyway (possibly undersized).
    pub max_wait_ms: f64,
}

/// Run the admission loop over a sorted open-loop arrival trace.
/// `service_ms(size)` prices one batch of `size` requests; the engine is
/// busy for exactly that long. Returns the full per-request and
/// per-batch ledger.
pub fn simulate(
    arrivals: &[f64],
    policy: &AdmissionPolicy,
    mut service_ms: impl FnMut(usize) -> f64,
) -> Ledger {
    assert!(policy.max_batch >= 1, "max_batch must admit at least one request");
    assert!(policy.max_wait_ms >= 0.0, "max_wait_ms must be non-negative");
    debug_assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "arrival trace must be sorted");
    let mut ledger = Ledger::default();
    let mut engine_free = 0.0f64;
    let mut next = 0usize;
    while next < arrivals.len() {
        let oldest = arrivals[next];
        let deadline = oldest + policy.max_wait_ms;
        // the instant the batch would reach max_batch, if the trace gets
        // there; launch at the earlier of "full" and "deadline", once
        // the engine is free
        let full_at = arrivals.get(next + policy.max_batch - 1).copied();
        let target = full_at.map_or(deadline, |f| f.min(deadline));
        let start = engine_free.max(target);
        let mut size = 0usize;
        while size < policy.max_batch
            && next + size < arrivals.len()
            && arrivals[next + size] <= start
        {
            size += 1;
        }
        debug_assert!(size >= 1, "oldest request arrived by construction");
        let busy = service_ms(size);
        assert!(busy >= 0.0 && busy.is_finite(), "service time must be finite");
        let done = start + busy;
        for (slot, &arrival_ms) in arrivals[next..next + size].iter().enumerate() {
            ledger.requests.push(RequestRecord {
                id: next + slot,
                arrival_ms,
                start_ms: start,
                done_ms: done,
                batch: size,
            });
        }
        ledger.batches.push(BatchRecord { start_ms: start, done_ms: done, size });
        engine_free = done;
        next += size;
    }
    ledger
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_request_is_served_in_order() {
        let arrivals = [0.0, 0.1, 0.2, 5.0, 5.1, 20.0];
        let policy = AdmissionPolicy { max_batch: 4, max_wait_ms: 1.0 };
        let ledger = simulate(&arrivals, &policy, |_| 2.0);
        assert_eq!(ledger.requests.len(), arrivals.len());
        let ids: Vec<usize> = ledger.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert!(ledger.requests.iter().all(|r| r.arrival_ms <= r.start_ms));
        assert!(ledger.requests.iter().all(|r| r.done_ms > r.start_ms));
        assert!(ledger.batches.windows(2).all(|w| w[0].done_ms <= w[1].start_ms));
    }

    #[test]
    fn a_full_backlog_launches_immediately_at_max_batch() {
        // everyone arrives at t=0; full batches launch back to back the
        // moment the engine frees up, never waiting out max_wait. The
        // final *partial* batch can never fill, so the online policy
        // holds it until the oldest request's deadline — the server has
        // no way to know the trace ended.
        let arrivals = [0.0; 10];
        let policy = AdmissionPolicy { max_batch: 4, max_wait_ms: 100.0 };
        let ledger = simulate(&arrivals, &policy, |_| 3.0);
        let sizes: Vec<usize> = ledger.batches.iter().map(|b| b.size).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(ledger.batches[0].start_ms, 0.0);
        assert_eq!(ledger.batches[1].start_ms, 3.0);
        assert_eq!(ledger.batches[2].start_ms, 100.0, "partial tail waits for its deadline");
    }

    #[test]
    fn a_lone_request_waits_out_max_wait_not_forever() {
        let arrivals = [1.0];
        let policy = AdmissionPolicy { max_batch: 8, max_wait_ms: 2.5 };
        let ledger = simulate(&arrivals, &policy, |_| 1.0);
        assert_eq!(ledger.batches.len(), 1);
        assert_eq!(ledger.batches[0].start_ms, 3.5, "launches at oldest + max_wait");
        assert_eq!(ledger.requests[0].latency_ms(), 3.5);
    }

    #[test]
    fn zero_wait_degrades_to_run_whatever_arrived() {
        let arrivals = [0.0, 0.0, 4.0];
        let policy = AdmissionPolicy { max_batch: 8, max_wait_ms: 0.0 };
        let ledger = simulate(&arrivals, &policy, |_| 1.0);
        let sizes: Vec<usize> = ledger.batches.iter().map(|b| b.size).collect();
        assert_eq!(sizes, vec![2, 1]);
    }
}
