//! Serving runtime: open-loop traffic simulation over the sharded
//! engine.
//!
//! Training benches ask "how fast is a step"; serving asks "what latency
//! does a *request* see when steps are shared". This module answers the
//! second question without any new measurement machinery: a seeded
//! open-loop arrival generator ([`arrivals`]) feeds a continuous-batching
//! admission loop ([`admission`]), every request's timeline lands in a
//! [`ledger::Ledger`], and the per-batch service time comes from the same
//! overlap-aware cluster model the training side prices steps with — a
//! [`crate::cluster::StepInputs`] run over traffic profiled from a few
//! real [`crate::runtime::ShardedRun`] steps ([`bench::ServiceModel`]).
//!
//! Everything downstream of the profiled traffic is a pure function of
//! the cell params, so `BENCH_serve.json` is seed-pinned: same seed, same
//! rows, bit for bit, regardless of host speed or thread-pool size. The
//! grid itself ([`bench::spec`]) runs as the `serve` kind of the sweep
//! engine, so cells cache content-addressed like every other bench.
//!
//! See DESIGN.md §"Serving runtime & open-loop simulation".

pub mod admission;
pub mod arrivals;
pub mod bench;
pub mod ledger;

pub use admission::AdmissionPolicy;
pub use arrivals::{ArrivalMode, ArrivalSpec};
pub use bench::{ServeBenchRow, ServiceModel};
pub use ledger::{LatencySummary, Ledger};
