//! Seeded open-loop arrival generation.
//!
//! Open-loop means arrivals do not react to the server: the trace is a
//! pure function of the [`ArrivalSpec`] (mode, rate, count, seed), fixed
//! before the admission loop ever sees it. That is the property the
//! serve bench leans on for determinism — and it is what makes overload
//! visible at all, since a closed-loop client would politely slow down
//! instead of letting the queue grow.
//!
//! Three traffic shapes, all normalized so the *time-averaged* rate is
//! exactly `rate_per_ms`:
//!
//! * [`ArrivalMode::Poisson`] — memoryless gaps, the queueing-theory
//!   baseline;
//! * [`ArrivalMode::Bursty`] — a piecewise-constant on/off cycle (a long
//!   calm phase at half rate, a short burst at 3x), drawn *exactly* by
//!   integrating the exponential clock through the phases rather than by
//!   approximation, so the trace stays deterministic and unbiased;
//! * [`ArrivalMode::Diurnal`] — a sinusoidally modulated rate drawn by
//!   thinning against the peak rate, the standard exact sampler for an
//!   inhomogeneous Poisson process.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Stream tag folded into the arrival RNG so the trace decorrelates from
/// every other consumer of the same cell seed (profiling, batching).
const ARRIVAL_STREAM: u64 = 0xA221_7A15_0F5E_11ED;

/// Bursty cycle, in units of mean inter-arrival times (1/rate): 60 calm
/// at 0.5x, then 15 burst at 3.0x. Time average: (0.5*60 + 3.0*15) / 75
/// = 1.0, so the offered load is mode-independent.
const BURSTY_CALM_LEN: f64 = 60.0;
const BURSTY_BURST_LEN: f64 = 15.0;
const BURSTY_CALM_RATE: f64 = 0.5;
const BURSTY_BURST_RATE: f64 = 3.0;

/// Diurnal sinusoid: rate(t) = rate * (1 + 0.6 sin(2 pi t / period)),
/// period = 200 mean inter-arrival times. Averages to `rate` over whole
/// periods.
const DIURNAL_AMPLITUDE: f64 = 0.6;
const DIURNAL_PERIOD: f64 = 200.0;

/// The arrival-process family of a serve cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalMode {
    Poisson,
    Bursty,
    Diurnal,
}

impl ArrivalMode {
    pub fn parse(text: &str) -> Result<ArrivalMode> {
        match text {
            "poisson" => Ok(ArrivalMode::Poisson),
            "bursty" => Ok(ArrivalMode::Bursty),
            "diurnal" => Ok(ArrivalMode::Diurnal),
            other => bail!("unknown arrival mode {other:?} (poisson, bursty, diurnal)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ArrivalMode::Poisson => "poisson",
            ArrivalMode::Bursty => "bursty",
            ArrivalMode::Diurnal => "diurnal",
        }
    }

    pub fn all() -> [ArrivalMode; 3] {
        [ArrivalMode::Poisson, ArrivalMode::Bursty, ArrivalMode::Diurnal]
    }

    fn stream_tag(self) -> u64 {
        match self {
            ArrivalMode::Poisson => 1,
            ArrivalMode::Bursty => 2,
            ArrivalMode::Diurnal => 3,
        }
    }
}

/// Everything the trace depends on. Same spec, same trace — bit for bit.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalSpec {
    pub mode: ArrivalMode,
    /// Time-averaged offered rate, requests per millisecond.
    pub rate_per_ms: f64,
    /// Trace length in requests.
    pub requests: usize,
    pub seed: u64,
}

/// One Exp(1) draw; `uniform` is in [0, 1) so the log argument stays in
/// (0, 1] and the draw is finite and non-negative.
fn exp_draw(rng: &mut Rng) -> f64 {
    -(1.0 - rng.uniform()).ln()
}

/// Generate the arrival trace: `requests` non-decreasing timestamps in
/// milliseconds starting after t = 0.
pub fn generate(spec: &ArrivalSpec) -> Vec<f64> {
    assert!(spec.rate_per_ms > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(spec.seed).fold_in(ARRIVAL_STREAM ^ spec.mode.stream_tag());
    let rate = spec.rate_per_ms;
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.requests);
    match spec.mode {
        ArrivalMode::Poisson => {
            for _ in 0..spec.requests {
                t += exp_draw(&mut rng) / rate;
                out.push(t);
            }
        }
        ArrivalMode::Bursty => {
            let calm_len = BURSTY_CALM_LEN / rate;
            let cycle = (BURSTY_CALM_LEN + BURSTY_BURST_LEN) / rate;
            for _ in 0..spec.requests {
                // spend one unit-rate exponential clock across the
                // piecewise-constant phases: within a phase the clock
                // burns at `phase_rate`, so crossing a boundary carries
                // the remainder over exactly
                let mut w = exp_draw(&mut rng);
                loop {
                    let pos = t - (t / cycle).floor() * cycle;
                    let (phase_rate, room) = if pos < calm_len {
                        (BURSTY_CALM_RATE * rate, calm_len - pos)
                    } else {
                        (BURSTY_BURST_RATE * rate, cycle - pos)
                    };
                    if w <= phase_rate * room {
                        t += w / phase_rate;
                        break;
                    }
                    w -= phase_rate * room;
                    t += room;
                }
                out.push(t);
            }
        }
        ArrivalMode::Diurnal => {
            let period = DIURNAL_PERIOD / rate;
            let peak = rate * (1.0 + DIURNAL_AMPLITUDE);
            for _ in 0..spec.requests {
                // thinning: draw from the homogeneous peak-rate process,
                // keep each candidate with probability rate(t) / peak
                loop {
                    t += exp_draw(&mut rng) / peak;
                    let instant = rate
                        * (1.0
                            + DIURNAL_AMPLITUDE * (std::f64::consts::TAU * t / period).sin());
                    if rng.uniform() * peak <= instant {
                        break;
                    }
                }
                out.push(t);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(mode: ArrivalMode, seed: u64) -> ArrivalSpec {
        ArrivalSpec { mode, rate_per_ms: 0.25, requests: 6000, seed }
    }

    #[test]
    fn same_seed_same_trace_bitwise() {
        for mode in ArrivalMode::all() {
            let a = generate(&spec(mode, 7));
            let b = generate(&spec(mode, 7));
            assert_eq!(a.len(), 6000);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} trace not deterministic", mode.name());
            }
            let c = generate(&spec(mode, 8));
            assert_ne!(a, c, "{}: different seeds must differ", mode.name());
        }
    }

    #[test]
    fn traces_are_nonnegative_and_sorted() {
        for mode in ArrivalMode::all() {
            let xs = generate(&spec(mode, 3));
            assert!(xs[0] >= 0.0);
            assert!(xs.windows(2).all(|w| w[0] <= w[1]), "{} trace unsorted", mode.name());
        }
    }

    #[test]
    fn every_mode_averages_to_the_offered_rate() {
        // the normalization constants exist so "load" means the same
        // thing in every mode: mean rate within 5% over 6000 arrivals
        for mode in ArrivalMode::all() {
            let s = spec(mode, 11);
            let xs = generate(&s);
            let measured = xs.len() as f64 / xs.last().unwrap();
            let err = (measured - s.rate_per_ms).abs() / s.rate_per_ms;
            assert!(err < 0.05, "{}: mean rate {measured} vs {} (err {err})", mode.name(),
                s.rate_per_ms);
        }
    }

    #[test]
    fn bursty_is_actually_burstier_than_poisson() {
        // squared coefficient of variation of the inter-arrival gaps:
        // exactly 1 for Poisson, well above 1 for the on/off cycle
        let cv2 = |xs: &[f64]| {
            let gaps: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
            let n = gaps.len() as f64;
            let mean = gaps.iter().sum::<f64>() / n;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
            var / (mean * mean)
        };
        let poisson = cv2(&generate(&spec(ArrivalMode::Poisson, 5)));
        let bursty = cv2(&generate(&spec(ArrivalMode::Bursty, 5)));
        assert!((poisson - 1.0).abs() < 0.15, "poisson cv^2 {poisson} should be ~1");
        assert!(bursty > poisson * 1.3, "bursty cv^2 {bursty} vs poisson {poisson}");
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in ArrivalMode::all() {
            assert_eq!(ArrivalMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(ArrivalMode::parse("uniform").is_err());
    }
}
