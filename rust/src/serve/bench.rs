//! Serve bench: open-loop traffic over the sharded engine, swept over
//! {poisson, bursty, diurnal} x D in {1, 4, 8} x offered load x
//! hot-expert skew x worker drain — 108 cells, driven through the sweep
//! engine's content-addressed store as the `serve` kind.
//!
//! Shared by `m6t serve-sim` (and the CI smoke + regression gate);
//! writes the tracked trajectory `BENCH_serve.json`.
//!
//! Each cell builds a [`ServiceModel`] by profiling a few real
//! [`ShardedRun`] steps (routing, all-to-all bytes, per-layer link
//! bottlenecks — the exact traffic the training-side overlap model
//! prices), then replays a seeded arrival trace through the
//! continuous-batching admission loop, pricing every batch size with a
//! [`StepInputs`] run over that profiled traffic. Skew and drain are
//! *axes of the same harness*, not separate tools: skew stretches the
//! straggler shard the way correlated prompts concentrate hot experts,
//! drain removes workers from the denominator the way a draining host
//! concentrates traffic on the survivors.
//!
//! Every row is a pure function of its cell params — no wall-clock
//! numbers ride along — so the JSON is seed-pinned bit for bit across
//! hosts and thread-pool sizes.
//!
//! The two gated regression fields (over the `gate` rows: poisson, no
//! skew, no drain, load <= 0.7 — the regime the policy must handle):
//!  * `max_p99_over_slo` — worst p99 / SLO; the CI floor keeps it < 1.0;
//!  * `min_goodput_share` — worst SLO attainment; floored at >= 0.9.

use std::sync::Arc;

use anyhow::{bail, ensure, Context as _, Result};

use crate::cluster::topology::layer_bottleneck_seconds;
use crate::cluster::{table2_hardware, ObservedTraffic, StepInputs};
use crate::config::ModelConfig;
use crate::data::{Batch, Batcher, Split};
use crate::runtime::native::registry;
use crate::runtime::shard::ShardedRun;
use crate::serve::admission::{self, AdmissionPolicy};
use crate::serve::arrivals::{self, ArrivalMode, ArrivalSpec};
use crate::sweep::{self, Cell, Engine, ParamValue, SweepOutcome, SweepSpec};
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::pool::WorkerPool;
use crate::util::table::{f2, Table};

/// Code-relevant version tag in every serve cell's store address.
pub const STORE_VERSION: &str = "serve-v1";

/// SLO as a multiple of the full-batch service time: generous enough
/// that a healthy cell clears it with one queued batch in flight, tight
/// enough that overload (load > 1) visibly blows through it.
pub const SLO_FACTOR: f64 = 6.0;

/// Arrival-trace length per cell — long enough for a stable p99 and for
/// overload to actually back the queue up.
pub const REQUESTS_PER_CELL: usize = 512;

/// Gate rows are the cells the CI floors apply to: poisson arrivals, no
/// skew, no drain, offered load at or below this — the regime where the
/// admission policy has no excuse.
pub const GATE_MAX_LOAD: f64 = 0.7;

/// The benched geometry (the E = 16 sim twin every other bench anchors
/// on).
const GEOMETRY: &str = "base-sim";

/// The benched grid as a declarative spec: 3 arrival modes x D in
/// {1, 4, 8} x load in {0.55, 0.9, 1.25} x skew in {0, 0.6} x drain in
/// {0, 1} — 108 cells, last axis fastest.
pub fn spec(steps: usize) -> SweepSpec {
    SweepSpec::new("serve", "serve")
        .steps(steps)
        .fix("model", ParamValue::Str(GEOMETRY.to_string()))
        .fix("requests", ParamValue::Num(REQUESTS_PER_CELL as f64))
        .axis("mode", sweep::strs(&["poisson", "bursty", "diurnal"]))
        .axis("workers", sweep::nums(&[1, 4, 8]))
        .axis("load", vec![ParamValue::Num(0.55), ParamValue::Num(0.9), ParamValue::Num(1.25)])
        .axis("skew", vec![ParamValue::Num(0.0), ParamValue::Num(0.6)])
        .axis("drain", sweep::nums(&[0, 1]))
}

/// Parsed serve cell.
struct ServeCellParams {
    cfg: ModelConfig,
    mode: ArrivalMode,
    workers: usize,
    load: f64,
    skew: f64,
    drain: usize,
    requests: usize,
    steps: usize,
    seed: u64,
}

fn cell_params(cell: &Cell) -> Result<ServeCellParams> {
    let name = cell.req_str("model")?;
    let Some(cfg) = registry().into_iter().find(|c| c.name == name) else {
        bail!("serve cell: unknown model {name:?}");
    };
    let mode = ArrivalMode::parse(cell.req_str("mode")?)?;
    let workers = cell.req_usize("workers")?;
    ensure!(workers >= 1, "serve cell: workers must be >= 1");
    let load = cell.req_f64("load")?;
    ensure!(load > 0.0 && load.is_finite(), "serve cell: load must be positive, got {load}");
    let skew = cell.req_f64("skew")?;
    ensure!(skew >= 0.0, "serve cell: skew must be non-negative, got {skew}");
    let drain = cell.req_usize("drain")?;
    ensure!(drain < workers.max(2), "serve cell: drain {drain} leaves no worker at D={workers}");
    let requests = cell.req_usize("requests")?;
    ensure!(requests >= 1, "serve cell: requests must be >= 1");
    let steps = cell.req_usize("steps")?.max(1);
    let seed = cell.req_u64("seed")?;
    Ok(ServeCellParams { cfg, mode, workers, load, skew, drain, requests, steps, seed })
}

/// Fold the fully-resolved model config into the cell before hashing.
pub fn resolve_cell(cell: &Cell) -> Result<Cell> {
    let p = cell_params(cell)?;
    let mut resolved = cell.clone();
    resolved.merge(&sweep::config_cell(&p.cfg));
    Ok(resolved)
}

/// Batch-size -> service-time model for one (geometry, D, skew, drain)
/// point, profiled once per cell and then consulted by the admission
/// loop as a pure lookup.
///
/// Requests pack `ceil(n / D)` per worker (data parallel), so service
/// time is piecewise constant in the request count; each per-worker
/// batch size is priced by a [`StepInputs`] run with the profiled
/// traffic scaled to that batch fraction.
#[derive(Debug, Clone)]
pub struct ServiceModel {
    workers: usize,
    batch_per_worker: usize,
    per_worker_ms: Vec<f64>,
}

impl ServiceModel {
    /// Largest batch one engine step absorbs: per-worker batch x D.
    pub fn full_batch(&self) -> usize {
        self.workers * self.batch_per_worker
    }

    /// The per-worker-batch-size pricing table (index = batch - 1); the
    /// determinism tests pin these bits across thread-pool sizes.
    pub fn per_worker_ms(&self) -> &[f64] {
        &self.per_worker_ms
    }

    /// Service time of one batch of `requests` requests, milliseconds.
    pub fn ms(&self, requests: usize) -> f64 {
        assert!(requests >= 1, "service time of an empty batch");
        let per_worker = requests.div_ceil(self.workers).min(self.batch_per_worker);
        self.per_worker_ms[per_worker - 1]
    }
}

/// Profile the engine and build the cell's [`ServiceModel`]: run `steps`
/// real sharded steps, take the final step's dispatch accounting and
/// per-layer link bottlenecks (the same matrices `runtime::shard` prices
/// for the training-side overlap model), fold in skew and drain, and
/// price every per-worker batch size through [`StepInputs`].
///
/// `pool` threads an explicit worker pool through (tests use it to pin
/// the pricing table bitwise across pool sizes); `None` uses the global.
pub fn profile(
    cfg: &ModelConfig,
    workers: usize,
    steps: usize,
    seed: u64,
    skew: f64,
    drain: usize,
    pool: Option<Arc<WorkerPool>>,
) -> Result<ServiceModel> {
    ensure!(workers >= 1, "serve profile needs at least one worker");
    let run = match pool {
        Some(p) => ShardedRun::with_pool(cfg, workers, p)?,
        None => ShardedRun::new(cfg, workers)?,
    };
    let hw = table2_hardware();
    let topo = run.topology();
    let d = workers;
    let mut state = run.init_state(seed)?;
    let mut batcher = Batcher::for_config(cfg, Split::Train, seed);
    let mut observed = ObservedTraffic { a2a_bytes_per_layer: 0.0, shard_balance: 1.0 };
    let mut plans_last = Vec::new();
    for _ in 0..steps.max(1) {
        let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
        let (next, stats, plans) = run.step_detailed(state, &batches)?;
        state = next;
        let dsp =
            stats.dispatch.as_ref().context("sharded step must carry dispatch accounting")?;
        observed = ObservedTraffic {
            a2a_bytes_per_layer: dsp.a2a_bytes_per_layer,
            shard_balance: dsp.shard_balance,
        };
        plans_last = plans;
    }
    let mut layer_comm_ms = Vec::with_capacity(plans_last.len());
    let mut link = vec![0u64; d * d];
    for plan in &plans_last {
        link.fill(0);
        plan.add_bytes_matrix_into(&mut link);
        layer_comm_ms.push(layer_bottleneck_seconds(&link, &topo, &hw) * 1e3);
    }
    let run_cfg = run.info().config.clone();
    ensure!(
        layer_comm_ms.len() == run_cfg.layers,
        "profiled {} layer plans for a {}-layer config",
        layer_comm_ms.len(),
        run_cfg.layers
    );
    // a draining worker concentrates the survivors' compute and traffic;
    // hot-expert skew from correlated prompts stretches the straggler
    // shard beyond what the profiled batch showed
    let drained = drain.min(d - 1);
    let drain_stretch = d as f64 / (d - drained) as f64;
    let mut per_worker_ms = Vec::with_capacity(run_cfg.batch);
    for per_worker in 1..=run_cfg.batch {
        let frac = per_worker as f64 / run_cfg.batch as f64;
        let mut cfg_b = run_cfg.clone();
        cfg_b.batch = per_worker;
        let obs_b = ObservedTraffic {
            a2a_bytes_per_layer: observed.a2a_bytes_per_layer * frac * drain_stretch,
            shard_balance: observed.shard_balance * (1.0 + skew) * drain_stretch,
        };
        let comm_b: Vec<f64> =
            layer_comm_ms.iter().map(|ms| ms * frac * drain_stretch).collect();
        let priced = StepInputs::new(&cfg_b, &hw).observed(&obs_b).layer_comm_ms(&comm_b).run();
        let ms = priced.step_ms();
        ensure!(ms > 0.0 && ms.is_finite(), "service model priced batch {per_worker} at {ms}");
        per_worker_ms.push(ms);
    }
    Ok(ServiceModel { workers: d, batch_per_worker: run_cfg.batch, per_worker_ms })
}

/// One measured (mode, D, load, skew, drain) cell.
#[derive(Debug, Clone)]
pub struct ServeBenchRow {
    pub model: String,
    pub mode: String,
    pub workers: usize,
    /// Offered load as a fraction of full-batch engine capacity.
    pub load: f64,
    pub skew: f64,
    pub drain: usize,
    pub requests: usize,
    /// Engine full batch (per-worker batch x D) = admission max_batch.
    pub max_batch: usize,
    pub service_full_ms: f64,
    pub max_wait_ms: f64,
    pub slo_ms: f64,
    /// Offered requests per second.
    pub offered_rps: f64,
    /// Offered rate x SLO attainment — the goodput-vs-offered-load curve.
    pub goodput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_batch: f64,
    pub slo_attainment: f64,
    /// Whether the CI floors apply to this row.
    pub gate: bool,
}

impl ServeBenchRow {
    /// p99 latency as a multiple of the SLO — the per-row regression
    /// field the CI gate ceilings at 1.0 over the gate rows.
    pub fn p99_over_slo(&self) -> f64 {
        self.p99_ms / self.slo_ms
    }
}

/// Execute one cell end to end. `pool` is the test hook for pinning rows
/// bitwise across thread-pool sizes; the runner passes `None`.
pub fn compute_row(cell: &Cell, pool: Option<Arc<WorkerPool>>) -> Result<ServeBenchRow> {
    let p = cell_params(cell)?;
    let service = profile(&p.cfg, p.workers, p.steps, p.seed, p.skew, p.drain, pool)?;
    let full = service.full_batch();
    let service_full_ms = service.ms(full);
    let slo_ms = SLO_FACTOR * service_full_ms;
    let max_wait_ms = service_full_ms;
    let rate_per_ms = p.load * full as f64 / service_full_ms;
    let trace = arrivals::generate(&ArrivalSpec {
        mode: p.mode,
        rate_per_ms,
        requests: p.requests,
        seed: p.seed,
    });
    let policy = AdmissionPolicy { max_batch: full, max_wait_ms };
    let ledger = admission::simulate(&trace, &policy, |b| service.ms(b));
    ensure!(
        ledger.requests.len() == p.requests,
        "admission served {} of {} requests",
        ledger.requests.len(),
        p.requests
    );
    let sum = ledger.summary(slo_ms);
    ensure!(
        sum.p50_ms <= sum.p99_ms && sum.p99_ms <= sum.p999_ms,
        "percentiles must be monotone: p50 {} p99 {} p99.9 {}",
        sum.p50_ms,
        sum.p99_ms,
        sum.p999_ms
    );
    let gate =
        p.mode == ArrivalMode::Poisson && p.skew == 0.0 && p.drain == 0 && p.load <= GATE_MAX_LOAD;
    let offered_rps = rate_per_ms * 1e3;
    Ok(ServeBenchRow {
        model: p.cfg.name.clone(),
        mode: p.mode.name().to_string(),
        workers: p.workers,
        load: p.load,
        skew: p.skew,
        drain: p.drain,
        requests: p.requests,
        max_batch: full,
        service_full_ms,
        max_wait_ms,
        slo_ms,
        offered_rps,
        goodput_rps: offered_rps * sum.slo_attainment,
        p50_ms: sum.p50_ms,
        p99_ms: sum.p99_ms,
        p999_ms: sum.p999_ms,
        mean_queue_ms: sum.mean_queue_ms,
        mean_batch: sum.mean_batch,
        slo_attainment: sum.slo_attainment,
        gate,
    })
}

/// The sweep executor's entry point for one cell.
pub fn run_cell(cell: &Cell) -> Result<Value> {
    let row = compute_row(cell, None)?;
    eprintln!(
        "[bench] serve {} D={} load {:.2} skew {:.1} drain {}: p50 {:.1} / p99 {:.1} / p99.9 {:.1} ms (SLO {:.1}, attain {:.2}, batch {:.1})",
        row.mode,
        row.workers,
        row.load,
        row.skew,
        row.drain,
        row.p50_ms,
        row.p99_ms,
        row.p999_ms,
        row.slo_ms,
        row.slo_attainment,
        row.mean_batch
    );
    Ok(row_json(&row))
}

/// Run the full grid through the sweep engine; previously-completed
/// cells come back from the store.
pub fn run_suite(engine: &Engine, steps: usize) -> Result<(Vec<ServeBenchRow>, SweepOutcome)> {
    let outcome = engine.run_spec(&spec(steps), &sweep::ServeRunner)?;
    let rows = rows_from(&outcome)?;
    Ok((rows, outcome))
}

/// Rebuild the typed rows from a sweep outcome's stored documents.
pub fn rows_from(outcome: &SweepOutcome) -> Result<Vec<ServeBenchRow>> {
    outcome.outcomes.iter().map(|o| row_from_json(&o.result)).collect()
}

/// Worst p99 / SLO over the gate rows — the CI gate ceilings this below
/// 1.0. A huge failing value when no gate rows exist, so an empty or
/// gate-less JSON fails the gate instead of passing it.
pub fn max_p99_over_slo(rows: &[ServeBenchRow]) -> f64 {
    let max = rows
        .iter()
        .filter(|r| r.gate)
        .map(ServeBenchRow::p99_over_slo)
        .fold(f64::NEG_INFINITY, f64::max);
    if max.is_finite() {
        max
    } else {
        1e9
    }
}

/// Worst SLO attainment over the gate rows — the CI gate floors this at
/// 0.9. 0 when no gate rows exist, failing the floor.
pub fn min_goodput_share(rows: &[ServeBenchRow]) -> f64 {
    let min =
        rows.iter().filter(|r| r.gate).map(|r| r.slo_attainment).fold(f64::INFINITY, f64::min);
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Human-readable table over the suite.
pub fn render_table(rows: &[ServeBenchRow], steps: usize) -> Table {
    let mut t = Table::new(
        format!(
            "open-loop serving over the sharded engine, {steps} profile steps/cell, SLO = {SLO_FACTOR}x full-batch service"
        ),
        &[
            "mode", "D", "load", "skew", "drain", "batch", "svc ms", "p50", "p99", "p99.9",
            "attain", "goodput/s", "gate",
        ],
    );
    for r in rows {
        t.row(vec![
            r.mode.clone(),
            r.workers.to_string(),
            f2(r.load),
            f2(r.skew),
            r.drain.to_string(),
            f2(r.mean_batch),
            f2(r.service_full_ms),
            f2(r.p50_ms),
            f2(r.p99_ms),
            f2(r.p999_ms),
            f2(r.slo_attainment),
            f2(r.goodput_rps),
            if r.gate { "*".to_string() } else { String::new() },
        ]);
    }
    t
}

/// One row as its stored (and emitted) JSON object.
fn row_json(r: &ServeBenchRow) -> Value {
    obj(vec![
        ("model", s(r.model.clone())),
        ("mode", s(r.mode.clone())),
        ("workers", num(r.workers as f64)),
        ("load", num(r.load)),
        ("skew", num(r.skew)),
        ("drain", num(r.drain as f64)),
        ("requests", num(r.requests as f64)),
        ("max_batch", num(r.max_batch as f64)),
        ("service_full_ms", num(r.service_full_ms)),
        ("max_wait_ms", num(r.max_wait_ms)),
        ("slo_ms", num(r.slo_ms)),
        ("offered_rps", num(r.offered_rps)),
        ("goodput_rps", num(r.goodput_rps)),
        ("p50_ms", num(r.p50_ms)),
        ("p99_ms", num(r.p99_ms)),
        ("p999_ms", num(r.p999_ms)),
        ("p99_over_slo", num(r.p99_over_slo())),
        ("mean_queue_ms", num(r.mean_queue_ms)),
        ("mean_batch", num(r.mean_batch)),
        ("slo_attainment", num(r.slo_attainment)),
        ("gate", Value::Bool(r.gate)),
    ])
}

/// Inverse of `row_json`, for rows recalled from the store.
pub fn row_from_json(v: &Value) -> Result<ServeBenchRow> {
    let gate = match v.get("gate") {
        Some(Value::Bool(b)) => *b,
        other => bail!("serve row: \"gate\" must be a bool, got {other:?}"),
    };
    Ok(ServeBenchRow {
        model: v.req_str("model")?.to_string(),
        mode: v.req_str("mode")?.to_string(),
        workers: v.req_usize("workers")?,
        load: v.req_f64("load")?,
        skew: v.req_f64("skew")?,
        drain: v.req_usize("drain")?,
        requests: v.req_usize("requests")?,
        max_batch: v.req_usize("max_batch")?,
        service_full_ms: v.req_f64("service_full_ms")?,
        max_wait_ms: v.req_f64("max_wait_ms")?,
        slo_ms: v.req_f64("slo_ms")?,
        offered_rps: v.req_f64("offered_rps")?,
        goodput_rps: v.req_f64("goodput_rps")?,
        p50_ms: v.req_f64("p50_ms")?,
        p99_ms: v.req_f64("p99_ms")?,
        p999_ms: v.req_f64("p999_ms")?,
        mean_queue_ms: v.req_f64("mean_queue_ms")?,
        mean_batch: v.req_f64("mean_batch")?,
        slo_attainment: v.req_f64("slo_attainment")?,
        gate,
    })
}

/// Serialize the suite to the tracked trajectory JSON.
pub fn to_json(rows: &[ServeBenchRow], steps: usize) -> Value {
    let items: Vec<Value> = rows.iter().map(row_json).collect();
    let gate_rows = rows.iter().filter(|r| r.gate).count();
    obj(vec![
        ("bench", s("serve")),
        ("steps_per_cell", num(steps as f64)),
        ("slo_factor", num(SLO_FACTOR)),
        ("requests_per_cell", num(REQUESTS_PER_CELL as f64)),
        ("gate_rows", num(gate_rows as f64)),
        ("max_p99_over_slo", num(max_p99_over_slo(rows))),
        ("min_goodput_share", num(min_goodput_share(rows))),
        ("rows", arr(items)),
    ])
}

/// Write `BENCH_serve.json` (or wherever `path` points).
pub fn write_json(rows: &[ServeBenchRow], steps: usize, path: &str) -> Result<()> {
    let text = json_write(&to_json(rows, steps)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_issue_matrix() {
        let cells = spec(2).expand().unwrap();
        assert_eq!(cells.len(), 108, "3 modes x 3 D x 3 loads x 2 skews x 2 drains");
        let mut keys = std::collections::BTreeSet::new();
        for cell in &cells {
            let p = cell_params(cell).unwrap();
            assert_eq!(p.cfg.name, GEOMETRY);
            assert_eq!(p.requests, REQUESTS_PER_CELL);
            let resolved = resolve_cell(cell).unwrap();
            assert!(resolved.req_str("cfg.name").is_ok(), "config fingerprint folded in");
            assert!(keys.insert(resolved.canonical()), "duplicate serve cell address");
        }
        // the acceptance matrix: {poisson, bursty} x D in {1, 4, 8}
        for mode in ["poisson", "bursty"] {
            for d in [1usize, 4, 8] {
                assert!(
                    cells.iter().any(|c| c.req_str("mode").unwrap() == mode
                        && c.req_usize("workers").unwrap() == d),
                    "grid missing {mode} at D={d}"
                );
            }
        }
    }

    #[test]
    fn gate_rows_are_the_calm_poisson_cells() {
        let cells = spec(2).expand().unwrap();
        let gated = cells
            .iter()
            .filter(|c| {
                c.req_str("mode").unwrap() == "poisson"
                    && c.req_f64("skew").unwrap() == 0.0
                    && c.req_usize("drain").unwrap() == 0
                    && c.req_f64("load").unwrap() <= GATE_MAX_LOAD
            })
            .count();
        assert_eq!(gated, 3, "one gate cell per D");
    }

    fn sample_row(gate: bool) -> ServeBenchRow {
        ServeBenchRow {
            model: "base-sim".into(),
            mode: "poisson".into(),
            workers: 4,
            load: 0.55,
            skew: 0.0,
            drain: 0,
            requests: 512,
            max_batch: 32,
            service_full_ms: 100.0,
            max_wait_ms: 100.0,
            slo_ms: 600.0,
            offered_rps: 176.0,
            goodput_rps: 176.0,
            p50_ms: 150.0,
            p99_ms: 240.0,
            p999_ms: 260.0,
            mean_queue_ms: 90.0,
            mean_batch: 17.6,
            slo_attainment: 1.0,
            gate,
        }
    }

    #[test]
    fn rows_round_trip_through_the_store_document() {
        for gate in [true, false] {
            let row = sample_row(gate);
            let back = row_from_json(&row_json(&row)).unwrap();
            assert_eq!(format!("{back:?}"), format!("{row:?}"));
        }
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![sample_row(true)];
        let v = to_json(&rows, 2);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("serve"));
        assert_eq!(v.get("slo_factor").and_then(|x| x.as_f64()), Some(SLO_FACTOR));
        assert_eq!(v.get("gate_rows").and_then(|x| x.as_f64()), Some(1.0));
        assert_eq!(v.get("max_p99_over_slo").and_then(|x| x.as_f64()), Some(0.4));
        assert_eq!(v.get("min_goodput_share").and_then(|x| x.as_f64()), Some(1.0));
        let items = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(items[0].get("p99_over_slo").and_then(|x| x.as_f64()), Some(0.4));
        assert_eq!(items[0].get("gate").and_then(|x| x.as_bool()), Some(true));
    }

    #[test]
    fn empty_or_gateless_suites_fail_the_gate() {
        assert!(max_p99_over_slo(&[]) >= 1.0, "empty suite must fail the p99 ceiling");
        assert_eq!(min_goodput_share(&[]), 0.0, "empty suite must fail the goodput floor");
        // rows exist but none are gated: same failure, the floors can
        // never silently pass on a grid that dropped its gate cells
        let ungated = vec![sample_row(false)];
        assert!(max_p99_over_slo(&ungated) >= 1.0);
        assert_eq!(min_goodput_share(&ungated), 0.0);
    }

    #[test]
    fn service_model_lookup_clamps_and_packs() {
        let m = ServiceModel {
            workers: 4,
            batch_per_worker: 2,
            per_worker_ms: vec![10.0, 16.0],
        };
        assert_eq!(m.full_batch(), 8);
        assert_eq!(m.ms(1), 10.0, "one request packs one per worker");
        assert_eq!(m.ms(4), 10.0);
        assert_eq!(m.ms(5), 16.0, "fifth request spills to a second row");
        assert_eq!(m.ms(8), 16.0);
        assert_eq!(m.ms(100), 16.0, "oversized asks clamp to the full batch");
        assert_eq!(m.per_worker_ms().len(), 2);
    }
}
