//! Scaling-law machinery for Figs 5/6: fit saturating power laws
//! L(s) = L_inf + a * s^(-b) to measured small-scale loss curves, model
//! the parameter-count effect across our scale twins, and extrapolate the
//! giant-model curves the paper trained on 480 GPUs (DESIGN.md §2).

use crate::util::stats::linear_fit;

/// L(s) = l_inf + a * s^(-b), s = training step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLaw {
    pub l_inf: f64,
    pub a: f64,
    pub b: f64,
}

impl PowerLaw {
    pub fn predict(&self, step: f64) -> f64 {
        self.l_inf + self.a * step.max(1.0).powf(-self.b)
    }

    /// Steps needed to reach `target` loss. None if unreachable.
    pub fn steps_to(&self, target: f64) -> Option<f64> {
        if target <= self.l_inf || self.a <= 0.0 || self.b <= 0.0 {
            return None;
        }
        Some(((target - self.l_inf) / self.a).powf(-1.0 / self.b))
    }
}

/// Fit L(s) = l_inf + a s^-b by scanning l_inf and solving the remaining
/// log-log linear problem exactly; picks the l_inf with least squared
/// error. Robust enough for smooth training curves.
pub fn fit_power_law(steps: &[f64], losses: &[f64]) -> PowerLaw {
    assert_eq!(steps.len(), losses.len());
    assert!(steps.len() >= 4, "need >= 4 points to fit");
    let min_loss = losses.iter().cloned().fold(f64::INFINITY, f64::min);

    let mut best = PowerLaw { l_inf: 0.0, a: 1.0, b: 0.0 };
    let mut best_err = f64::INFINITY;
    // candidate floors from 0 to just under the observed minimum
    for i in 0..40 {
        let l_inf = min_loss * (i as f64 / 40.0) * 0.999;
        let xs: Vec<f64> = steps.iter().map(|&s| s.max(1.0).ln()).collect();
        let ys: Vec<f64> = losses
            .iter()
            .map(|&l| (l - l_inf).max(1e-9).ln())
            .collect();
        let (ln_a, neg_b) = linear_fit(&xs, &ys);
        let cand = PowerLaw { l_inf, a: ln_a.exp(), b: -neg_b };
        let err: f64 = steps
            .iter()
            .zip(losses)
            .map(|(&s, &l)| {
                let p = cand.predict(s);
                (p - l) * (p - l)
            })
            .sum();
        if err < best_err {
            best_err = err;
            best = cand;
        }
    }
    best
}

/// Kaplan-style parameter scaling of the *achievable* loss floor:
/// l_inf(P) = l_irr + (p_c / P)^alpha. Fit from >= 3 (params, floor)
/// pairs measured on our scale twins; used to place the 100B/250B/1T
/// curves of Fig 6 relative to each other.
#[derive(Debug, Clone, Copy)]
pub struct ParamScaling {
    pub l_irr: f64,
    pub p_c: f64,
    pub alpha: f64,
}

impl ParamScaling {
    pub fn floor(&self, params: f64) -> f64 {
        self.l_irr + (self.p_c / params).powf(self.alpha)
    }
}

pub fn fit_param_scaling(params: &[f64], floors: &[f64]) -> ParamScaling {
    assert_eq!(params.len(), floors.len());
    assert!(params.len() >= 3);
    let min = floors.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut best = ParamScaling { l_irr: 0.0, p_c: 1.0, alpha: 0.0 };
    let mut best_err = f64::INFINITY;
    for i in 0..40 {
        let l_irr = min * (i as f64 / 40.0) * 0.999;
        let xs: Vec<f64> = params.iter().map(|&p| p.ln()).collect();
        let ys: Vec<f64> = floors.iter().map(|&f| (f - l_irr).max(1e-9).ln()).collect();
        let (intercept, slope) = linear_fit(&xs, &ys);
        // ln(f - l_irr) = alpha ln(p_c) - alpha ln(P)
        let alpha = -slope;
        if alpha <= 0.0 {
            continue;
        }
        let p_c = (intercept / alpha).exp();
        let cand = ParamScaling { l_irr, p_c, alpha };
        let err: f64 = params
            .iter()
            .zip(floors)
            .map(|(&p, &f)| {
                let d = cand.floor(p) - f;
                d * d
            })
            .sum();
        if err < best_err {
            best_err = err;
            best = cand;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_roundtrip() {
        let truth = PowerLaw { l_inf: 2.0, a: 6.0, b: 0.4 };
        let steps: Vec<f64> = (1..60).map(|i| (i * 10) as f64).collect();
        let losses: Vec<f64> = steps.iter().map(|&s| truth.predict(s)).collect();
        let fit = fit_power_law(&steps, &losses);
        for &s in &[25.0, 100.0, 400.0, 2000.0] {
            let rel = (fit.predict(s) - truth.predict(s)).abs() / truth.predict(s);
            assert!(rel < 0.02, "at {s}: fit {} truth {}", fit.predict(s), truth.predict(s));
        }
    }

    #[test]
    fn steps_to_inverts_predict() {
        let law = PowerLaw { l_inf: 2.0, a: 5.0, b: 0.5 };
        let s = law.steps_to(3.0).unwrap();
        assert!((law.predict(s) - 3.0).abs() < 1e-9);
        assert!(law.steps_to(1.9).is_none(), "below the floor is unreachable");
    }

    #[test]
    fn param_scaling_roundtrip() {
        let truth = ParamScaling { l_irr: 1.5, p_c: 1e9, alpha: 0.08 };
        let params = [1e8, 1e9, 1e10, 1e11, 1e12];
        let floors: Vec<f64> = params.iter().map(|&p| truth.floor(p)).collect();
        let fit = fit_param_scaling(&params, &floors);
        for &p in &params {
            let rel = (fit.floor(p) - truth.floor(p)).abs() / truth.floor(p);
            assert!(rel < 0.05, "at {p}: {} vs {}", fit.floor(p), truth.floor(p));
        }
        // bigger models have lower floors — the Fig-6 ordering
        assert!(fit.floor(1e12) < fit.floor(1e11));
    }

    #[test]
    fn fit_tolerates_noise() {
        let truth = PowerLaw { l_inf: 2.5, a: 4.0, b: 0.35 };
        let mut rng = crate::util::rng::Rng::new(9);
        let steps: Vec<f64> = (1..100).map(|i| (i * 5) as f64).collect();
        let losses: Vec<f64> = steps
            .iter()
            .map(|&s| truth.predict(s) + 0.02 * rng.normal())
            .collect();
        let fit = fit_power_law(&steps, &losses);
        let rel = (fit.predict(1000.0) - truth.predict(1000.0)).abs() / truth.predict(1000.0);
        assert!(rel < 0.05, "extrapolation error {rel}");
    }
}
