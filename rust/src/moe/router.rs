//! Host-side routing: top-k (k sequential argmax rounds over all experts)
//! and k top-1 prototyping (k parallel routers over disjoint expert
//! groups), with per-expert capacity and token dropping.
//!
//! Semantics match `python/compile/moe.py` exactly; the golden-fixture
//! test `rust/tests/routing_parity.rs` pins the python semantics (top-k
//! renormalization over all k selections including dropped ones, raw
//! un-renormalized gates for top-1 and prototyping) against both this
//! reference and the [`RoutingEngine`](super::engine::RoutingEngine).
//!
//! This file is the *reference* implementation: simple and allocation-
//! heavy. Combine-weight callers run the allocation-free engine instead,
//! and counts-only callers the fused single-pass kernel
//! ([`super::fused`]); `rust/tests/routing_properties.rs` and
//! `rust/tests/fused_routing.rs` hold all three bitwise identical.

use crate::config::Routing;
use crate::util::stats::coefficient_of_variation;

/// Routing problem: gate probabilities for T tokens over E experts.
#[derive(Debug, Clone)]
pub struct RouterSpec {
    pub routing: Routing,
    pub num_experts: usize,
    pub capacity: usize,
}

/// One token's assignment to one expert slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    pub token: usize,
    pub expert: usize,
    /// slot within the expert's capacity buffer
    pub position: usize,
    /// combine weight (renormalized for top-k, raw for prototyping)
    pub gate: f32,
}

#[derive(Debug, Clone, Default)]
pub struct RouteOutput {
    pub assignments: Vec<Assignment>,
    /// kept (real) tokens per expert — effective compute load (§3.1)
    pub load: Vec<u32>,
    /// pre-capacity selections per expert (kept + overflowed) — what the
    /// router *wanted*; `demand - load` is the per-expert drop count the
    /// expert-parallel dispatch accounting attributes to each shard
    pub demand: Vec<u32>,
    /// tokens that overflowed capacity and fell back to the residual path
    pub dropped: u32,
}

impl RouteOutput {
    /// Coefficient of variation of effective compute load (Fig 1 metric).
    pub fn cv(&self) -> f64 {
        let loads: Vec<f64> = self.load.iter().map(|&x| x as f64).collect();
        coefficient_of_variation(&loads)
    }
    /// Padding fraction: capacity slots left empty (they are still computed
    /// and communicated — the cost the paper's Table 1 accounts under
    /// "Capacity kx").
    pub fn padding_fraction(&self, capacity: usize) -> f64 {
        let total = self.load.len() * capacity;
        if total == 0 {
            return 0.0;
        }
        let used: usize = self.load.iter().map(|&x| x as usize).sum();
        1.0 - used as f64 / total as f64
    }
}

/// Route `gates` (T x E row-major, already softmaxed *per prototype group*
/// for prototyping) under `spec`.
///
/// Top-k with `k > E` is clamped to `k = E`: after E argmax rounds every
/// expert has been selected once per token, so further rounds have no
/// unmasked expert to pick — the clamp makes k >= E mean "dense top-E"
/// (one assignment per expert per token) instead of selecting a garbage
/// index in release builds. Drop accounting follows: each token accounts
/// for `min(k, E)` routed slots.
pub fn route(gates: &[f32], tokens: usize, spec: &RouterSpec) -> RouteOutput {
    let e = spec.num_experts;
    assert_eq!(gates.len(), tokens * e, "gate matrix shape mismatch");
    match spec.routing {
        Routing::TopK(k) => route_topk(gates, tokens, e, (k as usize).min(e), spec.capacity),
        Routing::Prototype(z) => route_prototype(gates, tokens, e, z as usize, spec.capacity),
    }
}

fn route_topk(
    gates: &[f32],
    tokens: usize,
    e: usize,
    k: usize,
    capacity: usize,
) -> RouteOutput {
    let mut load = vec![0u32; e];
    let mut demand = vec![0u32; e];
    let mut out = RouteOutput {
        assignments: Vec::new(),
        load: Vec::new(),
        demand: Vec::new(),
        dropped: 0,
    };
    // chosen[token] bitmask over experts already used by earlier rounds
    let mut chosen = vec![vec![false; e]; tokens];
    // raw gate of each selection, for renormalization
    let mut selections: Vec<Vec<(usize, usize, f32, bool)>> = vec![Vec::new(); tokens];

    for _round in 0..k {
        // sequential argmax round: tokens processed in order (cumsum
        // semantics), experts with earlier-round selections masked out
        for t in 0..tokens {
            let row = &gates[t * e..(t + 1) * e];
            let mut best = usize::MAX;
            let mut best_g = f32::NEG_INFINITY;
            for (i, (&g, &used)) in row.iter().zip(&chosen[t]).enumerate() {
                if !used && g > best_g {
                    best = i;
                    best_g = g;
                }
            }
            debug_assert!(best != usize::MAX);
            chosen[t][best] = true;
            demand[best] += 1;
            let pos = load[best] as usize;
            let kept = pos < capacity;
            if kept {
                load[best] += 1;
            } else {
                out.dropped += 1;
            }
            selections[t].push((best, pos, best_g, kept));
        }
    }

    // renormalize gate values over the k selections per token (Eq. 1) —
    // only when k > 1, matching `python/compile/moe.py`'s
    // `if renormalize and rounds > 1` guard: top-1 keeps the raw softmax
    // gate (< 1.0), it is NOT renormalized to ~1.0. The denominator sums
    // all k selections, dropped ones included (python lines 85-87).
    for (t, sels) in selections.iter().enumerate() {
        let denom: f32 = if k > 1 {
            sels.iter().map(|s| s.2).sum::<f32>() + 1e-9
        } else {
            1.0
        };
        for &(expert, position, g, kept) in sels {
            if kept {
                out.assignments.push(Assignment {
                    token: t,
                    expert,
                    position,
                    gate: g / denom,
                });
            }
        }
    }
    out.load = load;
    out.demand = demand;
    out
}

fn route_prototype(
    gates: &[f32],
    tokens: usize,
    e: usize,
    z: usize,
    capacity: usize,
) -> RouteOutput {
    assert!(e % z == 0, "experts {e} not divisible by prototypes {z}");
    let f = e / z;
    let mut load = vec![0u32; e];
    let mut demand = vec![0u32; e];
    let mut out = RouteOutput {
        assignments: Vec::new(),
        load: Vec::new(),
        demand: Vec::new(),
        dropped: 0,
    };
    // prototypes are independent routers — no cross-prototype interaction
    for proto in 0..z {
        for t in 0..tokens {
            let row = &gates[t * e + proto * f..t * e + (proto + 1) * f];
            let mut best = 0;
            let mut best_g = f32::NEG_INFINITY;
            for (i, &g) in row.iter().enumerate() {
                if g > best_g {
                    best = i;
                    best_g = g;
                }
            }
            let expert = proto * f + best;
            demand[expert] += 1;
            let pos = load[expert] as usize;
            if pos < capacity {
                load[expert] += 1;
                out.assignments.push(Assignment { token: t, expert, position: pos, gate: best_g });
            } else {
                out.dropped += 1;
            }
        }
    }
    out.load = load;
    out.demand = demand;
    out
}

/// Convenience: per-token softmax over each prototype group (what the L2
/// router does before the kernel).
pub fn softmax_gates(logits: &[f32], tokens: usize, e: usize, prototypes: usize) -> Vec<f32> {
    let mut out = logits.to_vec();
    softmax_rows_in_place(&mut out, tokens, e, prototypes);
    out
}

/// In-place variant of [`softmax_gates`]: turns `rows` logit rows (row
/// stride `e`, softmaxed per prototype group) into gate probabilities
/// without an output allocation — the form the native backend's sharded
/// gate generation writes directly into its reused gate buffer.
pub fn softmax_rows_in_place(buf: &mut [f32], rows: usize, e: usize, prototypes: usize) {
    assert_eq!(buf.len(), rows * e);
    assert!(prototypes > 0 && e % prototypes == 0);
    let f = e / prototypes;
    for t in 0..rows {
        for z in 0..prototypes {
            let row = &mut buf[t * e + z * f..t * e + z * f + f];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_gates(tokens: usize, e: usize, z: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let logits: Vec<f32> = (0..tokens * e).map(|_| rng.normal() as f32).collect();
        softmax_gates(&logits, tokens, e, z)
    }

    #[test]
    fn top1_respects_capacity() {
        let gates = random_gates(64, 8, 1, 1);
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: 8, capacity: 4 };
        let out = route(&gates, 64, &spec);
        assert!(out.load.iter().all(|&l| l <= 4));
        let kept: u32 = out.load.iter().sum();
        assert_eq!(kept + out.dropped, 64);
    }

    #[test]
    fn top2_assigns_two_distinct_experts() {
        let gates = random_gates(16, 8, 1, 2);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 8, capacity: 16 };
        let out = route(&gates, 16, &spec);
        // capacity ample: every token keeps both assignments
        assert_eq!(out.assignments.len(), 32);
        for t in 0..16 {
            let experts: Vec<usize> = out
                .assignments
                .iter()
                .filter(|a| a.token == t)
                .map(|a| a.expert)
                .collect();
            assert_eq!(experts.len(), 2);
            assert_ne!(experts[0], experts[1], "top-2 must pick distinct experts");
        }
    }

    #[test]
    fn top1_gate_equals_raw_max_gate() {
        // regression: top-1 used to renormalize its single selection,
        // yielding gate ~= 1.0 instead of the raw softmax gate —
        // python/compile/moe.py only renormalizes when rounds > 1
        let tokens = 24;
        let e = 8;
        let gates = random_gates(tokens, e, 1, 9);
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: e, capacity: tokens };
        let out = route(&gates, tokens, &spec);
        assert_eq!(out.assignments.len(), tokens);
        for a in &out.assignments {
            let row = &gates[a.token * e..(a.token + 1) * e];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(
                a.gate.to_bits(),
                max.to_bits(),
                "token {}: top-1 gate must be the raw per-token max gate",
                a.token
            );
            assert!(
                a.gate < 1.0,
                "token {}: a non-degenerate softmax row cannot give gate 1.0",
                a.token
            );
        }
    }

    #[test]
    fn topk_gates_renormalized() {
        let gates = random_gates(8, 4, 1, 3);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 4, capacity: 16 };
        let out = route(&gates, 8, &spec);
        for t in 0..8 {
            let s: f32 = out
                .assignments
                .iter()
                .filter(|a| a.token == t)
                .map(|a| a.gate)
                .sum();
            assert!((s - 1.0).abs() < 1e-4, "token {t} gates sum {s}");
        }
    }

    #[test]
    fn prototype_routes_one_per_group() {
        let gates = random_gates(32, 8, 2, 4);
        let spec = RouterSpec { routing: Routing::Prototype(2), num_experts: 8, capacity: 32 };
        let out = route(&gates, 32, &spec);
        assert_eq!(out.assignments.len(), 64); // 2 prototypes x 32 tokens
        for a in &out.assignments {
            assert!(a.expert < 8);
        }
        for t in 0..32 {
            let mut groups: Vec<usize> = out
                .assignments
                .iter()
                .filter(|a| a.token == t)
                .map(|a| a.expert / 4)
                .collect();
            groups.sort();
            assert_eq!(groups, vec![0, 1], "one expert from each prototype");
        }
    }

    #[test]
    fn positions_unique_per_expert() {
        let gates = random_gates(128, 8, 1, 5);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 8, capacity: 20 };
        let out = route(&gates, 128, &spec);
        for e in 0..8 {
            let mut pos: Vec<usize> = out
                .assignments
                .iter()
                .filter(|a| a.expert == e)
                .map(|a| a.position)
                .collect();
            let n = pos.len();
            pos.sort();
            pos.dedup();
            assert_eq!(pos.len(), n, "duplicate slot in expert {e}");
            assert!(pos.iter().all(|&p| p < 20));
        }
    }

    #[test]
    fn cv_zero_when_uniform() {
        // identical gates -> argmax always expert 0 within each group; use
        // a crafted gate matrix instead: distribute tokens round-robin
        let tokens = 32;
        let e = 4;
        let mut gates = vec![0f32; tokens * e];
        for t in 0..tokens {
            gates[t * e + (t % e)] = 1.0;
        }
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: e, capacity: 8 };
        let out = route(&gates, tokens, &spec);
        assert_eq!(out.cv(), 0.0);
        assert_eq!(out.dropped, 0);
        assert_eq!(out.padding_fraction(8), 0.0);
    }

    #[test]
    fn skewed_gates_drop_tokens() {
        // all tokens love expert 0 -> only `capacity` survive
        let tokens = 64;
        let e = 8;
        let mut gates = vec![0.001f32; tokens * e];
        for t in 0..tokens {
            gates[t * e] = 1.0;
        }
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: e, capacity: 10 };
        let out = route(&gates, tokens, &spec);
        assert_eq!(out.load[0], 10);
        assert_eq!(out.dropped, 54);
        assert!(out.cv() > 1.5);
    }

    #[test]
    fn topk_with_k_beyond_experts_clamps_to_dense() {
        // regression: k > E used to leave `best == usize::MAX` after all
        // experts were masked — UB-adjacent garbage indexing in release
        let gates = random_gates(16, 4, 1, 6);
        let spec = RouterSpec { routing: Routing::TopK(8), num_experts: 4, capacity: 16 };
        let out = route(&gates, 16, &spec);
        // clamped to dense top-E: every token reaches every expert once
        assert_eq!(out.assignments.len(), 16 * 4);
        assert_eq!(out.dropped, 0);
        for t in 0..16 {
            let mut experts: Vec<usize> = out
                .assignments
                .iter()
                .filter(|a| a.token == t)
                .map(|a| a.expert)
                .collect();
            experts.sort();
            assert_eq!(experts, vec![0, 1, 2, 3], "token {t} must cover all experts");
        }
        // accounting matches the clamped k
        let kept: u32 = out.load.iter().sum();
        assert_eq!(kept + out.dropped, 16 * 4);
    }

    #[test]
    fn topk_clamp_respects_capacity_too() {
        let gates = random_gates(32, 4, 1, 7);
        let spec = RouterSpec { routing: Routing::TopK(100), num_experts: 4, capacity: 8 };
        let out = route(&gates, 32, &spec);
        assert!(out.load.iter().all(|&l| l <= 8));
        let kept: u32 = out.load.iter().sum();
        assert_eq!(kept + out.dropped, 32 * 4);
    }

    #[test]
    fn demand_accounts_for_kept_and_dropped() {
        for (routing, z) in [(Routing::TopK(2), 1usize), (Routing::Prototype(2), 2)] {
            let gates = random_gates(96, 8, z, 8);
            let spec = RouterSpec { routing, num_experts: 8, capacity: 9 };
            let out = route(&gates, 96, &spec);
            // per-expert: demand = kept + dropped-at-that-expert
            let dropped_total: u32 = out
                .demand
                .iter()
                .zip(&out.load)
                .map(|(&d, &l)| {
                    assert!(d >= l, "demand below kept load");
                    d - l
                })
                .sum();
            assert_eq!(dropped_total, out.dropped);
            // every token demands exactly k slots
            let total: u32 = out.demand.iter().sum();
            assert_eq!(total, 96 * 2);
        }
    }

    #[test]
    fn softmax_rows_normalize_per_group() {
        let g = softmax_gates(&[1.0, 2.0, 3.0, 4.0], 1, 4, 2);
        assert!((g[0] + g[1] - 1.0).abs() < 1e-6);
        assert!((g[2] + g[3] - 1.0).abs() < 1e-6);
    }
}
