//! Routing microbench: tokens/sec of the allocation-free
//! [`RoutingEngine`] against the naive [`route`] reference, across the
//! paper's five strategies, two expert counts, and tight/ample capacity.
//!
//! Shared by `m6t bench --routing` and `cargo bench --bench routing`;
//! both write `BENCH_routing.json` at the repo root so the routing hot
//! path has a tracked perf trajectory (ROADMAP: "hot path measurably
//! faster"). Every case first cross-checks that engine and reference
//! produce identical outputs, so the bench doubles as a parity smoke.

use anyhow::{Context as _, Result};

use crate::config::Routing;
use crate::util::bench::bench;
use crate::util::json::{arr, num, obj, s, write as json_write, Value};
use crate::util::rng::Rng;
use crate::util::table::{f2, Table};

use super::engine::RoutingEngine;
use super::router::{route, softmax_gates, RouteOutput, RouterSpec};

/// One measured (strategy, E, capacity-regime) cell.
#[derive(Debug, Clone)]
pub struct RoutingBenchRow {
    pub strategy: String,
    pub experts: usize,
    /// "tight" (capacity 1x at factor 1.0 — drops guaranteed under k > 1)
    /// or "ample" (capacity kx at factor 1.25 — the paper's default).
    pub regime: &'static str,
    pub capacity: usize,
    pub tokens: usize,
    pub reference_ns: f64,
    pub engine_ns: f64,
}

impl RoutingBenchRow {
    pub fn reference_tokens_per_sec(&self) -> f64 {
        self.tokens as f64 * 1e9 / self.reference_ns
    }
    pub fn engine_tokens_per_sec(&self) -> f64 {
        self.tokens as f64 * 1e9 / self.engine_ns
    }
    pub fn speedup(&self) -> f64 {
        self.reference_ns / self.engine_ns
    }
}

/// The benched grid: {top1, top2, top4, 2top1, 4top1} x {E=16, 64} x
/// {tight, ample}.
pub fn cases() -> Vec<(Routing, usize, &'static str)> {
    let strategies = [
        Routing::TopK(1),
        Routing::TopK(2),
        Routing::TopK(4),
        Routing::Prototype(2),
        Routing::Prototype(4),
    ];
    let mut out = Vec::new();
    for &experts in &[16usize, 64] {
        for &routing in &strategies {
            for regime in ["tight", "ample"] {
                out.push((routing, experts, regime));
            }
        }
    }
    out
}

fn capacity_for(routing: Routing, regime: &str, tokens: usize, experts: usize) -> usize {
    let k = routing.k().max(1) as f64;
    let t_over_e = tokens as f64 / experts as f64;
    let c = match regime {
        // Eq.-2 with k_eff = 1, gamma = 1.0: overflow is the common case
        "tight" => t_over_e,
        // Eq.-2 with k_eff = k, gamma = 1.25: the paper's default headroom
        _ => k * t_over_e * 1.25,
    };
    (c.ceil() as usize).max(1)
}

/// Run the full grid at `tokens` tokens per route call. Panics if the
/// engine and the reference ever disagree on an output.
pub fn run_suite(tokens: usize) -> Vec<RoutingBenchRow> {
    let mut engine = RoutingEngine::new();
    let mut out = RouteOutput::default();
    let mut rows = Vec::new();
    for (case_idx, (routing, experts, regime)) in cases().into_iter().enumerate() {
        let z = routing.prototypes().max(1) as usize;
        let mut rng = Rng::new(0xB0B5 ^ ((case_idx as u64) << 8));
        let logits: Vec<f32> = (0..tokens * experts).map(|_| rng.normal() as f32).collect();
        let gates = softmax_gates(&logits, tokens, experts, z);
        let capacity = capacity_for(routing, regime, tokens, experts);
        let spec = RouterSpec { routing, num_experts: experts, capacity };

        // parity smoke before timing anything
        let expect = route(&gates, tokens, &spec);
        engine.route_into(&gates, tokens, &spec, &mut out);
        assert_eq!(out.load, expect.load, "{} E={experts} {regime}: load", routing.name());
        assert_eq!(out.demand, expect.demand, "{} E={experts} {regime}: demand", routing.name());
        assert_eq!(out.dropped, expect.dropped, "{} E={experts} {regime}: drops", routing.name());
        assert_eq!(
            out.assignments, expect.assignments,
            "{} E={experts} {regime}: assignments",
            routing.name()
        );

        let label = format!("{} E={experts} C={capacity} ({regime})", routing.name());
        let r_ref = bench(&format!("reference {label}"), || {
            std::hint::black_box(route(&gates, tokens, &spec));
        });
        let r_eng = bench(&format!("engine    {label}"), || {
            engine.route_into(&gates, tokens, &spec, &mut out);
            std::hint::black_box(&out);
        });
        let row = RoutingBenchRow {
            strategy: routing.name(),
            experts,
            regime,
            capacity,
            tokens,
            reference_ns: r_ref.median_ns,
            engine_ns: r_eng.median_ns,
        };
        eprintln!(
            "[bench] {label}: ref {:.2} Mtok/s, engine {:.2} Mtok/s ({:.2}x)",
            row.reference_tokens_per_sec() / 1e6,
            row.engine_tokens_per_sec() / 1e6,
            row.speedup()
        );
        rows.push(row);
    }
    rows
}

/// Human-readable table over the suite — shared by `m6t bench --routing`
/// and the `routing` cargo-bench target so their reports cannot diverge.
pub fn render_table(rows: &[RoutingBenchRow], tokens: usize) -> Table {
    let mut t = Table::new(
        format!("routing: engine vs naive reference, {tokens} tokens/call"),
        &["strategy", "E", "capacity", "ref Mtok/s", "engine Mtok/s", "speedup"],
    );
    for r in rows {
        t.row(vec![
            r.strategy.clone(),
            r.experts.to_string(),
            format!("{} ({})", r.capacity, r.regime),
            f2(r.reference_tokens_per_sec() / 1e6),
            f2(r.engine_tokens_per_sec() / 1e6),
            format!("{}x", f2(r.speedup())),
        ]);
    }
    t
}

/// Serialize the suite to the tracked perf-trajectory JSON.
pub fn to_json(rows: &[RoutingBenchRow], tokens: usize) -> Value {
    let items: Vec<Value> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("strategy", s(r.strategy.clone())),
                ("experts", num(r.experts as f64)),
                ("capacity_regime", s(r.regime)),
                ("capacity", num(r.capacity as f64)),
                ("tokens", num(r.tokens as f64)),
                ("reference_ns_per_route", num(r.reference_ns)),
                ("engine_ns_per_route", num(r.engine_ns)),
                ("reference_tokens_per_sec", num(r.reference_tokens_per_sec())),
                ("engine_tokens_per_sec", num(r.engine_tokens_per_sec())),
                ("speedup", num(r.speedup())),
            ])
        })
        .collect();
    obj(vec![
        ("bench", s("routing")),
        ("tokens_per_route", num(tokens as f64)),
        ("rows", arr(items)),
    ])
}

/// Write `BENCH_routing.json` (or wherever `path` points).
pub fn write_json(rows: &[RoutingBenchRow], tokens: usize, path: &str) -> Result<()> {
    let text = json_write(&to_json(rows, tokens)) + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_issue_matrix() {
        let cs = cases();
        assert_eq!(cs.len(), 20, "5 strategies x 2 expert counts x 2 regimes");
        assert!(cs.iter().any(|&(r, e, g)| r == Routing::Prototype(4) && e == 64 && g == "ample"));
        assert!(cs.iter().any(|&(r, e, g)| r == Routing::TopK(4) && e == 16 && g == "tight"));
    }

    #[test]
    fn capacity_regimes_bracket_the_load() {
        // tight at k=4 must be far below ample: drops guaranteed
        let tight = capacity_for(Routing::TopK(4), "tight", 4096, 16);
        let ample = capacity_for(Routing::TopK(4), "ample", 4096, 16);
        assert_eq!(tight, 256);
        assert_eq!(ample, 1280);
        assert!(capacity_for(Routing::TopK(1), "tight", 3, 64) >= 1);
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![RoutingBenchRow {
            strategy: "top2".into(),
            experts: 16,
            regime: "tight",
            capacity: 8,
            tokens: 128,
            reference_ns: 2000.0,
            engine_ns: 500.0,
        }];
        let v = to_json(&rows, 128);
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("routing"));
        let arr = v.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(arr.len(), 1);
        let row = &arr[0];
        assert_eq!(row.get("speedup").and_then(|s| s.as_f64()), Some(4.0));
        assert_eq!(row.get("strategy").and_then(|s| s.as_str()), Some("top2"));
    }
}
