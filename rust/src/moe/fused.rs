//! Fused single-pass **counts-only** routing kernel: the hot path behind
//! the native and sharded step.
//!
//! The two-pass path materializes a full `T x E` f32 gate matrix
//! (`runtime::native::fill_gates`) behind one pool barrier and then
//! re-reads the whole matrix in the routing engine's argmax phase behind
//! another. For callers that only need **counts** — per-expert kept load,
//! pre-capacity demand, and drop totals — that round trip through memory
//! is pure overhead: capacity under a cumulative slot counter is
//! order-independent, so
//!
//! ```text
//! kept_e = min(demand_e, C)      dropped = sum_e (demand_e - kept_e)
//! ```
//!
//! only the *demand histogram* matters, and demand histograms of disjoint
//! token tiles merge exactly (u32 sums). This module therefore processes
//! one [`TILE_TOKENS`]-token tile at a time: seed the tile's gate rows
//! (bitwise identical to `fill_gates`'s per-shard stream), softmax per
//! prototype group, run every argmax round, and emit one per-expert
//! demand histogram — never touching a global gate matrix. A whole
//! (worker, layer) cell, or any sub-range of its tiles, is an independent
//! work unit, which is what lets the sharded runtime dispatch its full
//! D x L grid in parallel (`runtime::native::route_grid_counts`).
//!
//! Determinism contract: tile `s` of a layer derives its RNG stream as
//! `Rng::new(layer_seed).fold_in(s)` — the exact stream `fill_gates` uses
//! for shard `s` — and the argmax predicate is the routing engine's, so
//! the merged counts are bitwise identical to the two-pass path (and to
//! the naive [`route`](super::router::route) reference) for every
//! strategy, capacity, and prototype grouping. `rust/tests/fused_routing.rs`
//! pins this; the two-pass engine stays around as the oracle and for
//! combine-weight callers, which genuinely need per-assignment output.

#![forbid(unsafe_code)]

use std::cell::RefCell;

use crate::config::Routing;
use crate::util::rng::Rng;

use super::router::softmax_rows_in_place;

/// Tokens per fused tile. MUST match the two-pass path's gate-generation
/// shard size (`runtime::native` uses this constant directly): the RNG
/// stream of tile `s` is `Rng::new(layer_seed).fold_in(s)`, so any
/// divergence in tile size would change which normals land in which gate
/// cell and break bitwise parity with the materialized path.
pub const TILE_TOKENS: usize = 512;

/// Number of tiles covering `tokens` tokens.
pub fn tiles_for(tokens: usize) -> usize {
    tokens.div_ceil(TILE_TOKENS)
}

/// Reusable scratch for one fused work unit: the current tile's gate rows
/// (the only gate storage the counts path ever materializes — at most
/// `TILE_TOKENS x E` floats, cache-resident) plus the top-k chosen-stamp
/// row. Grows monotonically to the largest shape routed.
#[derive(Default)]
pub struct FusedScratch {
    gates: Vec<f32>,
    /// E-wide stamp row: `chosen[x] == generation` means expert `x` was
    /// already selected for the token currently being routed.
    chosen: Vec<u32>,
    generation: u32,
}

impl FusedScratch {
    fn prepare(&mut self, rows: usize, experts: usize) {
        if self.gates.len() < rows * experts {
            self.gates.resize(rows * experts, 0.0);
        }
        if self.chosen.len() < experts {
            self.chosen.clear();
            self.chosen.resize(experts, 0);
            self.generation = 0;
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<FusedScratch> = RefCell::new(FusedScratch::default());
}

/// Run `f` with this thread's fused scratch. Pool workers route many
/// tiles each; keeping one scratch per thread makes the hot loop
/// allocation-free after warmup without any cross-unit coordination
/// (outputs never depend on scratch history — every cell a unit reads is
/// a cell it wrote first).
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut FusedScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Fill `gates` with tile `tile_idx`'s gate rows: seeded normal logits
/// plus the persistent router bias, softmaxed in place per prototype
/// group. Bitwise identical to what `fill_gates` writes for shard
/// `tile_idx` — this is the single source of truth both paths call.
pub fn gen_tile_gates(
    gates: &mut [f32],
    layer_seed: u64,
    tile_idx: usize,
    bias_row: &[f32],
    rows: usize,
    experts: usize,
    prototypes: usize,
) {
    assert_eq!(gates.len(), rows * experts, "tile gate buffer shape mismatch");
    let mut rng = Rng::new(layer_seed).fold_in(tile_idx as u64);
    for (i, v) in gates.iter_mut().enumerate() {
        *v = rng.normal() as f32 + bias_row[i % experts];
    }
    softmax_rows_in_place(gates, rows, experts, prototypes);
}

/// Accumulate per-expert pre-capacity demand for `rows` gate rows into
/// `demand`. Selection semantics are exactly the routing engine's: top-k
/// runs `min(k, E)` argmax rounds with earlier selections masked (first
/// strict maximum wins), prototyping one argmax per expert group.
pub fn accumulate_demand(
    gates: &[f32],
    rows: usize,
    experts: usize,
    routing: Routing,
    chosen: &mut [u32],
    generation: &mut u32,
    demand: &mut [u32],
) {
    assert_eq!(gates.len(), rows * experts, "gate tile shape mismatch");
    assert_eq!(demand.len(), experts, "demand histogram width mismatch");
    match routing {
        Routing::TopK(k) => {
            let k = (k as usize).min(experts);
            if k == 0 {
                return;
            }
            if k == 1 {
                // top-1 fast path: a single round masks nothing
                for t in 0..rows {
                    let row = &gates[t * experts..(t + 1) * experts];
                    let mut best = 0usize;
                    let mut best_g = f32::NEG_INFINITY;
                    for (x, &g) in row.iter().enumerate() {
                        if g > best_g {
                            best = x;
                            best_g = g;
                        }
                    }
                    demand[best] += 1;
                }
                return;
            }
            debug_assert!(chosen.len() >= experts);
            for t in 0..rows {
                if *generation == u32::MAX {
                    chosen.fill(0);
                    *generation = 0;
                }
                *generation += 1;
                let gen = *generation;
                let row = &gates[t * experts..(t + 1) * experts];
                for _round in 0..k {
                    let mut best = usize::MAX;
                    let mut best_g = f32::NEG_INFINITY;
                    // gate test before the stamp load, exactly like the
                    // engine: `&&` keeps the predicate identical
                    for (x, &g) in row.iter().enumerate() {
                        if g > best_g && chosen[x] != gen {
                            best = x;
                            best_g = g;
                        }
                    }
                    debug_assert!(best != usize::MAX);
                    chosen[best] = gen;
                    demand[best] += 1;
                }
            }
        }
        Routing::Prototype(z) => {
            let z = z as usize;
            assert!(z > 0, "prototype count must be positive");
            assert!(experts % z == 0, "experts {experts} not divisible by prototypes {z}");
            let f = experts / z;
            for t in 0..rows {
                let row = &gates[t * experts..(t + 1) * experts];
                for p in 0..z {
                    let group = &row[p * f..(p + 1) * f];
                    let mut best = 0usize;
                    let mut best_g = f32::NEG_INFINITY;
                    for (x, &g) in group.iter().enumerate() {
                        if g > best_g {
                            best = x;
                            best_g = g;
                        }
                    }
                    demand[p * f + best] += 1;
                }
            }
        }
    }
}

/// One fused work unit: generate tile `tile_idx`'s gates from
/// `(layer_seed, tile_idx)` and add its selections to `demand` — the
/// single pass that replaces materialize-then-route. `rows` is the tile's
/// token count (the last tile of a layer may be short); `demand` is
/// accumulated into, so the caller zeroes it once per histogram.
#[allow(clippy::too_many_arguments)]
pub fn tile_demand(
    scratch: &mut FusedScratch,
    layer_seed: u64,
    tile_idx: usize,
    rows: usize,
    bias_row: &[f32],
    experts: usize,
    prototypes: usize,
    routing: Routing,
    demand: &mut [u32],
) {
    scratch.prepare(rows, experts);
    let FusedScratch { gates, chosen, generation } = scratch;
    let gates = &mut gates[..rows * experts];
    gen_tile_gates(gates, layer_seed, tile_idx, bias_row, rows, experts, prototypes);
    accumulate_demand(gates, rows, experts, routing, chosen, generation, demand);
}

/// Capacity-clamp a merged demand histogram into kept load. Counts-only
/// routing is order-independent: slot positions come from a cumulative
/// per-expert counter, so exactly the first `C` selections of each expert
/// are kept no matter which tokens they belong to — `kept_e =
/// min(demand_e, C)`. Returns the dropped-selection total.
pub fn counts_from_demand(demand: &[u32], capacity: usize, load: &mut [u32]) -> u32 {
    assert_eq!(demand.len(), load.len(), "demand/load width mismatch");
    let cap = capacity.min(u32::MAX as usize) as u32;
    let mut dropped = 0u32;
    for (l, &d) in load.iter_mut().zip(demand) {
        let kept = d.min(cap);
        *l = kept;
        dropped += d - kept;
    }
    dropped
}

/// Serial whole-layer fused counts: every tile of the layer accumulated
/// into one histogram, then capacity-clamped. This is the reference shape
/// of the fused path (the parallel grid in `runtime::native` merges the
/// same per-tile histograms in the same tile order) and the entry point
/// the parity tests drive. Returns the dropped-selection total.
#[allow(clippy::too_many_arguments)]
pub fn layer_counts(
    scratch: &mut FusedScratch,
    layer_seed: u64,
    bias_row: &[f32],
    tokens: usize,
    experts: usize,
    prototypes: usize,
    routing: Routing,
    capacity: usize,
    demand: &mut [u32],
    load: &mut [u32],
) -> u32 {
    demand.fill(0);
    for s in 0..tiles_for(tokens) {
        let t0 = s * TILE_TOKENS;
        let rows = TILE_TOKENS.min(tokens - t0);
        tile_demand(scratch, layer_seed, s, rows, bias_row, experts, prototypes, routing, demand);
    }
    counts_from_demand(demand, capacity, load)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::engine::RoutingEngine;
    use crate::moe::router::{route, RouteOutput, RouterSpec};

    /// Materialize a full layer's gates tile by tile — the oracle input
    /// for comparing fused counts against the two-pass implementations.
    fn layer_gates(seed: u64, bias_row: &[f32], tokens: usize, e: usize, z: usize) -> Vec<f32> {
        let mut gates = vec![0f32; tokens * e];
        for s in 0..tiles_for(tokens) {
            let t0 = s * TILE_TOKENS;
            let rows = TILE_TOKENS.min(tokens - t0);
            gen_tile_gates(&mut gates[t0 * e..(t0 + rows) * e], seed, s, bias_row, rows, e, z);
        }
        gates
    }

    fn fused_counts(
        seed: u64,
        bias_row: &[f32],
        tokens: usize,
        e: usize,
        routing: Routing,
        capacity: usize,
    ) -> (Vec<u32>, Vec<u32>, u32) {
        let mut scratch = FusedScratch::default();
        let mut demand = vec![0u32; e];
        let mut load = vec![0u32; e];
        let z = routing.prototypes().max(1) as usize;
        let dropped = layer_counts(
            &mut scratch,
            seed,
            bias_row,
            tokens,
            e,
            z,
            routing,
            capacity,
            &mut demand,
            &mut load,
        );
        (demand, load, dropped)
    }

    #[test]
    fn fused_matches_reference_and_engine() {
        let e = 16;
        let mut engine = RoutingEngine::new();
        let mut counts = RouteOutput::default();
        let cases = [
            (Routing::TopK(1), 700, 45, 1u64),    // spans 2 tiles
            (Routing::TopK(2), 64, 5, 2),         // tight capacity
            (Routing::TopK(4), 1200, 9999, 3),    // ample, 3 tiles
            (Routing::Prototype(2), 300, 20, 4),
            (Routing::Prototype(4), 1025, 70, 5), // short last tile
            (Routing::TopK(16), 96, 4, 6),        // k == E
        ];
        // Miri interprets every gate visit; the two-tile and tight-capacity
        // cases already cover the tile-merge and clamp paths.
        let take = if cfg!(miri) { 2 } else { cases.len() };
        for (routing, tokens, capacity, seed) in cases.into_iter().take(take) {
            let z = routing.prototypes().max(1) as usize;
            let bias: Vec<f32> = (0..e).map(|i| (i as f32 - 8.0) * 0.07).collect();
            let gates = layer_gates(seed, &bias, tokens, e, z);
            let spec = RouterSpec { routing, num_experts: e, capacity };
            let expect = route(&gates, tokens, &spec);
            let (demand, load, dropped) = fused_counts(seed, &bias, tokens, e, routing, capacity);
            assert_eq!(demand, expect.demand, "{routing:?} demand");
            assert_eq!(load, expect.load, "{routing:?} load");
            assert_eq!(dropped, expect.dropped, "{routing:?} dropped");
            engine.route_counts_into(&gates, tokens, &spec, &mut counts);
            assert_eq!(load, counts.load, "{routing:?} engine load");
            assert_eq!(demand, counts.demand, "{routing:?} engine demand");
            assert_eq!(dropped, counts.dropped, "{routing:?} engine dropped");
        }
    }

    #[test]
    fn counts_from_demand_clamps_exactly() {
        let demand = vec![0u32, 3, 7, 12];
        let mut load = vec![0u32; 4];
        let dropped = counts_from_demand(&demand, 7, &mut load);
        assert_eq!(load, vec![0, 3, 7, 7]);
        assert_eq!(dropped, 5);
        let dropped = counts_from_demand(&demand, 0, &mut load);
        assert_eq!(load, vec![0; 4]);
        assert_eq!(dropped, 22);
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // a big top-4 call followed by a small top-2 call over fewer
        // experts: stale stamps must not leak into the second histogram
        let bias_big: Vec<f32> = vec![0.0; 32];
        let bias_small: Vec<f32> = vec![0.1; 4];
        let mut scratch = FusedScratch::default();
        let mut demand = vec![0u32; 32];
        let mut load = vec![0u32; 32];
        layer_counts(
            &mut scratch,
            9,
            &bias_big,
            900,
            32,
            1,
            Routing::TopK(4),
            40,
            &mut demand,
            &mut load,
        );
        let mut demand_s = vec![0u32; 4];
        let mut load_s = vec![0u32; 4];
        let dropped = layer_counts(
            &mut scratch,
            10,
            &bias_small,
            33,
            4,
            1,
            Routing::TopK(2),
            5,
            &mut demand_s,
            &mut load_s,
        );
        let gates = layer_gates(10, &bias_small, 33, 4, 1);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 4, capacity: 5 };
        let expect = route(&gates, 33, &spec);
        assert_eq!(demand_s, expect.demand);
        assert_eq!(load_s, expect.load);
        assert_eq!(dropped, expect.dropped);
    }

    #[test]
    fn generation_wrap_refills_cleanly() {
        // force the wrap branch: a scratch whose last call ended on the
        // final stamp value (generation == MAX, stale MAX stamps in the
        // row) must re-zero the row before the next token routes
        let e = 8;
        let bias: Vec<f32> = vec![0.0; e];
        let mut scratch = FusedScratch::default();
        scratch.prepare(TILE_TOKENS, e);
        scratch.generation = u32::MAX;
        scratch.chosen.fill(u32::MAX); // stale stamps from the "previous" call
        let mut demand = vec![0u32; e];
        let mut load = vec![0u32; e];
        let dropped = layer_counts(
            &mut scratch,
            21,
            &bias,
            16,
            e,
            1,
            Routing::TopK(3),
            4,
            &mut demand,
            &mut load,
        );
        let gates = layer_gates(21, &bias, 16, e, 1);
        let spec = RouterSpec { routing: Routing::TopK(3), num_experts: e, capacity: 4 };
        let expect = route(&gates, 16, &spec);
        assert_eq!(demand, expect.demand);
        assert_eq!(load, expect.load);
        assert_eq!(dropped, expect.dropped);
    }
}
