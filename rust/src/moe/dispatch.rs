//! Expert-parallel dispatch accounting: what D workers actually exchange.
//!
//! The paper's headline systems result (1T params on 480 V100 workers)
//! rests on expert parallelism: every worker routes its *local* batch of
//! `T_local` tokens with per-worker capacity `C = k·T_local/E·γ` (Eq. 2 at
//! local scope), then all-to-alls the dispatched tokens to the workers
//! hosting each expert shard (E/D experts per worker). The cluster model
//! prices this traffic analytically as O(ECM); this module *accounts* it
//! exactly from executed routing decisions, so the runtime can observe
//! where multi-worker behavior diverges from the single-router
//! idealization — per-shard load skew, per-shard drop concentration, and
//! the real (non-padded, non-local) byte volume on each link.
//!
//! A [`DispatchPlan`] is one layer's exchange: per (source worker,
//! destination expert) kept and demanded token counts, from which every
//! per-shard and per-link quantity is derived. A [`DispatchSummary`]
//! aggregates the per-layer plans of one training step into the compact
//! record that [`StepStats`](crate::runtime::StepStats) and the metrics
//! sink carry.
//!
//! Conservation contract (pinned by `rust/tests/dispatch_properties.rs`):
//! per worker, kept + dropped equals the routed-slot total `k_eff·T_local`;
//! the bytes every worker sends equal the bytes every shard receives; and
//! at D = 1 all traffic is local, so measured all-to-all bytes are zero.

use crate::util::stats::coefficient_of_variation;

use super::router::RouteOutput;

/// Bytes of one dispatched token vector (f32 activations of width M).
fn token_bytes(hidden: usize) -> u64 {
    hidden as u64 * 4
}

/// One MoE layer's all-to-all exchange across D expert-parallel workers.
///
/// Experts are sharded contiguously: worker `v` hosts experts
/// `[v·E/D, (v+1)·E/D)`. `send`/`demand` are row-major D x E counts of the
/// tokens each source worker routed toward each (global) expert — `send`
/// after local capacity enforcement, `demand` before it.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchPlan {
    pub workers: usize,
    pub num_experts: usize,
    /// per-worker per-expert capacity C (Eq. 2 at local scope)
    pub capacity: usize,
    /// token vector width M — the byte accounting's scale factor
    pub hidden: usize,
    /// D x E kept (dispatched) token counts, row-major
    pub send: Vec<u32>,
    /// D x E pre-capacity demanded token counts, row-major
    pub demand: Vec<u32>,
}

impl DispatchPlan {
    /// Build a plan from raw per-worker count matrices.
    pub fn new(
        workers: usize,
        num_experts: usize,
        capacity: usize,
        hidden: usize,
        send: Vec<u32>,
        demand: Vec<u32>,
    ) -> DispatchPlan {
        assert!(workers > 0, "dispatch plan needs at least one worker");
        assert!(
            num_experts % workers == 0,
            "experts {num_experts} not divisible by workers {workers}: shards must be equal"
        );
        assert_eq!(send.len(), workers * num_experts, "send matrix shape mismatch");
        assert_eq!(demand.len(), workers * num_experts, "demand matrix shape mismatch");
        DispatchPlan { workers, num_experts, capacity, hidden, send, demand }
    }

    /// Build a plan from each worker's executed [`RouteOutput`] over its
    /// local batch (all workers route the same expert set).
    pub fn from_worker_routes(
        num_experts: usize,
        capacity: usize,
        hidden: usize,
        routes: &[RouteOutput],
    ) -> DispatchPlan {
        let workers = routes.len();
        let mut send = vec![0u32; workers * num_experts];
        let mut demand = vec![0u32; workers * num_experts];
        for (w, r) in routes.iter().enumerate() {
            assert_eq!(r.load.len(), num_experts, "worker {w}: load width mismatch");
            assert_eq!(r.demand.len(), num_experts, "worker {w}: demand width mismatch");
            send[w * num_experts..(w + 1) * num_experts].copy_from_slice(&r.load);
            demand[w * num_experts..(w + 1) * num_experts].copy_from_slice(&r.demand);
        }
        DispatchPlan::new(workers, num_experts, capacity, hidden, send, demand)
    }

    pub fn experts_per_shard(&self) -> usize {
        self.num_experts / self.workers
    }

    /// Worker hosting (global) expert `e`.
    pub fn shard_of(&self, expert: usize) -> usize {
        expert / self.experts_per_shard()
    }

    /// Tokens worker `w` dispatches in total (kept under local capacity).
    pub fn kept_per_worker(&self) -> Vec<u64> {
        (0..self.workers)
            .map(|w| {
                self.send[w * self.num_experts..(w + 1) * self.num_experts]
                    .iter()
                    .map(|&x| x as u64)
                    .sum()
            })
            .collect()
    }

    /// Tokens worker `w` dropped at its local capacity gate.
    pub fn dropped_per_worker(&self) -> Vec<u64> {
        (0..self.workers)
            .map(|w| {
                let at = w * self.num_experts;
                (0..self.num_experts)
                    .map(|e| (self.demand[at + e] - self.send[at + e]) as u64)
                    .sum()
            })
            .collect()
    }

    /// Tokens landing on (processed by) each expert shard.
    pub fn recv_per_shard(&self) -> Vec<u64> {
        let mut recv = vec![0u64; self.workers];
        for w in 0..self.workers {
            for e in 0..self.num_experts {
                recv[self.shard_of(e)] += self.send[w * self.num_experts + e] as u64;
            }
        }
        recv
    }

    /// Drops attributed to each destination shard: demand that overflowed
    /// the local capacity of experts hosted there.
    pub fn dropped_per_shard(&self) -> Vec<u64> {
        let mut drops = vec![0u64; self.workers];
        for w in 0..self.workers {
            for e in 0..self.num_experts {
                let at = w * self.num_experts + e;
                drops[self.shard_of(e)] += (self.demand[at] - self.send[at]) as u64;
            }
        }
        drops
    }

    /// Total kept tokens this layer (across all workers).
    pub fn kept_total(&self) -> u64 {
        self.send.iter().map(|&x| x as u64).sum()
    }

    /// Total dropped tokens this layer.
    pub fn dropped_total(&self) -> u64 {
        self.demand.iter().map(|&x| x as u64).sum::<u64>() - self.kept_total()
    }

    /// Kept tokens whose destination shard is not their source worker —
    /// the tokens that actually traverse the network.
    pub fn cross_tokens(&self) -> u64 {
        let mut cross = 0u64;
        for w in 0..self.workers {
            for e in 0..self.num_experts {
                if self.shard_of(e) != w {
                    cross += self.send[w * self.num_experts + e] as u64;
                }
            }
        }
        cross
    }

    /// D x D dispatch-direction byte matrix: `bytes[w * D + v]` is what
    /// worker `w` sends to shard `v`. The diagonal is zero — tokens for
    /// locally hosted experts never touch the network. The combine
    /// direction is the transpose (same totals).
    pub fn bytes_matrix(&self) -> Vec<u64> {
        let mut bytes = vec![0u64; self.workers * self.workers];
        self.add_bytes_matrix_into(&mut bytes);
        bytes
    }

    /// Accumulate this layer's [`DispatchPlan::bytes_matrix`] into a
    /// caller-owned D x D buffer — the allocation-free form the sharded
    /// hot loop and the link-level cost model (`cluster::topology`) use,
    /// both per layer (zeroed buffer) and summed over a step's plans.
    pub fn add_bytes_matrix_into(&self, out: &mut [u64]) {
        let d = self.workers;
        assert_eq!(out.len(), d * d, "link-byte buffer must be D x D");
        let per_token = token_bytes(self.hidden);
        for w in 0..d {
            for e in 0..self.num_experts {
                let v = self.shard_of(e);
                if v != w {
                    out[w * d + v] += self.send[w * self.num_experts + e] as u64 * per_token;
                }
            }
        }
    }

    /// Accumulate the *full* D x D kept-byte matrix — like
    /// [`DispatchPlan::add_bytes_matrix_into`] but including the diagonal
    /// (tokens for locally hosted experts). The placement search
    /// (`cluster::placement`) needs the local column too: under a
    /// non-identity shard→worker assignment, traffic that is local today
    /// becomes a network flow, so the zero-diagonal matrix understates
    /// the cost of moving a shard off its co-resident worker.
    pub fn add_full_bytes_matrix_into(&self, out: &mut [u64]) {
        let d = self.workers;
        assert_eq!(out.len(), d * d, "link-byte buffer must be D x D");
        let per_token = token_bytes(self.hidden);
        for w in 0..d {
            for e in 0..self.num_experts {
                let v = self.shard_of(e);
                out[w * d + v] += self.send[w * self.num_experts + e] as u64 * per_token;
            }
        }
    }

    /// Accumulate the zero-diagonal link-byte matrix under an explicit
    /// shard→worker assignment: `assign[s]` is the worker hosting expert
    /// shard `s`, and bytes from worker `w` toward shard `s` land on link
    /// `(w, assign[s])` — local (free) exactly when `assign[s] == w`. With
    /// the identity assignment this is bitwise
    /// [`DispatchPlan::add_bytes_matrix_into`].
    pub fn add_placed_bytes_matrix_into(&self, assign: &[usize], out: &mut [u64]) {
        let d = self.workers;
        assert_eq!(assign.len(), d, "assignment must cover every shard");
        assert_eq!(out.len(), d * d, "link-byte buffer must be D x D");
        let per_token = token_bytes(self.hidden);
        for w in 0..d {
            for e in 0..self.num_experts {
                let v = assign[self.shard_of(e)];
                if v != w {
                    out[w * d + v] += self.send[w * self.num_experts + e] as u64 * per_token;
                }
            }
        }
    }

    /// Measured all-to-all payload, one direction, this layer.
    pub fn dispatch_bytes(&self) -> u64 {
        self.cross_tokens() * token_bytes(self.hidden)
    }

    /// Coefficient of variation of per-shard received tokens — the
    /// cross-worker load-balance metric (Fig-1's c_v at shard scope).
    pub fn shard_load_cv(&self) -> f64 {
        let recv: Vec<f64> = self.recv_per_shard().iter().map(|&x| x as f64).collect();
        coefficient_of_variation(&recv)
    }
}

/// One training step's dispatch record, aggregated over the per-layer
/// plans: the per-worker / per-shard series the metrics sink carries and
/// the observed traffic the cluster model consumes in place of its
/// analytic O(ECM) estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchSummary {
    pub workers: usize,
    pub layers: usize,
    /// c_v of per-shard received tokens, summed over layers
    pub shard_load_cv: f64,
    /// mean over layers of the per-layer max/mean per-shard load (>= 1) —
    /// the straggler stretch an imbalanced exchange puts on expert
    /// compute. Per-layer because every layer synchronizes independently
    /// at its combine all-to-all: opposing imbalances in different
    /// layers must not cancel
    pub shard_balance: f64,
    /// per source worker: tokens dropped at the local capacity gate
    pub per_worker_dropped: Vec<f64>,
    /// per destination shard: tokens received (all layers)
    pub per_shard_recv: Vec<f64>,
    /// per destination shard: demand lost to capacity (all layers)
    pub per_shard_dropped: Vec<f64>,
    /// measured all-to-all payload bytes per layer per direction (mean
    /// over layers) — the analytic model's O(ECM) replacement
    pub a2a_bytes_per_layer: f64,
    /// exact one-direction cross-worker byte total for the whole step
    /// (the integer sum of the per-layer `dispatch_bytes`, not the mean
    /// re-multiplied) — the denominator of `bottleneck_link_share`
    pub a2a_bytes_total: f64,
    /// measured bytes for the whole step: dispatch + combine forward and
    /// their backward transposes (4 transfers per layer)
    pub a2a_bytes_step: f64,
    /// fraction of kept tokens that crossed a worker boundary
    pub cross_fraction: f64,
    /// dropped / demanded tokens over the whole step
    pub drop_fraction: f64,
    /// bytes on the most-loaded (source, destination) link, summed over
    /// the step's layers, one direction — the link-level bottleneck the
    /// aggregate byte count cannot see
    pub max_link_bytes: f64,
    /// source worker of the most-loaded link (0 when nothing crossed)
    pub bottleneck_src: usize,
    /// destination shard of the most-loaded link
    pub bottleneck_dst: usize,
    /// cluster-model step time over the observed traffic (the serial
    /// half of a [`cluster::StepInputs`](crate::cluster::StepInputs)
    /// run); 0 until the driver fills it in
    pub observed_ms: f64,
    /// overlap-aware cluster step time (per-link bottleneck comm
    /// pipelined against expert compute — the overlap half of the same
    /// [`cluster::StepInputs`](crate::cluster::StepInputs) run);
    /// never exceeds `observed_ms`; 0 until the driver fills it in
    pub observed_overlap_ms: f64,
    /// fraction of link-model comm hidden behind compute, in [0, 1];
    /// 0 until the driver fills it in
    pub overlap_efficiency: f64,
    /// true when the elastic capacity controller reshaped this step's
    /// per-(layer, shard) capacities (`moe::capacity`); false on the
    /// static path, whose numbers stay the bitwise oracle
    pub elastic: bool,
    /// smallest effective per-(layer, shard) capacity this step — equals
    /// the static Eq.-2 `C` when the controller is off
    pub capacity_min: usize,
    /// largest effective per-(layer, shard) capacity this step
    pub capacity_max: usize,
    /// identity-layout / placed-layout bottleneck seconds over the
    /// step-summed traffic (`cluster::placement`); 1.0 under the identity
    /// assignment (structurally >= 1.0: the search falls back to identity)
    pub placement_gain: f64,
    /// `bottleneck_link_share` of the placed layout, same denominator as
    /// the identity share — equals `bottleneck_link_share()` when the
    /// placement search is off
    pub placed_link_share: f64,
}

impl DispatchSummary {
    /// Aggregate one step's per-layer plans. All plans must share the
    /// same worker count.
    pub fn from_plans(plans: &[DispatchPlan]) -> DispatchSummary {
        assert!(!plans.is_empty(), "a dispatch summary needs at least one layer plan");
        let workers = plans[0].workers;
        let layers = plans.len();
        let mut per_worker_dropped = vec![0u64; workers];
        let mut per_shard_recv = vec![0u64; workers];
        let mut per_shard_dropped = vec![0u64; workers];
        let mut cross = 0u64;
        let mut bytes_one_direction = 0u64;
        let mut kept = 0u64;
        let mut dropped = 0u64;
        let mut balance_sum = 0.0f64;
        let mut link_bytes = vec![0u64; workers * workers];
        for p in plans {
            assert_eq!(p.workers, workers, "mixed worker counts in one summary");
            let layer_recv = p.recv_per_shard();
            for (acc, &x) in per_shard_recv.iter_mut().zip(&layer_recv) {
                *acc += x;
            }
            for (acc, x) in per_worker_dropped.iter_mut().zip(p.dropped_per_worker()) {
                *acc += x;
            }
            for (acc, x) in per_shard_dropped.iter_mut().zip(p.dropped_per_shard()) {
                *acc += x;
            }
            // per-layer straggler stretch: each layer synchronizes at its
            // own combine all-to-all, so the balance is averaged over
            // layers, never computed from layer-summed totals (where a
            // shard-0-heavy layer and a shard-1-heavy layer would cancel)
            let mean = layer_recv.iter().map(|&x| x as f64).sum::<f64>() / workers as f64;
            let max = layer_recv.iter().map(|&x| x as f64).fold(0.0f64, f64::max);
            balance_sum += if mean > 0.0 { (max / mean).max(1.0) } else { 1.0 };
            cross += p.cross_tokens();
            bytes_one_direction += p.dispatch_bytes();
            kept += p.kept_total();
            dropped += p.dropped_total();
            p.add_bytes_matrix_into(&mut link_bytes);
        }
        let recv_f: Vec<f64> = per_shard_recv.iter().map(|&x| x as f64).collect();
        let shard_balance = balance_sum / layers as f64;
        // the most-loaded ordered link over the whole step (one direction)
        let mut max_link_bytes = 0u64;
        let mut bottleneck_src = 0usize;
        let mut bottleneck_dst = 0usize;
        for w in 0..workers {
            for v in 0..workers {
                let b = link_bytes[w * workers + v];
                if b > max_link_bytes {
                    max_link_bytes = b;
                    bottleneck_src = w;
                    bottleneck_dst = v;
                }
            }
        }
        let capacity_min = plans.iter().map(|p| p.capacity).min().unwrap_or(1);
        let capacity_max = plans.iter().map(|p| p.capacity).max().unwrap_or(1);
        let a2a_bytes_total = bytes_one_direction as f64;
        let identity_share =
            if bytes_one_direction > 0 { max_link_bytes as f64 / a2a_bytes_total } else { 0.0 };
        DispatchSummary {
            workers,
            layers,
            shard_load_cv: coefficient_of_variation(&recv_f),
            shard_balance,
            per_worker_dropped: per_worker_dropped.iter().map(|&x| x as f64).collect(),
            per_shard_recv: recv_f,
            per_shard_dropped: per_shard_dropped.iter().map(|&x| x as f64).collect(),
            a2a_bytes_per_layer: bytes_one_direction as f64 / layers as f64,
            a2a_bytes_total,
            a2a_bytes_step: bytes_one_direction as f64 * 4.0,
            cross_fraction: cross as f64 / (kept as f64).max(1.0),
            drop_fraction: dropped as f64 / ((kept + dropped) as f64).max(1.0),
            max_link_bytes: max_link_bytes as f64,
            bottleneck_src,
            bottleneck_dst,
            observed_ms: 0.0,
            observed_overlap_ms: 0.0,
            overlap_efficiency: 0.0,
            elastic: false,
            capacity_min,
            capacity_max,
            placement_gain: 1.0,
            placed_link_share: identity_share,
        }
    }

    /// Share of the step's cross-worker bytes carried by the single
    /// most-loaded link — 0 when nothing crossed. The bench's
    /// `bottleneck_link_share` field: at 1.0 one link is the whole story,
    /// at ~1/(D·(D-1)) the exchange is perfectly spread. The denominator
    /// is the exact integer byte total carried through `from_plans`
    /// (`a2a_bytes_total`), not the per-layer mean re-multiplied by L —
    /// the old reconstruction could land an ULP below the true sum when
    /// L is not a power of two and needed a clamp to stay in [0, 1].
    pub fn bottleneck_link_share(&self) -> f64 {
        if self.a2a_bytes_total > 0.0 {
            self.max_link_bytes / self.a2a_bytes_total
        } else {
            0.0
        }
    }

    /// Serial / overlapped cluster step time (>= 1.0 once the driver has
    /// filled both fields) — the one shared definition behind the CLI
    /// report and the overlap bench's per-row regression field.
    pub fn overlap_speedup(&self) -> f64 {
        if self.observed_overlap_ms > 0.0 {
            self.observed_ms / self.observed_overlap_ms
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Routing;
    use crate::moe::router::{route, softmax_gates, RouterSpec};
    use crate::util::rng::Rng;

    fn worker_routes(
        workers: usize,
        tokens: usize,
        e: usize,
        routing: Routing,
        capacity: usize,
        seed: u64,
    ) -> Vec<RouteOutput> {
        let z = routing.prototypes().max(1) as usize;
        let spec = RouterSpec { routing, num_experts: e, capacity };
        (0..workers)
            .map(|w| {
                let mut rng = Rng::new(seed ^ ((w as u64 + 1) * 0x9E37));
                let logits: Vec<f32> = (0..tokens * e).map(|_| rng.normal() as f32).collect();
                let gates = softmax_gates(&logits, tokens, e, z);
                route(&gates, tokens, &spec)
            })
            .collect()
    }

    #[test]
    fn plan_conserves_tokens() {
        let routes = worker_routes(4, 128, 16, Routing::TopK(2), 18, 7);
        let plan = DispatchPlan::from_worker_routes(16, 18, 64, &routes);
        // per-worker kept + dropped == routed slots
        let kept = plan.kept_per_worker();
        let drops = plan.dropped_per_worker();
        for w in 0..4 {
            assert_eq!(kept[w] + drops[w], 128 * 2, "worker {w}");
        }
        // global send == global receive
        let recv_total: u64 = plan.recv_per_shard().iter().sum();
        assert_eq!(recv_total, plan.kept_total());
        assert_eq!(kept.iter().sum::<u64>(), plan.kept_total());
        // drops attributed to shards account for every drop
        assert_eq!(plan.dropped_per_shard().iter().sum::<u64>(), plan.dropped_total());
    }

    #[test]
    fn bytes_matrix_is_conserved_with_zero_diagonal() {
        let routes = worker_routes(4, 96, 8, Routing::Prototype(2), 30, 11);
        let plan = DispatchPlan::from_worker_routes(8, 30, 32, &routes);
        let m = plan.bytes_matrix();
        let d = plan.workers;
        for w in 0..d {
            assert_eq!(m[w * d + w], 0, "diagonal (local) traffic must be zero");
        }
        let row_total: u64 = m.iter().sum();
        assert_eq!(row_total, plan.dispatch_bytes());
        // column sums (per-shard received bytes) conserve the total too
        let col_total: u64 =
            (0..d).map(|v| (0..d).map(|w| m[w * d + v]).sum::<u64>()).sum();
        assert_eq!(col_total, plan.dispatch_bytes());
        assert_eq!(plan.dispatch_bytes(), plan.cross_tokens() * 32 * 4);
    }

    #[test]
    fn single_worker_has_no_network_traffic() {
        let routes = worker_routes(1, 200, 8, Routing::TopK(1), 40, 3);
        let plan = DispatchPlan::from_worker_routes(8, 40, 64, &routes);
        assert_eq!(plan.cross_tokens(), 0);
        assert_eq!(plan.dispatch_bytes(), 0);
        assert_eq!(plan.shard_load_cv(), 0.0, "one shard is trivially balanced");
        assert_eq!(plan.recv_per_shard(), vec![plan.kept_total()]);
    }

    #[test]
    fn summary_aggregates_layers() {
        let l0 = DispatchPlan::from_worker_routes(
            8,
            20,
            16,
            &worker_routes(2, 64, 8, Routing::TopK(2), 20, 21),
        );
        let l1 = DispatchPlan::from_worker_routes(
            8,
            20,
            16,
            &worker_routes(2, 64, 8, Routing::TopK(2), 20, 22),
        );
        let s = DispatchSummary::from_plans(&[l0.clone(), l1.clone()]);
        assert_eq!(s.workers, 2);
        assert_eq!(s.layers, 2);
        let bytes = (l0.dispatch_bytes() + l1.dispatch_bytes()) as f64;
        assert_eq!(s.a2a_bytes_total, bytes, "step total is the exact integer sum");
        assert_eq!(s.a2a_bytes_per_layer, bytes / 2.0);
        assert_eq!(s.a2a_bytes_step, bytes * 4.0);
        assert_eq!(s.capacity_min, 20);
        assert_eq!(s.capacity_max, 20);
        assert!(!s.elastic);
        assert_eq!(s.placement_gain, 1.0);
        assert_eq!(s.placed_link_share, s.bottleneck_link_share());
        assert!(s.shard_balance >= 1.0);
        assert!((0.0..=1.0).contains(&s.cross_fraction));
        assert!((0.0..=1.0).contains(&s.drop_fraction));
        let recv_sum: f64 = s.per_shard_recv.iter().sum();
        assert_eq!(recv_sum, (l0.kept_total() + l1.kept_total()) as f64);
        // the bottleneck link is the max cell of the layer-summed matrix
        let d = s.workers;
        let mut summed = l0.bytes_matrix();
        for (acc, x) in summed.iter_mut().zip(l1.bytes_matrix()) {
            *acc += x;
        }
        let max = summed.iter().copied().max().unwrap();
        assert_eq!(s.max_link_bytes, max as f64);
        assert_eq!(summed[s.bottleneck_src * d + s.bottleneck_dst], max);
        assert!((0.0..=1.0).contains(&s.bottleneck_link_share()));
        assert!(s.max_link_bytes <= bytes, "one link cannot carry more than the total");
    }

    #[test]
    fn single_worker_summary_has_no_bottleneck_link() {
        // regression pin: at D = 1 every token is local, the exact byte
        // total is zero, and the share must be exactly 0.0 (no 0/0)
        let routes = worker_routes(1, 64, 8, Routing::TopK(2), 20, 9);
        let plan = DispatchPlan::from_worker_routes(8, 20, 32, &routes);
        let s = DispatchSummary::from_plans(&[plan]);
        assert_eq!(s.max_link_bytes, 0.0);
        assert_eq!(s.a2a_bytes_total, 0.0);
        assert_eq!(s.bottleneck_link_share(), 0.0);
        assert_eq!((s.bottleneck_src, s.bottleneck_dst), (0, 0));
    }

    #[test]
    fn link_share_uses_the_exact_total_over_odd_layer_counts() {
        // three layers (not a power of two): the old mean * L
        // reconstruction could sit an ULP off the integer sum; the share
        // must now be exactly max_link / sum with no clamp in the way
        let layers: Vec<DispatchPlan> = (0..3)
            .map(|i| {
                DispatchPlan::from_worker_routes(
                    16,
                    18,
                    64,
                    &worker_routes(4, 96, 16, Routing::TopK(2), 18, 100 + i),
                )
            })
            .collect();
        let s = DispatchSummary::from_plans(&layers);
        let exact: u64 = layers.iter().map(|p| p.dispatch_bytes()).sum();
        assert_eq!(s.a2a_bytes_total, exact as f64);
        assert_eq!(s.bottleneck_link_share(), s.max_link_bytes / exact as f64);
        assert!((0.0..=1.0).contains(&s.bottleneck_link_share()));
    }

    #[test]
    fn full_matrix_restores_the_diagonal_and_identity_placement_matches() {
        let routes = worker_routes(4, 96, 8, Routing::Prototype(2), 30, 11);
        let plan = DispatchPlan::from_worker_routes(8, 30, 32, &routes);
        let d = plan.workers;
        let mut full = vec![0u64; d * d];
        plan.add_full_bytes_matrix_into(&mut full);
        // full total = every kept token, cross or local
        let full_total: u64 = full.iter().sum();
        assert_eq!(full_total, plan.kept_total() * 32 * 4);
        // zeroing the diagonal recovers the network-only matrix
        let m = plan.bytes_matrix();
        for w in 0..d {
            for v in 0..d {
                if w == v {
                    assert!(full[w * d + v] >= m[w * d + v]);
                } else {
                    assert_eq!(full[w * d + v], m[w * d + v]);
                }
            }
        }
        // identity assignment reproduces bytes_matrix bitwise
        let assign: Vec<usize> = (0..d).collect();
        let mut placed = vec![0u64; d * d];
        plan.add_placed_bytes_matrix_into(&assign, &mut placed);
        assert_eq!(placed, m);
        // any permutation conserves the full total minus its new diagonal
        let rotated: Vec<usize> = (0..d).map(|s| (s + 1) % d).collect();
        let mut rot = vec![0u64; d * d];
        plan.add_placed_bytes_matrix_into(&rotated, &mut rot);
        let rot_local: u64 = (0..d).map(|w| full[w * d + (d + w - 1) % d]).sum();
        assert_eq!(rot.iter().sum::<u64>(), full_total - rot_local);
        for w in 0..d {
            assert_eq!(rot[w * d + w], 0, "placed matrix keeps a zero diagonal");
        }
    }

    #[test]
    fn opposing_layer_imbalances_do_not_cancel_in_shard_balance() {
        // regression: layer 0 one-hot on shard 0, layer 1 one-hot on
        // shard 1 — the layer-summed recv is perfectly balanced, but
        // every layer still ran at a 2x straggler pace
        let d = 2;
        let e = 2;
        let t = 10u32;
        // worker rows both demand/keep everything on one expert
        let one_hot = |expert: usize| -> (Vec<u32>, Vec<u32>) {
            let mut counts = vec![0u32; d * e];
            for w in 0..d {
                counts[w * e + expert] = t;
            }
            (counts.clone(), counts)
        };
        let (send0, demand0) = one_hot(0);
        let (send1, demand1) = one_hot(1);
        let l0 = DispatchPlan::new(d, e, t as usize, 4, send0, demand0);
        let l1 = DispatchPlan::new(d, e, t as usize, 4, send1, demand1);
        let s = DispatchSummary::from_plans(&[l0, l1]);
        // aggregate recv is [2t, 2t] -> cv 0, but the per-layer stretch
        // is 2x in both layers and must survive aggregation
        assert_eq!(s.shard_load_cv, 0.0);
        assert_eq!(s.shard_balance, 2.0, "per-layer straggler stretch cancelled");
    }

    #[test]
    fn skewed_load_concentrates_on_one_shard() {
        // every token demands expert 0 -> shard 0 receives everything
        let e = 8;
        let tokens = 64;
        let mut gates = vec![0.001f32; tokens * e];
        for t in 0..tokens {
            gates[t * e] = 1.0;
        }
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: e, capacity: 10 };
        let routes: Vec<RouteOutput> = (0..4).map(|_| route(&gates, tokens, &spec)).collect();
        let plan = DispatchPlan::from_worker_routes(e, 10, 16, &routes);
        let recv = plan.recv_per_shard();
        assert_eq!(recv[0], 4 * 10, "only expert 0 keeps tokens, capped at capacity");
        assert_eq!(recv[1..].iter().sum::<u64>(), 0);
        assert!(plan.shard_load_cv() > 1.5);
        // worker 0's tokens to expert 0 are local; workers 1..3 cross
        assert_eq!(plan.cross_tokens(), 3 * 10);
    }
}
