//! Rust mirror of the MoE routing semantics (GShard top-k and k top-1
//! expert prototyping, with Eq.-2 capacity).
//!
//! The authoritative implementation lives in the lowered HLO (L2 + the
//! Pallas routing kernel); this mirror exists so that
//!  * the cluster simulator can replay routing decisions over synthetic
//!    gate distributions at paper scale (Tables 2, Fig 6) without XLA,
//!  * property tests can hammer the routing invariants (capacity never
//!    exceeded, positions unique, drops accounted) over random inputs,
//!  * the c_v load-balance analytics (Fig 1) have a host-side oracle.
//!
//! Three implementations share one semantics:
//!  * [`router::route`] — the naive reference: simple, obviously correct,
//!    allocation-heavy; kept as the oracle for property tests and as the
//!    baseline the routing microbench measures speedups against;
//!  * [`engine::RoutingEngine`] — the allocation-free, pool-parallel
//!    engine for callers that need per-assignment combine weights
//!    (`m6t bench --routing` tracks the gap in `BENCH_routing.json`);
//!  * [`fused`] — the single-pass **counts-only** kernel: per-tile gate
//!    generation fused with the argmax rounds into a per-expert demand
//!    histogram, never materializing the global gate matrix. Counts are
//!    order-independent (`kept_e = min(demand_e, C)`), so tile histograms
//!    merge exactly — the property the parallel (worker x layer) sharded
//!    step is built on (`m6t bench --step` tracks the end-to-end gap in
//!    `BENCH_step.json`).
//!
//! On top of the routers, [`dispatch`] accounts what D expert-parallel
//! workers actually exchange: per-(worker, expert) token counts, per-shard
//! load/drops, and exact all-to-all byte volumes — the layer the sharded
//! runtime (`runtime::shard`) and the observed-traffic cluster simulation
//! are built on.
//!
//! Downstream of routing, [`ffn`] holds the expert-batched FFN compute
//! kernels (tiled forward/backward GEMMs) that turn routed counts into
//! real per-expert compute for the `ComputeMode::Real` variants.

//! [`capacity`] closes the measurement loop: an elastic per-(layer,
//! shard) capacity controller that feeds the dispatch plans' exact
//! demand histograms back into next step's capacities under a constant
//! slot budget (off by default; the static path stays the bitwise
//! oracle).

pub mod capacity;
pub mod dispatch;
pub mod engine;
pub mod ffn;
pub mod fused;
pub mod microbench;
pub mod router;

pub use capacity::ElasticCapacity;
pub use dispatch::{DispatchPlan, DispatchSummary};
pub use engine::{RouterScratch, RoutingEngine};
pub use fused::FusedScratch;
pub use router::{route, RouteOutput, RouterSpec};
