//! Allocation-free, parallel routing engine — the hot-path replacement
//! for the naive [`route`](super::router::route) reference.
//!
//! The reference implementation allocates `Vec<Vec<bool>>` chosen-masks
//! and per-token selection vectors on every call; at 16k tokens that is
//! tens of thousands of heap allocations per routing round. The engine
//! keeps flat scratch buffers in a reusable [`RouterScratch`] and reuses
//! them across calls:
//!
//! * `chosen` is a flat `T x E` round-stamp array: a cell is "already
//!   selected this call" iff it holds the current generation stamp, so
//!   the buffer never needs clearing between calls;
//! * selection arenas (`sel_expert/sel_gate/sel_pos/sel_kept`) are flat
//!   `T x k` arrays reused call over call;
//! * assignments are emitted into a caller-owned [`RouteOutput`] whose
//!   vectors keep their capacity across steps ([`RoutingEngine::route_into`]).
//!
//! Routing splits into three phases:
//!
//! 1. **argmax** (parallel): each token's k-round argmax sequence depends
//!    only on its own gate row, so tokens are sharded across the
//!    [`WorkerPool`] — this is the O(k·T·E) bulk of the work;
//! 2. **capacity** (sequential, O(k·T)): slot positions come from a
//!    cumulative per-expert counter walked round-major then token-major —
//!    the exact cumsum semantics of the reference and the lowered HLO;
//! 3. **emit** (sequential, O(k·T)): combine gates, renormalized over all
//!    k selections (kept *and* dropped, per `python/compile/moe.py`)
//!    when k > 1, raw when k == 1.
//!
//! Determinism contract: outputs are a pure function of (gates, spec) —
//! identical across pool sizes, shard counts, and serial/parallel paths,
//! and identical to the naive reference (pinned by
//! `rust/tests/routing_properties.rs` and `rust/tests/routing_parity.rs`).
//!
//! Role note: callers that need per-assignment combine weights route
//! here; the counts-only hot path (native + sharded step statistics) now
//! runs the fused single-pass kernel ([`super::fused`]), which never
//! materializes the gate matrix — this engine's `route_counts_into` is
//! kept as the two-pass baseline `m6t bench --step` measures against and
//! as the bitwise oracle the fused parity tests compare to.

#![forbid(unsafe_code)]

use std::sync::Arc;

use crate::config::Routing;
use crate::util::pool::{self, WorkerPool};
use crate::util::shard::DisjointChunks;

use super::router::{Assignment, RouteOutput, RouterSpec};

/// Tokens per parallel work unit. Fixed (not derived from the pool size)
/// so the work decomposition — and therefore the output — is identical
/// no matter how many workers execute it.
const SHARD_TOKENS: usize = 512;

/// Below this many argmax candidate visits (`T * E * k`) the pool handoff
/// costs more than it saves; route on the calling thread instead. The
/// serial and parallel paths produce identical outputs.
const MIN_PARALLEL_WORK: usize = 1 << 15;

/// Flat, reusable scratch for the routing engine. Grows monotonically to
/// the largest shape routed; never shrinks, never cleared wholesale.
#[derive(Default)]
pub struct RouterScratch {
    /// T x E round-stamp array: `chosen[t * e + x] == generation` means
    /// expert `x` was already selected for token `t` in this call.
    chosen: Vec<u32>,
    generation: u32,
    /// T x k selected expert index per (token, round).
    sel_expert: Vec<u32>,
    /// T x k raw gate of each selection.
    sel_gate: Vec<f32>,
    /// T x k capacity slot of each selection (valid where kept).
    sel_pos: Vec<u32>,
    /// T x k whether the selection fit under capacity.
    sel_kept: Vec<bool>,
}

impl RouterScratch {
    /// Bump the generation stamp (re-zeroing only on growth or the
    /// once-in-2^32 wrap) and make sure the flat buffers cover
    /// `tokens x e` / `tokens x sels`.
    fn prepare(&mut self, tokens: usize, e: usize, sels: usize) -> u32 {
        if self.chosen.len() < tokens * e {
            self.chosen.clear();
            self.chosen.resize(tokens * e, 0);
            self.generation = 0;
        }
        if self.generation == u32::MAX {
            self.chosen.fill(0);
            self.generation = 0;
        }
        self.generation += 1;
        let n = tokens * sels;
        if self.sel_expert.len() < n {
            self.sel_expert.resize(n, 0);
            self.sel_gate.resize(n, 0.0);
            self.sel_pos.resize(n, 0);
            self.sel_kept.resize(n, false);
        }
        self.generation
    }
}

/// Reusable routing engine: scratch buffers plus the worker pool that
/// runs the argmax phase. One engine per thread of control; `route_into`
/// takes `&mut self` and reuses everything across calls.
pub struct RoutingEngine {
    scratch: RouterScratch,
    pool: Option<Arc<WorkerPool>>,
}

impl Default for RoutingEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl RoutingEngine {
    /// Engine on the process-wide pool.
    pub fn new() -> Self {
        Self { scratch: RouterScratch::default(), pool: None }
    }

    /// Engine on an injected pool — how the determinism tests pin
    /// identical outputs across pool sizes.
    pub fn with_pool(pool: Arc<WorkerPool>) -> Self {
        Self { scratch: RouterScratch::default(), pool: Some(pool) }
    }

    /// Route into a caller-owned output, reusing its allocations.
    /// Semantics are identical to [`route`](super::router::route).
    pub fn route_into(
        &mut self,
        gates: &[f32],
        tokens: usize,
        spec: &RouterSpec,
        out: &mut RouteOutput,
    ) {
        self.route_impl(gates, tokens, spec, out, true);
    }

    /// Counts-only routing: fills `load`, `demand`, and `dropped`, leaves
    /// `assignments` empty. For callers that never read the combine
    /// weights (the native backend's per-layer load statistics) this
    /// skips the emission phase — gate renormalization and one push per
    /// kept selection — entirely. Load/drop results are identical to
    /// [`RoutingEngine::route_into`].
    pub fn route_counts_into(
        &mut self,
        gates: &[f32],
        tokens: usize,
        spec: &RouterSpec,
        out: &mut RouteOutput,
    ) {
        self.route_impl(gates, tokens, spec, out, false);
    }

    fn route_impl(
        &mut self,
        gates: &[f32],
        tokens: usize,
        spec: &RouterSpec,
        out: &mut RouteOutput,
        emit: bool,
    ) {
        let e = spec.num_experts;
        assert_eq!(gates.len(), tokens * e, "gate matrix shape mismatch");
        out.assignments.clear();
        out.load.clear();
        out.load.resize(e, 0);
        out.demand.clear();
        out.demand.resize(e, 0);
        out.dropped = 0;
        match spec.routing {
            Routing::TopK(k) => {
                self.route_topk(gates, tokens, e, (k as usize).min(e), spec.capacity, out, emit)
            }
            Routing::Prototype(z) => {
                self.route_prototype(gates, tokens, e, z as usize, spec.capacity, out, emit)
            }
        }
    }

    /// Convenience wrapper allocating a fresh output.
    pub fn route(&mut self, gates: &[f32], tokens: usize, spec: &RouterSpec) -> RouteOutput {
        let mut out = RouteOutput::default();
        self.route_into(gates, tokens, spec, &mut out);
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn route_topk(
        &mut self,
        gates: &[f32],
        tokens: usize,
        e: usize,
        k: usize,
        capacity: usize,
        out: &mut RouteOutput,
        emit: bool,
    ) {
        if tokens == 0 || k == 0 {
            return;
        }
        let gen = self.scratch.prepare(tokens, e, k);

        // Phase 1 — per-token argmax sequences, sharded over tokens. Each
        // shard owns the token range [t0, t1) of every scratch buffer; the
        // disjoint carve makes that a checked property instead of a comment.
        {
            let sc = &mut self.scratch;
            let chosen_views = DisjointChunks::new(&mut sc.chosen[..tokens * e], SHARD_TOKENS * e);
            let sel_expert_views =
                DisjointChunks::new(&mut sc.sel_expert[..tokens * k], SHARD_TOKENS * k);
            let sel_gate_views =
                DisjointChunks::new(&mut sc.sel_gate[..tokens * k], SHARD_TOKENS * k);
            let body = |s: usize| {
                let t0 = s * SHARD_TOKENS;
                let t1 = (t0 + SHARD_TOKENS).min(tokens);
                let chosen = chosen_views.view(s);
                let sel_expert = sel_expert_views.view(s);
                let sel_gate = sel_gate_views.view(s);
                for (i, t) in (t0..t1).enumerate() {
                    let row = &gates[t * e..(t + 1) * e];
                    if k == 1 {
                        // top-1 fast path: a single round masks nothing,
                        // so the chosen-stamp array is never touched —
                        // selection is identical to the general path
                        let mut best = 0;
                        let mut best_g = f32::NEG_INFINITY;
                        for (x, &g) in row.iter().enumerate() {
                            if g > best_g {
                                best = x;
                                best_g = g;
                            }
                        }
                        sel_expert[i] = best as u32;
                        sel_gate[i] = best_g;
                        continue;
                    }
                    let ch = &mut chosen[i * e..(i + 1) * e];
                    for r in 0..k {
                        let mut best = usize::MAX;
                        let mut best_g = f32::NEG_INFINITY;
                        // testing the gate before the stamp keeps the
                        // chosen-array load off the common (non-max) path;
                        // `&&` makes the predicate identical either way
                        for (x, &g) in row.iter().enumerate() {
                            if g > best_g && ch[x] != gen {
                                best = x;
                                best_g = g;
                            }
                        }
                        debug_assert!(best != usize::MAX);
                        ch[best] = gen;
                        sel_expert[i * k + r] = best as u32;
                        sel_gate[i * k + r] = best_g;
                    }
                }
            };
            Self::run_sharded(self.pool.as_deref(), tokens, e * k, &body);
        }

        // Phase 2 — capacity slots, round-major then token-major: the
        // cumulative-counter order of the reference (and HLO cumsum).
        let sc = &mut self.scratch;
        for r in 0..k {
            for t in 0..tokens {
                let x = sc.sel_expert[t * k + r] as usize;
                out.demand[x] += 1;
                let pos = out.load[x];
                let kept = (pos as usize) < capacity;
                if kept {
                    out.load[x] += 1;
                } else {
                    out.dropped += 1;
                }
                sc.sel_pos[t * k + r] = pos;
                sc.sel_kept[t * k + r] = kept;
            }
        }

        // Phase 3 — emit, token-major. Renormalize over all k selections
        // (dropped ones included, per python/compile/moe.py) iff k > 1;
        // top-1 keeps the raw softmax gate.
        if !emit {
            return;
        }
        for t in 0..tokens {
            let base = t * k;
            let denom: f32 = if k > 1 {
                sc.sel_gate[base..base + k].iter().sum::<f32>() + 1e-9
            } else {
                1.0
            };
            for r in 0..k {
                if sc.sel_kept[base + r] {
                    out.assignments.push(Assignment {
                        token: t,
                        expert: sc.sel_expert[base + r] as usize,
                        position: sc.sel_pos[base + r] as usize,
                        gate: sc.sel_gate[base + r] / denom,
                    });
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn route_prototype(
        &mut self,
        gates: &[f32],
        tokens: usize,
        e: usize,
        z: usize,
        capacity: usize,
        out: &mut RouteOutput,
        emit: bool,
    ) {
        assert!(z > 0, "prototype count must be positive");
        assert!(e % z == 0, "experts {e} not divisible by prototypes {z}");
        if tokens == 0 {
            return;
        }
        let f = e / z;
        self.scratch.prepare(tokens, 0, z); // no chosen-mask needed: one round

        // Phase 1 — per-token, per-prototype argmax, sharded over tokens
        // (disjoint token ranges per shard; see route_topk).
        {
            let sc = &mut self.scratch;
            let sel_expert_views =
                DisjointChunks::new(&mut sc.sel_expert[..tokens * z], SHARD_TOKENS * z);
            let sel_gate_views =
                DisjointChunks::new(&mut sc.sel_gate[..tokens * z], SHARD_TOKENS * z);
            let body = |s: usize| {
                let t0 = s * SHARD_TOKENS;
                let t1 = (t0 + SHARD_TOKENS).min(tokens);
                let sel_expert = sel_expert_views.view(s);
                let sel_gate = sel_gate_views.view(s);
                for (i, t) in (t0..t1).enumerate() {
                    let row = &gates[t * e..(t + 1) * e];
                    for p in 0..z {
                        let group = &row[p * f..(p + 1) * f];
                        let mut best = 0;
                        let mut best_g = f32::NEG_INFINITY;
                        for (x, &g) in group.iter().enumerate() {
                            if g > best_g {
                                best = x;
                                best_g = g;
                            }
                        }
                        sel_expert[i * z + p] = (p * f + best) as u32;
                        sel_gate[i * z + p] = best_g;
                    }
                }
            };
            Self::run_sharded(self.pool.as_deref(), tokens, e, &body);
        }

        // Phase 2+3 — prototypes are independent routers; walk them in
        // prototype-major order (the reference's emission order). Gates
        // stay raw: no cross-prototype renormalization (paper Eq. 3).
        let sc = &self.scratch;
        for p in 0..z {
            for t in 0..tokens {
                let x = sc.sel_expert[t * z + p] as usize;
                out.demand[x] += 1;
                let pos = out.load[x] as usize;
                if pos < capacity {
                    out.load[x] += 1;
                    if emit {
                        out.assignments.push(Assignment {
                            token: t,
                            expert: x,
                            position: pos,
                            gate: sc.sel_gate[t * z + p],
                        });
                    }
                } else {
                    out.dropped += 1;
                }
            }
        }
    }

    /// Run `body(shard)` over `ceil(tokens / SHARD_TOKENS)` shards — on
    /// the pool when the total work justifies the handoff, inline
    /// otherwise (`pool::run_shards` policy; identical outputs either way).
    /// Associated (not a method) so callers can keep `&mut` borrows of
    /// `self.scratch` live across the call.
    fn run_sharded(
        pool: Option<&WorkerPool>,
        tokens: usize,
        work_per_token: usize,
        body: &(dyn Fn(usize) + Sync),
    ) {
        let shards = tokens.div_ceil(SHARD_TOKENS);
        pool::run_shards(pool, shards, tokens * work_per_token, MIN_PARALLEL_WORK, body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::router::{route, softmax_gates};
    use crate::util::rng::Rng;

    fn random_gates(tokens: usize, e: usize, z: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let logits: Vec<f32> = (0..tokens * e).map(|_| rng.normal() as f32).collect();
        softmax_gates(&logits, tokens, e, z)
    }

    fn assert_same(a: &RouteOutput, b: &RouteOutput) {
        crate::testing::route_outputs_bitwise_eq(a, b).unwrap();
    }

    #[test]
    fn matches_reference_on_mixed_shapes() {
        let mut engine = RoutingEngine::new();
        for (tokens, e, routing, capacity, seed) in [
            (64, 8, Routing::TopK(1), 4, 1u64),
            (64, 8, Routing::TopK(2), 4, 2),
            (200, 16, Routing::TopK(4), 13, 3),
            (31, 4, Routing::TopK(4), 31, 4), // k == E
            (128, 16, Routing::Prototype(2), 9, 5),
            (128, 16, Routing::Prototype(4), 2, 6), // tight capacity
            (1, 2, Routing::TopK(2), 1, 7),
        ] {
            let z = routing.prototypes().max(1) as usize;
            let gates = random_gates(tokens, e, z, seed);
            let spec = RouterSpec { routing, num_experts: e, capacity };
            let expect = route(&gates, tokens, &spec);
            let got = engine.route(&gates, tokens, &spec);
            assert_same(&got, &expect);
        }
    }

    #[test]
    fn scratch_reuse_across_shrinking_shapes_is_clean() {
        // route a big shape, then a small one: stale stamps/selections
        // from the big call must not leak into the small call
        let mut engine = RoutingEngine::new();
        let spec_big = RouterSpec { routing: Routing::TopK(4), num_experts: 16, capacity: 64 };
        let gates_big = random_gates(600, 16, 1, 11);
        let _ = engine.route(&gates_big, 600, &spec_big);
        let spec_small = RouterSpec { routing: Routing::TopK(2), num_experts: 4, capacity: 3 };
        let gates_small = random_gates(10, 4, 1, 12);
        let expect = route(&gates_small, 10, &spec_small);
        let got = engine.route(&gates_small, 10, &spec_small);
        assert_same(&got, &expect);
    }

    #[test]
    fn identical_across_pool_sizes() {
        // big enough to cross MIN_PARALLEL_WORK and span several shards
        // (kept just above the threshold under Miri, where every gate
        // visit is interpreted)
        let tokens = if cfg!(miri) { 2 * SHARD_TOKENS + 37 } else { 4 * SHARD_TOKENS + 37 };
        let gates = random_gates(tokens, 16, 1, 21);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 16, capacity: 200 };
        let expect = RoutingEngine::with_pool(Arc::new(WorkerPool::new(0)))
            .route(&gates, tokens, &spec);
        for workers in [1usize, 2, pool::default_workers()] {
            let got = RoutingEngine::with_pool(Arc::new(WorkerPool::new(workers)))
                .route(&gates, tokens, &spec);
            assert_same(&got, &expect);
        }
    }

    #[test]
    fn top1_gate_is_raw_not_renormalized() {
        // headline bugfix: k = 1 must keep the raw softmax gate
        let tokens = 32;
        let e = 8;
        let gates = random_gates(tokens, e, 1, 33);
        let spec = RouterSpec { routing: Routing::TopK(1), num_experts: e, capacity: tokens };
        let mut engine = RoutingEngine::new();
        let out = engine.route(&gates, tokens, &spec);
        assert_eq!(out.assignments.len(), tokens);
        for a in &out.assignments {
            let row = &gates[a.token * e..(a.token + 1) * e];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(a.gate.to_bits(), max.to_bits(), "gate must be the raw row max");
            assert!(a.gate < 1.0, "softmax over 8 experts cannot saturate");
        }
    }

    #[test]
    fn counts_only_route_matches_full_route() {
        let mut engine = RoutingEngine::new();
        let mut counts = RouteOutput::default();
        for (routing, seed) in
            [(Routing::TopK(2), 51u64), (Routing::TopK(1), 52), (Routing::Prototype(4), 53)]
        {
            let z = routing.prototypes().max(1) as usize;
            let gates = random_gates(96, 8, z, seed);
            let spec = RouterSpec { routing, num_experts: 8, capacity: 7 };
            let full = engine.route(&gates, 96, &spec);
            engine.route_counts_into(&gates, 96, &spec, &mut counts);
            assert_eq!(counts.load, full.load);
            assert_eq!(counts.demand, full.demand);
            assert_eq!(counts.dropped, full.dropped);
            assert!(counts.assignments.is_empty(), "counts-only must not emit");
        }
    }

    #[test]
    fn route_output_reuse_resets_state() {
        let mut engine = RoutingEngine::new();
        let gates = random_gates(40, 8, 1, 44);
        let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 8, capacity: 5 };
        let mut out = RouteOutput::default();
        engine.route_into(&gates, 40, &spec, &mut out);
        let first = (out.assignments.clone(), out.load.clone(), out.dropped);
        // second call into the same output must fully overwrite it
        engine.route_into(&gates, 40, &spec, &mut out);
        assert_eq!(out.assignments, first.0);
        assert_eq!(out.load, first.1);
        assert_eq!(out.dropped, first.2);
    }
}
