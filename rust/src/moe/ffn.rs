//! Expert-batched FFN kernels: the Rust port of the paper's compute hot
//! spot (`python/compile/kernels/moe_ffn.py`), the two expert matmuls
//! §A.3 profiles at ~98% of the MoE layer's forward FLOPs:
//!
//! ```text
//!   x (E, C, M) -> h = x @ w1 (E, C, I) -> a = gelu(h) -> a @ w2 (E, C, M)
//! ```
//!
//! The tiled kernel mirrors the Pallas grid exactly: one **(expert,
//! I-tile)** pair per work unit on the [`WorkerPool`], with the
//! `(C, I_blk)` activation tile living in thread-local scratch (the VMEM
//! analogue) and never materializing the full `(E, C, I)` hidden matrix.
//! Each forward unit writes its partial `(C, M)` down-projection into a
//! disjoint slice of a caller-owned buffer; partials merge serially in
//! fixed tile order, so results are **bitwise identical across pool
//! sizes** — the same determinism contract as `route_grid_counts`.
//!
//! The backward pass rematerializes `h` and `a = gelu(h)` per tile
//! instead of storing them (the kernel's custom-VJP strategy): each unit
//! owns the `[e, :, i0..i1]` slice of `dw1` and `[e, i0..i1, :]` slice of
//! `dw2` outright, so weight grads need no merge at all; `dx` partials
//! (only needed by parity tests — the training path feeds a frozen slab)
//! merge in tile order like the forward.
//!
//! Memory layout is plain row-major f32 with the inner loops arranged so
//! every innermost access is contiguous (axpy over rows of `w1`/`w2`,
//! dot over rows of `g`/`w2`) — the shape LLVM autovectorizes. The
//! `*_naive` twins use the textbook strided dot-product order and are the
//! baseline `m6t bench --ffn` measures the speedup against.

#![forbid(unsafe_code)]

use std::cell::RefCell;

use anyhow::{bail, Result};

use crate::util::pool::{self, WorkerPool};
use crate::util::shard::{DisjointChunks, StridedViews};

/// Default inner tile over the intermediate dimension — same constant as
/// `moe_ffn.DEFAULT_I_BLOCK` (sized for the paper's base geometry VMEM
/// budget; on CPU it keeps the `(C, I_blk)` tile L2-resident).
pub const DEFAULT_I_BLOCK: usize = 512;

/// Below this many flops per call the pool handoff costs more than the
/// GEMM work it spreads; run the units serially instead (bitwise
/// identical either way).
const MIN_PARALLEL_FLOPS: u64 = 1 << 16;

// tanh-GeLU constants, bit-for-bit the ones in `kernels/ref.py`.
const SQRT_2_OVER_PI: f64 = 0.7978845608028654;
const GELU_C: f64 = 0.044715;

/// tanh-approximated GeLU, matching `ref.gelu` in f32.
#[inline]
pub fn gelu(x: f32) -> f32 {
    let s = SQRT_2_OVER_PI as f32;
    let c = GELU_C as f32;
    let u = s * (x + c * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// Analytic d gelu / dx, matching `ref.gelu_grad` in f32.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let s = SQRT_2_OVER_PI as f32;
    let c = GELU_C as f32;
    let u = s * (x + c * x * x * x);
    let t = u.tanh();
    let du = s * (1.0 + 3.0 * c * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Mirror of `moe_ffn._pick_i_block`: clamp the requested block to I,
/// then halve until it divides I exactly.
pub fn pick_i_block(intermediate: usize, requested: Option<usize>) -> Result<usize> {
    if intermediate == 0 {
        bail!("intermediate dimension must be positive");
    }
    let mut blk = requested.unwrap_or(DEFAULT_I_BLOCK).min(intermediate);
    while blk > 0 && intermediate % blk != 0 {
        blk /= 2;
    }
    if blk == 0 {
        bail!("intermediate={intermediate} has no power-of-2 tile");
    }
    Ok(blk)
}

/// Geometry of one expert-batched FFN application:
/// `x (E, C, M)`, `w1 (E, M, I)`, `w2 (E, I, M)`, `out (E, C, M)`.
#[derive(Debug, Clone, Copy)]
pub struct FfnShape {
    pub experts: usize,      // E
    pub capacity: usize,     // C
    pub hidden: usize,       // M
    pub intermediate: usize, // I
    pub i_block: usize,
}

impl FfnShape {
    pub fn new(
        experts: usize,
        capacity: usize,
        hidden: usize,
        intermediate: usize,
    ) -> Result<Self> {
        Self::with_block(experts, capacity, hidden, intermediate, None)
    }

    pub fn with_block(
        experts: usize,
        capacity: usize,
        hidden: usize,
        intermediate: usize,
        requested: Option<usize>,
    ) -> Result<Self> {
        if experts == 0 || capacity == 0 || hidden == 0 {
            bail!("FFN shape has a zero dimension: E={experts} C={capacity} M={hidden}");
        }
        let i_block = pick_i_block(intermediate, requested)?;
        Ok(Self { experts, capacity, hidden, intermediate, i_block })
    }

    /// I-tiles per expert; the pool grid is `experts x n_tiles` units.
    pub fn n_tiles(&self) -> usize {
        self.intermediate / self.i_block
    }
    pub fn units(&self) -> usize {
        self.experts * self.n_tiles()
    }
    pub fn x_len(&self) -> usize {
        self.experts * self.capacity * self.hidden
    }
    pub fn w1_len(&self) -> usize {
        self.experts * self.hidden * self.intermediate
    }
    pub fn w2_len(&self) -> usize {
        self.experts * self.intermediate * self.hidden
    }
    /// Forward FLOPs: the two GEMMs at mul+add = 2 (`moe_ffn.fwd_flops`).
    pub fn fwd_flops(&self) -> u64 {
        let (e, c, m, i) = (
            self.experts as u64,
            self.capacity as u64,
            self.hidden as u64,
            self.intermediate as u64,
        );
        e * (2 * c * m * i + 2 * c * i * m)
    }
}

/// Per-thread `(C, I_blk)` tile buffers — the VMEM analogue. Thread-local
/// so pool units never contend or allocate after warmup.
#[derive(Default)]
struct TileScratch {
    h: Vec<f32>,
    a: Vec<f32>,
    da: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<TileScratch> = RefCell::new(TileScratch::default());
}

fn with_tile_scratch<R>(f: impl FnOnce(&mut TileScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// The read-only operands of one expert-batched FFN application:
/// `x (E, C, M)`, `w1 (E, M, I)`, `w2 (E, I, M)`.
#[derive(Clone, Copy)]
pub struct FfnInputs<'a> {
    pub x: &'a [f32],
    pub w1: &'a [f32],
    pub w2: &'a [f32],
}

/// The gradient outputs of [`bwd_tiled`]: `dw1 (E, M, I)`, `dw2 (E, I, M)`
/// fully overwritten; `dx (E, C, M)` optional (the training path feeds a
/// frozen slab and skips it).
pub struct FfnGrads<'a> {
    pub dw1: &'a mut [f32],
    pub dw2: &'a mut [f32],
    pub dx: Option<&'a mut [f32]>,
}

fn check_shapes(shape: &FfnShape, x: &[f32], w1: &[f32], w2: &[f32], out: &[f32]) {
    assert_eq!(x.len(), shape.x_len(), "x shape mismatch");
    assert_eq!(w1.len(), shape.w1_len(), "w1 shape mismatch");
    assert_eq!(w2.len(), shape.w2_len(), "w2 shape mismatch");
    assert_eq!(out.len(), shape.x_len(), "out shape mismatch");
}

/// One forward (expert, I-tile) unit: `dst (C, M) = gelu(x_e @ w1_tile)
/// @ w2_tile`. `h` accumulates in m-order (axpy), so the hidden tile is
/// bitwise identical to the naive dot-product order.
fn fwd_tile(
    sc: &mut TileScratch,
    x: &[f32],  // (C, M) — one expert's slab
    w1: &[f32], // (M, I) — one expert's up-projection
    w2: &[f32], // (I, M)
    dst: &mut [f32],
    shape: FfnShape,
    i0: usize,
) {
    let FfnShape { capacity: c, hidden: m, intermediate: i, i_block: blk, .. } = shape;
    let h = &mut sc.h;
    h.clear();
    h.resize(c * blk, 0.0);
    for t in 0..c {
        let xr = &x[t * m..(t + 1) * m];
        let hr = &mut h[t * blk..(t + 1) * blk];
        for (mm, &xv) in xr.iter().enumerate() {
            let wr = &w1[mm * i + i0..mm * i + i0 + blk];
            for (hv, &wv) in hr.iter_mut().zip(wr) {
                *hv += xv * wv;
            }
        }
    }
    let a = &mut sc.a;
    a.clear();
    a.extend(h.iter().map(|&hv| gelu(hv)));
    dst.fill(0.0);
    for t in 0..c {
        let ar = &a[t * blk..(t + 1) * blk];
        let dr = &mut dst[t * m..(t + 1) * m];
        for (ii, &av) in ar.iter().enumerate() {
            let wr = &w2[(i0 + ii) * m..(i0 + ii + 1) * m];
            for (dv, &wv) in dr.iter_mut().zip(wr) {
                *dv += av * wv;
            }
        }
    }
}

/// Cache-tiled forward: `out = gelu(x @ w1) @ w2` per expert, one
/// (expert, I-tile) unit per pool task. `partial` is a caller-owned
/// reusable buffer (resized to `units x C x M`); tile partials merge
/// serially in fixed tile order, so the output is bitwise identical
/// across pool sizes (including a zero-worker pool).
pub fn fwd_tiled(
    pool_ref: &WorkerPool,
    shape: FfnShape,
    inputs: FfnInputs<'_>,
    out: &mut [f32],
    partial: &mut Vec<f32>,
) {
    let FfnInputs { x, w1, w2 } = inputs;
    check_shapes(&shape, x, w1, w2, out);
    let FfnShape { experts: e, capacity: c, hidden: m, intermediate: i, i_block: blk } = shape;
    let tiles = shape.n_tiles();
    let units = shape.units();
    let cm = c * m;
    if partial.len() < units * cm {
        partial.resize(units * cm, 0.0);
    }
    {
        // unit `u` owns the disjoint range [u * cm, (u + 1) * cm) of
        // `partial`; the pool joins every unit before the merge reads it
        let views = DisjointChunks::new(&mut partial[..units * cm], cm);
        let body = |u: usize| {
            let e_idx = u / tiles;
            let i0 = (u % tiles) * blk;
            let xe = &x[e_idx * cm..(e_idx + 1) * cm];
            let w1e = &w1[e_idx * m * i..(e_idx + 1) * m * i];
            let w2e = &w2[e_idx * i * m..(e_idx + 1) * i * m];
            let dst = views.view(u);
            with_tile_scratch(|sc| fwd_tile(sc, xe, w1e, w2e, dst, shape, i0));
        };
        pool::run_shards(
            Some(pool_ref),
            units,
            shape.fwd_flops().min(usize::MAX as u64) as usize,
            MIN_PARALLEL_FLOPS as usize,
            &body,
        );
    }
    // exact merge in fixed tile order per expert: same association no
    // matter how many workers computed the partials
    for e_idx in 0..e {
        let out_e = &mut out[e_idx * cm..(e_idx + 1) * cm];
        let unit0 = e_idx * tiles;
        out_e.copy_from_slice(&partial[unit0 * cm..(unit0 + 1) * cm]);
        for t_idx in 1..tiles {
            let src = &partial[(unit0 + t_idx) * cm..(unit0 + t_idx + 1) * cm];
            for (acc, &v) in out_e.iter_mut().zip(src) {
                *acc += v;
            }
        }
    }
}

/// Naive baseline: untiled per-expert dot-product GEMMs. The first
/// matmul walks `w1` columns at stride I and the second walks `w2`
/// columns at stride M — the textbook order the tiled kernel exists to
/// beat. `h_scratch` holds one expert's full `(C, I)` hidden matrix.
pub fn fwd_naive(
    shape: FfnShape,
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    out: &mut [f32],
    h_scratch: &mut Vec<f32>,
) {
    check_shapes(&shape, x, w1, w2, out);
    let FfnShape { experts: e, capacity: c, hidden: m, intermediate: i, .. } = shape;
    let cm = c * m;
    h_scratch.clear();
    h_scratch.resize(c * i, 0.0);
    for e_idx in 0..e {
        let xe = &x[e_idx * cm..(e_idx + 1) * cm];
        let w1e = &w1[e_idx * m * i..(e_idx + 1) * m * i];
        let w2e = &w2[e_idx * i * m..(e_idx + 1) * i * m];
        for t in 0..c {
            for ii in 0..i {
                let mut acc = 0.0f32;
                for mm in 0..m {
                    acc += xe[t * m + mm] * w1e[mm * i + ii];
                }
                h_scratch[t * i + ii] = acc;
            }
        }
        for hv in h_scratch.iter_mut() {
            *hv = gelu(*hv);
        }
        let out_e = &mut out[e_idx * cm..(e_idx + 1) * cm];
        for t in 0..c {
            for mm in 0..m {
                let mut acc = 0.0f32;
                for ii in 0..i {
                    acc += h_scratch[t * i + ii] * w2e[ii * m + mm];
                }
                out_e[t * m + mm] = acc;
            }
        }
    }
}

/// Tiled backward with activation rematerialization. Per (expert,
/// I-tile) unit, recomputes `h` and `a = gelu(h)`, then emits
///
/// ```text
///   dh = (g @ w2_tile^T) * gelu'(h)
///   dw1[e, :, i0..i1] = x_e^T @ dh        (unit-owned slice, no merge)
///   dw2[e, i0..i1, :] = a^T @ g_e         (unit-owned slice, no merge)
///   dx_e += dh @ w1_tile^T                (partials merged in tile order)
/// ```
///
/// `dw1`/`dw2` are fully overwritten. `dx` is optional: the training
/// path feeds a frozen input slab and skips it; parity tests pass
/// `Some` to check the full VJP against `ref.py`.
pub fn bwd_tiled(
    pool_ref: &WorkerPool,
    shape: FfnShape,
    inputs: FfnInputs<'_>,
    g: &[f32],
    grads: FfnGrads<'_>,
    partial: &mut Vec<f32>,
) {
    let FfnInputs { x, w1, w2 } = inputs;
    let FfnGrads { dw1, dw2, mut dx } = grads;
    check_shapes(&shape, x, w1, w2, g);
    assert_eq!(dw1.len(), shape.w1_len(), "dw1 shape mismatch");
    assert_eq!(dw2.len(), shape.w2_len(), "dw2 shape mismatch");
    let FfnShape { experts: e, capacity: c, hidden: m, intermediate: i, i_block: blk } = shape;
    let tiles = shape.n_tiles();
    let units = shape.units();
    let cm = c * m;
    let want_dx = dx.is_some();
    if let Some(dxs) = dx.as_deref() {
        assert_eq!(dxs.len(), shape.x_len(), "dx shape mismatch");
    }
    if want_dx && partial.len() < units * cm {
        partial.resize(units * cm, 0.0);
    }
    {
        // unit `u = e_idx * tiles + tile` owns dw1[e, :, i0..i0+blk) —
        // `m` rows of `blk` columns at stride I — and the contiguous
        // dw2[e, i0..i0+blk, :); the strided carve encodes exactly those
        // index sets, so tiles of the same expert cannot alias
        let dw1_views = StridedViews::new(dw1, e, m, tiles, blk);
        let dw2_views = StridedViews::new(dw2, e, 1, tiles, blk * m);
        // dx partials: unit `u` owns [u * cm, (u + 1) * cm)
        let dx_views = if want_dx {
            Some(DisjointChunks::new(&mut partial[..units * cm], cm))
        } else {
            None
        };
        let body = |u: usize| {
            let e_idx = u / tiles;
            let i0 = (u % tiles) * blk;
            let xe = &x[e_idx * cm..(e_idx + 1) * cm];
            let ge = &g[e_idx * cm..(e_idx + 1) * cm];
            let w1e = &w1[e_idx * m * i..(e_idx + 1) * m * i];
            let w2e = &w2[e_idx * i * m..(e_idx + 1) * i * m];
            let mut dw1t = dw1_views.view(u);
            let mut dw2t = dw2_views.view(u);
            let dw2_tile = dw2t.row(0);
            with_tile_scratch(|sc| {
                // rematerialize h and a for this tile
                let (h, a, da) = (&mut sc.h, &mut sc.a, &mut sc.da);
                h.clear();
                h.resize(c * blk, 0.0);
                for t in 0..c {
                    let xr = &xe[t * m..(t + 1) * m];
                    let hr = &mut h[t * blk..(t + 1) * blk];
                    for (mm, &xv) in xr.iter().enumerate() {
                        let wr = &w1e[mm * i + i0..mm * i + i0 + blk];
                        for (hv, &wv) in hr.iter_mut().zip(wr) {
                            *hv += xv * wv;
                        }
                    }
                }
                a.clear();
                a.extend(h.iter().map(|&hv| gelu(hv)));
                // da = g @ w2_tile^T (contiguous dot), then dh in place
                da.clear();
                da.resize(c * blk, 0.0);
                for t in 0..c {
                    let gr = &ge[t * m..(t + 1) * m];
                    let dr = &mut da[t * blk..(t + 1) * blk];
                    for (ii, dv) in dr.iter_mut().enumerate() {
                        let wr = &w2e[(i0 + ii) * m..(i0 + ii + 1) * m];
                        let mut acc = 0.0f32;
                        for (&gv, &wv) in gr.iter().zip(wr) {
                            acc += gv * wv;
                        }
                        *dv = acc;
                    }
                }
                for (dv, &hv) in da.iter_mut().zip(h.iter()) {
                    *dv *= gelu_grad(hv);
                }
                // dw1 tile: dw1[e, mm, i0..i1] = sum_t x[t, mm] * dh[t, :]
                for mm in 0..m {
                    dw1t.row(mm).fill(0.0);
                }
                for t in 0..c {
                    let dhr = &da[t * blk..(t + 1) * blk];
                    let xr = &xe[t * m..(t + 1) * m];
                    for (mm, &xv) in xr.iter().enumerate() {
                        let dst = dw1t.row(mm);
                        for (dv, &dhv) in dst.iter_mut().zip(dhr) {
                            *dv += xv * dhv;
                        }
                    }
                }
                // dw2 tile: dw2[e, i0+ii, :] = sum_t a[t, ii] * g[t, :]
                dw2_tile.fill(0.0);
                for t in 0..c {
                    let ar = &a[t * blk..(t + 1) * blk];
                    let gr = &ge[t * m..(t + 1) * m];
                    for (ii, &av) in ar.iter().enumerate() {
                        let dst = &mut dw2_tile[ii * m..(ii + 1) * m];
                        for (dv, &gv) in dst.iter_mut().zip(gr) {
                            *dv += av * gv;
                        }
                    }
                }
                // dx partial: dh @ w1_tile^T (contiguous dot)
                if let Some(views) = &dx_views {
                    let dst = views.view(u);
                    for t in 0..c {
                        let dhr = &da[t * blk..(t + 1) * blk];
                        let dr = &mut dst[t * m..(t + 1) * m];
                        for (mm, dv) in dr.iter_mut().enumerate() {
                            let wr = &w1e[mm * i + i0..mm * i + i0 + blk];
                            let mut acc = 0.0f32;
                            for (&dhv, &wv) in dhr.iter().zip(wr) {
                                acc += dhv * wv;
                            }
                            *dv = acc;
                        }
                    }
                }
            });
        };
        pool::run_shards(
            Some(pool_ref),
            units,
            (3 * shape.fwd_flops()).min(usize::MAX as u64) as usize,
            MIN_PARALLEL_FLOPS as usize,
            &body,
        );
    }
    if let Some(dxs) = dx.as_deref_mut() {
        for e_idx in 0..e {
            let dx_e = &mut dxs[e_idx * cm..(e_idx + 1) * cm];
            let unit0 = e_idx * tiles;
            dx_e.copy_from_slice(&partial[unit0 * cm..(unit0 + 1) * cm]);
            for t_idx in 1..tiles {
                let src = &partial[(unit0 + t_idx) * cm..(unit0 + t_idx + 1) * cm];
                for (acc, &v) in dx_e.iter_mut().zip(src) {
                    *acc += v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn fill(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() as f32) * scale).collect()
    }

    fn rel_close(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.iter().zip(b).all(|(&x, &y)| (x - y).abs() <= tol * y.abs().max(1.0))
    }

    #[test]
    fn pick_i_block_mirrors_python() {
        assert_eq!(pick_i_block(4096, None).unwrap(), 512);
        assert_eq!(pick_i_block(256, None).unwrap(), 256);
        assert_eq!(pick_i_block(24, None).unwrap(), 24);
        assert_eq!(pick_i_block(24, Some(8)).unwrap(), 8);
        assert_eq!(pick_i_block(48, Some(36)).unwrap(), 4); // 36 -> 18 -> 9 -> 4
        assert!(pick_i_block(0, None).is_err());
    }

    #[test]
    fn tiled_matches_naive_forward() {
        let shape = FfnShape::with_block(3, 5, 8, 24, Some(8)).unwrap();
        let mut rng = Rng::new(11);
        let x = fill(&mut rng, shape.x_len(), 1.0);
        let w1 = fill(&mut rng, shape.w1_len(), 0.1);
        let w2 = fill(&mut rng, shape.w2_len(), 0.1);
        let pool = WorkerPool::new(2);
        let mut out_t = vec![0.0; shape.x_len()];
        let mut out_n = vec![0.0; shape.x_len()];
        let mut partial = Vec::new();
        let mut h = Vec::new();
        fwd_tiled(&pool, shape, FfnInputs { x: &x, w1: &w1, w2: &w2 }, &mut out_t, &mut partial);
        fwd_naive(shape, &x, &w1, &w2, &mut out_n, &mut h);
        assert!(rel_close(&out_t, &out_n, 1e-5), "tiled vs naive forward diverged");
    }

    #[test]
    fn forward_bitwise_stable_across_pools() {
        let shape = FfnShape::with_block(4, 6, 16, 32, Some(8)).unwrap();
        let mut rng = Rng::new(7);
        let x = fill(&mut rng, shape.x_len(), 1.0);
        let w1 = fill(&mut rng, shape.w1_len(), 0.05);
        let w2 = fill(&mut rng, shape.w2_len(), 0.05);
        let mut reference: Option<Vec<u32>> = None;
        for workers in [0usize, 1, 3] {
            let pool = Arc::new(WorkerPool::new(workers));
            let mut out = vec![0.0; shape.x_len()];
            let mut partial = Vec::new();
            fwd_tiled(&pool, shape, FfnInputs { x: &x, w1: &w1, w2: &w2 }, &mut out, &mut partial);
            let bits: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
            match &reference {
                None => reference = Some(bits),
                Some(r) => assert_eq!(&bits, r, "pool size {workers} diverged"),
            }
        }
    }

    #[test]
    fn backward_bitwise_stable_across_pools_with_dx() {
        let shape = FfnShape::with_block(2, 4, 8, 16, Some(4)).unwrap();
        let mut rng = Rng::new(23);
        let x = fill(&mut rng, shape.x_len(), 1.0);
        let w1 = fill(&mut rng, shape.w1_len(), 0.1);
        let w2 = fill(&mut rng, shape.w2_len(), 0.1);
        let g = fill(&mut rng, shape.x_len(), 0.01);
        let mut reference: Option<Vec<u32>> = None;
        for workers in [0usize, 2] {
            let pool = Arc::new(WorkerPool::new(workers));
            let mut dw1 = vec![0.0; shape.w1_len()];
            let mut dw2 = vec![0.0; shape.w2_len()];
            let mut dx = vec![0.0; shape.x_len()];
            let mut partial = Vec::new();
            bwd_tiled(
                &pool,
                shape,
                FfnInputs { x: &x, w1: &w1, w2: &w2 },
                &g,
                FfnGrads { dw1: &mut dw1, dw2: &mut dw2, dx: Some(&mut dx) },
                &mut partial,
            );
            let bits: Vec<u32> = dw1
                .iter()
                .chain(dw2.iter())
                .chain(dx.iter())
                .map(|v| v.to_bits())
                .collect();
            match &reference {
                None => reference = Some(bits),
                Some(r) => assert_eq!(&bits, r, "pool size {workers} diverged"),
            }
        }
    }

    #[test]
    fn gelu_limits() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4, "gelu(x) -> x for large x");
        assert!(gelu(-10.0).abs() < 1e-4, "gelu(x) -> 0 for very negative x");
        assert!((gelu_grad(10.0) - 1.0).abs() < 1e-4);
        assert!(gelu_grad(-10.0).abs() < 1e-4);
    }
}
