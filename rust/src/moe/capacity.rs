//! Elastic per-(layer, shard) expert capacity under a fixed slot budget.
//!
//! The static runtime prices every expert with one Eq.-2 capacity
//! `C = ceil(k_eff·T/E·γ)` ([`ModelConfig::capacity`]): hot experts drop
//! the demand above `C` while cold experts pad their unused slots — the
//! drop/padding trade Switch Transformers measures empirically with the
//! capacity *factor*. This module makes the knob adaptive per (layer,
//! shard) without spending any extra compute: a controller consumes the
//! exact per-expert demand histograms the dispatch plan already emits,
//! and reallocates whole slots from padding-dominated shards to
//! drop-dominated ones under the hard budget
//!
//! ```text
//!   Σ_s caps[l][s] = D · C      (every layer l, caps[l][s] >= 1)
//! ```
//!
//! so the per-worker slot total `Σ_e caps[shard(e)] = E·C` — and with it
//! the padded expert-compute cost — is exactly the static path's.
//!
//! **Controller law.** Per (layer, expert) the controller tracks an EMA
//! (β = 0.5) of the worst-case demand across workers, and takes the
//! conservative estimate `est = ceil(max(ema, last))` (growth is
//! immediate, shrink is EMA-gradual). Each layer's capacities are then
//! re-derived from scratch by greedy water-filling, warm-started at the
//! static `C`: repeatedly move one slot from the shard where removing it
//! strands the fewest estimated tokens (`loss = #{e in s : est_e >=
//! cap_s}`) to the shard where adding it recovers the most (`gain =
//! #{e in s : est_e >= cap_s + 1}`), while `gain > loss`. Every move
//! strictly reduces estimated drops, so — with demand estimated exactly —
//! elastic drops are never worse than static drops; ties break on the
//! lowest shard index and the procedure is single-threaded, so the caps
//! are a deterministic pure function of the (seeded) demand history.
//!
//! The controller is *off* by default: [`runtime::shard::ShardedRun`]
//! (`crate::runtime::shard`) only consults it behind
//! `set_elastic_capacity(true)`, and the static path stays the bitwise
//! oracle every determinism test pins.
#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// EMA decay of the per-expert worst-case demand tracker. 0.5 keeps the
/// controller responsive within a handful of steps (benches run tens of
/// steps) while still smoothing single-step routing noise.
pub const DEMAND_EMA_BETA: f64 = 0.5;

/// Per-(layer, shard) capacity controller. See the module docs for the
/// law; [`ElasticCapacity::observe`] ingests one step's demand,
/// [`ElasticCapacity::caps_layer`] exposes the capacities to apply on the
/// *next* step (capacities are always derived from strictly earlier
/// steps, so applying them is causal and replay-deterministic).
#[derive(Debug, Clone)]
pub struct ElasticCapacity {
    layers: usize,
    experts: usize,
    shards: usize,
    experts_per_shard: usize,
    base_capacity: usize,
    /// L x E: EMA of the per-step max-over-workers demand
    ema: Vec<f64>,
    /// L x E: conservative working estimate ceil(max(ema, last))
    est: Vec<u32>,
    /// L x S: current per-shard capacities (sum = shards * base per layer)
    caps: Vec<u32>,
    steps_observed: u64,
}

impl ElasticCapacity {
    /// Controller over `layers` x `shards` with the static Eq.-2
    /// `base_capacity` as both the warm start and the per-layer budget
    /// (`shards * base_capacity` slots).
    pub fn new(
        layers: usize,
        experts: usize,
        shards: usize,
        base_capacity: usize,
    ) -> Result<ElasticCapacity> {
        if layers == 0 || experts == 0 || shards == 0 {
            bail!("elastic capacity needs non-empty layers/experts/shards");
        }
        if experts % shards != 0 {
            bail!("experts {experts} not divisible into {shards} equal shards");
        }
        if base_capacity == 0 {
            bail!("elastic capacity needs a positive static baseline");
        }
        Ok(ElasticCapacity {
            layers,
            experts,
            shards,
            experts_per_shard: experts / shards,
            base_capacity,
            ema: vec![0.0; layers * experts],
            est: vec![0; layers * experts],
            caps: vec![base_capacity as u32; layers * shards],
            steps_observed: 0,
        })
    }

    /// True once at least one step's demand has been observed — before
    /// that the controller has no history and the caller must run the
    /// static capacity.
    pub fn ready(&self) -> bool {
        self.steps_observed > 0
    }

    /// Per-shard capacities for layer `l` (length = shard count).
    pub fn caps_layer(&self, l: usize) -> &[u32] {
        &self.caps[l * self.shards..(l + 1) * self.shards]
    }

    /// Smallest per-(layer, shard) capacity currently assigned.
    pub fn min_cap(&self) -> usize {
        self.caps.iter().copied().min().unwrap_or(1) as usize
    }

    /// Largest per-(layer, shard) capacity currently assigned — what the
    /// real-compute slabs must be sized for.
    pub fn max_cap(&self) -> usize {
        self.caps.iter().copied().max().unwrap_or(1) as usize
    }

    /// Per-layer slot budget the allocation always sums to.
    pub fn slot_budget(&self) -> usize {
        self.shards * self.base_capacity
    }

    /// Ingest one step's per-(layer, expert) worst-case demand (max over
    /// workers, length L x E) and re-derive every layer's capacities for
    /// the next step.
    pub fn observe(&mut self, demand_max: &[u32]) {
        assert_eq!(
            demand_max.len(),
            self.layers * self.experts,
            "demand histogram must be layers x experts"
        );
        for (i, &d) in demand_max.iter().enumerate() {
            let df = d as f64;
            self.ema[i] = if self.steps_observed == 0 {
                df
            } else {
                DEMAND_EMA_BETA * self.ema[i] + (1.0 - DEMAND_EMA_BETA) * df
            };
            self.est[i] = self.ema[i].max(df).ceil() as u32;
        }
        for l in 0..self.layers {
            self.reallocate_layer(l);
        }
        self.steps_observed += 1;
    }

    /// Greedy water-filling for one layer, warm-started at the static
    /// baseline (see the module docs). O(budget · E) worst case.
    fn reallocate_layer(&mut self, l: usize) {
        let s_at = l * self.shards;
        let e_at = l * self.experts;
        let eps = self.experts_per_shard;
        let est = &self.est[e_at..e_at + self.experts];
        let caps = &mut self.caps[s_at..s_at + self.shards];
        caps.fill(self.base_capacity as u32);
        // #{e in shard : est_e >= cap} — tokens a one-slot shrink strands /
        // a one-slot grow recovers (at cap, resp. cap + 1)
        let over = |s: usize, cap: u32| -> usize {
            est[s * eps..(s + 1) * eps].iter().filter(|&&d| d >= cap).count()
        };
        // each move strictly reduces estimated drops, so the loop is
        // bounded by the layer's estimated static drops; the explicit cap
        // is a safety net only
        let max_moves = self.shards * self.base_capacity;
        for _ in 0..max_moves {
            let mut best_gain = 0usize;
            let mut recipient = usize::MAX;
            for s in 0..self.shards {
                let g = over(s, caps[s] + 1);
                if g > best_gain {
                    best_gain = g;
                    recipient = s;
                }
            }
            if recipient == usize::MAX {
                break;
            }
            let mut best_loss = usize::MAX;
            let mut donor = usize::MAX;
            for s in 0..self.shards {
                if s == recipient || caps[s] <= 1 {
                    continue;
                }
                let loss = over(s, caps[s]);
                if loss < best_loss {
                    best_loss = loss;
                    donor = s;
                }
            }
            if donor == usize::MAX || best_gain <= best_loss {
                break;
            }
            caps[donor] -= 1;
            caps[recipient] += 1;
        }
    }
}

/// Re-clamp one worker-layer's kept counts under per-shard capacities:
/// `load_e = min(demand_e, caps[shard(e)])`, returning the dropped total.
/// The per-shard generalization of `fused::counts_from_demand` — with
/// every cap equal to the static `C` it reproduces that kernel exactly.
pub fn apply_caps(demand: &[u32], caps: &[u32], experts_per_shard: usize, load: &mut [u32]) -> u32 {
    assert_eq!(demand.len(), load.len(), "demand/load histograms must match");
    assert_eq!(
        demand.len(),
        caps.len() * experts_per_shard,
        "caps must cover every expert shard"
    );
    let mut dropped = 0u32;
    for (e, (&d, slot)) in demand.iter().zip(load.iter_mut()).enumerate() {
        let kept = d.min(caps[e / experts_per_shard]);
        *slot = kept;
        dropped += d - kept;
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drops(est: &[u32], caps: &[u32], eps: usize) -> u64 {
        est.iter()
            .enumerate()
            .map(|(e, &d)| d.saturating_sub(caps[e / eps]) as u64)
            .sum()
    }

    #[test]
    fn rejects_bad_geometry() {
        assert!(ElasticCapacity::new(0, 8, 4, 5).is_err());
        assert!(ElasticCapacity::new(2, 9, 4, 5).is_err(), "9 % 4 != 0");
        assert!(ElasticCapacity::new(2, 8, 4, 0).is_err());
        assert!(ElasticCapacity::new(2, 8, 4, 5).is_ok());
    }

    #[test]
    fn cold_controller_is_not_ready_and_stays_static() {
        let el = ElasticCapacity::new(2, 8, 4, 5).unwrap();
        assert!(!el.ready());
        assert_eq!(el.caps_layer(0), &[5, 5, 5, 5]);
        assert_eq!(el.min_cap(), 5);
        assert_eq!(el.max_cap(), 5);
    }

    #[test]
    fn uniform_demand_is_a_fixed_point_at_the_static_allocation() {
        // every expert at or below C: no move has positive gain; every
        // expert above C uniformly: gain == loss everywhere — either way
        // the static allocation survives
        for demand in [3u32, 5, 9] {
            let mut el = ElasticCapacity::new(2, 8, 4, 5).unwrap();
            el.observe(&vec![demand; 16]);
            assert!(el.ready());
            assert_eq!(el.caps_layer(0), &[5, 5, 5, 5], "uniform demand {demand}");
            assert_eq!(el.caps_layer(1), &[5, 5, 5, 5]);
        }
    }

    #[test]
    fn skewed_demand_moves_slots_and_conserves_the_budget() {
        // shard 0 holds a hot expert (demand 20 >> C = 5), the rest idle
        let mut el = ElasticCapacity::new(1, 8, 4, 5).unwrap();
        let demand = [20u32, 1, 1, 1, 1, 1, 1, 1];
        el.observe(&demand);
        let caps = el.caps_layer(0);
        assert_eq!(caps.iter().sum::<u32>() as usize, el.slot_budget());
        assert!(caps.iter().all(|&c| c >= 1));
        assert!(caps[0] > 5, "hot shard must grow, got {caps:?}");
        assert!(caps[1..].iter().all(|&c| c < 5), "cold shards shrink: {caps:?}");
        // cold shards floor at one slot, so the hot shard absorbs every
        // other spare slot: caps = [17, 1, 1, 1] under budget 20
        assert_eq!(caps, &[17, 1, 1, 1]);
        // estimated drops fall strictly below the static allocation's
        let est: Vec<u32> = demand.to_vec();
        assert!(drops(&est, caps, 2) < drops(&est, &[5, 5, 5, 5], 2));
        assert_eq!(drops(&est, caps, 2), 3, "only the un-fundable 20 - 17 remains");
    }

    #[test]
    fn water_filling_never_estimates_worse_than_static() {
        // pseudo-random persistent skews: elastic estimated drops must be
        // <= static estimated drops for every one (the structural
        // guarantee behind the bench's drop-delta floor)
        let mut x = 0x2545_F491_4F6C_DD1Du64;
        for trial in 0..50 {
            let layers = 1 + trial % 3;
            let shards = [2usize, 4, 8][trial % 3];
            let eps = [4usize, 2, 3][(trial / 3) % 3];
            let experts = shards * eps;
            let base = 4 + trial % 7;
            let mut el = ElasticCapacity::new(layers, experts, shards, base).unwrap();
            let demand: Vec<u32> = (0..layers * experts)
                .map(|_| {
                    x ^= x << 13;
                    x ^= x >> 7;
                    x ^= x << 17;
                    (x % (3 * base as u64 + 1)) as u32
                })
                .collect();
            // persistent skew: same histogram for a few steps
            for _ in 0..3 {
                el.observe(&demand);
            }
            let static_caps = vec![base as u32; shards];
            for l in 0..layers {
                let est = &demand[l * experts..(l + 1) * experts];
                let caps = el.caps_layer(l);
                assert_eq!(caps.iter().sum::<u32>() as usize, el.slot_budget());
                assert!(caps.iter().all(|&c| c >= 1));
                assert!(
                    drops(est, caps, eps) <= drops(est, &static_caps, eps),
                    "trial {trial} layer {l}: {caps:?} vs static {base}"
                );
            }
        }
    }

    #[test]
    fn controller_is_deterministic() {
        let demand: Vec<u32> = (0..24).map(|i| (i * 7 % 13) as u32).collect();
        let run = || {
            let mut el = ElasticCapacity::new(2, 12, 4, 3).unwrap();
            for step in 0..5 {
                let d: Vec<u32> = demand.iter().map(|&x| x + step % 2).collect();
                el.observe(&d);
            }
            el.caps.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn growth_is_immediate_and_shrink_is_gradual() {
        let mut el = ElasticCapacity::new(1, 4, 2, 5).unwrap();
        // a demand spike on expert 0 grows shard 0 the very next step:
        // the donor shard floors at one slot, so shard 0 takes 9 of 10
        el.observe(&[18, 0, 0, 0]);
        assert_eq!(el.caps_layer(0), &[9, 1]);
        // after the spike passes, the estimate decays with the EMA
        // instead of snapping back: 18 -> est 10 (held), est 6, then the
        // sub-C regime where the static allocation returns
        el.observe(&[2, 0, 0, 0]);
        assert_eq!(el.caps_layer(0), &[9, 1], "conservative hold one step after the spike");
        el.observe(&[2, 0, 0, 0]);
        assert_eq!(el.caps_layer(0), &[6, 4], "shrink begins, not all the way at once");
        for _ in 0..8 {
            el.observe(&[2, 0, 0, 0]);
        }
        assert_eq!(el.caps_layer(0), &[5, 5], "fully decayed demand is sub-C: static");
    }

    #[test]
    fn apply_caps_matches_the_static_kernel_and_conserves_tokens() {
        let demand = [7u32, 2, 9, 0, 4, 4];
        let mut load = [0u32; 6];
        // uniform caps == static C reproduces counts_from_demand
        let dropped = apply_caps(&demand, &[5, 5, 5], 2, &mut load);
        let mut oracle = [0u32; 6];
        let oracle_dropped = crate::moe::fused::counts_from_demand(&demand, 5, &mut oracle);
        assert_eq!(load, oracle);
        assert_eq!(dropped, oracle_dropped);
        // per-shard caps: kept + dropped == demand, kept <= cap
        let dropped = apply_caps(&demand, &[9, 1, 5], 2, &mut load);
        assert_eq!(load, [7, 2, 1, 0, 4, 4]);
        assert_eq!(dropped + load.iter().sum::<u32>(), demand.iter().sum::<u32>());
    }
}
