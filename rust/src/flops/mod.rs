//! Analytical FLOPs model — regenerates the paper's Table 1 and feeds the
//! cluster simulator's compute times.
//!
//! Follows §A.3's accounting: the MoE FFN layer is dominated by the two
//! expert matmuls, total O(ECMI); dispatch/combine einsums are O(TECM);
//! all-to-all volume is O(ECM). With Eq.-2 capacity C = kTγ/E these
//! collapse to the forms the paper's Table 1 demonstrates: expert compute
//! = 4γkTMI per worker — linear in k under "Capacity kx", equal across all
//! strategies under "Capacity 1x". All counts are *forward* FLOPs per
//! worker per step (the paper reports single-GPU FLOPs from the TF
//! profiler); backward is modelled as 2x forward where needed (simulator).

use crate::config::{CapacityMode, ModelConfig, Routing};

/// Per-component forward FLOPs of one step on one worker.
#[derive(Debug, Clone, PartialEq)]
pub struct FlopsBreakdown {
    pub attention: f64,
    pub gating: f64,
    pub dispatch_combine: f64,
    pub expert_ffn: f64,
    pub embed_head: f64,
    /// all-to-all payload bytes per worker per MoE layer direction
    pub a2a_bytes_per_layer: f64,
}

impl FlopsBreakdown {
    pub fn total(&self) -> f64 {
        self.attention + self.gating + self.dispatch_combine + self.expert_ffn + self.embed_head
    }
    pub fn gflops(&self) -> f64 {
        self.total() / 1e9
    }
}

/// Forward FLOPs for `cfg` under an explicit (routing, capacity-mode)
/// override — so one preset covers every Table-1 cell.
pub fn forward_flops(cfg: &ModelConfig, routing: Routing, mode: CapacityMode) -> FlopsBreakdown {
    let t = cfg.tokens_per_batch() as f64; // tokens per worker (T)
    let m = cfg.hidden as f64;
    let i = cfg.intermediate as f64;
    let e = cfg.num_experts as f64;
    let c = cfg.capacity_for(routing, mode) as f64;
    let l = cfg.layers as f64;
    let h = (cfg.heads * cfg.head_dim) as f64;
    let s = cfg.seq_len() as f64;
    let b = cfg.batch as f64;
    let v = cfg.vocab_size as f64;

    // attention: QKVO projections (4 matmuls) + scores + context
    let proj = 4.0 * 2.0 * t * m * h;
    let scores = 2.0 * 2.0 * b * s * s * h;
    let attention = l * (proj + scores);

    // router: logits einsum over all E experts (+ per-round argmax/cumsum,
    // negligible FLOPs — their cost is serialization, modelled in cluster)
    let gating = l * 2.0 * t * m * e;

    // dispatch + combine one-hot einsums (Fig. 7): 2TECM each
    let dispatch_combine = l * 2.0 * (2.0 * t * e * c * m);

    // the two expert matmuls: every expert processes a full C-slot buffer
    // (padding included — that is the point of Table 1's capacity column)
    let expert_ffn = l * 4.0 * e * c * m * i;

    // embedding lookup is a gather (~0 FLOPs); output head is a matmul
    let embed_head = 2.0 * (b * cfg.text_len as f64) * m * v;

    // all-to-all payload per direction per layer (§A.3: O(ECM) entries)
    let a2a_bytes_per_layer = e * c * m * 4.0;

    FlopsBreakdown {
        attention,
        gating,
        dispatch_combine,
        expert_ffn,
        embed_head,
        a2a_bytes_per_layer,
    }
}

/// The five strategies of Tables 1/2/3 in paper order.
pub fn table_strategies() -> Vec<Routing> {
    vec![
        Routing::TopK(1),
        Routing::TopK(2),
        Routing::TopK(4),
        Routing::Prototype(2),
        Routing::Prototype(4),
    ]
}

/// One Table-1 row: GFLOPs per strategy at the given capacity mode.
pub fn table1_row(cfg: &ModelConfig, mode: CapacityMode) -> Vec<(Routing, f64)> {
    table_strategies()
        .into_iter()
        .map(|r| (r, forward_flops(cfg, r, mode).gflops()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;

    #[test]
    fn capacity_kx_scales_with_k() {
        let cfg = paper::base();
        let f1 = forward_flops(&cfg, Routing::TopK(1), CapacityMode::TimesK);
        let f2 = forward_flops(&cfg, Routing::TopK(2), CapacityMode::TimesK);
        let f4 = forward_flops(&cfg, Routing::TopK(4), CapacityMode::TimesK);
        // expert compute strictly doubles with k
        assert!((f2.expert_ffn / f1.expert_ffn - 2.0).abs() < 1e-9);
        assert!((f4.expert_ffn / f1.expert_ffn - 4.0).abs() < 1e-9);
        assert!(f4.total() > f2.total() && f2.total() > f1.total());
    }

    #[test]
    fn capacity_1x_equalizes() {
        // Table 1's point: limited capacity makes all strategies cost alike
        let cfg = paper::base();
        let rows = table1_row(&cfg, CapacityMode::Times1);
        let base = rows[0].1;
        for (r, g) in &rows {
            assert!(
                (g / base - 1.0).abs() < 1e-9,
                "{} differs: {} vs {}",
                r.name(),
                g,
                base
            );
        }
    }

    #[test]
    fn prototyping_matches_topk_flops() {
        // k top-1 and top-k have identical FLOPs at equal capacity —
        // the efficiency difference is serialization, not arithmetic
        let cfg = paper::base();
        for mode in [CapacityMode::TimesK, CapacityMode::Times1] {
            let tk = forward_flops(&cfg, Routing::TopK(2), mode).total();
            let pr = forward_flops(&cfg, Routing::Prototype(2), mode).total();
            assert!((tk / pr - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn expert_ffn_dominates_at_paper_scale() {
        // §A.3 profiles the 1T model: the two expert matmuls hold ~98% of
        // MoE-layer FLOPs there (I/T ~ 21x). At base scale (I/T = 4) the
        // dense one-hot dispatch einsums take a larger share (~20%).
        let base = paper::base();
        let f = forward_flops(&base, Routing::TopK(1), CapacityMode::TimesK);
        let moe_total = f.expert_ffn + f.dispatch_combine + f.gating;
        assert!(f.expert_ffn / moe_total > 0.75, "base: {}", f.expert_ffn / moe_total);

        let one_t = paper::one_t();
        let f = forward_flops(&one_t, Routing::TopK(1), CapacityMode::TimesK);
        let moe_total = f.expert_ffn + f.dispatch_combine + f.gating;
        assert!(f.expert_ffn / moe_total > 0.93, "1T: {}", f.expert_ffn / moe_total);
    }

    #[test]
    fn a2a_volume_is_oecm() {
        let cfg = paper::base();
        let f = forward_flops(&cfg, Routing::TopK(1), CapacityMode::TimesK);
        let e = cfg.num_experts as f64;
        let c = cfg.capacity() as f64;
        let m = cfg.hidden as f64;
        assert_eq!(f.a2a_bytes_per_layer, e * c * m * 4.0);
    }

    #[test]
    fn base_magnitude_sane() {
        // base: T=1024, M=1024, I=4096, E=32, C=40, 5 layers
        // expert_ffn = 5 * 4 * 32 * 40 * 1024 * 4096 ~ 107 GFLOPs fwd
        let cfg = paper::base();
        let f = forward_flops(&cfg, Routing::TopK(1), CapacityMode::TimesK);
        assert!((50.0..500.0).contains(&f.gflops()), "{}", f.gflops());
    }
}
