//! Smoke test for the AOT bridge: a tiny stateful two-output HLO module
//! (see /tmp is not used — the module ships with the repo test artifacts).
//! Kept as a binary so `make smoke` can verify the PJRT + untuple patch
//! wiring without the full artifact set. The real coverage lives in
//! rust/tests/.

use anyhow::Result;

fn main() -> Result<()> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    // Build fn(w, x) = (w + 0.5x, sum((w + 0.5x) * x)) directly with the
    // XlaBuilder — no python needed for the smoke path.
    let b = xla::XlaBuilder::new("smoke");
    let shape = xla::ArrayShape::new::<f32>(vec![4]);
    let w = b.parameter_s(0, &xla::Shape::Array(shape.clone()), "w").map_err(err)?;
    let x = b.parameter_s(1, &xla::Shape::Array(shape), "x").map_err(err)?;
    let half = b.c0(0.5f32).map_err(err)?;
    let nw = (w + (x.clone() * half).map_err(err)?).map_err(err)?;
    let loss = (nw.clone() * x).map_err(err)?.reduce_sum(&[0], false).map_err(err)?;
    let comp = b.build(&b.tuple(&[nw, loss]).map_err(err)?).map_err(err)?;
    let exe = client.compile(&comp).map_err(err)?;

    let w0 = xla::Literal::vec1(&[0f32, 0., 0., 0.]);
    let x0 = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
    let out = exe.execute::<xla::Literal>(&[w0, x0.clone()]).map_err(err)?;
    assert_eq!(out[0].len(), 2, "untuple_result patch must flatten outputs");
    let loss1 = out[0][1].to_literal_sync().map_err(err)?.to_vec::<f32>().map_err(err)?[0];
    // feed the state buffer back without a host round-trip
    let xb = client.buffer_from_host_literal(None, &x0).map_err(err)?;
    let mut bufs = out.into_iter().next().unwrap();
    let _ = bufs.pop();
    let wb = bufs.pop().unwrap();
    let out2 = exe.execute_b::<xla::PjRtBuffer>(&[wb, xb]).map_err(err)?;
    let loss2 = out2[0][1].to_literal_sync().map_err(err)?.to_vec::<f32>().map_err(err)?[0];
    assert_eq!(loss1, 15.0);
    assert_eq!(loss2, 30.0);
    println!("SMOKE OK: untupled outputs + device-resident state");
    Ok(())
}

fn err(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
