//! Experiment drivers — one per table/figure of the paper's evaluation
//! (DESIGN.md §3 maps each to its modules). All drivers print the same
//! rows/series the paper reports and drop machine-readable CSVs under
//! `results/`. Training runs are cached in the sweep engine's
//! content-addressed store via [`Runner`] (DESIGN.md §"Sweep driver &
//! experiment store"), so figures and tables share identical runs.

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod runner;
pub mod table1;
pub mod table2;
pub mod table34;

pub use runner::{CachedRun, Runner, TrainCellRunner};
