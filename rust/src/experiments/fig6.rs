//! Figure 6 — 100B / 250B / 1T baselines + the 1T expert-prototyping model.
//!
//! The giant models are unreachable on this testbed; the curves are
//! *modelled* (DESIGN.md §2):
//!  1. fit L(s) power laws to our measured scale twins,
//!  2. fit the parameter-scaling of the loss floor across twins,
//!  3. place the 100B/250B/1T floors from the paper's true param counts,
//!  4. give the 1T-prototyping curve the *measured* relative improvement
//!     of 2top1 over top-1 at our largest twin (scaled by the Fig-5 trend),
//!  5. convert steps to wall clock with the calibrated cluster simulator.
//! The headline number is the convergence speedup: steps(baseline) /
//! steps(prototyping) to reach the baseline's 30k-step loss (paper: ~5x).

use anyhow::Result;

use super::runner::Runner;
use crate::cluster::steps_per_second;
use crate::config::{paper, CapacityMode, Routing};
use crate::runtime::BackendProvider as _;
use crate::scaling::{fit_param_scaling, fit_power_law, PowerLaw};
use crate::util::table::{f2, f3, Table};

pub struct Fig6Output {
    pub curves: Table,
    pub summary: Table,
    pub speedup: f64,
}

pub fn run(runner: &Runner, steps: i64) -> Result<Fig6Output> {
    // 1) measured twins (same runs as Fig 5 — served from cache)
    let twins = [
        ("base-sim", "base-sim-2top1-cap1"),
        ("large-sim", "large-sim-2top1-cap1"),
        ("xlarge-sim", "xlarge-sim-2top1-cap1"),
    ];
    let mut twin_params = Vec::new();
    let mut twin_floors = Vec::new();
    let mut proto_gain = Vec::new(); // relative floor improvement of 2top1
    let mut laws: Vec<PowerLaw> = Vec::new();
    for (baseline, proto) in twins {
        let b = runner.run(baseline, steps)?;
        let p = runner.run(proto, steps)?;
        let steps_f: Vec<f64> = b.curve.iter().map(|&(s, _)| s as f64 + 1.0).collect();
        let losses: Vec<f64> = b.curve.iter().map(|&(_, l)| l).collect();
        let law = fit_power_law(&steps_f, &losses);
        let params = runner.provider.info(baseline)?.param_count as f64;
        twin_params.push(params);
        twin_floors.push(b.final_loss());
        proto_gain.push((b.final_loss() - p.final_loss()) / b.final_loss());
        laws.push(law);
    }

    // 2-3) parameter scaling of the floor, anchored on measured twins
    let pscale = fit_param_scaling(&twin_params, &twin_floors);
    // decay exponent: average of the measured twins' fits
    let mean_b = laws.iter().map(|l| l.b).sum::<f64>() / laws.len() as f64;
    let mean_a = laws.iter().map(|l| l.a).sum::<f64>() / laws.len() as f64;

    // 4) prototyping gain extrapolated along the measured Fig-5 trend
    // (linear in log params, clamped to [max measured, 2x max measured])
    let max_gain = proto_gain.iter().cloned().fold(0.0f64, f64::max);
    let gain_1t = (max_gain * 1.5).min(0.25);

    let giants = [paper::hundred_b(), paper::two_fifty_b(), paper::one_t()];
    let mut curves = Table::new(
        "Fig 6 — modelled giant-model convergence (loss vs step)",
        &["step", "model", "loss"],
    );
    let horizon = 30_000i64; // the paper's 1T training budget (§4 fn. 3)
    let mut giant_laws = Vec::new();
    for g in &giants {
        let law = PowerLaw {
            l_inf: pscale.floor(g.param_count() as f64),
            a: mean_a,
            b: mean_b,
        };
        for s in (0..=horizon).step_by(1000) {
            curves.row(vec![s.to_string(), g.name.clone(), f3(law.predict(s as f64 + 1.0))]);
        }
        giant_laws.push(law);
    }
    // the 1T prototyping curve: same shape, floor lowered by the gain
    let one_t_law = giant_laws[2];
    let proto_law = PowerLaw {
        l_inf: one_t_law.l_inf * (1.0 - gain_1t),
        a: mean_a,
        b: mean_b,
    };
    for s in (0..=horizon).step_by(1000) {
        curves.row(vec![
            s.to_string(),
            "1T-2top1".into(),
            f3(proto_law.predict(s as f64 + 1.0)),
        ]);
    }

    // 5) headline: steps for the prototyped model to reach the baseline's
    // horizon loss
    let target = one_t_law.predict(horizon as f64);
    let proto_steps = proto_law.steps_to(target).unwrap_or(f64::INFINITY);
    let speedup = horizon as f64 / proto_steps;

    let sps_base = steps_per_second(&paper::one_t(), Routing::TopK(1), CapacityMode::Times1);
    let sps_proto = steps_per_second(&paper::one_t(), Routing::Prototype(2), CapacityMode::Times1);

    let mut summary = Table::new(
        "Fig 6 — summary (paper: larger models better; 1T prototyping ~5x faster convergence)",
        &["model", "loss@30k (modelled)", "steps/s (sim)", "speedup-to-target"],
    );
    for (g, law) in giants.iter().zip(&giant_laws) {
        summary.row(vec![
            g.name.clone(),
            f3(law.predict(horizon as f64)),
            f3(sps_base),
            "1.0".into(),
        ]);
    }
    summary.row(vec![
        "1T-2top1".into(),
        f3(proto_law.predict(horizon as f64)),
        f3(sps_proto),
        f2(speedup),
    ]);
    Ok(Fig6Output { curves, summary, speedup })
}
