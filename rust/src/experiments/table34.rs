//! Tables 3 & 4 — downstream zero-shot captioning PPL on the held-out
//! E-commerce-IC-like split. Table 3: base scale, all five strategies ×
//! both capacity policies. Table 4: the 10B twin at capacity 1x
//! (paper: top1 6.97 / top2 5.73 / 2top1 5.64 — 2top1 ≈ top2).
//!
//! PPLs come from the same cached runs as Fig 3/5 — paired eval batches.

use anyhow::Result;

use super::runner::Runner;
use crate::util::table::{f2, Table};

pub fn table3(runner: &Runner, steps: i64) -> Result<Table> {
    let strategies = ["top1", "top2", "top4", "2top1", "4top1"];
    let mut header = vec!["capacity".to_string()];
    header.extend(strategies.iter().map(|s| s.to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 3 — eval PPL on held-out split (base scale)",
        &header_refs,
    );
    for cap in ["capk", "cap1"] {
        let mut row = vec![format!(
            "Capacity {}",
            if cap == "capk" { "kx" } else { "1x" }
        )];
        for s in strategies {
            let variant = if s == "top1" {
                "base-sim".to_string() // top-1 is identical under both policies
            } else {
                format!("base-sim-{s}-{cap}")
            };
            let run = runner.run(&variant, steps)?;
            row.push(f2(run.final_ppl));
        }
        t.row(row);
    }
    Ok(t)
}

pub fn table4(runner: &Runner, steps: i64) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — eval PPL, 10B twin at capacity 1x (paper: 6.97 / 5.73 / 5.64)",
        &["model", "top1", "top2", "2top1"],
    );
    let top1 = runner.run("large-sim", steps)?;
    let top2 = runner.run("large-sim-top2-cap1", steps)?;
    let p2 = runner.run("large-sim-2top1-cap1", steps)?;
    t.row(vec![
        "large-sim (10B twin)".into(),
        f2(top1.final_ppl),
        f2(top2.final_ppl),
        f2(p2.final_ppl),
    ]);
    Ok(t)
}
