//! Figure 1 — development of per-layer compute-load c_v with and without
//! the auxiliary balancing loss, plus the training log-pplx curves.
//!
//! The paper's finding: the aux loss drives every layer's c_v to ~0.3
//! quickly, but that balance does *not* buy better pplx — the unbalanced
//! baseline matches or beats it. Trains the base-sim twin both ways and
//! emits the c_v series straight from the train step's load outputs.

use anyhow::Result;

use super::runner::Runner;
use crate::util::table::{f3, f2, Table};

pub struct Fig1Output {
    pub series: Table,
    pub summary: Table,
}

pub fn run(runner: &Runner, steps: i64) -> Result<Fig1Output> {
    let base = runner.run("base-sim", steps)?;
    let aux = runner.run("base-sim-aux", steps)?;

    let layers = base.cv.first().map(|(_, row)| row.len()).unwrap_or(0);
    let mut header = vec!["step".to_string(), "run".to_string()];
    header.extend((0..layers).map(|l| format!("cv_layer{l}")));
    header.push("loss".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut series = Table::new("Fig 1 — c_v per layer over training", &header_refs);

    for run in [&base, &aux] {
        for ((step, cvs), &(_, loss)) in run.cv.iter().zip(run.curve.iter()) {
            if step % 10 != 0 {
                continue; // thin the series for readability; CSV keeps cadence
            }
            let mut row = vec![step.to_string(), run.variant.clone()];
            row.extend(cvs.iter().map(|&c| f3(c)));
            row.push(f2(loss));
            series.row(row);
        }
    }

    let mut summary = Table::new(
        "Fig 1 — balance vs quality (paper: aux pplx 2.694 vs baseline 2.645)",
        &["run", "tail c_v (mean over layers)", "final loss", "final PPL"],
    );
    for run in [&base, &aux] {
        let tail_cv: f64 = {
            let tail: Vec<&Vec<f64>> =
                run.cv.iter().rev().take(20).map(|(_, r)| r).collect();
            let n = (tail.len() * layers).max(1);
            tail.iter().flat_map(|r| r.iter()).sum::<f64>() / n as f64
        };
        summary.row(vec![
            run.variant.clone(),
            f3(tail_cv),
            f3(run.final_loss()),
            f2(run.final_ppl),
        ]);
    }
    Ok(Fig1Output { series, summary })
}
