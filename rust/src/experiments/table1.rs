//! Table 1 — FLOPs of models with different top-k routing strategies,
//! under "Capacity kx" and "Capacity 1x". Pure analytics (flops module)
//! at the paper's base scale; the pytest suite cross-checks the same
//! formulas against `jax.stage.cost_analysis` on the runnable twins.

use crate::config::{paper, CapacityMode, ModelConfig};
use crate::flops::{table1_row, table_strategies};
use crate::util::table::{f1, Table};

pub fn run(cfg: Option<ModelConfig>) -> Table {
    let cfg = cfg.unwrap_or_else(paper::base);
    let names: Vec<String> = table_strategies().iter().map(|r| r.name()).collect();
    let mut header = vec!["capacity".to_string()];
    header.extend(names);
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();

    let mut t = Table::new(
        format!("Table 1 — per-GPU forward GFLOPs ({})", cfg.name),
        &header_refs,
    );
    for (label, mode) in [("kx", CapacityMode::TimesK), ("1x", CapacityMode::Times1)] {
        let mut row = vec![format!("Capacity {label}")];
        for (_r, gflops) in table1_row(&cfg, mode) {
            row.push(f1(gflops));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_holds() {
        let t = run(None);
        assert_eq!(t.rows.len(), 2);
        // row 0 (kx): strictly increasing in k for top-k columns 1..=3
        let kx: Vec<f64> = t.rows[0][1..].iter().map(|s| s.parse().unwrap()).collect();
        assert!(kx[1] > kx[0] && kx[2] > kx[1]);
        // prototyping == top-k at equal k (columns: top1 top2 top4 2top1 4top1)
        assert!((kx[3] - kx[1]).abs() < 0.1);
        assert!((kx[4] - kx[2]).abs() < 0.1);
        // row 1 (1x): all equal
        let x1: Vec<f64> = t.rows[1][1..].iter().map(|s| s.parse().unwrap()).collect();
        for v in &x1 {
            assert!((v - x1[0]).abs() < 0.1);
        }
    }
}
