//! Figure 4 — MoE attention (§3.4). Left: shallow models — MoE attention
//! hurts and can diverge; k top-1 prototyping mitigates. Right: deeper
//! models with fewer experts — MoE attention trains but still trails the
//! plain-MoE baseline.

use anyhow::Result;

use super::runner::Runner;
use crate::util::table::{f2, f3, Table};

pub struct Fig4Output {
    pub curves: Table,
    pub summary: Table,
}

pub fn shallow_variants() -> Vec<&'static str> {
    vec!["base-sim", "base-sim-moeattn", "base-sim-moeattn-2top1"]
}

pub fn deep_variants() -> Vec<&'static str> {
    vec!["deep-sim", "deep-sim-moeattn", "deep-sim-moeattn-2top1"]
}

pub fn run(runner: &Runner, steps: i64, side: &str) -> Result<Fig4Output> {
    let variants = match side {
        "left" | "shallow" => shallow_variants(),
        "right" | "deep" => deep_variants(),
        other => anyhow::bail!("side must be left|right, got {other:?}"),
    };
    let mut runs = Vec::new();
    for v in &variants {
        runs.push(runner.run(v, steps)?);
    }

    let mut curves = Table::new(
        format!("Fig 4 ({side}) — MoE attention loss curves"),
        &["step", "variant", "loss"],
    );
    for run in &runs {
        for &(step, loss) in &run.curve {
            if step % 5 == 0 {
                curves.row(vec![step.to_string(), run.variant.clone(), f3(loss)]);
            }
        }
    }
    let mut summary = Table::new(
        format!("Fig 4 ({side}) — summary"),
        &["variant", "final loss", "eval PPL", "diverged"],
    );
    for run in &runs {
        let diverged = run
            .curve
            .iter()
            .any(|&(_, l)| !l.is_finite() || l > 12.0);
        summary.row(vec![
            run.variant.clone(),
            f3(run.final_loss()),
            f2(run.final_ppl),
            diverged.to_string(),
        ]);
    }
    Ok(Fig4Output { curves, summary })
}
