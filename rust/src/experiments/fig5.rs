//! Figure 5 — routing strategies at larger scale (paper: 10B and 100B).
//!
//! Substitution (DESIGN.md §2): the scale axis is expert count at fixed
//! hidden size — large-sim (2x layers, 2x experts of base-sim) and
//! xlarge-sim (4x experts) are the runnable twins of the 10B/100B rows.
//! The paper's claim under test: the k top-1 advantage *grows* with scale.

use anyhow::Result;

use super::runner::Runner;
use crate::util::table::{f2, f3, Table};

pub struct Fig5Output {
    pub curves: Table,
    pub summary: Table,
    /// (scale label, baseline final loss, 2top1 final loss)
    pub advantage: Vec<(String, f64, f64)>,
}

pub fn run(runner: &Runner, steps: i64) -> Result<Fig5Output> {
    // scale twins: (label, baseline top-1 variant, prototyped variants)
    let grid: Vec<(&str, &str, Vec<&str>)> = vec![
        ("base", "base-sim", vec!["base-sim-2top1-cap1", "base-sim-4top1-cap1"]),
        ("large(10B-twin)", "large-sim", vec!["large-sim-2top1-cap1", "large-sim-4top1-cap1"]),
        ("xlarge(100B-twin)", "xlarge-sim", vec!["xlarge-sim-2top1-cap1"]),
    ];

    let mut curves = Table::new(
        "Fig 5 — loss curves across scale twins",
        &["step", "scale", "variant", "loss"],
    );
    let mut summary = Table::new(
        "Fig 5 — prototyping advantage grows with scale",
        &["scale", "variant", "final loss", "eval PPL", "Δloss vs top-1"],
    );
    let mut advantage = Vec::new();

    for (label, baseline, protos) in grid {
        let base_run = runner.run(baseline, steps)?;
        for &(step, loss) in base_run.curve.iter().filter(|&&(s, _)| s % 5 == 0) {
            curves.row(vec![step.to_string(), label.into(), base_run.variant.clone(), f3(loss)]);
        }
        summary.row(vec![
            label.into(),
            base_run.variant.clone(),
            f3(base_run.final_loss()),
            f2(base_run.final_ppl),
            "0.000".into(),
        ]);
        let mut best_proto = f64::INFINITY;
        for p in protos {
            let run = runner.run(p, steps)?;
            for &(step, loss) in run.curve.iter().filter(|&&(s, _)| s % 5 == 0) {
                curves.row(vec![step.to_string(), label.into(), run.variant.clone(), f3(loss)]);
            }
            let delta = run.final_loss() - base_run.final_loss();
            summary.row(vec![
                label.into(),
                run.variant.clone(),
                f3(run.final_loss()),
                f2(run.final_ppl),
                format!("{delta:+.3}"),
            ]);
            best_proto = best_proto.min(run.final_loss());
        }
        advantage.push((label.to_string(), base_run.final_loss(), best_proto));
    }
    Ok(Fig5Output { curves, summary, advantage })
}
