//! Cached experiment runner: trains a variant once and persists the loss
//! curve / c_v series / eval points; figure and table drivers share runs
//! (e.g. Fig 3 curves and Table 3 PPLs come from the same training).
//!
//! Training runs live in the sweep engine's content-addressed store
//! (`<results>/store/train/<key>/`), keyed by the *fully resolved* model
//! config — not just the `(variant, steps, seed)` filename the old
//! `results/runs/` cache used. That filename key had a stale-cache bug:
//! editing a registry variant's config silently reused the old curve.
//! Under the store, a config edit changes the address and forces a
//! re-train (pinned by `runner_rebuilds_when_the_variant_config_changes`
//! in `rust/tests/sweep_store.rs`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::coordinator::{TrainOptions, Trainer};
use crate::runtime::{Backend as _, BackendProvider};
use crate::sweep::{self, Cell, CellRunner, Engine, ParamValue};
use crate::util::json::{arr, num, obj, s, Value};

/// Code-relevant version tag in every training cell's store address.
pub const STORE_VERSION: &str = "train-v1";

/// The persisted essence of one training run.
#[derive(Debug, Clone)]
pub struct CachedRun {
    pub variant: String,
    pub steps: i64,
    pub seed: u64,
    /// (step, loss)
    pub curve: Vec<(i64, f64)>,
    /// (step, per-layer c_v)
    pub cv: Vec<(i64, Vec<f64>)>,
    /// (step, eval PPL)
    pub evals: Vec<(i64, f64)>,
    pub final_ppl: f64,
    pub mean_ms: f64,
    pub dropped_per_step: f64,
}

impl CachedRun {
    pub fn final_loss(&self) -> f64 {
        let tail: Vec<f64> = self
            .curve
            .iter()
            .rev()
            .take(20)
            .map(|&(_, l)| l)
            .collect();
        tail.iter().sum::<f64>() / tail.len().max(1) as f64
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("variant", s(self.variant.clone())),
            ("steps", num(self.steps as f64)),
            ("seed", num(self.seed as f64)),
            (
                "curve",
                arr(self
                    .curve
                    .iter()
                    .map(|&(st, l)| arr(vec![num(st as f64), num(l)]))
                    .collect()),
            ),
            (
                "cv",
                arr(self
                    .cv
                    .iter()
                    .map(|(st, row)| {
                        arr(vec![
                            num(*st as f64),
                            arr(row.iter().map(|&x| num(x)).collect()),
                        ])
                    })
                    .collect()),
            ),
            (
                "evals",
                arr(self
                    .evals
                    .iter()
                    .map(|&(st, p)| arr(vec![num(st as f64), num(p)]))
                    .collect()),
            ),
            ("final_ppl", num(self.final_ppl)),
            ("mean_ms", num(self.mean_ms)),
            ("dropped_per_step", num(self.dropped_per_step)),
        ])
    }

    fn from_json(v: &Value) -> Result<CachedRun> {
        let pair = |x: &Value| -> Result<(i64, f64)> {
            let a = x.as_array().ok_or_else(|| anyhow!("bad pair"))?;
            Ok((a[0].as_i64().unwrap_or(0), a[1].as_f64().unwrap_or(f64::NAN)))
        };
        let curve = v
            .req("curve")
            .map_err(|e| anyhow!("{e}"))?
            .as_array()
            .ok_or_else(|| anyhow!("curve not array"))?
            .iter()
            .map(pair)
            .collect::<Result<Vec<_>>>()?;
        let cv = v
            .req("cv")
            .map_err(|e| anyhow!("{e}"))?
            .as_array()
            .ok_or_else(|| anyhow!("cv not array"))?
            .iter()
            .map(|x| {
                let a = x.as_array().ok_or_else(|| anyhow!("bad cv row"))?;
                let step = a[0].as_i64().unwrap_or(0);
                let row = a[1]
                    .as_array()
                    .ok_or_else(|| anyhow!("bad cv vec"))?
                    .iter()
                    .map(|y| y.as_f64().unwrap_or(f64::NAN))
                    .collect();
                Ok((step, row))
            })
            .collect::<Result<Vec<_>>>()?;
        let evals = v
            .req("evals")
            .map_err(|e| anyhow!("{e}"))?
            .as_array()
            .ok_or_else(|| anyhow!("evals not array"))?
            .iter()
            .map(pair)
            .collect::<Result<Vec<_>>>()?;
        Ok(CachedRun {
            variant: v.req("variant").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("?").into(),
            steps: v.req("steps").map_err(|e| anyhow!("{e}"))?.as_i64().unwrap_or(0),
            seed: v.req("seed").map_err(|e| anyhow!("{e}"))?.as_i64().unwrap_or(0) as u64,
            curve,
            cv,
            evals,
            final_ppl: v.req("final_ppl").map_err(|e| anyhow!("{e}"))?.as_f64().unwrap_or(f64::NAN),
            mean_ms: v.req("mean_ms").map_err(|e| anyhow!("{e}"))?.as_f64().unwrap_or(f64::NAN),
            dropped_per_step: v
                .get("dropped_per_step")
                .and_then(|x| x.as_f64())
                .unwrap_or(f64::NAN),
        })
    }
}

/// Sweep-engine executor for training cells (`kind = "train"`). The
/// resolve step folds the variant's full [`ModelConfig`] into the cell,
/// which is exactly the stale-cache fix: two cells agree in address only
/// when every config field agrees.
///
/// [`ModelConfig`]: crate::config::ModelConfig
pub struct TrainCellRunner<'e> {
    provider: &'e dyn BackendProvider,
    verbose: bool,
}

impl<'e> TrainCellRunner<'e> {
    pub fn new(provider: &'e dyn BackendProvider, verbose: bool) -> Self {
        Self { provider, verbose }
    }
}

impl CellRunner for TrainCellRunner<'_> {
    fn kind(&self) -> &'static str {
        "train"
    }

    fn version(&self) -> &'static str {
        STORE_VERSION
    }

    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        let variant = cell.req_str("variant")?;
        let info = self.provider.info(variant)?;
        let mut resolved = cell.clone();
        resolved.merge(&sweep::config_cell(&info.config));
        Ok(resolved)
    }

    fn run(&self, cell: &Cell) -> Result<Value> {
        let variant = cell.req_str("variant")?;
        let steps = cell.req_usize("steps")? as i64;
        let seed = cell.req_u64("seed")?;
        let backend = self.provider.load(variant)?;
        if self.verbose {
            let info = backend.info();
            eprintln!(
                "[runner] {variant}: training {steps} steps ({:.1}M params, C={})",
                info.param_count as f64 / 1e6,
                info.capacity
            );
        }
        let opts = TrainOptions {
            steps,
            seed,
            eval_every: (steps / 4).max(1),
            eval_batches: 8,
            verbose: self.verbose,
            ..Default::default()
        };
        let trainer = Trainer::new(backend, opts);
        let (outcome, _state) = trainer.train()?;

        let n = outcome.log.records.len().max(1) as f64;
        let run = CachedRun {
            variant: variant.to_string(),
            steps,
            seed,
            curve: outcome.log.loss_curve(),
            cv: outcome
                .log
                .records
                .iter()
                .map(|r| (r.step, r.cv_per_layer.clone()))
                .collect(),
            evals: outcome.evals.clone(),
            final_ppl: outcome.evals.last().map(|&(_, p)| p).unwrap_or(f64::NAN),
            mean_ms: outcome.log.records.iter().map(|r| r.ms_per_step).sum::<f64>() / n,
            dropped_per_step: outcome.log.records.iter().map(|r| r.dropped).sum::<f64>() / n,
        };
        Ok(run.to_json())
    }
}

/// Runner over the content-addressed store, generic over the execution
/// backend.
pub struct Runner<'e> {
    pub provider: &'e dyn BackendProvider,
    pub results_dir: PathBuf,
    pub steps: i64,
    pub seed: u64,
    pub force: bool,
    pub verbose: bool,
}

impl<'e> Runner<'e> {
    pub fn new(provider: &'e dyn BackendProvider, results_dir: impl AsRef<Path>) -> Self {
        Self {
            provider,
            results_dir: results_dir.as_ref().to_path_buf(),
            steps: 200,
            seed: 42,
            force: false,
            verbose: true,
        }
    }

    fn engine(&self) -> Engine {
        Engine::new(&self.results_dir).force(self.force).verbose(self.verbose)
    }

    /// Train (or recall from the store) one variant for `steps` steps,
    /// reporting whether the store served it.
    pub fn run_traced(&self, variant: &str, steps: i64) -> Result<(CachedRun, bool)> {
        let mut cell = Cell::new();
        cell.set("variant", ParamValue::Str(variant.to_string()));
        cell.set("steps", ParamValue::Num(steps as f64));
        cell.set("seed", ParamValue::Num(self.seed as f64));
        let runner = TrainCellRunner::new(self.provider, self.verbose);
        let outcome = self.engine().run_cell(&runner, &cell, variant)?;
        Ok((CachedRun::from_json(&outcome.result)?, outcome.cached))
    }

    /// Train (or recall from the store) one variant for `steps` steps.
    pub fn run(&self, variant: &str, steps: i64) -> Result<CachedRun> {
        Ok(self.run_traced(variant, steps)?.0)
    }

    /// Run with the runner's default step budget.
    pub fn run_default(&self, variant: &str) -> Result<CachedRun> {
        self.run(variant, self.steps)
    }
}
