//! Figure 3 — model convergence under different top-k setups.
//! Left: top-k routing, k in {1,2,4}, capacity kx and 1x.
//! Right: k top-1 expert prototyping, same grid.
//!
//! The paper's shape: k>1 beats k=1 even at capacity 1x; the top-2 -> top-4
//! gain is much smaller than top-1 -> top-2 (diminishing returns); k top-1
//! at capacity 1x loses part of its advantage at small scale (§3.3).

use anyhow::Result;

use super::runner::{CachedRun, Runner};
use crate::util::table::{f2, f3, Table};

pub fn left_variants() -> Vec<&'static str> {
    vec![
        "base-sim",
        "base-sim-top2-capk",
        "base-sim-top4-capk",
        "base-sim-top2-cap1",
        "base-sim-top4-cap1",
    ]
}

pub fn right_variants() -> Vec<&'static str> {
    vec![
        "base-sim",
        "base-sim-2top1-capk",
        "base-sim-4top1-capk",
        "base-sim-2top1-cap1",
        "base-sim-4top1-cap1",
    ]
}

pub struct Fig3Output {
    pub curves: Table,
    pub summary: Table,
    pub runs: Vec<CachedRun>,
}

pub fn run(runner: &Runner, steps: i64, side: &str) -> Result<Fig3Output> {
    let variants = match side {
        "left" => left_variants(),
        "right" => right_variants(),
        other => anyhow::bail!("side must be left|right, got {other:?}"),
    };
    let mut runs = Vec::new();
    for v in &variants {
        runs.push(runner.run(v, steps)?);
    }

    let mut curves = Table::new(
        format!("Fig 3 ({side}) — training loss curves"),
        &["step", "variant", "loss"],
    );
    for run in &runs {
        for &(step, loss) in &run.curve {
            if step % 5 == 0 {
                curves.row(vec![step.to_string(), run.variant.clone(), f3(loss)]);
            }
        }
    }

    let mut summary = Table::new(
        format!("Fig 3 ({side}) — convergence summary"),
        &["variant", "final loss", "eval PPL", "dropped/step"],
    );
    for run in &runs {
        summary.row(vec![
            run.variant.clone(),
            f3(run.final_loss()),
            f2(run.final_ppl),
            f2(run.dropped_per_step),
        ]);
    }
    Ok(Fig3Output { curves, summary, runs })
}
