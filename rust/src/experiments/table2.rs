//! Table 2 — training speed (ms/step) per routing strategy, "Base" and
//! "10B" rows at capacity 1x. Produced by the calibrated cluster simulator
//! (DESIGN.md §2: the 8/16-GPU Whale testbed is simulated); the measured
//! single-host wall-clock of the runnable twins is reported as a secondary
//! series by the bench harness.

use crate::cluster::{simulate_step, table2_hardware};
use crate::config::{paper, CapacityMode};
use crate::flops::table_strategies;
use crate::util::table::{f1, Table};

/// Known cells from the paper, for side-by-side printing.
pub fn paper_cells() -> Vec<(&'static str, &'static str, f64)> {
    vec![
        ("Base", "top2", 218.2),
        ("Base", "2top1", 220.1),
        ("Base", "4top1", 225.3),
        ("10B", "top2", 493.0),
        ("10B", "2top1", 466.9),
        ("10B", "4top1", 473.9),
    ]
}

pub fn run() -> Table {
    let hw = table2_hardware();
    let strategies = table_strategies();
    let mut header = vec!["model".to_string()];
    header.extend(strategies.iter().map(|r| r.name()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 2 — simulated ms/step (capacity 1x, calibrated to Base/top2)",
        &header_refs,
    );
    for cfg in [paper::base(), paper::ten_b()] {
        let label = if cfg.name == "base" { "Base" } else { "10B" };
        let mut row = vec![label.to_string()];
        for r in &strategies {
            let ms = simulate_step(&cfg, *r, CapacityMode::Times1, &hw).total_ms();
            row.push(f1(ms));
        }
        t.row(row);
    }
    t
}

/// Paper-vs-simulated comparison rows for EXPERIMENTS.md.
pub fn comparison() -> Table {
    let hw = table2_hardware();
    let mut t = Table::new(
        "Table 2 — paper vs simulated",
        &["model", "strategy", "paper ms", "sim ms", "rel err"],
    );
    for (model, strat, want) in paper_cells() {
        let cfg = if model == "Base" { paper::base() } else { paper::ten_b() };
        let routing = crate::config::Routing::parse(strat).unwrap();
        let got = simulate_step(&cfg, routing, CapacityMode::Times1, &hw).total_ms();
        t.row(vec![
            model.into(),
            strat.into(),
            f1(want),
            f1(got),
            format!("{:+.1}%", (got - want) / want * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_ordering() {
        let t = run();
        assert_eq!(t.rows.len(), 2);
        let base: Vec<f64> = t.rows[0][1..].iter().map(|s| s.parse().unwrap()).collect();
        // columns: top1 top2 top4 2top1 4top1
        assert!(base[2] > base[1], "top4 slower than top2");
        assert!(base[4] < base[2], "4top1 faster than top4");
        let ten: Vec<f64> = t.rows[1][1..].iter().map(|s| s.parse().unwrap()).collect();
        assert!(ten[1] > base[1], "10B slower than base");
    }

    #[test]
    fn comparison_close() {
        let t = comparison();
        for row in &t.rows {
            let rel: f64 = row[4]
                .trim_end_matches('%')
                .parse()
                .unwrap();
            assert!(rel.abs() < 16.0, "{row:?}");
        }
    }
}
