//! Property-testing harness (proptest is not in the offline vendor set).
//!
//! `check` runs a property over many seeded random cases; on failure it
//! re-runs with progressively simpler generator bounds ("shrinking by
//! regeneration") and reports the smallest failing seed/bounds so the case
//! is trivially reproducible with a unit test.

use crate::moe::RouteOutput;
use crate::util::rng::Rng;

/// Bitwise equality of two [`RouteOutput`]s: load, demand, drop counts,
/// and assignment tuples, with combine gates compared as raw f32 bits. This
/// is the engine-vs-reference equivalence contract, kept in one place so
/// the engine unit tests, the routing property tests, and the golden-
/// fixture parity tests cannot silently drift apart in what they check.
pub fn route_outputs_bitwise_eq(a: &RouteOutput, b: &RouteOutput) -> Result<(), String> {
    if a.load != b.load {
        return Err(format!("load diverged: {:?} vs {:?}", a.load, b.load));
    }
    if a.demand != b.demand {
        return Err(format!("demand diverged: {:?} vs {:?}", a.demand, b.demand));
    }
    if a.dropped != b.dropped {
        return Err(format!("dropped diverged: {} vs {}", a.dropped, b.dropped));
    }
    if a.assignments.len() != b.assignments.len() {
        return Err(format!(
            "assignment count diverged: {} vs {}",
            a.assignments.len(),
            b.assignments.len()
        ));
    }
    for (i, (x, y)) in a.assignments.iter().zip(&b.assignments).enumerate() {
        if (x.token, x.expert, x.position) != (y.token, y.expert, y.position)
            || x.gate.to_bits() != y.gate.to_bits()
        {
            return Err(format!("assignment {i} diverged: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

/// Size bounds handed to generators; shrinking lowers `max`.
#[derive(Debug, Clone, Copy)]
pub struct Bounds {
    pub max: usize,
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub bounds: Bounds,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed (seed={}, max={}): {}",
            self.seed, self.bounds.max, self.message
        )
    }
}

/// Run `prop` over `cases` random cases. `prop` gets an RNG and bounds and
/// returns Err(msg) on violation. Panics with the smallest repro found.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng, Bounds) -> Result<(), String>,
{
    let full = Bounds { max: 64 };
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, full) {
            // shrink: halve the bounds until the property passes again
            let mut best = Failure { seed, bounds: full, message: msg };
            let mut max = full.max / 2;
            while max >= 2 {
                let mut r2 = Rng::new(seed);
                match prop(&mut r2, Bounds { max }) {
                    Err(m) => {
                        best = Failure { seed, bounds: Bounds { max }, message: m };
                        max /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!("[{name}] {best}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Bounds;
    use crate::util::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range_i64(lo as i64, hi as i64) as usize
    }

    /// Random token count, expert count (power of two), capacity.
    ///
    /// Every dimension scales off `b.max` so shrinking the bounds actually
    /// shrinks the generated case: the old fixed table `[2, 4, 8, 16, 32]`
    /// clamped with `.min(b.max.max(2))` could only ever produce the same
    /// five values at `max = 64`, and collapsing `max` left the non-expert
    /// dimensions untouched by the table.
    pub fn routing_shape(rng: &mut Rng, b: Bounds) -> (usize, usize, usize) {
        let bound = b.max.max(2);
        let mut choices: Vec<usize> = Vec::new();
        let mut e = 2usize;
        while e <= bound.min(64) {
            choices.push(e);
            e *= 2;
        }
        let experts = choices[usize_in(rng, 0, choices.len() - 1)];
        let tokens = usize_in(rng, 1, bound * 4);
        let capacity = usize_in(rng, 1, bound);
        (tokens, experts, capacity)
    }

    /// Random probability-ish gate matrix (T x E), rows positive.
    pub fn gates(rng: &mut Rng, tokens: usize, e: usize) -> Vec<f32> {
        (0..tokens * e).map(|_| rng.uniform_f32() + 1e-4).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("tautology", 50, |rng, b| {
            let n = gen::usize_in(rng, 0, b.max);
            if n <= b.max {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check("contradiction", 5, |_rng, _b| Err("always fails".into()));
    }

    #[test]
    fn routing_shape_scales_with_bounds() {
        let mut rng = crate::util::rng::Rng::new(123);
        for _ in 0..200 {
            // at the tightest bound every dimension collapses
            let (tokens, experts, capacity) = gen::routing_shape(&mut rng, Bounds { max: 2 });
            assert_eq!(experts, 2, "shrunk bounds must shrink experts");
            assert!(tokens <= 8 && capacity <= 2);
        }
        // at full bounds the generator can reach large expert counts
        let mut rng = crate::util::rng::Rng::new(7);
        let mut max_experts = 0;
        for _ in 0..200 {
            let (_, experts, _) = gen::routing_shape(&mut rng, Bounds { max: 64 });
            assert!(experts.is_power_of_two() && (2..=64).contains(&experts));
            max_experts = max_experts.max(experts);
        }
        assert!(max_experts > 16, "full bounds should reach >16 experts");
    }

    #[test]
    fn shrinking_reports_smaller_bounds() {
        let result = std::panic::catch_unwind(|| {
            check("fails-above-4", 3, |rng, b| {
                let n = gen::usize_in(rng, 0, b.max);
                if n > 4 {
                    Err(format!("n={n} too big"))
                } else {
                    Ok(())
                }
            });
        });
        // may or may not fail depending on seeds; if it failed, the panic
        // message must carry the repro info
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            assert!(msg.contains("seed="), "{msg}");
        }
    }
}
