//! Property tests for `util::shard`, the crate's single audited unsafe
//! module: every flat index is covered by exactly one view, overlapping
//! unit claims panic in debug builds, and the strided carve agrees with
//! a naive index-set oracle. The cross-thread cases run the real
//! `WorkerPool`, so the Miri and ThreadSanitizer CI jobs exercise the
//! same claim/write paths the kernels use (sizes shrink under Miri).

use m6t::util::pool::WorkerPool;
use m6t::util::shard::{DisjointChunks, StridedViews};

#[test]
fn chunks_cover_every_index_exactly_once() {
    let cases: &[(usize, usize)] = &[(0, 3), (1, 3), (10, 4), (12, 4), (5, 9), (257, 16)];
    for &(len, chunk) in cases {
        let mut buf = vec![0u32; len];
        let views = DisjointChunks::new(&mut buf, chunk);
        assert_eq!(views.units(), len.div_ceil(chunk), "unit count for len {len} chunk {chunk}");
        for u in 0..views.units() {
            for x in views.view(u).iter_mut() {
                *x += 1;
            }
        }
        drop(views);
        assert!(buf.iter().all(|&x| x == 1), "len {len} chunk {chunk}: every index exactly once");
    }
}

#[test]
fn chunk_views_map_to_their_ranges() {
    let mut buf = vec![0usize; 11];
    let views = DisjointChunks::new(&mut buf, 4);
    for u in 0..views.units() {
        for x in views.view(u).iter_mut() {
            *x = u + 1;
        }
    }
    drop(views);
    let want: Vec<usize> = (0..11).map(|i| i / 4 + 1).collect();
    assert_eq!(buf, want, "view u must own exactly [u * chunk, (u + 1) * chunk)");
}

/// The naive oracle: the flat indices unit `u = o * inner + t` owns in an
/// `outer x rows x inner x width` carve.
fn strided_unit_indices(rows: usize, inner: usize, width: usize, u: usize) -> Vec<usize> {
    let (o, t) = (u / inner, u % inner);
    let mut idx = Vec::new();
    for r in 0..rows {
        let start = ((o * rows + r) * inner + t) * width;
        idx.extend(start..start + width);
    }
    idx
}

#[test]
fn strided_views_match_the_naive_index_oracle() {
    let geoms: &[(usize, usize, usize, usize)] =
        &[(1, 1, 1, 1), (2, 3, 2, 4), (3, 1, 4, 2), (4, 16, 2, 8)];
    for &(outer, rows, inner, width) in geoms {
        let mut buf = vec![usize::MAX; outer * rows * inner * width];
        let views = StridedViews::new(&mut buf, outer, rows, inner, width);
        assert_eq!(views.units(), outer * inner);
        for u in 0..views.units() {
            let mut v = views.view(u);
            assert_eq!(v.rows(), rows);
            for r in 0..v.rows() {
                for x in v.row(r).iter_mut() {
                    *x = u;
                }
            }
        }
        drop(views);
        for u in 0..outer * inner {
            for i in strided_unit_indices(rows, inner, width, u) {
                assert_eq!(buf[i], u, "flat index {i} must be owned by unit {u}");
            }
        }
        // and nothing outside the per-unit index sets was left unwritten,
        // so the sets partition the buffer exactly
        assert!(buf.iter().all(|&x| x != usize::MAX), "no index may be uncovered");
    }
}

#[test]
fn cross_thread_chunk_writes_are_deterministic() {
    let len = if cfg!(miri) { 1024 } else { 65536 };
    let chunk = 256;
    let mut golden: Option<Vec<u64>> = None;
    for workers in [0usize, 1, 2, 4] {
        let pool = WorkerPool::new(workers);
        let mut buf = vec![0u64; len];
        let views = DisjointChunks::new(&mut buf, chunk);
        pool.parallel_for(views.units(), &|u| {
            for (j, x) in views.view(u).iter_mut().enumerate() {
                *x = ((u as u64) << 32) | j as u64;
            }
        });
        drop(views);
        match &golden {
            None => golden = Some(buf),
            Some(g) => assert_eq!(g, &buf, "chunk writes diverged at {workers} workers"),
        }
    }
}

#[test]
fn cross_thread_strided_writes_are_deterministic() {
    let (outer, inner, width) = (4usize, 4usize, 8usize);
    let rows = if cfg!(miri) { 4 } else { 32 };
    let mut golden: Option<Vec<u64>> = None;
    for workers in [0usize, 2, 4] {
        let pool = WorkerPool::new(workers);
        let mut buf = vec![0u64; outer * rows * inner * width];
        let views = StridedViews::new(&mut buf, outer, rows, inner, width);
        pool.parallel_for(views.units(), &|u| {
            let mut v = views.view(u);
            for r in 0..v.rows() {
                for (j, x) in v.row(r).iter_mut().enumerate() {
                    *x = ((u as u64) << 32) | ((r as u64) << 16) | j as u64;
                }
            }
        });
        drop(views);
        match &golden {
            None => golden = Some(buf),
            Some(g) => assert_eq!(g, &buf, "strided writes diverged at {workers} workers"),
        }
    }
}

#[test]
#[should_panic(expected = "out of range")]
fn chunk_unit_out_of_range_panics() {
    let mut buf = vec![0u8; 8];
    let views = DisjointChunks::new(&mut buf, 4);
    let _ = views.view(2);
}

#[test]
#[should_panic(expected = "out of range")]
fn strided_row_out_of_range_panics() {
    let mut buf = vec![0u8; 8];
    let views = StridedViews::new(&mut buf, 2, 2, 1, 2);
    let mut v = views.view(0);
    let _ = v.row(2);
}

// The runtime overlap checker only exists in debug builds (the release
// contract is the compile-time audit + these debug runs in CI).
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "claimed twice")]
fn overlapping_chunk_claims_panic_in_debug() {
    let mut buf = vec![0u8; 16];
    let views = DisjointChunks::new(&mut buf, 8);
    let _a = views.view(0);
    let _b = views.view(0);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "claimed twice")]
fn overlapping_strided_claims_panic_in_debug() {
    let mut buf = vec![0u16; 2 * 3 * 2 * 2];
    let views = StridedViews::new(&mut buf, 2, 3, 2, 2);
    let _a = views.view(1);
    let _b = views.view(1);
}
