//! Golden-fixture parity with `python/compile/moe.py` routing semantics,
//! checked against BOTH implementations (the naive `route()` reference
//! and the allocation-free `RoutingEngine`):
//!
//! * top-k: gate values renormalized over all k selections — *including
//!   dropped ones* (python lines 85-87: the denominator is the sum over
//!   rounds before `keep` masking);
//! * top-1: NO renormalization (`if renormalize and rounds > 1`): the
//!   combine gate is the raw per-token max softmax gate, < 1.0;
//! * prototyping: raw gates, no cross-prototype renormalization (Eq. 3);
//!   prototype outputs simply sum.
//!
//! The fixtures are small enough to verify by hand; positions follow the
//! round-major cumulative-counter order of the HLO's cumsum.

use m6t::config::Routing;
use m6t::moe::{route, RouteOutput, RouterSpec, RoutingEngine};
use m6t::testing::route_outputs_bitwise_eq;

const EPS: f32 = 1e-6;

/// Run a fixture through both implementations and check them against the
/// hand-computed expectation.
fn check_fixture(
    name: &str,
    gates: &[f32],
    tokens: usize,
    spec: &RouterSpec,
    want: &[(usize, usize, usize, f32)], // (token, expert, position, gate)
    want_load: &[u32],
    want_dropped: u32,
) {
    let reference = route(gates, tokens, spec);
    let engine = RoutingEngine::new().route(gates, tokens, spec);
    for (which, out) in [("reference", &reference), ("engine", &engine)] {
        assert_eq!(out.load, want_load, "{name}/{which}: load");
        assert_eq!(out.dropped, want_dropped, "{name}/{which}: dropped");
        assert_eq!(out.assignments.len(), want.len(), "{name}/{which}: assignment count");
        for (i, (a, &(t, e, p, g))) in out.assignments.iter().zip(want).enumerate() {
            assert_eq!((a.token, a.expert, a.position), (t, e, p), "{name}/{which}: slot {i}");
            assert!(
                (a.gate - g).abs() < EPS,
                "{name}/{which}: slot {i} gate {} != {g}",
                a.gate
            );
        }
    }
    // and the two implementations must agree bitwise with each other
    assert_identical(name, &reference, &engine);
}

fn assert_identical(name: &str, a: &RouteOutput, b: &RouteOutput) {
    if let Err(e) = route_outputs_bitwise_eq(a, b) {
        panic!("{name}: implementations diverged: {e}");
    }
}

#[test]
fn top2_ample_renormalizes_over_both_selections() {
    // T=2, E=3, C=4 (ample). Row-major gates:
    //   t0: [0.2, 0.5, 0.3] -> rounds pick e1 (0.5) then e2 (0.3)
    //   t1: [0.6, 0.1, 0.3] -> rounds pick e0 (0.6) then e2 (0.3)
    let gates = [0.2, 0.5, 0.3, 0.6, 0.1, 0.3];
    let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 3, capacity: 4 };
    check_fixture(
        "top2-ample",
        &gates,
        2,
        &spec,
        &[
            (0, 1, 0, 0.5 / 0.8), // 0.625
            (0, 2, 0, 0.3 / 0.8), // 0.375
            (1, 0, 0, 0.6 / 0.9), // 0.6667
            (1, 2, 1, 0.3 / 0.9), // 0.3333
        ],
        &[1, 1, 2],
        0,
    );
}

#[test]
fn top2_tight_keeps_dropped_selection_in_denominator() {
    // T=3, E=2, C=1. Round 0: t0->e0 kept, t1->e0 DROPPED, t2->e1 kept.
    // Round 1: t0->e1 dropped, t1->e1 dropped, t2->e0 dropped.
    // t0 keeps only its e0 selection, but its combine gate is
    // 0.7 / (0.7 + 0.3) = 0.7 — the dropped second selection stays in the
    // denominator, exactly as python renormalizes before `keep` masking.
    let gates = [0.7, 0.3, 0.8, 0.2, 0.4, 0.6];
    let spec = RouterSpec { routing: Routing::TopK(2), num_experts: 2, capacity: 1 };
    check_fixture(
        "top2-tight",
        &gates,
        3,
        &spec,
        &[(0, 0, 0, 0.7), (2, 1, 0, 0.6)],
        &[1, 1],
        4,
    );
}

#[test]
fn top1_gate_is_the_raw_softmax_gate() {
    // headline bugfix fixture: rounds == 1 -> no renormalization.
    // The kept gate is the raw row max (0.5, 0.6), NOT ~1.0.
    let gates = [0.2, 0.5, 0.3, 0.6, 0.1, 0.3];
    let spec = RouterSpec { routing: Routing::TopK(1), num_experts: 3, capacity: 4 };
    check_fixture(
        "top1-raw",
        &gates,
        2,
        &spec,
        &[(0, 1, 0, 0.5), (1, 0, 0, 0.6)],
        &[1, 1, 0],
        0,
    );
}

#[test]
fn prototyping_keeps_raw_gates_without_cross_prototype_renorm() {
    // E=4 split into Z=2 prototypes of F=2. Per-group softmaxed gates:
    //   t0: group0 [0.6, 0.4], group1 [0.3, 0.7] -> picks e0, e3
    //   t1: group0 [0.2, 0.8], group1 [0.5, 0.5] -> picks e1, e2 (tie:
    //       first index wins, matching the kernel's argmax)
    // Emission is prototype-major; gates stay raw (t0's sum is 1.3).
    let gates = [0.6, 0.4, 0.3, 0.7, 0.2, 0.8, 0.5, 0.5];
    let spec = RouterSpec { routing: Routing::Prototype(2), num_experts: 4, capacity: 4 };
    check_fixture(
        "2top1-raw",
        &gates,
        2,
        &spec,
        &[(0, 0, 0, 0.6), (1, 1, 0, 0.8), (0, 3, 0, 0.7), (1, 2, 0, 0.5)],
        &[1, 1, 1, 1],
        0,
    );
}

#[test]
fn prototype_capacity_is_shared_per_expert_not_per_prototype() {
    // Both tokens' group-0 router picks e0; C=1 drops the second.
    //   t0: group0 [0.9, 0.1], group1 [0.5, 0.5]
    //   t1: group0 [0.8, 0.2], group1 [0.1, 0.9]
    let gates = [0.9, 0.1, 0.5, 0.5, 0.8, 0.2, 0.1, 0.9];
    let spec = RouterSpec { routing: Routing::Prototype(2), num_experts: 4, capacity: 1 };
    check_fixture(
        "2top1-tight",
        &gates,
        2,
        &spec,
        &[(0, 0, 0, 0.9), (0, 2, 0, 0.5), (1, 3, 0, 0.9)],
        &[1, 0, 1, 1],
        1,
    );
}
