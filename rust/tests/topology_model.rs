//! Invariants of the link-level, overlap-aware all-to-all model
//! (`cluster::topology`):
//!
//! * the plan's per-link byte matrix conserves the aggregate a2a byte
//!   count (rows, columns, and the summary's bottleneck link all agree);
//! * the flat-topology per-link bottleneck never exceeds the serial
//!   aggregate model, which serializes every link's bytes through a
//!   single NIC — so the refined model can only *reduce* the priced
//!   exchange, never inflate it past the pre-PR oracle;
//! * a hierarchical grouping (faster intra-node links) never prices the
//!   exchange above flat;
//! * D = 1 has zero links and zero comm time;
//! * the `--no-overlap` serial baseline is bitwise the pre-overlap
//!   serial model (re-derived through a `StepInputs` run), and the
//!   overlapped time never exceeds it (`overlap_speedup >= 1.0` is
//!   structural).

use m6t::cluster::topology::layer_bottleneck_seconds;
use m6t::cluster::{table2_hardware, HardwareModel, ObservedTraffic, StepInputs, Topology};
use m6t::config::Routing;
use m6t::data::{Batch, Batcher, Split};
use m6t::moe::dispatch::{DispatchPlan, DispatchSummary};
use m6t::moe::{route, RouterSpec};
use m6t::runtime::native::registry;
use m6t::runtime::ShardedRun;
use m6t::testing::{check, gen};
use m6t::util::rng::Rng;

/// Random multi-worker plan over random routed gates.
fn random_plan(rng: &mut Rng, b: m6t::testing::Bounds) -> DispatchPlan {
    let (tokens, experts, capacity) = gen::routing_shape(rng, b);
    let divisors: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|d| experts % d == 0).collect();
    let workers = divisors[gen::usize_in(rng, 0, divisors.len() - 1)];
    let k = 1 + gen::usize_in(rng, 0, 3) as u32;
    let routing = if rng.below(2) == 0 { Routing::TopK(k) } else { Routing::Prototype(1) };
    let spec = RouterSpec { routing, num_experts: experts, capacity };
    let routes: Vec<_> = (0..workers)
        .map(|w| {
            let mut wrng = Rng::new(rng.next_u64() ^ (w as u64));
            let gates = gen::gates(&mut wrng, tokens, experts);
            route(&gates, tokens, &spec)
        })
        .collect();
    let hidden = 8 + gen::usize_in(rng, 0, 64);
    DispatchPlan::from_worker_routes(experts, capacity, hidden, &routes)
}

#[test]
fn prop_per_link_bytes_sum_to_aggregate() {
    check("topology-link-conservation", 60, |rng, b| {
        let plan = random_plan(rng, b);
        let d = plan.workers;
        let m = plan.bytes_matrix();
        let sum: u64 = m.iter().sum();
        if sum != plan.dispatch_bytes() {
            return Err(format!(
                "link bytes {sum} != aggregate a2a bytes {}",
                plan.dispatch_bytes()
            ));
        }
        // the summary's bottleneck link is the max cell and never more
        // than the total
        let s = DispatchSummary::from_plans(&[plan.clone()]);
        let max = m.iter().copied().max().unwrap_or(0);
        if s.max_link_bytes != max as f64 {
            return Err(format!("summary max link {} != matrix max {max}", s.max_link_bytes));
        }
        if max > sum {
            return Err("one link carries more than the total".into());
        }
        if max > 0 && m[s.bottleneck_src * d + s.bottleneck_dst] != max {
            return Err("bottleneck coordinates do not point at the max link".into());
        }
        let share = s.bottleneck_link_share();
        if !(0.0..=1.0).contains(&share) {
            return Err(format!("bottleneck share {share} out of range"));
        }
        Ok(())
    });
}

#[test]
fn prop_flat_bottleneck_never_exceeds_serial_aggregate() {
    // the pre-PR serial model pushes the layer's ENTIRE cross-worker
    // byte volume through one NIC; draining every worker's queues
    // concurrently can only be faster (and the hop-latency charge is
    // identical), so the refined model never beats the oracle *upward*
    check("topology-flat-vs-aggregate", 60, |rng, b| {
        let plan = random_plan(rng, b);
        let d = plan.workers;
        let hw = table2_hardware();
        let topo = Topology::flat(d);
        let got = layer_bottleneck_seconds(&plan.bytes_matrix(), &topo, &hw);
        let serial = plan.dispatch_bytes() as f64 / hw.net_bw
            + hw.a2a_latency * (d as f64 - 1.0).max(0.0);
        if got > serial + 1e-15 {
            return Err(format!(
                "flat bottleneck {got} exceeds serial aggregate {serial} at D={d}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchy_never_slower_than_flat() {
    check("topology-hier-vs-flat", 40, |rng, b| {
        let plan = random_plan(rng, b);
        let d = plan.workers;
        let hw = table2_hardware();
        let m = plan.bytes_matrix();
        let flat = layer_bottleneck_seconds(&m, &Topology::flat(d), &hw);
        for wpn in [2usize, 4] {
            let hier = layer_bottleneck_seconds(&m, &Topology::hierarchical(d, wpn), &hw);
            if hier > flat + 1e-15 {
                return Err(format!(
                    "nodes{wpn} bottleneck {hier} above flat {flat} at D={d}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn single_worker_has_zero_comm_everywhere() {
    let hw = table2_hardware();
    assert_eq!(layer_bottleneck_seconds(&[0], &Topology::flat(1), &hw), 0.0);

    // end to end: a D = 1 sharded step moves nothing, so the link model
    // sees an empty exchange and the overlap fields degrade cleanly
    let cfg = registry().into_iter().find(|c| c.name == "base-sim").unwrap();
    let run = ShardedRun::new(&cfg, 1).unwrap();
    let state = run.init_state(3).unwrap();
    let mut batcher = Batcher::for_config(&cfg, Split::Train, 3);
    let batches = vec![batcher.next_batch()];
    let (_, stats) = run.step(state, &batches).unwrap();
    let dsp = stats.dispatch.as_ref().unwrap();
    assert_eq!(dsp.max_link_bytes, 0.0);
    assert_eq!(dsp.bottleneck_link_share(), 0.0);
    assert_eq!(dsp.overlap_efficiency, 1.0, "no comm counts as fully hidden");
    assert!(dsp.observed_overlap_ms > 0.0);
    assert!(dsp.observed_overlap_ms <= dsp.observed_ms);
}

/// The `--no-overlap` oracle: the sharded runtime's serial observed-ms
/// series must be bitwise what the pre-overlap serial model (a
/// `StepInputs` run with observed traffic and no per-layer comm)
/// produces from the same aggregate traffic — the overlap refactor may
/// only *add* numbers, never move the old ones.
#[test]
fn serial_observed_ms_is_bitwise_the_pre_overlap_model() {
    for (name, d) in [("base-sim", 4usize), ("large-sim", 8), ("xlarge-sim", 4)] {
        let cfg = registry().into_iter().find(|c| c.name == name).unwrap();
        let run = ShardedRun::new(&cfg, d).unwrap();
        let run_cfg = run.info().config.clone();
        let mut state = run.init_state(17).unwrap();
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 17);
        for step in 0..2 {
            let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
            let (next, stats) = run.step(state, &batches).unwrap();
            state = next;
            let dsp = stats.dispatch.as_ref().unwrap();
            let hw = table2_hardware();
            let observed = ObservedTraffic {
                a2a_bytes_per_layer: dsp.a2a_bytes_per_layer,
                shard_balance: dsp.shard_balance,
            };
            let oracle = StepInputs::new(&run_cfg, &hw).observed(&observed).run().serial_ms();
            assert_eq!(
                dsp.observed_ms.to_bits(),
                oracle.to_bits(),
                "{name} D={d} step {step}: serial path drifted from the StepInputs oracle"
            );
        }
    }
}

#[test]
fn overlap_never_slower_across_the_bench_grid_slice() {
    // a small slice of the bench grid: every cell's overlapped time is
    // bounded by its serial time on both topologies
    for name in ["base-sim", "large-sim"] {
        let cfg = registry().into_iter().find(|c| c.name == name).unwrap();
        for d in [4usize, 8] {
            for wpn in [1usize, 4] {
                let mut run = ShardedRun::new(&cfg, d).unwrap();
                run.set_workers_per_node(wpn);
                let state = run.init_state(23).unwrap();
                let mut batcher = Batcher::for_config(&cfg, Split::Train, 23);
                let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
                let (_, stats) = run.step(state, &batches).unwrap();
                let dsp = stats.dispatch.as_ref().unwrap();
                assert!(
                    dsp.observed_overlap_ms <= dsp.observed_ms,
                    "{name} D={d} wpn={wpn}: overlap {} above serial {}",
                    dsp.observed_overlap_ms,
                    dsp.observed_ms
                );
                assert!(dsp.observed_overlap_ms > 0.0);
                assert!((0.0..=1.0).contains(&dsp.overlap_efficiency));
            }
        }
    }
}

#[test]
fn intra_tier_defaults_keep_the_invariants_sound() {
    // the "hierarchy never slower" and "flat never above aggregate"
    // invariants lean on the hardware defaults: intra-node links must be
    // at least as fast (and as low-latency) as inter-node ones
    let hw = HardwareModel::v100();
    assert!(hw.intra_node_bw >= hw.net_bw);
    assert!(hw.intra_node_latency <= hw.a2a_latency);
    assert_eq!(hw.workers_per_node, 1, "the paper's testbed is flat");
    assert_eq!(hw.clone().with_workers_per_node(0).workers_per_node, 1);
    assert_eq!(hw.with_workers_per_node(4).workers_per_node, 4);
}
