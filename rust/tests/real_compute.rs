//! End-to-end tests of `ComputeMode::Real`: the native expert-FFN +
//! optimizer step actually training, staying bitwise deterministic
//! across pool sizes and across the native/sharded split, and
//! round-tripping mid-run through a v2 checkpoint.
//!
//! The heavy checks run on a shrunken Real config (tiny M/I/E, two
//! layers) so the whole file stays cheap under `cargo test` debug
//! builds; the registry twins themselves get a short smoke. The descent
//! thresholds have wide margin: a numpy simulation of the same
//! objective/optimizer puts the 60-step tail/head loss ratio at ~0.5
//! (AdamW) and ~0.1 (Adafactor) across seeds, and we assert < 0.75.

use std::sync::Arc;

use m6t::config::ModelConfig;
use m6t::coordinator::{Checkpoint, TrainOptions, Trainer};
use m6t::data::{Batcher, Split};
use m6t::runtime::native::registry;
use m6t::runtime::{
    Backend, BackendProvider, NativeBackend, NativeProvider, ShardedRun, StateRepr, TrainState,
};
use m6t::util::pool::WorkerPool;

/// A Real-compute config small enough that 60 debug-mode steps are
/// cheap: it inherits every policy knob from the registry twin and only
/// shrinks the geometry.
fn tiny_real(optimizer: &str) -> ModelConfig {
    let mut cfg = registry()
        .into_iter()
        .find(|c| c.name == "base-sim-real")
        .expect("base-sim-real in registry");
    cfg.name = format!("tiny-real-{optimizer}");
    cfg.hidden = 16;
    cfg.intermediate = 32;
    cfg.num_experts = 4;
    cfg.layers = 2;
    cfg.batch = 2;
    cfg.patches = 8;
    cfg.text_len = 24;
    cfg.optimizer = optimizer.into();
    if optimizer == "adafactor" {
        cfg.lr = 5e-3;
    }
    cfg
}

fn host_leaves(state: &TrainState) -> &Vec<Vec<f32>> {
    match &state.repr {
        StateRepr::Host(leaves) => leaves,
        #[cfg(feature = "pjrt")]
        StateRepr::Device(_) => panic!("native state must be host-resident"),
    }
}

/// Run `steps` training steps from a fresh init and return the loss
/// series plus the final state.
fn run_steps(backend: &dyn Backend, steps: usize, seed: u64) -> (Vec<f32>, TrainState) {
    let cfg = &backend.info().config;
    let mut state = backend.init_state(seed).unwrap();
    let mut batcher = Batcher::for_config(cfg, Split::Train, seed);
    let mut losses = Vec::with_capacity(steps);
    for i in 0..steps {
        let batch = batcher.next_batch();
        let (next, stats) = backend.step(state, &batch).unwrap();
        state = next;
        assert!(stats.loss.is_finite(), "step {i}: loss {}", stats.loss);
        assert!(stats.loss > 0.0, "step {i}: sum-of-squares loss must be positive");
        assert!(
            stats.grad_norm.is_finite() && stats.grad_norm > 0.0,
            "step {i}: grad_norm {}",
            stats.grad_norm
        );
        losses.push(stats.loss);
    }
    (losses, state)
}

fn descent_ratio(losses: &[f32]) -> f64 {
    let head: f64 = losses[..5].iter().map(|&l| l as f64).sum::<f64>() / 5.0;
    let tail: f64 = losses[losses.len() - 5..].iter().map(|&l| l as f64).sum::<f64>() / 5.0;
    tail / head
}

#[test]
fn real_adamw_training_descends() {
    let backend = NativeBackend::new(&tiny_real("adamw"));
    let (losses, _) = run_steps(&backend, 60, 42);
    let ratio = descent_ratio(&losses);
    assert!(
        ratio < 0.75,
        "60 AdamW steps on the real FFN must cut the regression loss: \
         head->tail ratio {ratio:.3} (losses {:?} .. {:?})",
        &losses[..3],
        &losses[losses.len() - 3..]
    );
}

#[test]
fn real_adafactor_training_descends() {
    let backend = NativeBackend::new(&tiny_real("adafactor"));
    let (losses, _) = run_steps(&backend, 60, 42);
    let ratio = descent_ratio(&losses);
    assert!(
        ratio < 0.75,
        "60 Adafactor steps on the real FFN must cut the regression loss: \
         head->tail ratio {ratio:.3}"
    );
}

/// The (expert, I-tile) pool sharding merges partials in a fixed tile
/// order, so the whole training trajectory must be bitwise identical no
/// matter how many workers execute it.
#[test]
fn real_step_is_bitwise_identical_across_pool_sizes() {
    let cfg = tiny_real("adamw");
    let mut reference: Option<(Vec<u32>, Vec<Vec<f32>>)> = None;
    for workers in [0usize, 2, 5] {
        let backend = NativeBackend::with_pool(&cfg, Arc::new(WorkerPool::new(workers)));
        let (losses, state) = run_steps(&backend, 4, 9);
        let bits: Vec<u32> = losses.iter().map(|l| l.to_bits()).collect();
        let leaves = backend.state_to_host(&state).unwrap();
        match &reference {
            None => reference = Some((bits, leaves)),
            Some((ref_bits, ref_leaves)) => {
                assert_eq!(ref_bits, &bits, "W={workers}: per-step loss bits diverged");
                assert_eq!(ref_leaves, &leaves, "W={workers}: final state diverged");
            }
        }
    }
}

/// Worker 0's shard seed folds in `0 * WORKER_SEED_MIX`, so a D=1
/// sharded run must reproduce the single-process native trajectory
/// bitwise — losses and the full final state.
#[test]
fn sharded_d1_real_run_matches_native_bitwise() {
    let cfg = tiny_real("adamw");
    let native = NativeBackend::new(&cfg);
    let shard = ShardedRun::new(&cfg, 1).unwrap();

    let mut n_state = native.init_state(11).unwrap();
    let mut s_state = shard.init_state(11).unwrap();
    assert_eq!(host_leaves(&n_state), host_leaves(&s_state), "init diverged");

    let mut batcher = Batcher::for_config(&cfg, Split::Train, 11);
    for i in 0..4 {
        let batch = batcher.next_batch();
        let (n_next, n_stats) = native.step(n_state, &batch).unwrap();
        let (s_next, s_stats, _) =
            shard.step_detailed(s_state, std::slice::from_ref(&batch)).unwrap();
        assert_eq!(
            n_stats.loss.to_bits(),
            s_stats.loss.to_bits(),
            "step {i}: native {} vs sharded {}",
            n_stats.loss,
            s_stats.loss
        );
        n_state = n_next;
        s_state = s_next;
    }
    assert_eq!(host_leaves(&n_state), host_leaves(&s_state), "final state diverged");
}

/// Acceptance: a mid-run Real checkpoint round-trips through the v2
/// on-disk format (named, dtype-tagged leaves) and resumes bitwise
/// identically — and the leaf names actually carry the FFN weights and
/// optimizer moments.
#[test]
fn real_checkpoint_v2_roundtrip_resumes_bitwise() {
    let cfg = tiny_real("adamw");
    let opts = TrainOptions { steps: 4, seed: 42, verbose: false, ..Default::default() };
    let trainer = Trainer::new(Box::new(NativeBackend::new(&cfg)), opts);
    let (_, state) = trainer.train().unwrap();

    let ck = trainer.snapshot(&state).unwrap();
    let has = |name: &str| ck.names.iter().any(|n| n == name);
    assert!(has("layer0/ffn_w1"), "missing layer0/ffn_w1 in {:?}", ck.names);
    assert!(has("layer1/ffn_w2"), "missing layer1/ffn_w2 in {:?}", ck.names);
    assert!(has("opt/layer0/ffn_w1/m"), "missing opt moment leaf in {:?}", ck.names);
    assert!(has("opt/layer1/ffn_w2/v"), "missing opt moment leaf in {:?}", ck.names);

    let path = std::env::temp_dir().join("m6t-real-v2-roundtrip.bin");
    ck.save(&path).unwrap();
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(&raw[..8], b"M6TCKPT2", "mid-run saves must use the v2 format");

    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.step, state.step);
    let restored = trainer.restore(&loaded).unwrap();

    // continue both one step on the same batch: bitwise-identical loss
    // and next state
    let mut batcher = Batcher::for_config(&cfg, Split::Train, 42);
    batcher.seek(state.step as u64 * cfg.batch as u64);
    let batch = batcher.next_batch();
    let (mem_next, mem_stats) = trainer.backend.step(state, &batch).unwrap();
    let (ck_next, ck_stats) = trainer.backend.step(restored, &batch).unwrap();
    assert_eq!(mem_stats.loss.to_bits(), ck_stats.loss.to_bits());
    assert_eq!(host_leaves(&mem_next), host_leaves(&ck_next), "post-resume state diverged");
    let _ = std::fs::remove_file(path);
}

/// Registry smoke for the real twins: they load through the provider,
/// step with finite positive loss, and eval deterministically.
#[test]
fn registry_real_twins_step_and_eval() {
    let provider = NativeProvider::new();
    for name in ["base-sim-real", "base-sim-real-af"] {
        let backend = provider.load(name).expect(name);
        let (losses, state) = run_steps(backend.as_ref(), 2, 7);
        assert_eq!(losses.len(), 2, "{name}");

        let mut b1 = Batcher::for_config(&backend.info().config, Split::Eval, 5);
        let mut b2 = Batcher::for_config(&backend.info().config, Split::Eval, 5);
        let (nll1, c1) = backend.eval(&state, &b1.next_batch()).unwrap();
        let (nll2, c2) = backend.eval(&state, &b2.next_batch()).unwrap();
        assert_eq!(nll1.to_bits(), nll2.to_bits(), "{name}: eval must be deterministic");
        assert_eq!(c1, c2, "{name}");
        assert!(nll1.is_finite() && c1 > 0.0, "{name}: nll {nll1}, count {c1}");
    }
}
