//! Golden parity: the native FFN and optimizer kernels against the
//! Python reference (`kernels/ref.py`, `kernels/moe_ffn.py`,
//! `compile/optim.py`), via the checked-in fixtures in
//! `tests/fixtures/*.json` (regenerate with
//! `python3 -m python.compile.kernels.gen_fixtures`).
//!
//! Tolerance is 1e-5 *relative* (`|a - b| <= 1e-5 * max(1, |b|)`): the
//! Rust kernels accumulate in a different association order than the
//! jax einsums, so bitwise equality is not expected — but anything
//! looser than 1e-5 on these shapes means the math diverged.
//!
//! The FFN grid covers the acceptance cases: base geometry,
//! non-128-multiple dims, a single expert, and capacity 1.

use m6t::moe::ffn::{self, FfnShape};
use m6t::runtime::optim;
use m6t::util::json::{self, Value};
use m6t::util::pool::WorkerPool;

const REL_TOL: f32 = 1e-5;

fn load(name: &str) -> Value {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    json::parse(&text).expect("fixture JSON parses")
}

fn f32s(v: &Value, key: &str) -> Vec<f32> {
    v.get(key)
        .and_then(|a| a.as_array())
        .unwrap_or_else(|| panic!("fixture missing array {key:?}"))
        .iter()
        .map(|x| x.as_f64().expect("fixture number") as f32)
        .collect()
}

fn usize_of(v: &Value, key: &str) -> usize {
    v.get(key)
        .and_then(|x| x.as_usize())
        .unwrap_or_else(|| panic!("fixture missing int {key:?}"))
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (j, (&a, &b)) in got.iter().zip(want).enumerate() {
        let tol = REL_TOL * b.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "{what}[{j}]: got {a}, reference {b} (|diff| {} > tol {tol})",
            (a - b).abs()
        );
    }
}

#[test]
fn gelu_matches_reference() {
    let fix = load("gelu.json");
    let x = f32s(&fix, "x");
    let want_g = f32s(&fix, "gelu");
    let want_dg = f32s(&fix, "gelu_grad");
    let got_g: Vec<f32> = x.iter().map(|&v| ffn::gelu(v)).collect();
    let got_dg: Vec<f32> = x.iter().map(|&v| ffn::gelu_grad(v)).collect();
    assert_close(&got_g, &want_g, "gelu");
    assert_close(&got_dg, &want_dg, "gelu_grad");
}

#[test]
fn moe_ffn_forward_and_backward_match_reference() {
    let fix = load("moe_ffn.json");
    let cases = fix.get("cases").and_then(|c| c.as_array()).expect("cases");
    assert_eq!(cases.len(), 4, "the acceptance grid has four geometries");
    for case in cases {
        let name = case.get("name").and_then(|n| n.as_str()).expect("case name").to_string();
        let (e, c) = (usize_of(case, "experts"), usize_of(case, "capacity"));
        let (m, i) = (usize_of(case, "hidden"), usize_of(case, "intermediate"));
        let i_block = usize_of(case, "i_block");
        let shape = FfnShape::with_block(e, c, m, i, Some(i_block)).expect("shape");
        assert_eq!(shape.i_block, i_block, "{name}: tile pick diverged from python");

        let x = f32s(case, "x");
        let w1 = f32s(case, "w1");
        let w2 = f32s(case, "w2");
        let g = f32s(case, "g");
        let want_out = f32s(case, "out");
        let want_dx = f32s(case, "dx");
        let want_dw1 = f32s(case, "dw1");
        let want_dw2 = f32s(case, "dw2");

        // naive forward
        let mut out = vec![0.0f32; shape.x_len()];
        let mut h = Vec::new();
        ffn::fwd_naive(shape, &x, &w1, &w2, &mut out, &mut h);
        assert_close(&out, &want_out, &format!("{name}/fwd_naive"));

        for workers in [0usize, 2] {
            let pool = WorkerPool::new(workers);
            let mut out_t = vec![0.0f32; shape.x_len()];
            let mut partial = Vec::new();
            let inputs = ffn::FfnInputs { x: &x, w1: &w1, w2: &w2 };
            ffn::fwd_tiled(&pool, shape, inputs, &mut out_t, &mut partial);
            assert_close(&out_t, &want_out, &format!("{name}/fwd_tiled/W{workers}"));

            let mut dw1 = vec![0.0f32; shape.w1_len()];
            let mut dw2 = vec![0.0f32; shape.w2_len()];
            let mut dx = vec![0.0f32; shape.x_len()];
            let grads = ffn::FfnGrads { dw1: &mut dw1, dw2: &mut dw2, dx: Some(&mut dx) };
            ffn::bwd_tiled(&pool, shape, inputs, &g, grads, &mut partial);
            assert_close(&dx, &want_dx, &format!("{name}/dx/W{workers}"));
            assert_close(&dw1, &want_dw1, &format!("{name}/dw1/W{workers}"));
            assert_close(&dw2, &want_dw2, &format!("{name}/dw2/W{workers}"));
        }
    }
}

#[test]
fn adamw_step_matches_reference() {
    let fix = load("optim.json");
    let case = fix.get("adamw").expect("adamw fixture");
    let lr_peak = case.get("lr").and_then(|x| x.as_f64()).expect("lr");
    let warmup = usize_of(case, "warmup");
    let step = case.get("step").and_then(|x| x.as_i64()).expect("step");
    let wd = case.get("weight_decay").and_then(|x| x.as_f64()).expect("wd") as f32;
    let mut p = f32s(case, "p");
    let g = f32s(case, "g");
    let mut m = f32s(case, "m");
    let mut v = f32s(case, "v");
    let lr = optim::lr_schedule(lr_peak, warmup, step);
    optim::adamw_update(&mut p, &g, &mut m, &mut v, step, lr, wd);
    assert_close(&p, &f32s(case, "new_p"), "adamw/p");
    assert_close(&m, &f32s(case, "new_m"), "adamw/m");
    assert_close(&v, &f32s(case, "new_v"), "adamw/v");
}

#[test]
fn adafactor_factored_step_matches_reference() {
    let fix = load("optim.json");
    let case = fix.get("adafactor_factored").expect("adafactor fixture");
    let lr_peak = case.get("lr").and_then(|x| x.as_f64()).expect("lr");
    let warmup = usize_of(case, "warmup");
    let step = case.get("step").and_then(|x| x.as_i64()).expect("step");
    let wd = case.get("weight_decay").and_then(|x| x.as_f64()).expect("wd") as f32;
    let shape: Vec<usize> = case
        .get("shape")
        .and_then(|a| a.as_array())
        .expect("shape")
        .iter()
        .map(|x| x.as_usize().expect("dim"))
        .collect();
    let (mats, rows, cols) = (shape[0], shape[1], shape[2]);
    let mut p = f32s(case, "p");
    let g = f32s(case, "g");
    let mut vr = f32s(case, "vr");
    let mut vc = f32s(case, "vc");
    let mut u = Vec::new();
    let lr = optim::lr_schedule(lr_peak, warmup, step);
    optim::adafactor_update_factored(
        &mut p, &g, &mut vr, &mut vc, mats, rows, cols, step, lr, wd, &mut u,
    );
    assert_close(&p, &f32s(case, "new_p"), "adafactor/p");
    assert_close(&vr, &f32s(case, "new_vr"), "adafactor/vr");
    assert_close(&vc, &f32s(case, "new_vc"), "adafactor/vc");
}

#[test]
fn adafactor_vector_step_matches_reference() {
    let fix = load("optim.json");
    let case = fix.get("adafactor_vector").expect("vector fixture");
    let lr_peak = case.get("lr").and_then(|x| x.as_f64()).expect("lr");
    let warmup = usize_of(case, "warmup");
    let step = case.get("step").and_then(|x| x.as_i64()).expect("step");
    let wd = case.get("weight_decay").and_then(|x| x.as_f64()).expect("wd") as f32;
    let mut p = f32s(case, "p");
    let g = f32s(case, "g");
    let mut v = f32s(case, "v");
    let mut u = Vec::new();
    let lr = optim::lr_schedule(lr_peak, warmup, step);
    optim::adafactor_update_vector(&mut p, &g, &mut v, step, lr, wd, &mut u);
    assert_close(&p, &f32s(case, "new_p"), "adafactor_vector/p");
    assert_close(&v, &f32s(case, "new_v"), "adafactor_vector/v");
}
