//! Property tests for the scaling-law machinery: `fit_power_law` must
//! round-trip synthetic laws across the parameter space, and `steps_to`
//! must refuse degenerate or unreachable targets.

use m6t::scaling::{fit_power_law, PowerLaw};
use m6t::testing::check;

#[test]
fn prop_fit_roundtrips_synthetic_laws() {
    check("powerlaw-roundtrip", 25, |rng, _b| {
        let truth = PowerLaw {
            l_inf: 0.5 + rng.uniform() * 2.5,
            a: 1.0 + rng.uniform() * 6.0,
            b: 0.2 + rng.uniform() * 0.5,
        };
        let steps: Vec<f64> = (1..80).map(|i| (i * 25) as f64).collect();
        let losses: Vec<f64> = steps.iter().map(|&s| truth.predict(s)).collect();
        let fit = fit_power_law(&steps, &losses);
        for &s in &[50.0, 200.0, 1000.0, 1900.0] {
            let rel = (fit.predict(s) - truth.predict(s)).abs() / truth.predict(s);
            if rel > 0.08 {
                return Err(format!(
                    "rel err {rel:.4} at step {s}: truth {truth:?}, fit {fit:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fit_survives_observation_noise() {
    check("powerlaw-noise", 15, |rng, _b| {
        let truth = PowerLaw {
            l_inf: 1.0 + rng.uniform() * 2.0,
            a: 2.0 + rng.uniform() * 4.0,
            b: 0.25 + rng.uniform() * 0.3,
        };
        let steps: Vec<f64> = (1..120).map(|i| (i * 10) as f64).collect();
        let losses: Vec<f64> = steps
            .iter()
            .map(|&s| truth.predict(s) + 0.01 * rng.normal())
            .collect();
        let fit = fit_power_law(&steps, &losses);
        let s = 800.0;
        let rel = (fit.predict(s) - truth.predict(s)).abs() / truth.predict(s);
        if rel > 0.1 {
            return Err(format!("noisy fit off by {rel:.4} (truth {truth:?}, fit {fit:?})"));
        }
        Ok(())
    });
}

#[test]
fn steps_to_edge_cases() {
    let law = PowerLaw { l_inf: 2.0, a: 3.0, b: 0.4 };
    // reachable target inverts predict exactly
    let s = law.steps_to(2.5).expect("2.5 is above the floor");
    assert!((law.predict(s) - 2.5).abs() < 1e-9);
    // at or below the floor: unreachable
    assert!(law.steps_to(2.0).is_none(), "target == floor");
    assert!(law.steps_to(1.0).is_none(), "target < floor");
    // degenerate decay never reaches anything
    assert!(PowerLaw { l_inf: 2.0, a: 3.0, b: 0.0 }.steps_to(2.5).is_none(), "b == 0");
    assert!(PowerLaw { l_inf: 2.0, a: 3.0, b: -0.2 }.steps_to(2.5).is_none(), "b < 0");
    // non-positive amplitude: the curve never sits above the floor
    assert!(PowerLaw { l_inf: 2.0, a: 0.0, b: 0.4 }.steps_to(2.5).is_none(), "a == 0");
    assert!(PowerLaw { l_inf: 2.0, a: -1.0, b: 0.4 }.steps_to(2.5).is_none(), "a < 0");
}

#[test]
fn steps_to_is_monotone_in_target() {
    // easier targets (higher loss) must need fewer steps
    let law = PowerLaw { l_inf: 2.0, a: 5.0, b: 0.35 };
    let hard = law.steps_to(2.2).unwrap();
    let easy = law.steps_to(3.0).unwrap();
    assert!(hard > easy, "harder target needs more steps: {hard} vs {easy}");
}
