//! Sweep-engine integration tests: spec parse/expand round-trips (and
//! loud rejection of malformed specs), content-address stability across
//! field ordering, resume-skips-completed-cells, gc never deleting a
//! live cell, and the `experiments::Runner` stale-cache regression — a
//! config edit must change the address and force a re-train.

use std::collections::BTreeSet;
use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{ensure, Result};

use m6t::config::ModelConfig;
use m6t::experiments::Runner;
use m6t::runtime::native::{registry, variant_info};
use m6t::runtime::{Backend, BackendProvider, NativeBackend, VariantInfo};
use m6t::sweep::{self, cell_key, nums, Cell, CellRunner, Engine, ParamValue, SweepSpec};
use m6t::util::json::{self, num, obj, Value};

/// A fresh per-test results dir under the system temp root.
fn temp_results(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("m6t-sweep-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Deterministic fake executor: doubles `x`, counting real executions so
/// tests can distinguish store hits from re-runs.
struct CountingRunner {
    runs: AtomicUsize,
}

impl CountingRunner {
    fn new() -> Self {
        Self { runs: AtomicUsize::new(0) }
    }
}

impl CellRunner for CountingRunner {
    fn kind(&self) -> &'static str {
        "fake"
    }

    fn version(&self) -> &'static str {
        "fake-v1"
    }

    fn resolve(&self, cell: &Cell) -> Result<Cell> {
        Ok(cell.clone())
    }

    fn run(&self, cell: &Cell) -> Result<Value> {
        self.runs.fetch_add(1, Ordering::SeqCst);
        let x = cell.req_f64("x")?;
        Ok(obj(vec![("doubled", num(x * 2.0))]))
    }
}

fn fake_spec() -> SweepSpec {
    SweepSpec::new("fake-sweep", "fake").steps(1).axis("x", nums(&[1, 2, 3]))
}

#[test]
fn spec_json_roundtrip_and_expansion() {
    let spec = SweepSpec::new("demo", "fake")
        .steps(3)
        .seed(7)
        .fix("model", ParamValue::Str("base".into()))
        .axis("x", nums(&[1, 2]))
        .axis("y", nums(&[10, 20, 30]));
    let back = SweepSpec::parse(&json::write(&spec.to_json())).expect("round-trip");
    assert_eq!(back, spec);
    let cells = back.expand().expect("expand");
    assert_eq!(cells.len(), 6);
    // last axis fastest; every cell carries the fixed + reserved params
    assert_eq!(cells[0].req_usize("y").unwrap(), 10);
    assert_eq!(cells[1].req_usize("y").unwrap(), 20);
    assert_eq!(cells[3].req_usize("x").unwrap(), 2);
    for c in &cells {
        assert_eq!(c.req_str("model").unwrap(), "base");
        assert_eq!(c.req_usize("steps").unwrap(), 3);
        assert_eq!(c.req_u64("seed").unwrap(), 7);
    }
}

#[test]
fn malformed_specs_are_rejected() {
    // a minimal valid spec, to guard the harness itself
    assert!(SweepSpec::parse(r#"{"name": "d", "kind": "f"}"#).is_ok());
    let cases = [
        ("missing name", r#"{"kind": "f"}"#),
        ("missing kind", r#"{"name": "d"}"#),
        ("unknown top-level key", r#"{"name": "d", "kind": "f", "grid": []}"#),
        ("zero steps", r#"{"name": "d", "kind": "f", "steps": 0}"#),
        ("axes not an array", r#"{"name": "d", "kind": "f", "axes": {}}"#),
        ("axis missing values", r#"{"name": "d", "kind": "f", "axes": [{"name": "x"}]}"#),
        ("empty axis", r#"{"name": "d", "kind": "f", "axes": [{"name": "x", "values": []}]}"#),
        (
            "duplicate axis",
            r#"{"name": "d", "kind": "f",
                "axes": [{"name": "x", "values": [1]}, {"name": "x", "values": [2]}]}"#,
        ),
        (
            "axis shadows reserved key",
            r#"{"name": "d", "kind": "f", "axes": [{"name": "steps", "values": [1]}]}"#,
        ),
        (
            "fixed shadows reserved key",
            r#"{"name": "d", "kind": "f", "fixed": {"seed": 1}}"#,
        ),
        (
            "fixed collides with axis",
            r#"{"name": "d", "kind": "f", "fixed": {"x": 1},
                "axes": [{"name": "x", "values": [1]}]}"#,
        ),
        (
            "non-scalar axis value",
            r#"{"name": "d", "kind": "f", "axes": [{"name": "x", "values": [[1]]}]}"#,
        ),
        (
            "unknown axis key",
            r#"{"name": "d", "kind": "f", "axes": [{"name": "x", "values": [1], "step": 2}]}"#,
        ),
    ];
    for (what, text) in cases {
        assert!(SweepSpec::parse(text).is_err(), "{what} should be rejected");
    }
}

#[test]
fn store_keys_ignore_field_order_but_see_values() {
    let cell = |text: &str| Cell::from_json(&json::parse(text).expect("json")).expect("cell");
    let a = cell(r#"{"a": 1, "b": "x", "c": true}"#);
    let b = cell(r#"{"c": true, "b": "x", "a": 1}"#);
    assert_eq!(cell_key("k", "v1", &a), cell_key("k", "v1", &b), "field order must not matter");
    let edited = cell(r#"{"a": 2, "b": "x", "c": true}"#);
    assert_ne!(cell_key("k", "v1", &a), cell_key("k", "v1", &edited));
    assert_ne!(cell_key("k", "v1", &a), cell_key("k", "v2", &a), "version tag is part of the key");
    assert_ne!(cell_key("k", "v1", &a), cell_key("other", "v1", &a), "kind is part of the key");
}

#[test]
fn resume_skips_completed_cells() {
    let results = temp_results("resume");
    let engine = Engine::new(&results).verbose(false);
    let runner = CountingRunner::new();
    let spec = fake_spec();

    let first = engine.run_spec(&spec, &runner).expect("first run");
    assert_eq!(first.executed(), 3);
    assert_eq!(first.hits(), 0);
    assert_eq!(runner.runs.load(Ordering::SeqCst), 3);

    let second = engine.run_spec(&spec, &runner).expect("second run");
    assert_eq!(second.executed(), 0);
    assert_eq!(second.hits(), 3);
    assert_eq!(runner.runs.load(Ordering::SeqCst), 3, "identical sweep must be zero re-runs");
    for (f, s) in first.outcomes.iter().zip(&second.outcomes) {
        assert_eq!(f.key, s.key);
        assert_eq!(json::write(&f.result), json::write(&s.result));
    }

    // deleting one completion marker re-runs exactly that cell
    let victim = engine.store().cell_dir("fake", &first.outcomes[1].key);
    fs::remove_file(victim.join("result.json")).expect("remove completion marker");
    let third = engine.run_spec(&spec, &runner).expect("third run");
    assert_eq!(third.executed(), 1);
    assert_eq!(third.hits(), 2);
    assert_eq!(runner.runs.load(Ordering::SeqCst), 4);
    let _ = fs::remove_dir_all(&results);
}

#[test]
fn gc_prunes_only_dead_cells() {
    let results = temp_results("gc");
    let engine = Engine::new(&results).verbose(false);
    let runner = CountingRunner::new();
    let spec = fake_spec();
    engine.run_spec(&spec, &runner).expect("seed the store");

    // an orphan cell in the covered kind, and a foreign kind no spec covers
    let store = engine.store();
    let mut orphan = Cell::new();
    orphan.set("x", ParamValue::Num(99.0));
    let orphan_key = cell_key("fake", "fake-v1", &orphan);
    let doubled = obj(vec![("doubled", num(198.0))]);
    store.insert("fake", &orphan_key, &orphan, &doubled).expect("insert orphan");
    let loss = obj(vec![("loss", num(1.0))]);
    store.insert("train", "00aa", &orphan, &loss).expect("insert foreign kind");

    let live = sweep::live_keys(&spec, &runner).expect("live keys");
    let kinds: BTreeSet<String> = ["fake".to_string()].into_iter().collect();

    let dry = store.gc(&live, &kinds, true).expect("dry run");
    assert_eq!(dry.scanned, 4);
    assert_eq!(dry.kept, 3);
    assert_eq!(dry.pruned.len(), 1);
    assert!(store.lookup("fake", &orphan_key).is_some(), "dry-run must not delete");

    let real = store.gc(&live, &kinds, false).expect("gc");
    assert_eq!(real.kept, 3);
    assert_eq!(real.pruned.len(), 1);
    assert!(store.lookup("fake", &orphan_key).is_none(), "orphan must be pruned");
    assert!(store.lookup("train", "00aa").is_some(), "foreign kind untouched");

    // every live cell still serves from the store afterwards
    let after = engine.run_spec(&spec, &runner).expect("after gc");
    assert_eq!(after.hits(), 3);
    assert_eq!(runner.runs.load(Ordering::SeqCst), 3);
    let _ = fs::remove_dir_all(&results);
}

#[test]
fn builtin_specs_expand_and_resolve() {
    let mut addresses = BTreeSet::new();
    for name in sweep::BUILTIN_SPECS {
        let spec = sweep::builtin_spec(name, Some(2)).expect("builtin spec");
        assert_eq!(spec.kind, name);
        let runner = sweep::runner_for(&spec.kind).expect("runner");
        let cells = spec.expand().expect("expand");
        assert!(!cells.is_empty(), "{name} expands to no cells");
        for cell in &cells {
            let key = sweep::address(runner.as_ref(), cell).expect("address");
            assert!(addresses.insert(key), "duplicate address in {name}");
        }
    }
}

/// A provider over one mutable config — the knob the old filename cache
/// could not see.
struct OneVariantProvider {
    cfg: ModelConfig,
}

impl BackendProvider for OneVariantProvider {
    fn names(&self) -> Vec<String> {
        vec![self.cfg.name.clone()]
    }

    fn info(&self, name: &str) -> Result<VariantInfo> {
        ensure!(name == self.cfg.name, "unknown variant {name:?}");
        Ok(variant_info(&self.cfg))
    }

    fn load(&self, name: &str) -> Result<Box<dyn Backend>> {
        ensure!(name == self.cfg.name, "unknown variant {name:?}");
        Ok(Box::new(NativeBackend::new(&self.cfg)))
    }
}

#[test]
fn runner_rebuilds_when_the_variant_config_changes() {
    let results = temp_results("runner");
    let cfg = registry().into_iter().find(|c| c.name == "base-sim").expect("registry geometry");

    let provider = OneVariantProvider { cfg: cfg.clone() };
    let mut runner = Runner::new(&provider, &results);
    runner.verbose = false;
    let (_, cached) = runner.run_traced("base-sim", 2).expect("first train");
    assert!(!cached, "fresh store must train");
    let (_, cached) = runner.run_traced("base-sim", 2).expect("second train");
    assert!(cached, "identical config must be a store hit");

    // the old filename cache keyed only (variant, steps, seed); the
    // content address must see this config edit and re-train
    let mut edited = cfg;
    edited.capacity_factor = 2.0;
    let provider = OneVariantProvider { cfg: edited };
    let mut runner = Runner::new(&provider, &results);
    runner.verbose = false;
    let (_, cached) = runner.run_traced("base-sim", 2).expect("train after config edit");
    assert!(!cached, "stale cache: a config edit did not change the address");
    let _ = fs::remove_dir_all(&results);
}
