//! Parity and determinism contract of the fused counts-only routing
//! kernel and the parallel (worker x layer) sharded step built on it:
//!
//! * the fused kernel's demand/load/drop counts are bitwise identical to
//!   the naive `route()` reference and to the two-pass engine across the
//!   routing grid ({top1, top2, top4, 2top1, 4top1} x {tight, ample
//!   capacity} x prototype groupings), including multi-tile layers;
//! * the two-pass `fill_gates` materializer and the fused per-tile gate
//!   generator consume identical RNG streams (same gate bits);
//! * `ShardedRun`'s fused step reproduces the serial two-pass baseline
//!   bit for bit — StepStats, dispatch summary, and per-layer plans —
//!   at every D, and stays bitwise stable across pool sizes;
//! * at D = 1 both modes reproduce `NativeBackend::step` (itself fused
//!   now) exactly, closing the fused/two-pass/native triangle.

use std::sync::Arc;

use m6t::config::Routing;
use m6t::data::{Batch, Batcher, Split};
use m6t::moe::fused::{self, FusedScratch};
use m6t::moe::{route, RouteOutput, RouterSpec, RoutingEngine};
use m6t::runtime::native::{fill_gates, registry};
use m6t::runtime::{Backend as _, NativeBackend, ShardedRun, StepMode, StepStats};
use m6t::testing::{check, gen};
use m6t::util::pool::{default_workers, WorkerPool};
use m6t::util::rng::Rng;

/// Materialize a full layer's gates tile by tile via the fused path's
/// generator — the oracle input for the reference router.
fn layer_gates(seed: u64, bias_row: &[f32], tokens: usize, e: usize, z: usize) -> Vec<f32> {
    let mut gates = vec![0f32; tokens * e];
    for s in 0..fused::tiles_for(tokens) {
        let t0 = s * fused::TILE_TOKENS;
        let rows = fused::TILE_TOKENS.min(tokens - t0);
        fused::gen_tile_gates(&mut gates[t0 * e..(t0 + rows) * e], seed, s, bias_row, rows, e, z);
    }
    gates
}

#[test]
fn prop_fused_counts_match_reference_and_engine() {
    let mut engine = RoutingEngine::new();
    let mut counts = RouteOutput::default();
    let mut scratch = FusedScratch::default();
    check("fused-parity", 150, |rng, b| {
        let bound = b.max.max(2);
        // powers of two up to 64, like gen::routing_shape — but tokens
        // stretched so a good fraction of cases span multiple 512-token
        // tiles (the histogram-merge path)
        let (_, experts, _) = gen::routing_shape(rng, b);
        let tokens = gen::usize_in(rng, 1, bound * 20);
        let strategies = [
            Routing::TopK(1),
            Routing::TopK(2),
            Routing::TopK(4),
            Routing::Prototype(2),
            Routing::Prototype(4),
        ];
        let mut routing = strategies[gen::usize_in(rng, 0, strategies.len() - 1)];
        let z = routing.prototypes().max(1) as usize;
        if experts % z != 0 {
            routing = Routing::TopK(routing.k());
        }
        let z = routing.prototypes().max(1) as usize;
        // tight (drops guaranteed under load) vs ample capacity
        let capacity = if rng.below(2) == 0 {
            gen::usize_in(rng, 1, (tokens / experts).max(1))
        } else {
            tokens
        };
        let seed = rng.next_u64();
        let bias: Vec<f32> = (0..experts).map(|_| (rng.normal() * 0.4) as f32).collect();

        let gates = layer_gates(seed, &bias, tokens, experts, z);
        let spec = RouterSpec { routing, num_experts: experts, capacity };
        let expect = route(&gates, tokens, &spec);

        let mut demand = vec![0u32; experts];
        let mut load = vec![0u32; experts];
        let dropped = fused::layer_counts(
            &mut scratch,
            seed,
            &bias,
            tokens,
            experts,
            z,
            routing,
            capacity,
            &mut demand,
            &mut load,
        );
        if demand != expect.demand {
            return Err(format!("{routing:?}: fused demand diverged from reference"));
        }
        if load != expect.load {
            return Err(format!("{routing:?}: fused load diverged from reference"));
        }
        if dropped != expect.dropped {
            return Err(format!(
                "{routing:?}: fused dropped {dropped} != reference {}",
                expect.dropped
            ));
        }
        engine.route_counts_into(&gates, tokens, &spec, &mut counts);
        if load != counts.load || demand != counts.demand || dropped != counts.dropped {
            return Err(format!("{routing:?}: fused diverged from two-pass engine"));
        }
        Ok(())
    });
}

#[test]
fn fill_gates_matches_fused_tile_generator() {
    // the two-pass materializer and the fused kernel must consume
    // identical RNG streams: same seeds, same tile split, same gate bits
    let experts = 16;
    let prototypes = 2;
    let tokens = 2 * fused::TILE_TOKENS + 131; // three tiles, last short
    let mut rng = Rng::new(99);
    let bias: Vec<f32> = (0..experts).map(|_| (rng.normal() * 0.4) as f32).collect();
    let seed = 0xDEAD_BEEF_u64;
    let expect = layer_gates(seed, &bias, tokens, experts, prototypes);
    let mut got = vec![0f32; tokens * experts];
    for workers in [0usize, 2] {
        let pool = WorkerPool::new(workers);
        got.fill(0.0);
        fill_gates(&pool, &mut got, seed, &bias, tokens, experts, prototypes);
        assert_eq!(
            expect.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "fill_gates diverged from the fused tile generator (pool {workers})"
        );
    }
}

/// Everything in StepStats, as bits.
fn stats_bits(s: &StepStats) -> (u32, u32, u32, Vec<u32>, Vec<u32>, u64) {
    (
        s.loss.to_bits(),
        s.aux_loss.to_bits(),
        s.grad_norm.to_bits(),
        s.load.iter().map(|x| x.to_bits()).collect(),
        s.dropped.iter().map(|x| x.to_bits()).collect(),
        s.sim_step_ms.to_bits(),
    )
}

fn worker_batches(run: &ShardedRun, seed: u64, steps: usize) -> Vec<Vec<Batch>> {
    let cfg = run.info().config.clone();
    let d = run.workers();
    let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
    (0..steps).map(|_| (0..d).map(|_| batcher.next_batch()).collect()).collect()
}

fn run_mode(run: &ShardedRun, seed: u64, steps: usize, mode: StepMode) -> Vec<StepStats> {
    let mut state = run.init_state(seed).expect("init");
    let mut out = Vec::with_capacity(steps);
    for batches in worker_batches(run, seed, steps) {
        let (next, stats, _plans) = run.step_detailed_mode(state, &batches, mode).expect("step");
        state = next;
        out.push(stats);
    }
    out
}

#[test]
fn fused_step_reproduces_two_pass_baseline_bitwise() {
    // acceptance: the fused parallel grid and the serial two-pass
    // baseline are the same function — stats, dispatch, and plans
    for (name, d) in [("base-sim", 4usize), ("large-sim", 2), ("xlarge-sim", 8), ("base-sim-aux", 1)]
    {
        let cfg = registry().into_iter().find(|c| c.name == name).expect("variant");
        let run = ShardedRun::new(&cfg, d).unwrap();
        // plans compared on a fresh first step, where the recycling pool
        // is cold in both modes
        let all = worker_batches(&run, 13, 1);
        let batches = &all[0];
        let init = run.init_state(13).unwrap();
        let (_, fa, pa) = run.step_detailed_mode(init, batches, StepMode::Fused).unwrap();
        let init = run.init_state(13).unwrap();
        let (_, fb, pb) = run.step_detailed_mode(init, batches, StepMode::TwoPass).unwrap();
        assert_eq!(stats_bits(&fa), stats_bits(&fb), "{name} D={d}: StepStats diverged");
        assert_eq!(fa.dispatch, fb.dispatch, "{name} D={d}: dispatch summary diverged");
        assert_eq!(pa, pb, "{name} D={d}: per-layer plans diverged");

        // and over a short multi-step run (scratch reuse in both modes)
        let fused = run_mode(&run, 17, 3, StepMode::Fused);
        let twopass = run_mode(&run, 17, 3, StepMode::TwoPass);
        for (i, (a, b)) in fused.iter().zip(&twopass).enumerate() {
            assert_eq!(stats_bits(a), stats_bits(b), "{name} D={d}: step {i} diverged");
            assert_eq!(a.dispatch, b.dispatch, "{name} D={d}: step {i} dispatch diverged");
        }
    }
}

#[test]
fn fused_step_bitwise_identical_across_pool_sizes() {
    // the fused grid's unit decomposition is pure shape: pool geometry
    // must never leak into the emitted stats (xlarge-sim = the E=64
    // acceptance geometry)
    let cfg = registry().into_iter().find(|c| c.name == "xlarge-sim").expect("variant");
    let reference = {
        let run = ShardedRun::with_pool(&cfg, 4, Arc::new(WorkerPool::new(1))).unwrap();
        run_mode(&run, 23, 2, StepMode::Fused)
    };
    for workers in [0usize, 2, default_workers()] {
        let run = ShardedRun::with_pool(&cfg, 4, Arc::new(WorkerPool::new(workers))).unwrap();
        let got = run_mode(&run, 23, 2, StepMode::Fused);
        for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(stats_bits(a), stats_bits(b), "pool {workers}: step {i} diverged");
            assert_eq!(a.dispatch, b.dispatch, "pool {workers}: step {i} dispatch diverged");
        }
    }
}

#[test]
fn both_modes_at_d1_reproduce_native_backend() {
    // the triangle: native (fused), sharded fused D=1, and sharded
    // two-pass D=1 all emit the same bits
    let cfg = registry().into_iter().find(|c| c.name == "large-sim").expect("variant");
    let backend = NativeBackend::new(&cfg);
    let mut state = backend.init_state(7).expect("init");
    let mut batcher = Batcher::for_config(&cfg, Split::Train, 7);
    let mut native_stats = Vec::new();
    for _ in 0..2 {
        let batch = batcher.next_batch();
        let (next, stats) = backend.step(state, &batch).expect("step");
        state = next;
        native_stats.push(stats);
    }
    let run = ShardedRun::new(&cfg, 1).unwrap();
    for mode in [StepMode::Fused, StepMode::TwoPass] {
        let sharded = run_mode(&run, 7, 2, mode);
        for (i, (n, s)) in native_stats.iter().zip(&sharded).enumerate() {
            assert_eq!(
                stats_bits(n),
                stats_bits(s),
                "step {i}: {mode:?} at D=1 diverged from NativeBackend"
            );
        }
    }
}
