//! Serving-runtime invariants (`serve::*`):
//!
//! * arrival generation is a pure function of its spec — same seed, same
//!   trace, bit for bit, and the modes are genuinely different processes;
//! * the admission loop's two policy bounds hold on random traces: no
//!   batch exceeds `max_batch`, and no batch starts later than
//!   `max(engine_free, oldest + max_wait)` — a request is never parked
//!   past its deadline while the engine idles;
//! * the profiled service model and the full bench row are bitwise
//!   independent of the worker-pool size — `BENCH_serve.json` is
//!   seed-pinned, not host-pinned;
//! * the calm poisson gate cells actually clear the CI floors, and
//!   overload visibly degrades latency the way the goodput curve claims.

use std::sync::Arc;

use m6t::runtime::native::registry;
use m6t::serve::admission::{self, AdmissionPolicy};
use m6t::serve::arrivals::{self, ArrivalMode, ArrivalSpec};
use m6t::serve::bench;
use m6t::sweep::{Cell, ParamValue};
use m6t::testing::{check, gen};
use m6t::util::json::write as json_write;
use m6t::util::pool::WorkerPool;

fn base_sim() -> m6t::config::ModelConfig {
    registry().into_iter().find(|c| c.name == "base-sim").unwrap()
}

fn serve_cell(workers: usize, mode: &str, load: f64, requests: usize) -> Cell {
    let mut c = Cell::new();
    c.set("model", ParamValue::Str("base-sim".into()));
    c.set("mode", ParamValue::Str(mode.into()));
    c.set("workers", ParamValue::Num(workers as f64));
    c.set("load", ParamValue::Num(load));
    c.set("skew", ParamValue::Num(0.0));
    c.set("drain", ParamValue::Num(0.0));
    c.set("requests", ParamValue::Num(requests as f64));
    c.set("steps", ParamValue::Num(2.0));
    c.set("seed", ParamValue::Num(7.0));
    c
}

#[test]
fn arrival_traces_are_seed_pinned_and_mode_distinct() {
    for mode in ArrivalMode::all() {
        let spec = ArrivalSpec { mode, rate_per_ms: 0.5, requests: 400, seed: 11 };
        let a = arrivals::generate(&spec);
        let b = arrivals::generate(&spec);
        assert_eq!(a.len(), 400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} trace drifted", mode.name());
        }
    }
    let p = arrivals::generate(&ArrivalSpec {
        mode: ArrivalMode::Poisson,
        rate_per_ms: 0.5,
        requests: 400,
        seed: 11,
    });
    let burst = arrivals::generate(&ArrivalSpec {
        mode: ArrivalMode::Bursty,
        rate_per_ms: 0.5,
        requests: 400,
        seed: 11,
    });
    assert_ne!(p, burst, "modes must be different processes, not relabelings");
}

#[test]
fn prop_admission_respects_batch_and_wait_bounds() {
    check("serve-admission-bounds", 80, |rng, _b| {
        let mode = ArrivalMode::all()[gen::usize_in(rng, 0, 2)];
        let rate = 0.05 + rng.uniform() * 2.0;
        let requests = 20 + gen::usize_in(rng, 0, 280);
        let trace = arrivals::generate(&ArrivalSpec {
            mode,
            rate_per_ms: rate,
            requests,
            seed: rng.next_u64(),
        });
        let max_batch = 1 + gen::usize_in(rng, 0, 15);
        let max_wait_ms = rng.uniform() * 20.0;
        let svc = 0.5 + rng.uniform() * 10.0;
        let policy = AdmissionPolicy { max_batch, max_wait_ms };
        let ledger = admission::simulate(&trace, &policy, |b| svc * (1.0 + b as f64 / 8.0));
        if ledger.requests.len() != requests {
            return Err(format!("served {} of {requests}", ledger.requests.len()));
        }
        let mut engine_free = 0.0f64;
        let mut next = 0usize;
        for batch in &ledger.batches {
            if batch.size == 0 || batch.size > max_batch {
                return Err(format!("batch size {} vs max {max_batch}", batch.size));
            }
            let oldest = trace[next];
            if oldest > batch.start_ms {
                return Err("batch launched before its oldest request arrived".into());
            }
            // the max-wait property: once the engine is free, the batch
            // may not sit past the oldest request's deadline
            let bound = engine_free.max(oldest + max_wait_ms);
            if batch.start_ms > bound + 1e-9 {
                return Err(format!(
                    "batch start {} after bound {bound} (engine_free {engine_free}, oldest {oldest})",
                    batch.start_ms
                ));
            }
            if batch.start_ms + 1e-12 < engine_free {
                return Err("batches overlap on the engine".into());
            }
            next += batch.size;
            engine_free = batch.done_ms;
        }
        if next != requests {
            return Err(format!("batches partition {next} of {requests} requests"));
        }
        for r in &ledger.requests {
            if r.arrival_ms > r.start_ms + 1e-12 {
                return Err(format!("request {} served before it arrived", r.id));
            }
        }
        Ok(())
    });
}

#[test]
fn service_pricing_is_bitwise_identical_across_pool_sizes() {
    let cfg = base_sim();
    for workers in [1usize, 4] {
        let a = bench::profile(&cfg, workers, 2, 7, 0.0, 0, Some(Arc::new(WorkerPool::new(1))))
            .unwrap();
        let b = bench::profile(&cfg, workers, 2, 7, 0.0, 0, Some(Arc::new(WorkerPool::new(3))))
            .unwrap();
        assert_eq!(a.full_batch(), b.full_batch());
        for (x, y) in a.per_worker_ms().iter().zip(b.per_worker_ms()) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "D={workers}: service pricing depends on the thread pool"
            );
        }
    }
}

#[test]
fn rows_are_pure_functions_of_the_cell() {
    let cell = serve_cell(4, "bursty", 0.9, 96);
    let a = bench::compute_row(&cell, Some(Arc::new(WorkerPool::new(1)))).unwrap();
    let b = bench::compute_row(&cell, Some(Arc::new(WorkerPool::new(3)))).unwrap();
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "row depends on the thread pool");
    assert!(a.p50_ms <= a.p99_ms && a.p99_ms <= a.p999_ms);
    assert!((0.0..=1.0).contains(&a.slo_attainment));
    assert!(a.goodput_rps <= a.offered_rps + 1e-9);
    assert!(a.mean_batch >= 1.0 && a.mean_batch <= a.max_batch as f64);
}

#[test]
fn run_cell_documents_are_seed_pinned() {
    let cell = serve_cell(1, "poisson", 0.55, 64);
    let a = bench::run_cell(&cell).unwrap();
    let b = bench::run_cell(&cell).unwrap();
    assert_eq!(json_write(&a), json_write(&b), "stored document must be reproducible");
}

#[test]
fn calm_poisson_gate_cells_clear_the_ci_floors() {
    // the local twin of the BENCH_serve.json regression gate: at the
    // gated load the policy has no excuse, on every benched D
    for workers in [1usize, 4, 8] {
        let row = bench::compute_row(&serve_cell(workers, "poisson", 0.55, 256), None).unwrap();
        assert!(row.gate, "calm poisson cell must be gated");
        assert!(
            row.p99_over_slo() < 1.0,
            "D={workers}: p99 {} ms blows the {} ms SLO",
            row.p99_ms,
            row.slo_ms
        );
        assert!(
            row.slo_attainment >= 0.9,
            "D={workers}: goodput share {} under the 0.9 floor",
            row.slo_attainment
        );
    }
}

#[test]
fn overload_degrades_latency_and_goodput() {
    let calm = bench::compute_row(&serve_cell(1, "poisson", 0.55, 192), None).unwrap();
    let hot = bench::compute_row(&serve_cell(1, "poisson", 1.25, 192), None).unwrap();
    assert!(!hot.gate, "overloaded cells are never gate rows");
    assert!(hot.p99_ms > calm.p99_ms, "overload must back the queue up");
    assert!(hot.slo_attainment < calm.slo_attainment);
    assert!(hot.mean_batch >= calm.mean_batch, "pressure should pack bigger batches");
}

#[test]
fn skew_and_drain_stretch_the_service_model() {
    let cfg = base_sim();
    let base = bench::profile(&cfg, 4, 2, 7, 0.0, 0, None).unwrap();
    let skewed = bench::profile(&cfg, 4, 2, 7, 0.6, 0, None).unwrap();
    let drained = bench::profile(&cfg, 4, 2, 7, 0.0, 1, None).unwrap();
    let full = base.full_batch();
    assert!(skewed.ms(full) > base.ms(full), "hot-expert skew must cost something");
    assert!(drained.ms(full) > base.ms(full), "a draining worker must cost something");
}
