//! The native backend's step must be a pure function of (state, step,
//! batch) — NOT of the worker-pool geometry. These tests pin bitwise-
//! identical [`StepStats`] across pool sizes 1, 2, and the default
//! (available-parallelism) pool, replacing the guarantee the old
//! thread-per-layer spawn provided only by accident.

use std::sync::Arc;

use m6t::data::{Batcher, Split};
use m6t::runtime::native::registry;
use m6t::runtime::{Backend as _, NativeBackend, StepStats};
use m6t::util::pool::{default_workers, WorkerPool};

/// Everything in StepStats, as bits: f32/f64 payloads must match exactly,
/// not just approximately.
fn stats_bits(s: &StepStats) -> (u32, u32, u32, Vec<u32>, Vec<u32>, u64, usize, usize) {
    (
        s.loss.to_bits(),
        s.aux_loss.to_bits(),
        s.grad_norm.to_bits(),
        s.load.iter().map(|x| x.to_bits()).collect(),
        s.dropped.iter().map(|x| x.to_bits()).collect(),
        s.sim_step_ms.to_bits(),
        s.layers,
        s.experts,
    )
}

fn run_steps(backend: &NativeBackend, steps: usize) -> Vec<(u32, u32, u32, Vec<u32>, Vec<u32>, u64, usize, usize)> {
    let cfg = backend.info().config.clone();
    let mut state = backend.init_state(7).expect("init");
    let mut batcher = Batcher::for_config(&cfg, Split::Train, 7);
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let batch = batcher.next_batch();
        let (next, stats) = backend.step(state, &batch).expect("step");
        state = next;
        out.push(stats_bits(&stats));
    }
    out
}

#[test]
fn step_stats_bitwise_identical_across_pool_sizes() {
    // deep-sim: 12 layers (the old code spawned 12 unpooled threads for
    // it); base-top2 / base-4top1: paper-base geometry with 1024 tokens —
    // multiple 512-token shards, crossing every parallel threshold in
    // both the gate-gen and argmax phases for top-k and prototyping
    for name in ["deep-sim", "base-top2", "base-4top1"] {
        let cfg = registry()
            .into_iter()
            .find(|c| c.name == name)
            .expect("registry variant");
        let reference = run_steps(&NativeBackend::with_pool(&cfg, Arc::new(WorkerPool::new(1))), 3);
        for workers in [2usize, default_workers()] {
            let got =
                run_steps(&NativeBackend::with_pool(&cfg, Arc::new(WorkerPool::new(workers))), 3);
            assert_eq!(got, reference, "{name}: pool size {workers} diverged from size 1");
        }
        // the default constructor (process-wide pool) must agree too
        let got = run_steps(&NativeBackend::new(&cfg), 3);
        assert_eq!(got, reference, "{name}: global-pool backend diverged");
    }
}

#[test]
fn zero_worker_pool_matches_parallel_pools() {
    // a zero-worker pool runs everything inline on the caller: the
    // serial path must be bitwise identical to the parallel one
    let cfg = registry()
        .into_iter()
        .find(|c| c.name == "large-sim")
        .expect("registry variant");
    let serial = run_steps(&NativeBackend::with_pool(&cfg, Arc::new(WorkerPool::new(0))), 2);
    let parallel = run_steps(&NativeBackend::with_pool(&cfg, Arc::new(WorkerPool::new(3))), 2);
    assert_eq!(serial, parallel);
}
