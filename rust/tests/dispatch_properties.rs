//! Invariants of the expert-parallel dispatch layer:
//!
//! * per-worker kept + dropped always equals the routed-slot total, and a
//!   D = 1 plan is exactly the single-worker routing reference (all
//!   traffic local, zero all-to-all bytes);
//! * plan byte counts are conserved — what every worker sends equals
//!   what every shard receives;
//! * `ShardedRun` is bitwise deterministic across pool sizes 0/1/2 and
//!   the default, the same contract `pool_determinism.rs` pins for the
//!   single-worker backend;
//! * at D = 1 the sharded runtime reproduces `NativeBackend::step`'s
//!   `StepStats` bit for bit.

use std::sync::Arc;

use m6t::config::Routing;
use m6t::data::{Batch, Batcher, Split};
use m6t::moe::dispatch::DispatchPlan;
use m6t::moe::{route, RouterSpec};
use m6t::runtime::native::registry;
use m6t::runtime::{Backend as _, NativeBackend, ShardedRun, StepStats};
use m6t::testing::{check, gen};
use m6t::util::pool::{default_workers, WorkerPool};
use m6t::util::rng::Rng;

#[test]
fn prop_plan_conserves_tokens_and_bytes() {
    check("dispatch-conservation", 60, |rng, b| {
        let (tokens, experts, capacity) = gen::routing_shape(rng, b);
        // worker counts that divide the expert count
        let divisors: Vec<usize> = [1usize, 2, 4, 8]
            .into_iter()
            .filter(|d| experts % d == 0)
            .collect();
        let workers = divisors[gen::usize_in(rng, 0, divisors.len() - 1)];
        let k = 1 + gen::usize_in(rng, 0, 3) as u32;
        let routing =
            if rng.below(2) == 0 { Routing::TopK(k) } else { Routing::Prototype(1) };
        let spec = RouterSpec { routing, num_experts: experts, capacity };
        let routes: Vec<_> = (0..workers)
            .map(|w| {
                let mut wrng = Rng::new(rng.next_u64() ^ (w as u64));
                let gates = gen::gates(&mut wrng, tokens, experts);
                route(&gates, tokens, &spec)
            })
            .collect();
        let hidden = 8 + gen::usize_in(rng, 0, 64);
        let plan = DispatchPlan::from_worker_routes(experts, capacity, hidden, &routes);

        // per-worker kept + dropped == routed slots (k_eff per token)
        let k_eff = match routing {
            Routing::TopK(k) => (k as usize).min(experts),
            Routing::Prototype(z) => z as usize,
        };
        let kept = plan.kept_per_worker();
        let drops = plan.dropped_per_worker();
        for w in 0..workers {
            let total = kept[w] + drops[w];
            let want = (tokens * k_eff) as u64;
            if total != want {
                return Err(format!(
                    "worker {w}: kept {} + dropped {} = {total} != routed {want}",
                    kept[w], drops[w]
                ));
            }
        }

        // send totals == receive totals, for tokens and for bytes
        let sent: u64 = kept.iter().sum();
        let recv: u64 = plan.recv_per_shard().iter().sum();
        if sent != recv {
            return Err(format!("token conservation broken: sent {sent} recv {recv}"));
        }
        let m = plan.bytes_matrix();
        let d = plan.workers;
        let row_total: u64 = m.iter().sum();
        let col_total: u64 =
            (0..d).map(|v| (0..d).map(|w| m[w * d + v]).sum::<u64>()).sum();
        if row_total != col_total || row_total != plan.dispatch_bytes() {
            return Err(format!(
                "byte conservation broken: rows {row_total} cols {col_total} total {}",
                plan.dispatch_bytes()
            ));
        }
        for w in 0..d {
            if m[w * d + w] != 0 {
                return Err(format!("worker {w} 'sends' to itself over the network"));
            }
        }

        // the per-shard drop attribution accounts for every drop
        let shard_drops: u64 = plan.dropped_per_shard().iter().sum();
        let worker_drops: u64 = drops.iter().sum();
        if shard_drops != worker_drops {
            return Err(format!(
                "drop attribution broken: shards {shard_drops} workers {worker_drops}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_single_worker_plan_matches_reference() {
    // D = 1: the plan is the single-router reference — recv per (only)
    // shard equals total kept load, nothing crosses the network
    check("dispatch-d1-reference", 40, |rng, b| {
        let (tokens, experts, capacity) = gen::routing_shape(rng, b);
        let routing = Routing::TopK(2.min(experts as u32));
        let spec = RouterSpec { routing, num_experts: experts, capacity };
        let gates = gen::gates(rng, tokens, experts);
        let reference = route(&gates, tokens, &spec);
        let plan = DispatchPlan::from_worker_routes(experts, capacity, 16, &[reference.clone()]);
        let kept_ref: u64 = reference.load.iter().map(|&x| x as u64).sum();
        if plan.recv_per_shard() != vec![kept_ref] {
            return Err(format!(
                "D=1 recv {:?} != reference kept {kept_ref}",
                plan.recv_per_shard()
            ));
        }
        if plan.cross_tokens() != 0 || plan.dispatch_bytes() != 0 {
            return Err("D=1 must be all-local".into());
        }
        if plan.dropped_per_worker() != vec![reference.dropped as u64] {
            return Err(format!(
                "D=1 drops {:?} != reference {}",
                plan.dropped_per_worker(),
                reference.dropped
            ));
        }
        Ok(())
    });
}

/// Everything in StepStats, as bits (sharded runs additionally carry a
/// dispatch summary, compared separately via PartialEq).
fn stats_bits(s: &StepStats) -> (u32, u32, u32, Vec<u32>, Vec<u32>, u64) {
    (
        s.loss.to_bits(),
        s.aux_loss.to_bits(),
        s.grad_norm.to_bits(),
        s.load.iter().map(|x| x.to_bits()).collect(),
        s.dropped.iter().map(|x| x.to_bits()).collect(),
        s.sim_step_ms.to_bits(),
    )
}

fn run_sharded_steps(run: &ShardedRun, steps: usize, seed: u64) -> Vec<StepStats> {
    let cfg = run.info().config.clone();
    let d = run.workers();
    let mut state = run.init_state(seed).expect("init");
    let mut batcher = Batcher::for_config(&cfg, Split::Train, seed);
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let batches: Vec<Batch> = (0..d).map(|_| batcher.next_batch()).collect();
        let (next, stats) = run.step(state, &batches).expect("step");
        state = next;
        out.push(stats);
    }
    out
}

#[test]
fn sharded_d1_reproduces_native_backend_bitwise() {
    // acceptance: D = 1 reproduces the current single-worker StepStats
    // bit for bit — same seeds, same batch stream, same arithmetic
    for name in ["base-sim", "large-sim", "base-sim-aux"] {
        let cfg = registry().into_iter().find(|c| c.name == name).expect("variant");
        assert_eq!(cfg.workers, 1, "parity baseline must be a single-worker config");
        let backend = NativeBackend::new(&cfg);
        let mut state = backend.init_state(7).expect("init");
        let mut batcher = Batcher::for_config(&cfg, Split::Train, 7);
        let mut native_stats = Vec::new();
        for _ in 0..3 {
            let batch = batcher.next_batch();
            let (next, stats) = backend.step(state, &batch).expect("step");
            state = next;
            native_stats.push(stats);
        }

        let run = ShardedRun::new(&cfg, 1).expect("sharded D=1");
        let sharded_stats = run_sharded_steps(&run, 3, 7);
        for (i, (n, s)) in native_stats.iter().zip(&sharded_stats).enumerate() {
            assert_eq!(
                stats_bits(n),
                stats_bits(s),
                "{name}: step {i} diverged between NativeBackend and ShardedRun D=1"
            );
            let dsp = s.dispatch.as_ref().expect("sharded stats carry dispatch");
            assert_eq!(dsp.workers, 1);
            assert_eq!(dsp.a2a_bytes_step, 0.0, "a single worker moves nothing");
            assert_eq!(dsp.shard_load_cv, 0.0);
        }
    }
}

#[test]
fn sharded_bitwise_identical_across_pool_sizes() {
    // same contract as pool_determinism.rs, at D = 4: the worker-pool
    // geometry must never leak into the sharded runtime's output
    let cfg = registry()
        .into_iter()
        .find(|c| c.name == "large-sim")
        .expect("registry variant");
    let reference = {
        let run = ShardedRun::with_pool(&cfg, 4, Arc::new(WorkerPool::new(1))).unwrap();
        run_sharded_steps(&run, 3, 11)
    };
    for workers in [0usize, 2, default_workers()] {
        let run = ShardedRun::with_pool(&cfg, 4, Arc::new(WorkerPool::new(workers))).unwrap();
        let got = run_sharded_steps(&run, 3, 11);
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(
                stats_bits(a),
                stats_bits(b),
                "pool size {workers}: step {i} StepStats diverged"
            );
            assert_eq!(
                a.dispatch, b.dispatch,
                "pool size {workers}: step {i} dispatch summary diverged"
            );
        }
    }
    // the default constructor (process-wide pool) must agree too
    let run = ShardedRun::new(&cfg, 4).unwrap();
    let got = run_sharded_steps(&run, 3, 11);
    for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
        assert_eq!(stats_bits(a), stats_bits(b), "global pool: step {i} diverged");
        assert_eq!(a.dispatch, b.dispatch, "global pool: step {i} dispatch diverged");
    }
}

#[test]
fn sharding_changes_dispatch_not_convergence_seeds() {
    // different D: different per-worker streams and real cross traffic —
    // but the same conservation laws at every D
    let cfg = registry()
        .into_iter()
        .find(|c| c.name == "base-sim")
        .expect("registry variant");
    for d in [2usize, 4, 8] {
        let run = ShardedRun::new(&cfg, d).unwrap();
        let stats = run_sharded_steps(&run, 2, 5);
        for s in &stats {
            let dsp = s.dispatch.as_ref().unwrap();
            assert_eq!(dsp.workers, d);
            assert_eq!(dsp.per_shard_recv.len(), d);
            assert_eq!(dsp.per_worker_dropped.len(), d);
            // recv totals equal the global kept load
            let recv: f64 = dsp.per_shard_recv.iter().sum();
            let load: f64 = s.load.iter().map(|&x| x as f64).sum();
            assert_eq!(recv, load, "D={d}: recv/load mismatch");
            assert!(dsp.a2a_bytes_step > 0.0, "D={d}: cross traffic must exist");
            assert!(dsp.observed_ms > 0.0);
            assert!((0.0..=1.0).contains(&dsp.cross_fraction));
        }
    }
}
